"""repro.obs: traceparent propagation, span nesting, ring buffer, export.

Tests build private ``Tracer`` instances rather than mutating the global
``obs.TRACER`` so they stay independent of the HTTP-level tests running in
the same process.
"""
import json
import threading

from repro.obs import (NOOP, Span, SpanContext, Tracer, format_traceparent,
                       mint_span_id, mint_trace_id, parse_traceparent)
from repro.obs import profile
from repro.obs.trace import _CURRENT


# ------------------------------------------------------------- traceparent
def test_traceparent_roundtrip():
    tid, sid = mint_trace_id(), mint_span_id()
    assert len(tid) == 32 and len(sid) == 16
    hdr = format_traceparent(tid, sid)
    assert hdr == f"00-{tid}-{sid}-01"
    assert parse_traceparent(hdr) == (tid, sid)
    # whitespace and case are normalized per the spec
    assert parse_traceparent("  " + hdr.upper() + " ") == (tid, sid)


def test_traceparent_rejects_malformed_and_reserved():
    good_tid, good_sid = "ab" * 16, "cd" * 8
    for bad in (
            None, "", "garbage",
            f"00-{good_tid}-{good_sid}",            # missing flags
            f"00-{good_tid[:-1]}-{good_sid}-01",    # short trace id
            f"00-{good_tid}-{good_sid}-0",          # short flags
            f"00-{'z' * 32}-{good_sid}-01",         # non-hex
            f"ff-{good_tid}-{good_sid}-01",         # reserved version
            f"00-{'0' * 32}-{good_sid}-01",         # all-zero trace id
            f"00-{good_tid}-{'0' * 16}-01"):        # all-zero span id
        assert parse_traceparent(bad) is None, bad


def test_ids_unique():
    assert len({mint_trace_id() for _ in range(256)}) == 256
    assert len({mint_span_id() for _ in range(256)}) == 256


# ---------------------------------------------------------------- spanning
def test_span_nesting_records_parent_chain():
    tr = Tracer(capacity=8)
    root = tr.start_trace("req")
    with tr.attach(root):
        with tr.span("outer") as outer:
            with tr.span("inner", op="x") as inner:
                assert inner.parent_id == outer.span_id
            assert _CURRENT.get() is outer
    root.end()
    t = tr.get(root.trace_id)
    by_name = {s["name"]: s for s in t["spans"]}
    assert set(by_name) == {"req", "outer", "inner"}
    assert by_name["outer"]["parent_id"] == root.span_id
    assert by_name["inner"]["parent_id"] == by_name["outer"]["span_id"]
    assert by_name["inner"]["attrs"] == {"op": "x"}
    assert by_name["req"]["parent_id"] is None
    assert t["root"] == "req" and t["duration_us"] >= 0


def test_child_span_is_noop_outside_a_trace_and_when_disabled():
    tr = Tracer(capacity=8)
    assert tr.child_span("orphan") is NOOP
    with tr.span("orphan-cm") as sp:
        assert sp is NOOP and not sp
    tr.set_enabled(False)
    assert tr.start_trace("req") is NOOP
    assert not tr.stats()["enabled"]
    tr.set_enabled(True)
    root = tr.start_trace("req")
    assert root  # truthy again
    root.end()


def test_noop_span_absorbs_all_calls():
    NOOP.set_attr("k", "v")
    NOOP.add_link(SpanContext("ab" * 16, "cd" * 8))
    NOOP.end()
    assert NOOP.context is None
    assert not NOOP


def test_traceparent_continues_callers_trace():
    tr = Tracer(capacity=8)
    tid, parent_sid = mint_trace_id(), mint_span_id()
    root = tr.start_trace("req",
                          traceparent=format_traceparent(tid, parent_sid))
    assert root.trace_id == tid and root.parent_id == parent_sid
    root.end()
    assert tr.get(tid)["trace_id"] == tid


def test_attach_carries_span_across_threads():
    tr = Tracer(capacity=8)
    root = tr.start_trace("req")
    seen = {}

    def worker(parent):
        # a fresh thread has no inherited context ...
        seen["before"] = _CURRENT.get()
        with tr.attach(parent):
            with tr.span("work") as sp:
                seen["span"] = sp

    th = threading.Thread(target=worker, args=(root,))
    th.start()
    th.join(timeout=10)
    assert seen["before"] is None
    assert seen["span"].trace_id == root.trace_id
    assert seen["span"].parent_id == root.span_id
    root.end()
    names = [s["name"] for s in tr.get(root.trace_id)["spans"]]
    assert names == ["work", "req"]


def test_span_end_is_idempotent():
    tr = Tracer(capacity=8)
    root = tr.start_trace("req")
    root.end()
    first = tr.get(root.trace_id)["duration_us"]
    root.end()
    assert tr.get(root.trace_id)["duration_us"] == first
    assert tr.stats()["completed_total"] == 1


# -------------------------------------------------------------- ring buffer
def test_ring_buffer_caps_completed_traces():
    tr = Tracer(capacity=4)
    ids = []
    for i in range(10):
        root = tr.start_trace(f"t{i}")
        root.end()
        ids.append(root.trace_id)
    st = tr.stats()
    assert st["buffered"] == 4 and st["completed_total"] == 10
    assert [t["root"] for t in tr.recent()] == ["t9", "t8", "t7", "t6"]
    assert tr.recent(limit=2) == tr.recent()[:2]
    assert tr.get(ids[0]) is None          # evicted
    assert tr.get(ids[-1]) is not None     # newest survives


def test_max_spans_per_trace_drops_and_counts():
    tr = Tracer(capacity=4, max_spans_per_trace=3)
    root = tr.start_trace("req")
    with tr.attach(root):
        for i in range(5):
            with tr.span(f"c{i}"):
                pass
    root.end()
    # 2 children over the cap were dropped, root still finalizes the trace
    assert tr.stats()["spans_dropped"] == 3  # c3, c4, and the root record
    assert len(tr.get(root.trace_id)["spans"]) == 3


def test_straggler_span_lands_in_finished_trace():
    tr = Tracer(capacity=4)
    root = tr.start_trace("req")
    late = tr.child_span("late", parent=root)
    root.end()          # finalizes with just the root
    late.end()          # straggler: appended to the finished trace
    names = [s["name"] for s in tr.get(root.trace_id)["spans"]]
    assert names == ["req", "late"]
    assert tr.stats()["spans_dropped"] == 0


# ------------------------------------------------------------------- links
def test_links_resolve_one_hop():
    tr = Tracer(capacity=8)
    fused = tr.start_trace("fused")
    req = tr.start_trace("req")
    req.add_link(fused.context, kind="fused_dispatch")
    fused.add_link(req.context)
    fused.end()
    req.end()
    t = tr.get(req.trace_id)
    [link] = t["spans"][0]["links"]
    assert link["trace_id"] == fused.trace_id
    assert link["attrs"] == {"kind": "fused_dispatch"}
    [lt] = t["linked_traces"]
    assert lt["trace_id"] == fused.trace_id and lt["root"] == "fused"
    assert tr.get(req.trace_id, resolve_links=False).get("linked_traces") is None


# ------------------------------------------------------------ chrome export
def test_chrome_export_structure():
    tr = Tracer(capacity=8)
    fused = tr.start_trace("fused")
    root = tr.start_trace("req")
    with tr.attach(root):
        with tr.span("child", op="q") as sp:
            sp.add_link(fused.context)
    fused.end()
    root.end()
    doc = json.loads(tr.chrome_json(root.trace_id))
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} >= {"req", "child", "fused"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and "pid" in e and "tid" in e
    # per-trace process groups, named
    metas = [e for e in evs if e["ph"] == "M" and e["name"] == "process_name"]
    assert len({e["pid"] for e in metas}) == 2
    # flow event along the cross-trace link
    assert any(e["ph"] == "s" for e in evs)
    assert tr.chrome("0" * 32) is None


# -------------------------------------------------------------- profile hook
def test_profile_hooks_fire_and_survive_exceptions():
    calls = []

    def bad(*a):
        raise RuntimeError("hook must not break dispatch")

    def good(op, backend, size, seconds):
        calls.append((op, backend, size))

    profile.add_hook(bad)
    profile.add_hook(good)
    try:
        profile.record("fitting_loss", "numpy", 128, 0.001)
    finally:
        profile.remove_hook(bad)
        profile.remove_hook(good)
    assert calls == [("fitting_loss", "numpy", 128)]
    profile.record("fitting_loss", "numpy", 1, 0.0)  # no hooks: no-op
    assert calls == [("fitting_loss", "numpy", 128)]


def test_shape_bucket_boundaries():
    assert profile.shape_bucket(None) == "none"
    assert profile.shape_bucket(0) == "le_2^0"
    assert profile.shape_bucket(1) == "le_2^0"
    assert profile.shape_bucket(2) == "le_2^1"
    assert profile.shape_bucket(3) == "le_2^2"
    assert profile.shape_bucket(1024) == "le_2^10"
    assert profile.shape_bucket(1025) == "le_2^11"


# ------------------------------------------------------- attrs are immutable
def test_recorded_spans_are_snapshots():
    tr = Tracer(capacity=8)
    root = tr.start_trace("req")
    root.set_attr("k", 1)
    root.end()
    got = tr.get(root.trace_id)
    got["spans"][0]["attrs"]["k"] = 999
    assert tr.get(root.trace_id)["spans"][0]["attrs"]["k"] == 1


def test_span_reprs_do_not_crash():
    # Span is __slots__-only; just make sure the public surface holds
    tr = Tracer(capacity=2)
    sp = tr.start_trace("req")
    assert isinstance(sp, Span)
    ctx = sp.context
    assert ctx.to_dict() == {"trace_id": sp.trace_id, "span_id": sp.span_id}
    sp.end()
