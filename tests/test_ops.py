"""repro.ops — registry selection rules, backend parity (including awkward
shapes through the batched Pallas kernel's pad paths), the env override,
and the real multi-device mesh path for the dispatched batched loss."""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro import ops
from repro.core import fitting_loss, random_tree_segmentation, signal_coreset
from repro.data import piecewise_signal

RNG = np.random.default_rng(0)


def _coreset(n=57, m=41, k=5, eps=0.3, seed=0):
    return signal_coreset(piecewise_signal(n, m, k, noise=0.2, seed=seed),
                          k, eps)


def _candidates(n, m, k, t, seed=1):
    rng = np.random.default_rng(seed)
    segs = [random_tree_segmentation(n, m, k, rng) for _ in range(t)]
    return (np.stack([s.rects for s in segs]).astype(np.float64),
            np.stack([s.labels for s in segs]))


# ------------------------------------------------------------------ registry
def test_every_op_has_all_three_backends():
    for op in ops.OPS:
        assert ops.available_backends(op) == ops.BACKENDS


def test_env_override_bare_and_per_op(monkeypatch):
    monkeypatch.setenv(ops.ENV_VAR, "xla")
    assert all(ops.select_backend(op) == "xla" for op in ops.OPS)
    monkeypatch.setenv(ops.ENV_VAR, "xla,hist_split=numpy")
    assert ops.select_backend("fitting_loss") == "xla"
    assert ops.select_backend("hist_split") == "numpy"
    monkeypatch.setenv(ops.ENV_VAR, "nonsense")
    with pytest.raises(ops.BackendError):
        ops.select_backend("fitting_loss")
    # a typo'd OP name must fail loudly, not silently pin nothing
    monkeypatch.setenv(ops.ENV_VAR, "histsplit=numpy")
    with pytest.raises(ops.BackendError):
        ops.select_backend("hist_split")


def test_backend_override_context_beats_env(monkeypatch):
    monkeypatch.setenv(ops.ENV_VAR, "xla")
    with ops.backend_override("numpy"):
        assert ops.select_backend("sat_moments") == "numpy"
    assert ops.select_backend("sat_moments") == "xla"


def test_size_auto_selection_numpy_small_xla_large(monkeypatch):
    monkeypatch.delenv(ops.ENV_VAR, raising=False)
    thr = ops.registry.XLA_SIZE_THRESHOLD["fitting_loss_batched"]
    assert ops.select_backend("fitting_loss_batched", thr - 1) == "numpy"
    assert ops.select_backend("fitting_loss_batched", thr) == "xla"


def test_precision_critical_ops_never_size_promote(monkeypatch):
    # sat_moments / hist_split feed S2 - S1^2/S0 (catastrophic cancellation
    # in float32): the f64 numpy oracle must hold at ANY size unless pinned
    monkeypatch.delenv(ops.ENV_VAR, raising=False)
    for op in ("sat_moments", "hist_split"):
        assert ops.select_backend(op, 10**12) == "numpy"


def test_unknown_backend_and_op_raise():
    with pytest.raises(ops.BackendError):
        ops.resolve("fitting_loss", "cuda")
    with pytest.raises(ops.BackendError):
        ops.select_backend("matmul")


def test_snapshot_surfaces_selection_state():
    snap = ops.snapshot()
    assert set(snap) == set(ops.OPS)
    for entry in snap.values():
        assert set(entry["available"]) == set(ops.BACKENDS)
        assert entry["selected"] in ops.BACKENDS


# ------------------------------------------------------ backend parity (ops)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_batched_parity_vs_oracle(backend):
    cs = _coreset()
    sr, sl = _candidates(57, 41, 4, 5)
    want = np.array([fitting_loss(cs, r, l) for r, l in zip(sr, sl)])
    got = ops.fitting_loss_batched(cs, sr, sl, backend=backend)
    np.testing.assert_allclose(got, want, rtol=2e-3)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_single_loss_parity_vs_oracle(backend):
    cs = _coreset(seed=3)
    rng = np.random.default_rng(2)
    q = random_tree_segmentation(57, 41, 6, rng)
    want = fitting_loss(cs, q.rects, q.labels)
    got = ops.fitting_loss(cs, q.rects, q.labels, backend=backend)
    assert abs(got - want) / want < 2e-3


def test_k_equals_one_all_backends():
    cs = _coreset(seed=4)
    sr = np.array([[[0, 57, 0, 41]]], np.float64)     # one leaf covers all
    sl = np.array([[0.4]])
    want = ops.fitting_loss_batched(cs, sr, sl, backend="numpy")
    for b in ("xla", "pallas"):
        got = ops.fitting_loss_batched(cs, sr, sl, backend=b)
        np.testing.assert_allclose(got, want, rtol=2e-3)


def test_zero_weight_padded_blocks_contribute_nothing():
    # the fitting_loss_batched pad path: explicit zero-weight blocks must
    # not change the loss (same invariant the kernel's internal B-padding
    # relies on)
    cs = _coreset(seed=5)
    sr, sl = _candidates(57, 41, 3, 3, seed=6)
    base = ops.fitting_loss_batched(cs, sr, sl, backend="pallas")
    import copy
    padded = copy.copy(cs)
    extra = 7    # keeps B % tile awkward too
    padded.rects = np.vstack([cs.rects, np.zeros((extra, 4), np.int64)])
    padded.labels = np.vstack([cs.labels, RNG.normal(size=(extra, 4))])
    padded.weights = np.vstack([cs.weights, np.zeros((extra, 4))])
    got = ops.fitting_loss_batched(padded, sr, sl, backend="pallas")
    np.testing.assert_allclose(got, base, rtol=1e-6)


def test_sat_moments_parity_awkward_shape():
    y = piecewise_signal(33, 47, 4, noise=0.3, seed=7)   # non-tile multiple
    ref = ops.sat_moments(y, backend="numpy")
    for b in ("xla", "pallas"):
        got = ops.sat_moments(y, backend=b)
        np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-3)


def test_delta_sat_numpy_oracle_is_bitwise_continuation():
    # the whole point of the f64 delta_sat oracle: chaining patches must be
    # indistinguishable from a from-scratch sat_moments build
    y = piecewise_signal(41, 37, 4, noise=0.3, seed=20)
    full = ops.sat_moments(y, backend="numpy")
    chained = ops.delta_sat(np.zeros((3, 37)), y[:17], backend="numpy")
    chained = np.concatenate(
        [chained, ops.delta_sat(chained[:, -1, :], y[17:], backend="numpy")],
        axis=1)
    assert chained.shape == full.shape
    for c in range(3):
        np.testing.assert_array_equal(chained[c], full[c])


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_delta_sat_parity_vs_oracle(backend):
    rng = np.random.default_rng(21)
    y = rng.normal(size=(45, 37))                        # off tile quanta
    carry = ops.sat_moments(y, backend="numpy")[:, 29, :]
    tail = y[30:]
    want = ops.delta_sat(carry, tail, backend="numpy")
    got = ops.delta_sat(carry, tail, backend=backend)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-3)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_delta_sat_one_row_band_from_row_zero(backend):
    rng = np.random.default_rng(22)
    tail = rng.normal(size=(1, 129))                     # 1-row, m % 128 != 0
    want = ops.delta_sat(np.zeros((3, 129)), tail, backend="numpy")
    got = ops.delta_sat(np.zeros((3, 129)), tail, backend=backend)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-3)
    np.testing.assert_allclose(
        np.asarray(want), ops.sat_moments(tail, backend="numpy"))


def test_delta_sat_validates_shapes():
    with pytest.raises(ValueError):
        ops.delta_sat(np.zeros((3, 4)), np.zeros((2, 5)))   # carry mismatch
    with pytest.raises(ValueError):
        ops.delta_sat(np.zeros((3, 4)), np.zeros((0, 4)))   # empty band


@pytest.mark.parametrize("backend", ["numpy", "xla", "pallas"])
def test_streaming_compress_batched_parity(backend):
    """One dispatch recompresses several buckets; every backend must
    preserve the exact f64 mass/M1/M2 (those never route through f32) and
    agree with the numpy oracle on the recompressed geometry's loss."""
    from repro.core import compose
    y = piecewise_signal(64, 44, 5, noise=0.15, seed=23)
    parts = [signal_coreset(y[a:b], 5, 0.3) for a, b in ((0, 32), (32, 64))]
    buckets = [compose(parts, [0, 32], n_total=64),
               compose(list(reversed(parts)), [32, 0], n_total=64)]
    ref = ops.streaming_compress(buckets, backend="numpy")
    got = ops.streaming_compress(buckets, backend=backend)
    assert len(got) == len(buckets)
    rng = np.random.default_rng(24)
    q = random_tree_segmentation(64, 44, 5, rng)
    for g, r, b in zip(got, ref, buckets):
        assert np.isclose(g.total_mass(), b.total_mass())
        assert np.isclose(g.moments[:, 1].sum(), b.moments[:, 1].sum())
        assert np.isclose(g.moments[:, 2].sum(), b.moments[:, 2].sum())
        lg = fitting_loss(g, q.rects, q.labels)
        lr = fitting_loss(r, q.rects, q.labels)
        np.testing.assert_allclose(lg, lr, rtol=0.1)


def test_streaming_compress_empty_and_single():
    assert ops.streaming_compress([]) == []
    cs = _coreset(seed=25)
    from repro.core import recompress
    via_op = ops.streaming_compress([cs], backend="numpy")[0]
    direct = recompress(cs)
    assert via_op.fingerprint() == direct.fingerprint()


def test_hist_split_parity_awkward_sizes():
    P, F, B = 1030, 3, 17                                # P % tile != 0
    codes = RNG.integers(0, B, size=(P, F)).astype(np.uint8)
    w = RNG.uniform(0.1, 2, P)
    y = RNG.normal(size=P)
    ref = ops.hist_split(codes, w, w * y, w * y * y, B, backend="numpy")
    for b in ("xla", "pallas"):
        got = ops.hist_split(codes, w, w * y, w * y * y, B, backend=b)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ------------------------------------------- batched kernel tile pad paths
def test_batched_kernel_awkward_tile_quanta():
    """B and K not multiples of the tile quantum, T not a multiple of the
    T-tile: every pad path of the (T-tile, B-tile) grid at once."""
    import jax.numpy as jnp
    from repro.kernels.fitting_loss.kernel import fitting_loss_batched_call
    cs = _coreset(seed=8)
    B = cs.num_blocks
    assert B > 13
    rects = jnp.asarray(cs.rects[:13], jnp.float32)      # B=13, tile_b=8
    lab = jnp.asarray(cs.labels[:13], jnp.float32)
    wgt = jnp.asarray(cs.weights[:13], jnp.float32)
    sr, sl = _candidates(57, 41, 7, 3, seed=9)           # T=3, tile_t=2, K=7
    got = np.asarray(fitting_loss_batched_call(
        rects, lab, wgt, jnp.asarray(sr, jnp.float32),
        jnp.asarray(sl, jnp.float32), tile_b=8, tile_t=2, interpret=True))
    from repro.kernels.fitting_loss.ref import fitting_loss_ref
    want = np.array([float(fitting_loss_ref(
        rects, lab, wgt, jnp.asarray(r, jnp.float32),
        jnp.asarray(l, jnp.float32))) for r, l in zip(sr, sl)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- deprecated shim
def test_coreset_loss_many_shim_delegates_and_warns_once():
    import repro.kernels.fitting_loss.ops as fl_ops
    cs = _coreset(seed=10)
    sr, sl = _candidates(57, 41, 4, 3, seed=11)
    fl_ops._MANY_DEPRECATION_WARNED = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = np.asarray(fl_ops.coreset_loss_many(cs, list(sr), list(sl)))
        again = np.asarray(fl_ops.coreset_loss_many(cs, list(sr), list(sl)))
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1                       # warn once
    want = np.array([fitting_loss(cs, r, l) for r, l in zip(sr, sl)])
    np.testing.assert_allclose(got, want, rtol=2e-3)
    np.testing.assert_allclose(again, want, rtol=2e-3)


def test_coreset_loss_many_shim_accepts_ragged_leaf_counts():
    # the pre-dispatch loop accepted candidates with differing K; the shim
    # must too (per-item scoring instead of the fused stack).  The warn-once
    # flag is reset and the warning captured explicitly so this test is
    # order-independent and stays green under -W error::DeprecationWarning.
    import repro.kernels.fitting_loss.ops as fl_ops
    cs = _coreset(seed=14)
    rng = np.random.default_rng(15)
    segs = [random_tree_segmentation(57, 41, k, rng) for k in (3, 6)]
    fl_ops._MANY_DEPRECATION_WARNED = False
    with pytest.warns(DeprecationWarning, match="fitting_loss_batched"):
        got = np.asarray(fl_ops.coreset_loss_many(
            cs, [s.rects for s in segs], [s.labels for s in segs]))
    want = np.array([fitting_loss(cs, s.rects, s.labels) for s in segs])
    np.testing.assert_allclose(got, want, rtol=2e-3)


# -------------------------------------------------------- engine integration
def test_engine_stats_surface_ops_backends():
    from repro.service import CoresetEngine, ServiceMetrics
    eng = CoresetEngine(workers=1, metrics=ServiceMetrics())
    try:
        eng.register_signal("s", piecewise_signal(48, 32, 4, seed=12))
        sr, sl = _candidates(48, 32, 3, 2, seed=13)
        r = eng.tree_loss_batch("s", sr.astype(np.int64), sl, eps=0.3)
        assert r["backend"] in ("numpy", "xla", "pallas")
        snap = eng.stats()["ops_backends"]
        assert set(snap) == set(ops.OPS)
    finally:
        eng.close()


# ----------------------------------------------------------- real mesh path
@pytest.mark.slow   # subprocess + forced 8-device host mesh; ci_smoke's ops
                    # stage still runs it by name
def test_mesh_sharded_batched_loss_matches_oracle():
    """The ROADMAP's 'exercise the mesh path for real': a forced 8-device
    host mesh and fitting_loss_batched shard_map'd over it — parity against
    the numpy oracle, AND the dispatch profile must attribute the hop to the
    batched Pallas kernel (backend ``pallas+shard_map``), not the dense ref
    the old pjit path ran.  Runs in a subprocess so XLA_FLAGS takes effect
    before jax initializes."""
    script = textwrap.dedent("""
        import numpy as np, jax
        assert jax.device_count() >= 8, jax.devices()
        from repro.launch.mesh import compat_make_mesh
        from repro.core import (fitting_loss, fitting_loss_batched,
                                random_tree_segmentation, signal_coreset)
        from repro.core.sharded import MESH_BACKEND
        from repro.data import piecewise_signal
        from repro.obs import profile
        y = piecewise_signal(48, 40, 5, noise=0.2, seed=0)
        cs = signal_coreset(y, 5, 0.3)
        rng = np.random.default_rng(0)
        segs = [random_tree_segmentation(48, 40, 4, rng) for _ in range(3)]
        sr = np.stack([s.rects for s in segs]).astype(np.float64)
        sl = np.stack([s.labels for s in segs])
        samples = []
        profile.add_hook(lambda op, b, size, dt: samples.append((op, b)))
        mesh = compat_make_mesh((8,), ("data",), jax.devices())
        got = fitting_loss_batched(cs, sr, sl, mesh=mesh)
        want = np.array([fitting_loss(cs, s.rects, s.labels) for s in segs])
        assert np.allclose(got, want, rtol=2e-3, atol=1e-3), (got, want)
        assert ("fitting_loss_batched", MESH_BACKEND) in samples, samples
        assert MESH_BACKEND == "pallas+shard_map", MESH_BACKEND
        print("MESH-PARITY-OK devices=%d backend=%s"
              % (jax.device_count(), MESH_BACKEND))
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", script], env=env, cwd=repo,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "MESH-PARITY-OK" in proc.stdout
