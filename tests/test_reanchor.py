"""Version-aware cache re-anchoring: a disjoint append delta must re-key
every cached coreset to the new signal version in metadata time — the
re-anchored entry is **bitwise fingerprint-equal** to a from-scratch build
on the grown signal (the merge-reduce binary counter with an even band
count leaves level 0 empty, so the fresh build is exactly concat(cached
blocks, new leaf blocks)) — while any intersecting replace falls back to
invalidate+rebuild.  The cluster analogue: a forwarded band delta purges
ONLY the owning worker's content-addressed band-coreset cache entries."""
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro import obs
from repro.client import CoresetClient
from repro.cluster import ClusterEngine, ShardWorker, make_worker_server
from repro.core import random_tree_segmentation, signal_coreset, true_loss
from repro.data import piecewise_signal
from repro.service import (CacheEntry, CoresetEngine, DominanceCache,
                           ServiceMetrics, make_server,
                           serve_forever_in_thread)
from repro.service.cache import block_row_spans, spans_intersect

M, ROWS = 48, 12           # band geometry shared by every streamed test


def _engine(**kw):
    kw.setdefault("workers", 2)
    kw.setdefault("metrics", ServiceMetrics())
    return CoresetEngine(**kw)


def _bands(count, seed=0):
    y = piecewise_signal(ROWS * count, M, 8, noise=0.15, seed=seed)
    return [y[i * ROWS:(i + 1) * ROWS] for i in range(count)]


# ----------------------------------------------------------- span metadata
def test_block_row_spans_merges_overlapping_blocks():
    rects = np.array([[0, 4, 0, 48], [2, 6, 0, 48], [10, 12, 0, 48],
                      [6, 8, 0, 48]], np.int64)
    spans = block_row_spans(rects)
    assert spans.tolist() == [[0, 8], [10, 12]]
    assert block_row_spans(np.empty((0, 4))).shape == (0, 2)


def test_spans_intersect_half_open_semantics():
    spans = np.array([[0, 8], [10, 12]], np.int64)
    assert spans_intersect(spans, 7, 9)          # overlaps [0, 8)
    assert not spans_intersect(spans, 8, 10)     # exactly the gap
    assert not spans_intersect(spans, 12, 20)    # past the end
    assert not spans_intersect(spans, 5, 5)      # empty delta
    assert spans_intersect(None, 0, 1)           # unknown provenance: assume
    assert not spans_intersect(np.empty((0, 2)), 0, 100)


def _entry(version, k=4, eps=0.3, n=24, seed=0):
    cs = signal_coreset(piecewise_signal(n, M, k, seed=seed), k, eps)
    return CacheEntry(signal="s", version=version, k=k, eps=eps, eps_eff=eps,
                      coreset=cs, nbytes=cs.nbytes,
                      fingerprint=cs.fingerprint())


def test_cache_take_and_reanchor_candidate_counters():
    cache = DominanceCache(byte_budget=1 << 26)
    cache.put(_entry("v1", k=4))
    cache.put(_entry("v1", k=6))
    e = cache.take("s", "v1", 4, 0.3)
    assert e is not None and e.k == 4
    assert e.row_spans is not None          # put() derived spans from rects
    assert cache.take("s", "v1", 4, 0.3) is None   # gone, no counters bumped
    assert cache.metrics.get("cache_invalidations") == 0
    dropped = cache.invalidate_signal("s", keep_version="v2")
    assert [d.k for d in dropped] == [6]    # returned for re-anchor triage
    assert cache.stats()["reanchor_candidates"] == 1
    cache.mark_reanchored(3)
    assert cache.stats()["reanchored"] == 3


# ------------------------------------------------------- splice bit-parity
@pytest.mark.parametrize("nbands,k,eps", [(2, 5, 0.3), (4, 5, 0.3),
                                          (4, 8, 0.2), (6, 3, 0.4)])
def test_append_reanchor_is_bitwise_equal_to_fresh_build(nbands, k, eps):
    bands = _bands(nbands + 1, seed=nbands)
    eng, ref = _engine(), _engine()
    try:
        for b in bands[:-1]:
            eng.ingest_band("st", b)
        eng.get_coreset("st", k, eps)
        builds = eng.metrics.get("coreset_builds")
        out = eng.ingest_delta("st", bands[-1])        # append: disjoint
        assert out["entries_reanchored"] == 1
        cs, eps_eff, how = eng.get_coreset("st", k, eps)
        assert how == "exact"                          # served, not rebuilt
        assert eng.metrics.get("coreset_builds") == builds
        assert eng.metrics.get("cache_reanchored") == 1

        for b in bands:
            ref.ingest_band("st", b)
        cs_ref, eps_ref, _ = ref.get_coreset("st", k, eps)
        assert cs.fingerprint() == cs_ref.fingerprint()
        assert eps_eff == eps_ref
        np.testing.assert_array_equal(cs.rects, cs_ref.rects)
        np.testing.assert_array_equal(cs.labels, cs_ref.labels)
        np.testing.assert_array_equal(cs.weights, cs_ref.weights)
    finally:
        eng.close()
        ref.close()


def test_append_reanchor_covers_every_cached_spec():
    bands = _bands(5, seed=17)
    specs = [(4, 0.35), (6, 0.25), (8, 0.2)]
    eng, ref = _engine(), _engine()
    try:
        for b in bands[:-1]:
            eng.ingest_band("st", b)
        for kk, ee in specs:
            eng.get_coreset("st", kk, ee)
        builds = eng.metrics.get("coreset_builds")
        out = eng.ingest_delta("st", bands[-1])
        assert out["entries_reanchored"] == len(specs)
        for b in bands:
            ref.ingest_band("st", b)
        for kk, ee in specs:
            cs, _, how = eng.get_coreset("st", kk, ee)
            assert how == "exact"
            cs_ref, _, _ = ref.get_coreset("st", kk, ee)
            assert cs.fingerprint() == cs_ref.fingerprint()
        assert eng.metrics.get("coreset_builds") == builds
    finally:
        eng.close()
        ref.close()


def test_odd_band_count_append_falls_back_to_rebuild():
    # an odd prior band count cascades the binary counter on append, so the
    # cached blocks are NOT a prefix of the fresh build — must invalidate
    bands = _bands(4, seed=3)
    eng, ref = _engine(), _engine()
    try:
        for b in bands[:-1]:
            eng.ingest_band("st", b)      # 3 bands: ineligible
        eng.get_coreset("st", 5, 0.3)
        out = eng.ingest_delta("st", bands[-1])
        assert out["entries_reanchored"] == 0
        assert eng.metrics.get("cache_reanchored") == 0
        cs, _, _ = eng.get_coreset("st", 5, 0.3)
        for b in bands:
            ref.ingest_band("st", b)
        cs_ref, _, _ = ref.get_coreset("st", 5, 0.3)
        assert cs.fingerprint() == cs_ref.fingerprint()   # correct either way
    finally:
        eng.close()
        ref.close()


def test_intersecting_replace_never_serves_stale_coreset():
    bands = _bands(4, seed=5)
    eng = _engine()
    try:
        for b in bands:
            eng.ingest_band("st", b)
        eng.get_coreset("st", 5, 0.25)
        before = eng.cache.stats()["reanchor_candidates"]
        patch = piecewise_signal(ROWS, M, 4, noise=0.1, seed=99)
        out = eng.ingest_delta("st", patch, row0=ROWS)    # hits cached rows
        assert out["entries_reanchored"] == 0             # fell back
        assert eng.cache.stats()["reanchor_candidates"] > before
        # the re-cached entry answers for the PATCHED signal within eps
        y = np.vstack([bands[0], patch, bands[2], bands[3]])
        n = y.shape[0]
        rng = np.random.default_rng(2)
        for _ in range(3):
            q = random_tree_segmentation(n, M, 5, rng)
            r = eng.tree_loss("st", q.rects, q.labels, eps=0.25)
            tl = true_loss(y, q.rects, q.labels)
            assert abs(r["loss"] - tl) <= 0.25 * max(tl, 1e-9)
    finally:
        eng.close()


# ----------------------------------------------------------- HTTP service
def test_http_disjoint_delta_serves_with_zero_rebuilds():
    eng = _engine()
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    try:
        cl = CoresetClient(f"http://127.0.0.1:{srv.server_address[1]}")
        bands = _bands(3, seed=11)
        for b in bands[:-1]:
            cl.ingest("st", band=b)
        cl.build("st", 5, 0.3)
        builds = eng.metrics.get("coreset_builds")
        r = cl.ingest_delta("st", bands[-1])              # append
        assert r.entries_reanchored == 1                  # on the wire
        b2 = cl.build("st", 5, 0.3)
        assert b2.served_from == "exact"
        comp = cl.compress("st", 5, 0.3)
        assert comp.served_from == "exact" and len(comp.X) > 0
        assert eng.metrics.get("coreset_builds") == builds     # zero rebuilds
        stats = cl.stats()
        assert stats["cache"]["reanchored"] == 1
        assert stats["metrics"]["counters"].get("cache_reanchored", 0) == 1 \
            or eng.metrics.get("cache_reanchored") == 1
    finally:
        srv.shutdown()
        eng.close()


# ---------------------------------------------------------------- cluster
def _start_worker(i):
    w = ShardWorker(worker_id=f"w{i}")
    srv = make_worker_server(w, port=0, tracer=obs.Tracer())
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return SimpleNamespace(worker=w, server=srv,
                           url=f"http://127.0.0.1:{srv.server_address[1]}")


def test_cluster_delta_purges_only_owning_workers_band_cache():
    nodes = [_start_worker(i) for i in range(3)]
    coord = ClusterEngine([n.url for n in nodes], workers=2, reprobe_s=0.2,
                          rpc_timeout=10.0, metrics=ServiceMetrics())
    try:
        n_rows = 96
        y = piecewise_signal(n_rows, M, 5, noise=0.15, seed=21)
        coord.register_signal("sig", y)
        coord.get_coreset("sig", 5, 0.3)
        for nd in nodes:
            assert nd.worker.metrics.get("worker_band_builds") == 1
        # replace rows owned by exactly one worker's slab (middle band)
        slab = n_rows // 3
        r0 = slab + 4
        patch = piecewise_signal(8, M, 3, noise=0.1, seed=22)
        pre_keys = [set(nd.worker._cache) for nd in nodes]
        assert all(len(k) == 1 for k in pre_keys)
        coord.ingest_delta("sig", patch, row0=r0)
        # the delta schedules a background re-cache build; let it finish so
        # the counters below are stable
        deadline = time.time() + 15
        while coord.scheduler.in_flight() and time.time() < deadline:
            time.sleep(0.02)
        assert coord.scheduler.in_flight() == 0
        purged = [nd.worker.metrics.get("worker_band_cache_purged")
                  for nd in nodes]
        assert sum(1 for p in purged if p) == 1        # only the owner
        owner = purged.index(next(p for p in purged if p))
        for i, nd in enumerate(nodes):
            if i == owner:     # stale-hash entries gone from the owner
                assert not (pre_keys[i] & set(nd.worker._cache))
            else:              # untouched bands keep their entries
                assert pre_keys[i] <= set(nd.worker._cache)
        # steady state after the delta: re-gathers at the new version hit
        # every worker's band cache again
        coord.cache.invalidate_signal("sig", keep_version=None)
        coord.get_coreset("sig", 5, 0.3)       # warm caches at new version
        hits = coord.metrics.get("cluster_band_cache_hits")
        b0 = [nd.worker.metrics.get("worker_band_builds") for nd in nodes]
        coord.cache.invalidate_signal("sig", keep_version=None)
        cs, _, _ = coord.get_coreset("sig", 5, 0.3)
        assert coord.metrics.get("cluster_band_cache_hits") == hits + 3
        assert [nd.worker.metrics.get("worker_band_builds")
                for nd in nodes] == b0
        # parity with a single-host engine over the patched signal
        single = CoresetEngine(num_bands=3, workers=2,
                               metrics=ServiceMetrics())
        try:
            y2 = y.copy()
            y2[r0:r0 + 8] = patch
            single.register_signal("sig", y2)
            cs_s, _, _ = single.get_coreset("sig", 5, 0.3)
            assert cs.fingerprint() == cs_s.fingerprint()
        finally:
            single.close()
    finally:
        coord.close()
        for nd in nodes:
            nd.server.shutdown()
            nd.server.server_close()
