"""Coreset serving engine: dominance cache, scheduler, streamed ingest, HTTP."""
import json
import re
import threading
import time
import urllib.error
import urllib.request

import numpy as np

from repro.client import CoresetAPIError, CoresetClient
from repro.core import (fitting_loss, random_tree_segmentation, signal_coreset,
                        true_loss)
from repro.data import piecewise_signal
from repro.service import (BuildScheduler, CacheEntry, CoresetEngine,
                           DominanceCache, ServiceMetrics, make_server,
                           serve_forever_in_thread)

N, M, KMAX = 72, 48, 8


def _engine(**kw):
    kw.setdefault("workers", 2)
    kw.setdefault("metrics", ServiceMetrics())
    return CoresetEngine(**kw)


def _signal(seed=0):
    return piecewise_signal(N, M, KMAX, noise=0.15, seed=seed)


# ------------------------------------------------------------------ dominance
def test_dominance_hit_serves_weaker_requests_without_rebuild():
    eng = _engine()
    try:
        eng.register_signal("s", _signal())
        cs, eps_eff, how = eng.get_coreset("s", KMAX, 0.2)
        assert how == "built" and eps_eff == 0.2
        # weaker request (smaller k, looser eps): dominated, same build count
        cs2, eps2, how2 = eng.get_coreset("s", 4, 0.35)
        assert how2 == "dominated"
        assert cs2.fingerprint() == cs.fingerprint()
        assert eps2 <= 0.35
        assert eng.metrics.get("coreset_builds") == 1
        # stronger request (larger k) must NOT be served by dominance
        _, _, how3 = eng.get_coreset("s", KMAX + 2, 0.2)
        assert how3 == "built"
        assert eng.metrics.get("coreset_builds") == 2
    finally:
        eng.close()


def test_tree_loss_defaults_k_to_leaf_count_and_is_accurate():
    eng = _engine()
    try:
        y = _signal(1)
        eng.register_signal("s", y)
        rng = np.random.default_rng(0)
        eng.get_coreset("s", KMAX, 0.2)  # anchor
        for _ in range(4):
            q = random_tree_segmentation(N, M, 6, rng)
            r = eng.tree_loss("s", q.rects, q.labels, eps=0.3)
            assert r["served_from"] in ("exact", "dominated")
            tl = true_loss(y, q.rects, q.labels)
            assert abs(r["loss"] - tl) <= 0.3 * max(tl, 1e-9)
        assert eng.metrics.get("cache_hit_dominated") >= 1
    finally:
        eng.close()


def test_cache_byte_budget_evicts_and_rebuilds():
    # budget fits ~one coreset: the second distinct signal overflows and the
    # GDSF policy evicts the lower-priority entry; the evicted one rebuilds
    eng = _engine(cache_bytes=1)  # any insert overflows; keeps one entry
    try:
        eng.register_signal("a", _signal(0))
        eng.register_signal("b", _signal(1))
        eng.get_coreset("a", 4, 0.3)
        eng.get_coreset("b", 4, 0.3)
        assert len(eng.cache) == 1  # cost-aware eviction kept one entry
        assert eng.metrics.get("cache_evictions") >= 1
        builds = eng.metrics.get("coreset_builds")
        # exactly one of the two is gone; re-requesting it rebuilds
        missing = [s for s in ("a", "b")
                   if eng.cache.lookup(s, eng.signal(s).version, 4, 0.3,
                                       record=False)[0] is None]
        assert len(missing) == 1
        _, _, how = eng.get_coreset(missing[0], 4, 0.3)
        assert how == "built"
        assert eng.metrics.get("coreset_builds") == builds + 1
    finally:
        eng.close()


# ------------------------------------------------------------ streamed ingest
def test_streamed_ingest_consistent_with_one_shot_build():
    eng = _engine()
    try:
        y = _signal(2)
        for i in range(0, N, 12):
            info = eng.ingest_band("st", y[i:i + 12])
        assert info["n"] == N and info["streamed"]
        cs, eps_eff, _ = eng.get_coreset("st", KMAX, 0.25)
        assert np.isclose(cs.total_mass(), y.size)
        one = signal_coreset(y, KMAX, 0.25)
        rng = np.random.default_rng(3)
        for _ in range(5):
            q = random_tree_segmentation(N, M, 6, rng)
            tl = true_loss(y, q.rects, q.labels)
            ls = fitting_loss(cs, q.rects, q.labels)
            lo = fitting_loss(one, q.rects, q.labels)
            # each side is within its eps of the true loss -> composed bound
            assert abs(ls - lo) <= (eps_eff + 0.25) * max(tl, 1e-9)
            assert abs(ls - tl) <= eps_eff * max(tl, 1e-9)
    finally:
        eng.close()


def test_ingest_bumps_version_and_invalidates_cache():
    eng = _engine()
    try:
        y = _signal(4)
        eng.ingest_band("st", y[:24])
        v1 = eng.signal("st").version
        eng.get_coreset("st", 4, 0.3)
        assert len(eng.cache) == 1
        eng.ingest_band("st", y[24:48])
        assert eng.signal("st").version != v1
        assert len(eng.cache) == 0  # stale version freed eagerly
        _, _, how = eng.get_coreset("st", 4, 0.3)
        assert how == "built"
    finally:
        eng.close()


# --------------------------------------------------------- concurrent clients
def test_concurrent_clients_identical_answers_and_coalesced_builds():
    eng = _engine(workers=4)
    try:
        y = _signal(5)
        eng.register_signal("s", y)
        q = random_tree_segmentation(N, M, 5, np.random.default_rng(1))
        results, errors = [], []
        barrier = threading.Barrier(6)

        def client():
            try:
                barrier.wait()
                for _ in range(3):
                    r = eng.tree_loss("s", q.rects, q.labels, eps=0.25, k=KMAX)
                    results.append(r["loss"])
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=client) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert len(set(results)) == 1  # deterministic: one coreset served all
        # identical concurrent keys collapsed to a single actual construction
        # (coreset_builds counts real builds; the scheduler may complete more
        # jobs when a late submitter's worker short-circuits on the cache)
        assert eng.metrics.get("coreset_builds") == 1
    finally:
        eng.close()


def test_scheduler_coalesces_identical_keys():
    sched = BuildScheduler(max_workers=2, batch_window=0.02)
    try:
        gate = threading.Event()
        calls = []

        def slow():
            gate.wait(5.0)
            calls.append(1)
            return "done"

        f1, created1 = sched.submit(("k",), slow)
        f2, created2 = sched.submit(("k",), slow)
        assert created1 and not created2 and f1 is f2
        gate.set()
        assert f1.result(timeout=10.0) == "done"
        assert calls == [1]
        # after completion the key is free again
        f3, created3 = sched.submit(("k",), lambda: "again")
        assert created3 and f3.result(timeout=10.0) == "again"
    finally:
        sched.shutdown()


# ------------------------------------------------------------------- HTTP API
def _server():
    eng = _engine()
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    return eng, srv, f"http://127.0.0.1:{srv.server_address[1]}"


def test_http_v1_end_to_end_sdk():
    eng, srv, base = _server()
    try:
        y = _signal(6)
        for encoding in ("json", "binary"):
            cl = CoresetClient(base, encoding=encoding)
            cl.register_signal(f"s-{encoding}", values=y)
            b = cl.build(f"s-{encoding}", KMAX, 0.2)
            assert b.served_from == "built" and b.size > 0
            assert len(b.fingerprint) == 32 and b.build_seconds > 0
            q = random_tree_segmentation(N, M, 4, np.random.default_rng(2))
            r = cl.query_loss(f"s-{encoding}", q.rects, q.labels, eps=0.3)
            assert r.served_from in ("exact", "dominated")
            tl = true_loss(y, q.rects, q.labels)
            assert abs(r.loss - tl) <= 0.3 * max(tl, 1e-9)
            fit = cl.fit(f"s-{encoding}", KMAX, n_estimators=2,
                         predict=[[1, 1], [N - 2, M - 2]])
            assert fit.predictions.shape == (2,)
            comp = cl.compress(f"s-{encoding}", KMAX, 0.2, max_points=64)
            assert len(comp.X) <= 64 and comp.served_from == "exact"
            cl.ingest(f"st-{encoding}", synthetic={"kind": "piecewise",
                                                   "n": 16, "m": M, "seed": 1})
        health = CoresetClient(base).healthz()
        assert health["status"] == "ok" and health["signals"] == 4
        assert health["protocol"] == "v1"
        metrics = CoresetClient(base).metrics_text()
        assert "coreset_cache_hit_dominated" in metrics
        assert "coreset_build_seconds_bucket" in metrics
        # structured API error: unknown signal -> 404 envelope, server stays up
        try:
            CoresetClient(base).build("nope", 4, 0.3)
            raise AssertionError("expected CoresetAPIError")
        except CoresetAPIError as exc:
            assert exc.http == 404 and exc.code == "not_found"
        assert CoresetClient(base).healthz()["status"] == "ok"
    finally:
        srv.shutdown()
        eng.close()


def test_http_legacy_routes_answer_with_deprecation_header():
    eng, srv, base = _server()

    def post(path, payload):
        req = urllib.request.Request(base + path, data=json.dumps(payload).encode(),
                                     headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=60) as r:
            return json.loads(r.read()), dict(r.headers)

    try:
        y = _signal(6)
        body, hdr = post("/signals", {"name": "s", "values": y.tolist()})
        assert hdr.get("Deprecation") == "true"
        assert '</v1/signals>; rel="successor-version"' in hdr.get("Link", "")
        assert body["n"] == N and body["version"]
        body, hdr = post("/build", {"name": "s", "k": KMAX, "eps": 0.2})
        assert hdr.get("Deprecation") == "true"
        assert body["served_from"] == "built" and len(body["fingerprint"]) == 32
        # pre-v1 response compatibility: old key names still answer
        assert body["cache"] == "built" and "type" not in body
        comp, _ = post("/query/compress", {"name": "s", "k": KMAX, "eps": 0.2,
                                           "max_points": 64})
        assert comp["cache"] in ("exact", "dominated")
        assert len(comp["points"]["X"]) <= 64   # old nested points layout
        q = random_tree_segmentation(N, M, 4, np.random.default_rng(2))
        body, hdr = post("/query/loss", {"name": "s", "rects": q.rects.tolist(),
                                         "labels": q.labels.tolist(), "eps": 0.3})
        assert hdr.get("Deprecation") == "true"
        assert body["served_from"] in ("exact", "dominated")
        with urllib.request.urlopen(base + "/healthz", timeout=30) as r:
            assert r.headers.get("Deprecation") == "true"
            assert json.loads(r.read())["status"] == "ok"
        # v1 routes do NOT carry the deprecation header
        with urllib.request.urlopen(base + "/v1/healthz", timeout=30) as r:
            assert r.headers.get("Deprecation") is None
        # malformed legacy request -> 400 with the uniform v1 envelope
        try:
            post("/query/loss", {"name": "nope", "rects": [], "labels": []})
            raise AssertionError("expected HTTP error")
        except urllib.error.HTTPError as exc:
            assert exc.code == 400
            env = json.loads(exc.read())
            assert env["error"]["code"] == "bad_request"
            assert env["error"]["message"]
    finally:
        srv.shutdown()
        eng.close()


def test_http_400_envelope_for_ragged_and_non_numeric_arrays():
    eng, srv, base = _server()

    def post_raw(path, payload):
        req = urllib.request.Request(base + path, data=json.dumps(payload).encode(),
                                     headers={"Content-Type": "application/json"})
        urllib.request.urlopen(req, timeout=60).close()

    try:
        for bad_values in ([[1.0, 2.0], [3.0]],          # ragged
                           [["a", "b"], ["c", "d"]],     # non-numeric
                           [1.0, 2.0, 3.0],              # wrong ndim
                           [[1.0, float("nan")]]):       # non-finite signal
            for path, payload in (
                    ("/v1/signals", {"type": "register",
                                     "signal": {"name": "bad"},
                                     "values": bad_values}),
                    ("/signals", {"name": "bad", "values": bad_values})):
                try:
                    post_raw(path, payload)
                    raise AssertionError(f"expected 400 for {path} {bad_values}")
                except urllib.error.HTTPError as exc:
                    assert exc.code == 400, (path, bad_values)
                    env = json.loads(exc.read())
                    assert env["error"]["code"] == "bad_request"
                    assert isinstance(env["error"]["message"], str)
        # nothing got registered, server healthy
        assert CoresetClient(base).healthz()["signals"] == 0
    finally:
        srv.shutdown()
        eng.close()


def test_http_415_for_undecodable_codec_and_unknown_media_type():
    from repro.service import protocol as P
    eng, srv, base = _server()

    def post_raw(path, body, ctype):
        req = urllib.request.Request(base + path, data=body,
                                     headers={"Content-Type": ctype})
        urllib.request.urlopen(req, timeout=30).close()

    try:
        try:
            post_raw("/v1/signals", b"<xml/>", "application/xml")
            raise AssertionError("expected 415")
        except urllib.error.HTTPError as exc:
            assert exc.code == 415
            assert json.loads(exc.read())["error"]["code"] == "unsupported_media"
        if P.zstandard is None:
            # a zstd frame on this zlib-only host: 415 tells the SDK to
            # renegotiate down to JSON instead of failing with 400
            frame = b"RPV1" + b"Z" + b"\x28\xb5\x2f\xfd" + b"\x00" * 8
            try:
                post_raw("/v1/signals", frame, P.CONTENT_TYPE_BINARY)
                raise AssertionError("expected 415")
            except urllib.error.HTTPError as exc:
                assert exc.code == 415
                env = json.loads(exc.read())
                assert env["error"]["code"] == "unsupported_media"
    finally:
        srv.shutdown()
        eng.close()


# --------------------------------------------------- fused batch loss queries
def test_batch_loss_uses_fewer_scoring_calls_than_sequential():
    eng, srv, base = _server()
    try:
        y = _signal(8)
        cl = CoresetClient(base)
        cl.register_signal("s", values=y)
        rng = np.random.default_rng(3)
        segs = [random_tree_segmentation(N, M, 5, rng) for _ in range(32)]
        rects = np.stack([s.rects for s in segs])
        labels = np.stack([s.labels for s in segs])

        base_calls = eng.metrics.get("loss_scoring_calls")
        seq = [cl.query_loss("s", s.rects, s.labels, eps=0.3, k=KMAX).loss
               for s in segs]
        seq_calls = eng.metrics.get("loss_scoring_calls") - base_calls
        assert seq_calls == 32

        base_calls = eng.metrics.get("loss_scoring_calls")
        rb = cl.query_loss_batch("s", rects, labels, eps=0.3, k=KMAX)
        batch_calls = eng.metrics.get("loss_scoring_calls") - base_calls
        assert batch_calls == 1 < seq_calls
        assert rb.scoring_calls == 1
        assert rb.losses.shape == (32,)
        assert np.allclose(rb.losses, seq, rtol=1e-4)
        # the fused result honors the same guarantee as the sequential path
        for s, lb in zip(segs, rb.losses):
            tl = true_loss(y, s.rects, s.labels)
            assert abs(lb - tl) <= 0.3 * max(tl, 1e-9) * (1 + 1e-4)
    finally:
        srv.shutdown()
        eng.close()


def test_batch_loss_validates_shapes():
    eng = _engine()
    try:
        eng.register_signal("s", _signal())
        rng = np.random.default_rng(0)
        q = random_tree_segmentation(N, M, 4, rng)
        try:
            eng.tree_loss_batch("s", q.rects, q.labels)  # 2-D, not (T, K, 4)
            raise AssertionError("expected ValueError")
        except ValueError:
            pass
    finally:
        eng.close()


# ------------------------------------------------------- forest model caching
def test_fit_forest_caches_by_fingerprint_and_hyperparams():
    eng = _engine()
    try:
        eng.register_signal("s", _signal(9))
        r1 = eng.fit_forest("s", k=4, eps=0.3, n_estimators=2, seed=7,
                            predict=[[1, 1]])
        assert r1["model_cache"] == "fit"
        r2 = eng.fit_forest("s", k=4, eps=0.3, n_estimators=2, seed=7,
                            predict=[[1, 1]])
        assert r2["model_cache"] == "hit"
        assert r2["predictions"] == r1["predictions"]
        assert eng.metrics.get("forest_cache_hit") == 1
        # different hyperparams / seed -> distinct cache slots
        assert eng.fit_forest("s", k=4, eps=0.3, n_estimators=3,
                              seed=7)["model_cache"] == "fit"
        assert eng.fit_forest("s", k=4, eps=0.3, n_estimators=2,
                              seed=8)["model_cache"] == "fit"
    finally:
        eng.close()


# ------------------------------------------- cache build_seconds + eviction
def test_cache_records_build_seconds_and_exposes_in_stats():
    eng, srv, base = _server()
    try:
        cl = CoresetClient(base)
        cl.register_signal("s", values=_signal(10))
        b = cl.build("s", 4, 0.3)
        assert b.build_seconds > 0
        stats = cl.stats()
        keys = stats["cache"]["keys"]
        assert len(keys) == 1
        assert keys[0]["build_seconds"] > 0
        # insert-time record matches the build response's wall clock
        assert abs(keys[0]["build_seconds"] - b.build_seconds) < 1e-9
    finally:
        srv.shutdown()
        eng.close()


def test_dominance_cache_evicts_stale_versions_on_ingest():
    # cache-level: invalidate_signal drops every entry of other versions
    cache = DominanceCache(metrics=ServiceMetrics())
    cs = signal_coreset(_signal(11), 4, 0.3)

    def entry(version, k):
        return CacheEntry(signal="s", version=version, k=k, eps=0.3,
                          eps_eff=0.3, coreset=cs, nbytes=cs.nbytes,
                          fingerprint=cs.fingerprint(),
                          build_seconds=cs.build_seconds)

    cache.put(entry("v1", 4))
    cache.put(entry("v1", 8))
    cache.put(entry("v2", 4))
    assert len(cache) == 3
    dropped = cache.invalidate_signal("s", keep_version="v2")
    assert len(dropped) == 2 and len(cache) == 1
    assert {e.version for e in dropped} == {"v1"}
    assert cache.stats()["reanchor_candidates"] == 2
    e, kind = cache.lookup("s", "v2", 4, 0.3)
    assert kind == "exact" and e.build_seconds == cs.build_seconds
    assert cache.lookup("s", "v1", 4, 0.3) == (None, None)

    # engine-level: a fresh band bumps the version and evicts eagerly
    eng = _engine()
    try:
        y = _signal(11)
        eng.ingest_band("st", y[:24])
        eng.get_coreset("st", 4, 0.3)
        assert len(eng.cache) == 1
        eng.ingest_band("st", y[24:48])
        assert len(eng.cache) == 0
    finally:
        eng.close()


# ------------------------------------------------ cost-aware (GDSF) eviction
def _gdsf_entry(cs, name, *, build_seconds, nbytes=None):
    return CacheEntry(signal=name, version="v", k=4, eps=0.3, eps_eff=0.3,
                      coreset=cs, nbytes=nbytes or cs.nbytes,
                      fingerprint=cs.fingerprint(),
                      build_seconds=build_seconds)


def test_gdsf_expensive_entry_outlives_cheap_same_size_one():
    cs = signal_coreset(_signal(12), 4, 0.3)
    # budget fits exactly two entries of cs.nbytes
    cache = DominanceCache(byte_budget=2 * cs.nbytes, metrics=ServiceMetrics())
    cheap = _gdsf_entry(cs, "cheap", build_seconds=1e-4)
    pricey = _gdsf_entry(cs, "pricey", build_seconds=5.0)
    cache.put(cheap)
    cache.put(pricey)
    # equal recency, equal size, no hits: overflow must pick the cheap one
    cache.put(_gdsf_entry(cs, "third", build_seconds=1e-4))
    assert len(cache) == 2
    got, kind = cache.lookup("pricey", "v", 4, 0.3)
    assert kind == "exact" and got.build_seconds == 5.0
    assert cache.lookup("cheap", "v", 4, 0.3) == (None, None)


def test_gdsf_hit_rate_expensive_entry_survives_churn():
    # an expensive-to-rebuild entry keeps hitting across a stream of cheap
    # same-size inserts that each overflow the budget
    cs = signal_coreset(_signal(13), 4, 0.3)
    m = ServiceMetrics()
    cache = DominanceCache(byte_budget=2 * cs.nbytes, metrics=m)
    cache.put(_gdsf_entry(cs, "pricey", build_seconds=3.0))
    hits = 0
    for i in range(8):
        cache.put(_gdsf_entry(cs, f"cheap{i}", build_seconds=1e-4))
        e, kind = cache.lookup("pricey", "v", 4, 0.3)
        hits += kind == "exact"
    assert hits == 8                       # 100% hit rate for the hot entry
    assert m.get("cache_evictions") >= 7   # the cheap stream churned instead


def test_gdsf_clock_ages_out_untouched_entries():
    # recency still matters: once the clock has advanced past an idle
    # entry's stale priority, a fresher cheap entry outranks it
    cs = signal_coreset(_signal(14), 4, 0.3)
    cache = DominanceCache(byte_budget=2 * cs.nbytes, metrics=ServiceMetrics())
    cache.put(_gdsf_entry(cs, "idle", build_seconds=0.05))
    # churn enough cheap entries that each eviction raises the clock
    for i in range(50):
        cache.put(_gdsf_entry(cs, f"c{i}", build_seconds=0.02))
        cache.lookup(f"c{i}", "v", 4, 0.3)   # keep the newest one hot
    assert cache.lookup("idle", "v", 4, 0.3) == (None, None)
    assert cache.stats()["clock"] > 0.0


# ------------------------------------------- observability: /metrics grammar
_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LABEL_BODY = (r'[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\["\\n])*"')
_TYPE_RE = re.compile(rf"^# TYPE ({_NAME}) (counter|histogram)$")
_SAMPLE_RE = re.compile(
    rf"^({_NAME})(\{{{_LABEL_BODY}(?:,{_LABEL_BODY})*\}})?"
    r" (-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)"
    rf'( # \{{trace_id="(?:[^"\\\n]|\\["\\n])*"\}}'
    r" -?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)?$")
_LE_RE = re.compile(r'le="([^"]*)"')


def _check_prometheus_grammar(body: str):
    """Strict line-by-line parse of a /metrics exposition body.  Returns
    {family: type} after asserting: every line is a TYPE header or a
    well-formed sample, one unique TYPE per family, samples contiguous
    under their family's header, histogram bucket counts cumulative with
    le="+Inf" equal to the _count sample."""
    families: dict[str, str] = {}
    closed: set = set()
    current = None
    buckets: dict[tuple, list] = {}
    counts: dict[tuple, float] = {}
    assert body.endswith("\n")
    for line in body.splitlines():
        m = _TYPE_RE.match(line)
        if m:
            fam, typ = m.groups()
            assert fam not in families, f"duplicate # TYPE for {fam}"
            if current is not None:
                closed.add(current)
            families[fam] = typ
            current = fam
            continue
        m = _SAMPLE_RE.match(line)
        assert m, f"malformed exposition line: {line!r}"
        name, labels, value, exemplar = m.groups()
        fam = name
        for sfx in ("_bucket", "_sum", "_count"):
            if name.endswith(sfx) and name[:-len(sfx)] in families:
                fam = name[:-len(sfx)]
                break
        assert fam in families, f"sample {name!r} precedes its # TYPE"
        assert fam == current, f"sample {name!r} outside its family block"
        assert fam not in closed, f"family {fam} not contiguous"
        if families[fam] == "histogram" and name.endswith("_bucket"):
            assert exemplar is None or "trace_id=" in exemplar
            le = _LE_RE.search(labels or "")
            assert le, f"bucket sample without le label: {line!r}"
            key = (fam, _LE_RE.sub("", labels or ""))
            buckets.setdefault(key, []).append((le.group(1), float(value)))
        elif families[fam] == "histogram" and name.endswith("_count"):
            counts[(fam, labels or "")] = float(value)
        else:
            assert exemplar is None, f"exemplar on non-bucket line: {line!r}"
    for (fam, labels), series in buckets.items():
        vals = [v for _, v in series]
        assert vals == sorted(vals), f"{fam}{labels} buckets not cumulative"
        assert series[-1][0] == "+Inf", f"{fam}{labels} missing +Inf bucket"
        ckey = (fam, labels.replace("{,", "{").replace(",}", "}")
                .replace("{}", ""))
        assert counts[ckey] == vals[-1], \
            f"{fam}{labels}: +Inf bucket != _count"
    return families


def test_metrics_exposition_grammar_end_to_end():
    eng, srv, base = _server()
    try:
        cl = CoresetClient(base)
        cl.register_signal("s", values=_signal(15))
        cl.build("s", 4, 0.3)
        q = random_tree_segmentation(N, M, 4, np.random.default_rng(5))
        cl.query_loss("s", q.rects, q.labels, eps=0.3)
        body = cl.metrics_text()
        families = _check_prometheus_grammar(body)
        # the per-(op, backend, shape-bucket) dispatch families are present
        assert families.get("coreset_ops_dispatch_total") == "counter"
        assert families.get("coreset_ops_dispatch_seconds") == "histogram"
        assert re.search(r'coreset_ops_dispatch_total\{[^}]*backend="', body)
        assert re.search(r'coreset_ops_dispatch_total\{[^}]*bucket="le_2', body)
        # latency histograms carry OpenMetrics exemplars with trace ids
        assert re.search(r'_bucket\{[^}]*\} \d+ # \{trace_id="[0-9a-f]{32}"\}',
                         body)
    finally:
        srv.shutdown()
        eng.close()


def test_metrics_label_values_are_escaped():
    m = ServiceMetrics()
    hostile = 'x"y\\z\nw'
    m.inc("labelled_total", op=hostile)
    m.observe("labelled_lat", 0.01, op=hostile)
    body = m.render()
    _check_prometheus_grammar(body)     # hostile value must not break parse
    assert '\\"y' in body and "\\\\z" in body and "\\nw" in body
    assert "\nw" not in body.replace("\\nw", "")  # no raw newline leaked
    from repro.service.metrics import escape_label_value
    assert escape_label_value('a"b') == 'a\\"b'
    assert escape_label_value("a\\b") == "a\\\\b"
    assert escape_label_value("a\nb") == "a\\nb"
    # backslash first: an escaped quote does not double-escape
    assert escape_label_value('\\"') == '\\\\\\"'


def test_uptime_uses_monotonic_clock():
    m = ServiceMetrics()
    # a wall-clock step (NTP, DST) must not affect uptime: started_at is
    # display-only, uptime reads the monotonic clock
    m.started_at = time.time() + 3600.0
    u1 = m.uptime_s()
    assert 0.0 <= u1 < 60.0
    time.sleep(0.01)
    u2 = m.snapshot()["uptime_s"]
    assert u2 > u1


# ------------------------------------------------ observability: HTTP traces
def test_http_trace_retrieval_and_chrome_export():
    eng, srv, base = _server()
    try:
        cl = CoresetClient(base)
        cl.register_signal("s", values=_signal(16))
        cl.build("s", 4, 0.3)
        q = random_tree_segmentation(N, M, 4, np.random.default_rng(6))
        cl.query_loss("s", q.rects, q.labels, eps=0.3)
        tid = cl.last_trace_id
        assert tid and len(tid) == 32
        # the client's minted traceparent is the server-side trace id
        assert cl.last_traceparent.split("-")[1] == tid
        trace = cl.trace(tid)
        names = [s["name"] for s in trace["spans"]]
        assert "POST /v1/query/loss" in names
        assert "engine.tree_loss" in names and "coreset.get" in names
        # recent listing includes it, newest first
        recent = cl.traces_recent(limit=5)
        assert any(t["trace_id"] == tid for t in recent)
        # chrome export parses and has complete events
        chrome = cl.trace(tid, format="chrome")
        assert any(e.get("ph") == "X" for e in chrome["traceEvents"])
        # unknown id -> 404; bad format -> 400; bad limit -> 400
        try:
            cl.trace("0" * 32)
            raise AssertionError("expected 404")
        except CoresetAPIError as exc:
            assert exc.http == 404
        with urllib.request.urlopen(
                base + f"/v1/trace/{tid}?format=chrome", timeout=30) as r:
            assert json.loads(r.read())["traceEvents"]
        for bad in (f"/v1/trace/{tid}?format=xml", "/v1/traces:recent?limit=x"):
            try:
                urllib.request.urlopen(base + bad, timeout=30).close()
                raise AssertionError(f"expected 400 for {bad}")
            except urllib.error.HTTPError as exc:
                assert exc.code == 400
    finally:
        srv.shutdown()
        eng.close()


def test_client_surfaces_trace_id_on_api_errors():
    eng, srv, base = _server()
    try:
        cl = CoresetClient(base)
        try:
            cl.build("missing-signal", 4, 0.3)
            raise AssertionError("expected CoresetAPIError")
        except CoresetAPIError as exc:
            assert exc.http == 404
            assert exc.trace_id and len(exc.trace_id) == 32
            assert f"[trace {exc.trace_id}]" in str(exc)
            # the failed request's trace is itself retrievable
            assert cl.trace(exc.trace_id)["root"].startswith("POST ")
    finally:
        srv.shutdown()
        eng.close()


# ------------------------------------------------- satellite: fingerprint API
def test_fingerprint_stable_and_repr_informative():
    y = _signal(7)
    a = signal_coreset(y, 4, 0.3)
    b = signal_coreset(y, 4, 0.3)
    c = signal_coreset(y, 4, 0.2)
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != c.fingerprint()
    assert a.nbytes == a.rects.nbytes + a.labels.nbytes + a.weights.nbytes + a.moments.nbytes
    r = repr(a)
    assert f"k={a.k}" in r and "eps=0.3" in r and f"size={a.size}" in r
    assert a.fingerprint()[:10] in r
