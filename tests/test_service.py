"""Coreset serving engine: dominance cache, scheduler, streamed ingest, HTTP."""
import json
import threading
import urllib.error
import urllib.request

import numpy as np

from repro.core import (fitting_loss, random_tree_segmentation, signal_coreset,
                        true_loss)
from repro.data import piecewise_signal
from repro.service import (BuildScheduler, CoresetEngine, ServiceMetrics,
                           make_server, serve_forever_in_thread)

N, M, KMAX = 72, 48, 8


def _engine(**kw):
    kw.setdefault("workers", 2)
    kw.setdefault("metrics", ServiceMetrics())
    return CoresetEngine(**kw)


def _signal(seed=0):
    return piecewise_signal(N, M, KMAX, noise=0.15, seed=seed)


# ------------------------------------------------------------------ dominance
def test_dominance_hit_serves_weaker_requests_without_rebuild():
    eng = _engine()
    try:
        eng.register_signal("s", _signal())
        cs, eps_eff, how = eng.get_coreset("s", KMAX, 0.2)
        assert how == "built" and eps_eff == 0.2
        # weaker request (smaller k, looser eps): dominated, same build count
        cs2, eps2, how2 = eng.get_coreset("s", 4, 0.35)
        assert how2 == "dominated"
        assert cs2.fingerprint() == cs.fingerprint()
        assert eps2 <= 0.35
        assert eng.metrics.get("coreset_builds") == 1
        # stronger request (larger k) must NOT be served by dominance
        _, _, how3 = eng.get_coreset("s", KMAX + 2, 0.2)
        assert how3 == "built"
        assert eng.metrics.get("coreset_builds") == 2
    finally:
        eng.close()


def test_tree_loss_defaults_k_to_leaf_count_and_is_accurate():
    eng = _engine()
    try:
        y = _signal(1)
        eng.register_signal("s", y)
        rng = np.random.default_rng(0)
        eng.get_coreset("s", KMAX, 0.2)  # anchor
        for _ in range(4):
            q = random_tree_segmentation(N, M, 6, rng)
            r = eng.tree_loss("s", q.rects, q.labels, eps=0.3)
            assert r["cache"] in ("exact", "dominated")
            tl = true_loss(y, q.rects, q.labels)
            assert abs(r["loss"] - tl) <= 0.3 * max(tl, 1e-9)
        assert eng.metrics.get("cache_hit_dominated") >= 1
    finally:
        eng.close()


def test_cache_byte_budget_evicts_lru():
    # budget fits ~one coreset: the second distinct signal evicts the first
    eng = _engine(cache_bytes=1)  # any insert overflows; keeps newest entry
    try:
        eng.register_signal("a", _signal(0))
        eng.register_signal("b", _signal(1))
        eng.get_coreset("a", 4, 0.3)
        eng.get_coreset("b", 4, 0.3)
        assert len(eng.cache) == 1  # LRU evicted the older entry
        assert eng.metrics.get("cache_evictions") >= 1
        # evicted entry rebuilds correctly
        _, _, how = eng.get_coreset("a", 4, 0.3)
        assert how == "built"
    finally:
        eng.close()


# ------------------------------------------------------------ streamed ingest
def test_streamed_ingest_consistent_with_one_shot_build():
    eng = _engine()
    try:
        y = _signal(2)
        for i in range(0, N, 12):
            info = eng.ingest_band("st", y[i:i + 12])
        assert info["n"] == N and info["streamed"]
        cs, eps_eff, _ = eng.get_coreset("st", KMAX, 0.25)
        assert np.isclose(cs.total_mass(), y.size)
        one = signal_coreset(y, KMAX, 0.25)
        rng = np.random.default_rng(3)
        for _ in range(5):
            q = random_tree_segmentation(N, M, 6, rng)
            tl = true_loss(y, q.rects, q.labels)
            ls = fitting_loss(cs, q.rects, q.labels)
            lo = fitting_loss(one, q.rects, q.labels)
            # each side is within its eps of the true loss -> composed bound
            assert abs(ls - lo) <= (eps_eff + 0.25) * max(tl, 1e-9)
            assert abs(ls - tl) <= eps_eff * max(tl, 1e-9)
    finally:
        eng.close()


def test_ingest_bumps_version_and_invalidates_cache():
    eng = _engine()
    try:
        y = _signal(4)
        eng.ingest_band("st", y[:24])
        v1 = eng.signal("st").version
        eng.get_coreset("st", 4, 0.3)
        assert len(eng.cache) == 1
        eng.ingest_band("st", y[24:48])
        assert eng.signal("st").version != v1
        assert len(eng.cache) == 0  # stale version freed eagerly
        _, _, how = eng.get_coreset("st", 4, 0.3)
        assert how == "built"
    finally:
        eng.close()


# --------------------------------------------------------- concurrent clients
def test_concurrent_clients_identical_answers_and_coalesced_builds():
    eng = _engine(workers=4)
    try:
        y = _signal(5)
        eng.register_signal("s", y)
        q = random_tree_segmentation(N, M, 5, np.random.default_rng(1))
        results, errors = [], []
        barrier = threading.Barrier(6)

        def client():
            try:
                barrier.wait()
                for _ in range(3):
                    r = eng.tree_loss("s", q.rects, q.labels, eps=0.25, k=KMAX)
                    results.append(r["loss"])
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=client) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert len(set(results)) == 1  # deterministic: one coreset served all
        # identical concurrent keys collapsed to a single actual construction
        # (coreset_builds counts real builds; the scheduler may complete more
        # jobs when a late submitter's worker short-circuits on the cache)
        assert eng.metrics.get("coreset_builds") == 1
    finally:
        eng.close()


def test_scheduler_coalesces_identical_keys():
    sched = BuildScheduler(max_workers=2, batch_window=0.02)
    try:
        gate = threading.Event()
        calls = []

        def slow():
            gate.wait(5.0)
            calls.append(1)
            return "done"

        f1, created1 = sched.submit(("k",), slow)
        f2, created2 = sched.submit(("k",), slow)
        assert created1 and not created2 and f1 is f2
        gate.set()
        assert f1.result(timeout=10.0) == "done"
        assert calls == [1]
        # after completion the key is free again
        f3, created3 = sched.submit(("k",), lambda: "again")
        assert created3 and f3.result(timeout=10.0) == "again"
    finally:
        sched.shutdown()


# ------------------------------------------------------------------- HTTP API
def test_http_api_end_to_end():
    eng = _engine()
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"

    def post(path, payload):
        req = urllib.request.Request(base + path, data=json.dumps(payload).encode(),
                                     headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=60) as r:
            return json.loads(r.read())

    def get(path):
        with urllib.request.urlopen(base + path, timeout=30) as r:
            return r.read()

    try:
        y = _signal(6)
        post("/signals", {"name": "s", "values": y.tolist()})
        b = post("/build", {"name": "s", "k": KMAX, "eps": 0.2})
        assert b["cache"] == "built" and b["size"] > 0 and len(b["fingerprint"]) == 32
        q = random_tree_segmentation(N, M, 4, np.random.default_rng(2))
        r = post("/query/loss", {"name": "s", "rects": q.rects.tolist(),
                                 "labels": q.labels.tolist(), "eps": 0.3})
        assert r["cache"] in ("exact", "dominated")
        tl = true_loss(y, q.rects, q.labels)
        assert abs(r["loss"] - tl) <= 0.3 * max(tl, 1e-9)
        fit = post("/query/fit", {"name": "s", "k": KMAX, "n_estimators": 2,
                                  "predict": [[1, 1], [N - 2, M - 2]]})
        assert len(fit["predictions"]) == 2
        comp = post("/query/compress", {"name": "s", "k": KMAX, "eps": 0.2,
                                        "max_points": 64})
        assert len(comp["points"]["X"]) <= 64 and comp["cache"] == "exact"
        post("/ingest", {"name": "st", "synthetic":
                         {"kind": "piecewise", "n": 16, "m": M, "seed": 1}})
        health = json.loads(get("/healthz"))
        assert health["status"] == "ok" and health["signals"] == 2
        metrics = get("/metrics").decode()
        assert "coreset_cache_hit_dominated" in metrics
        assert "coreset_build_seconds_bucket" in metrics
        # malformed request -> 400, server stays up
        try:
            post("/query/loss", {"name": "nope", "rects": [], "labels": []})
            raise AssertionError("expected HTTP error")
        except urllib.error.HTTPError as exc:
            assert exc.code == 400
        assert json.loads(get("/healthz"))["status"] == "ok"
    finally:
        srv.shutdown()
        eng.close()


# ------------------------------------------------- satellite: fingerprint API
def test_fingerprint_stable_and_repr_informative():
    y = _signal(7)
    a = signal_coreset(y, 4, 0.3)
    b = signal_coreset(y, 4, 0.3)
    c = signal_coreset(y, 4, 0.2)
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != c.fingerprint()
    assert a.nbytes == a.rects.nbytes + a.labels.nbytes + a.weights.nbytes + a.moments.nbytes
    r = repr(a)
    assert f"k={a.k}" in r and "eps=0.3" in r and f"size={a.size}" in r
    assert a.fingerprint()[:10] in r
