"""Admission control & multi-tenant QoS: on-arrival 503 vs at-deadline 504
taxonomy, Retry-After monotonicity, weighted fair shares, bitwise parity of
admitted work, and the cluster coordinator's admit-before-scatter rule."""
import threading
from types import SimpleNamespace

import numpy as np
import pytest

from repro import obs
from repro.client import AdmissionRejectedError, CoresetAPIError, CoresetClient
from repro.cluster import ClusterEngine, ShardWorker, make_worker_server
from repro.core import random_tree_segmentation
from repro.data import piecewise_signal
from repro.service import (AdmissionConfig, AdmissionController,
                           AdmissionRejected, CoresetEngine, ServiceMetrics,
                           make_server, serve_forever_in_thread)

N, M, KMAX = 72, 48, 8


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def tick(self, dt: float) -> None:
        self.t += dt


def _signal(seed=0):
    return piecewise_signal(N, M, KMAX, noise=0.15, seed=seed)


def _engine(admission=None, **kw):
    kw.setdefault("workers", 2)
    kw.setdefault("metrics", ServiceMetrics())
    return CoresetEngine(admission=admission, **kw)


def _server(admission=None):
    eng = _engine(admission=admission)
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    return eng, srv, f"http://127.0.0.1:{srv.server_address[1]}"


# ----------------------------------------------------------- unit: controller
def test_token_bucket_enforces_weighted_rate_shares():
    clk = FakeClock()
    ctl = AdmissionController(AdmissionConfig(
        tenants={"hot": 3.0, "cold": 1.0}, rate_rps=40.0, burst_s=0.05),
        clock=clk)
    admitted = {"hot": 0, "cold": 0}
    for _ in range(4000):           # 4s of saturating demand from both
        clk.tick(0.001)
        for tenant in ("hot", "cold"):
            try:
                ctl.admit("loss_query", tenant, signal="s").done()
                admitted[tenant] += 1
            except AdmissionRejected:
                pass
    # shares 3/4 and 1/4 of 40 rps over 4s -> ~120 and ~40
    assert admitted["hot"] == pytest.approx(120, rel=0.2)
    assert admitted["cold"] == pytest.approx(40, rel=0.2)


def test_fair_share_property_random_mixes():
    """Admitted throughput tracks configured weights within 20% for random
    tenant mixes under uniformly saturating demand."""
    rng = np.random.default_rng(42)
    for trial in range(4):
        n_tenants = int(rng.integers(2, 5))
        weights = {f"t{i}": float(rng.integers(1, 6))
                   for i in range(n_tenants)}
        clk = FakeClock()
        ctl = AdmissionController(AdmissionConfig(
            tenants=weights, rate_rps=100.0, burst_s=0.02), clock=clk)
        admitted = dict.fromkeys(weights, 0)
        for _ in range(3000):      # 3 simulated seconds, everyone saturates
            clk.tick(0.001)
            for tenant in weights:
                try:
                    ctl.admit("loss_query", tenant, signal="s").done()
                    admitted[tenant] += 1
                except AdmissionRejected:
                    pass
        wsum = sum(weights.values())
        total = 100.0 * 3.0
        for tenant, w in weights.items():
            expect = total * w / wsum
            assert admitted[tenant] == pytest.approx(expect, rel=0.2), \
                f"trial {trial}: {tenant} w={w} got {admitted[tenant]} " \
                f"want ~{expect}"


def test_rejections_do_not_consume_tokens():
    clk = FakeClock()
    ctl = AdmissionController(AdmissionConfig(rate_rps=10.0, burst_s=0.1),
                              clock=clk)
    ctl.admit("build", "a", signal="s").done()     # drains the 1-token bucket
    for _ in range(50):                            # hammering while empty...
        with pytest.raises(AdmissionRejected):
            ctl.admit("build", "a", signal="s")
    clk.tick(0.11)                                 # ...must not delay refill
    ctl.admit("build", "a", signal="s").done()


def test_deadline_guard_uses_ewma_and_depth():
    clk = FakeClock()
    ctl = AdmissionController(AdmissionConfig(parallelism=1), clock=clk)
    t = ctl.admit("build", None, signal="s")
    clk.tick(0.5)
    t.done()                                       # class EWMA = 500ms
    # budget far below the predicted 500ms -> refused on arrival
    with pytest.raises(AdmissionRejected) as ei:
        ctl.admit("build", None, signal="s", deadline_ms=50.0)
    assert ei.value.reason == "deadline_unmeetable"
    # a generous budget sails through
    ctl.admit("build", None, signal="s", deadline_ms=5000.0).done()
    # other classes are unaffected by this class's EWMA
    ctl.admit("loss_query", None, signal="s", deadline_ms=50.0).done()


def test_retry_after_monotonic_in_queue_depth():
    clk = FakeClock()
    ctl = AdmissionController(AdmissionConfig(parallelism=2), clock=clk)
    t = ctl.admit("build", None, signal="s")
    clk.tick(0.1)
    t.done()                                       # EWMA = 100ms
    hints, held = [], []
    for depth in range(1, 8):                      # grow the admitted backlog
        held.append(ctl.admit("build", None, signal="s"))
        with pytest.raises(AdmissionRejected) as ei:
            ctl.admit("build", None, signal="s", deadline_ms=1.0)
        assert ei.value.reason == "deadline_unmeetable"
        hints.append(ei.value.retry_after)
    assert hints == sorted(hints), f"Retry-After not monotonic: {hints}"
    assert hints[-1] > hints[0]
    for t in held:
        t.done()


def test_inflight_cap_is_weighted_and_releases():
    clk = FakeClock()
    ctl = AdmissionController(AdmissionConfig(
        tenants={"big": 3.0, "small": 1.0}, max_inflight=4), clock=clk)
    big = [ctl.admit("build", "big", signal="s") for _ in range(3)]
    with pytest.raises(AdmissionRejected) as ei:
        ctl.admit("build", "big", signal="s")
    assert ei.value.reason == "tenant_inflight"
    # small's slice (1 of 4) is untouched by big's saturation
    small = ctl.admit("build", "small", signal="s")
    with pytest.raises(AdmissionRejected):
        ctl.admit("build", "small", signal="s")
    big[0].done()
    ctl.admit("build", "big", signal="s").done()   # slot freed
    for t in big[1:] + [small]:
        t.done()


def test_ticket_done_is_idempotent_and_snapshot_coherent():
    clk = FakeClock()
    ctl = AdmissionController(AdmissionConfig(), clock=clk)
    t = ctl.admit("build", "x", signal="s")
    t.done()
    t.done()
    snap = ctl.snapshot()
    assert snap["tenants"]["x"]["inflight"] == 0
    assert snap["tenants"]["x"]["admitted"] == 1
    assert snap["admitted_total"] == 1


# ------------------------------------------------------------- HTTP taxonomy
def test_http_503_on_arrival_vs_504_at_deadline():
    """One server, both failure modes: admitted work that misses its budget
    fails 504 deadline_exceeded; refused work fails 503 overloaded with a
    Retry-After hint, before touching the engine."""
    ctl = AdmissionController(AdmissionConfig(deadline_guard=False))
    eng, srv, base = _server(admission=ctl)
    try:
        cl = CoresetClient(base, retries=0)
        y = _signal(3)
        cl.register_signal("s", values=y)
        q = random_tree_segmentation(N, M, 5, np.random.default_rng(0))

        # 1) admitted + impossible budget -> 504, the AT-DEADLINE taxonomy
        with pytest.raises(CoresetAPIError) as ei:
            cl.query_loss("s", q.rects, q.labels, eps=0.3, deadline_ms=0.01)
        assert ei.value.http == 504
        assert ei.value.code == "deadline_exceeded"
        assert not isinstance(ei.value, AdmissionRejectedError)

        # 2) starve the rate bucket -> 503 overloaded ON ARRIVAL
        ctl.config.rate_rps = 1e-6       # ~1 token, then a 11-day refill
        cl.query_loss("s", q.rects, q.labels, eps=0.3)     # takes the token
        with pytest.raises(AdmissionRejectedError) as ei:
            cl.query_loss("s", q.rects, q.labels, eps=0.3)
        err = ei.value
        assert err.http == 503 and err.code == "overloaded"
        assert err.reason == "tenant_rate"
        assert err.retry_after is not None and err.retry_after > 0
        assert err.tenant == "default"
        # rejected on arrival: the engine never saw the request
        assert eng.metrics.get("http_503") == 1
        snap = eng.stats()["admission"]
        assert snap["rejected_total"] == 1
        assert snap["rejected_by_reason"] == {"tenant_rate": 1}
        # observability: the counter family carries reason + tenant labels
        assert ('admission_rejected_total{reason="tenant_rate",'
                'tenant="default"}') in eng.metrics.render()
    finally:
        srv.shutdown()
        eng.close()


def test_http_tenant_header_and_sdk_arg_reach_accounting():
    ctl = AdmissionController(AdmissionConfig())
    eng, srv, base = _server(admission=ctl)
    try:
        gold = CoresetClient(base, tenant="gold")
        gold.register_signal("s", values=_signal(4))
        anon = CoresetClient(base)
        anon.build("s", 4, 0.3)
        snap = ctl.snapshot()
        assert snap["tenants"]["gold"]["admitted"] >= 1
        assert snap["tenants"]["default"]["admitted"] >= 1
    finally:
        srv.shutdown()
        eng.close()


def test_sdk_retries_stretch_to_retry_after_then_surface_typed_error():
    ctl = AdmissionController(AdmissionConfig(rate_rps=1e-6, burst_s=1.0))
    eng, srv, base = _server(admission=ctl)
    try:
        # backoff_cap bounds the honored Retry-After: the 1e-6 rps rate
        # yields an honest ~1e6s hint that must NOT block the client
        cl = CoresetClient(base, retries=1, backoff=0.01, backoff_cap=0.05)
        cl.register_signal("s", values=_signal(5))    # consumes the token
        with pytest.raises(AdmissionRejectedError):
            cl.build("s", 4, 0.3)
        assert cl.last_retry_after is not None and cl.last_retry_after > 0
    finally:
        srv.shutdown()
        eng.close()


# ------------------------------------------------------------ bitwise parity
def test_admitted_work_bitwise_parity_with_no_admission_path():
    """Admission only gates entry: every admitted response is byte-for-byte
    the response an engine without admission produces."""
    ctl = AdmissionController(AdmissionConfig(
        tenants={"gold": 2.0}, rate_rps=10_000.0, max_inflight=64))
    eng_a, srv_a, base_a = _server(admission=ctl)
    eng_p, srv_p, base_p = _server(admission=None)
    try:
        y = _signal(9)
        ca = CoresetClient(base_a, tenant="gold")
        cp = CoresetClient(base_p)
        for cl in (ca, cp):
            cl.register_signal("s", values=y)
        ba = ca.build("s", KMAX, 0.2)
        bp = cp.build("s", KMAX, 0.2)
        assert ba.fingerprint == bp.fingerprint       # bitwise-equal build
        rng = np.random.default_rng(21)
        for _ in range(4):
            q = random_tree_segmentation(N, M, 6, rng)
            ra = ca.query_loss("s", q.rects, q.labels, eps=0.3)
            rp = cp.query_loss("s", q.rects, q.labels, eps=0.3)
            assert ra.loss == rp.loss                 # bitwise, not approx
            assert ra.fingerprint == rp.fingerprint
        assert ctl.snapshot()["rejected_total"] == 0
    finally:
        srv_a.shutdown()
        eng_a.close()
        srv_p.shutdown()
        eng_p.close()


# ------------------------------------------------- coordinator admit-first
def _start_worker(i: int):
    w = ShardWorker(worker_id=f"w{i}")
    srv = make_worker_server(w, port=0, tracer=obs.Tracer())
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return SimpleNamespace(worker=w, server=srv,
                           url=f"http://127.0.0.1:{srv.server_address[1]}")


def test_cluster_coordinator_admits_before_scatter():
    nodes = [_start_worker(i) for i in range(2)]
    ctl = AdmissionController(AdmissionConfig(rate_rps=1e-6, burst_s=1.0))
    coord = ClusterEngine([n.url for n in nodes], workers=2,
                          rpc_timeout=10.0, metrics=ServiceMetrics(),
                          admission=ctl)
    try:
        coord.register_signal("a", _signal(0))        # takes the only token
        scattered = coord.metrics.get("cluster_bands_scattered")
        assert scattered >= 1
        with pytest.raises(AdmissionRejected):
            coord.register_signal("b", _signal(1))
        # refused registration cost ZERO worker RPCs and no local state
        assert coord.metrics.get("cluster_bands_scattered") == scattered
        assert "b" not in [s["name"] for s in coord.list_signals()]
        snap = ctl.snapshot()
        assert snap["rejected_total"] == 1
        assert snap["tenants"]["default"]["admitted"] == 1
    finally:
        coord.close()
        for n in nodes:
            n.server.shutdown()
            n.server.server_close()
