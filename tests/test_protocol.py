"""v1 wire protocol: dataclass round-trips through JSON and binary frames."""
import numpy as np
import pytest

from repro.service import protocol as P

ENCODINGS = ("json", "binary")


def _ref(name="sig"):
    return P.SignalRef(name=name, version="abc123")


def _spec(k=4, eps=0.25):
    return P.CoresetSpec(k=k, eps=eps)


def _messages():
    rng = np.random.default_rng(0)
    rects1 = rng.integers(0, 16, size=(3, 4)).astype(np.int64)
    rects3 = rng.integers(0, 16, size=(5, 3, 4)).astype(np.int64)
    # NaN/inf labels MUST survive both encodings: real query labels are
    # finite, but the protocol layer may not silently corrupt payloads
    labels_nan = np.array([1.0, np.nan, -np.inf])
    return [
        _spec(),
        _ref(),
        P.RegisterRequest(signal=_ref(), values=rng.normal(size=(6, 5)),
                          replace=True),
        P.RegisterRequest(signal=_ref(), synthetic={"kind": "piecewise",
                                                    "n": 8, "m": 8}),
        P.IngestRequest(signal=_ref(), band=rng.normal(size=(2, 5))),
        P.IngestDeltaRequest(signal=_ref(), band=rng.normal(size=(2, 5)),
                             row0=16),
        P.IngestDeltaRequest(signal=_ref(),
                             band=rng.normal(size=(1, 5))),   # append form
        P.IngestDeltaResponse(name="s", n=18, m=5, bands=3, streamed=True,
                              version="deadbeef", mode="replace", row0=16,
                              rows=2, buckets_recompressed=3,
                              entries_recached=1),
        P.BuildRequest(signal=_ref(), spec=_spec()),
        P.LossQuery(signal=_ref(), rects=rects1, labels=labels_nan,
                    spec=_spec()),
        P.LossQuery(signal=_ref(), rects=rects1,
                    labels=np.array([1.0, 2.0, 3.0])),   # spec omitted
        P.BatchLossQuery(signal=_ref(), rects=rects3,
                         labels=rng.normal(size=(5, 3)), spec=_spec()),
        P.FitRequest(signal=_ref(), spec=_spec(), n_estimators=3,
                     max_leaves=7, predict=rng.normal(size=(2, 2)), seed=9),
        P.CompressRequest(signal=_ref(), spec=_spec(), target_frac=0.05,
                          style="caratheodory", max_points=128),
        P.SignalInfo(name="s", n=8, m=5, bands=2, streamed=True,
                     version="deadbeef", builders=[[4, 0.25]]),
        P.BuildResponse(fingerprint="f" * 32, eps_eff=0.2,
                        served_from="built", size=16, blocks=4, nbytes=352,
                        compression_ratio=0.1, certified=True,
                        build_seconds=0.5),
        P.LossResponse(loss=float("inf"), k=3, eps=0.2, eps_eff=0.2,
                       served_from="exact", fingerprint="f" * 32,
                       coreset_size=16),
        P.BatchLossResponse(losses=np.array([1.0, np.nan, 3.0]), k=3,
                            eps=0.2, eps_eff=0.25, served_from="dominated",
                            fingerprint="f" * 32, coreset_size=16,
                            scoring_calls=1),
        P.FitResponse(k=3, eps=0.2, eps_eff=0.2, served_from="exact",
                      fingerprint="f" * 32, train_size=16, n_estimators=3,
                      model_cache="hit", predictions=np.array([0.5, -1.0])),
        P.CompressResponse(k=3, eps_eff=0.2, served_from="built",
                           fingerprint="f" * 32, size=16, blocks=4,
                           nbytes=352, compression_ratio=0.1, truncated=False,
                           X=rng.normal(size=(4, 2)),
                           y=np.array([1.0, np.nan, 3.0, np.inf]),
                           w=rng.random(4)),
        P.ErrorResponse(error=P.ErrorInfo(code="bad_request", message="boom")),
    ]


@pytest.mark.parametrize("encoding", ENCODINGS)
@pytest.mark.parametrize("msg", _messages(), ids=lambda m: type(m).__name__)
def test_round_trip_every_message(msg, encoding):
    ctype, body = msg.to_wire(encoding)
    expected = (P.CONTENT_TYPE_JSON if encoding == "json"
                else P.CONTENT_TYPE_BINARY)
    assert ctype == expected
    out = P.decode(ctype, body)
    assert type(out) is type(msg)
    assert out == msg   # NaN-tolerant field-wise equality (_Wire.__eq__)


@pytest.mark.parametrize("encoding", ENCODINGS)
def test_round_trip_preserves_array_dtype_and_shape(encoding):
    msg = P.BatchLossQuery(signal=_ref(),
                           rects=np.arange(24, dtype=np.int64).reshape(2, 3, 4),
                           labels=np.zeros((2, 3)))
    ctype, body = msg.to_wire(encoding)
    out = P.decode(ctype, body, expect=P.BatchLossQuery)
    assert out.rects.shape == (2, 3, 4) and out.rects.dtype == np.int64
    assert out.labels.shape == (2, 3) and out.labels.dtype == np.float64


def test_binary_widens_extension_dtypes_losslessly():
    ml_dtypes = pytest.importorskip("ml_dtypes")
    a = (np.arange(12).reshape(3, 4) / 4).astype(ml_dtypes.bfloat16)
    assert a.dtype.kind == "V"   # the npz-hostile extension dtype
    msg = P.RegisterRequest(signal=_ref(), values=a)
    ctype, body = msg.to_wire("binary")
    out = P.decode(ctype, body, expect=P.RegisterRequest)
    # stored widened: float32 is exact for every bfloat16 value
    assert out.values.dtype == np.float32
    assert np.array_equal(out.values, a.astype(np.float32))


def test_json_and_binary_decode_agree():
    msg = P.LossQuery(signal=_ref(), rects=np.zeros((2, 4), np.int64),
                      labels=np.array([np.nan, 2.0]), spec=_spec())
    a = P.decode(*msg.to_wire("json"))
    b = P.decode(*msg.to_wire("binary"))
    assert a == b == msg


def test_decode_rejects_malformed_input():
    with pytest.raises(P.ProtocolError):
        P.decode(P.CONTENT_TYPE_JSON, b"not json")
    with pytest.raises(P.ProtocolError):
        P.decode(P.CONTENT_TYPE_JSON, b"[1, 2]")          # not an object
    with pytest.raises(P.ProtocolError):
        P.decode(P.CONTENT_TYPE_JSON, b'{"type": "zzz"}')  # unknown tag
    with pytest.raises(P.ProtocolError):
        P.decode(P.CONTENT_TYPE_BINARY, b"XXXX\x00garbage")  # bad magic
    with pytest.raises(P.ProtocolError):
        P.decode(P.CONTENT_TYPE_BINARY, b"RPV1qjunk")      # unknown codec
    with pytest.raises(P.ProtocolError):
        P.decode("application/xml", b"<x/>")               # unknown media type
    # expect pin: a valid message of the WRONG type is rejected
    spec_wire = _spec().to_wire("json")
    with pytest.raises(P.ProtocolError):
        P.decode(*spec_wire, expect=P.LossQuery)


def test_decompression_size_is_bounded(monkeypatch):
    # a zlib/zstd bomb must die with a ProtocolError before the allocation,
    # not in the OOM killer: shrink the ceiling and feed a legit oversized
    # frame through the decoder
    msg = P.RegisterRequest(signal=_ref(),
                            values=np.zeros((64, 64)))   # compresses well
    ctype, body = msg.to_wire("binary")
    monkeypatch.setattr(P, "_MAX_DECODED", 1024)
    with pytest.raises(P.ProtocolError):
        P.decode(ctype, body)


def test_zstd_frame_without_zstandard_is_unsupported_codec():
    if P.zstandard is not None:
        pytest.skip("zstandard installed: the zlib-only path is unreachable")
    frame = b"RPV1" + b"Z" + b"\x28\xb5\x2f\xfd" + b"\x00" * 8
    with pytest.raises(P.UnsupportedCodec):
        P.decode(P.CONTENT_TYPE_BINARY, frame)
    # UnsupportedCodec is a ProtocolError, but the server maps it to 415
    # (renegotiate) rather than 400 (bad request)
    assert issubclass(P.UnsupportedCodec, P.ProtocolError)


def test_field_validation():
    with pytest.raises(P.ProtocolError):
        P.CoresetSpec(k=0)
    with pytest.raises(P.ProtocolError):
        P.CoresetSpec(k=2, eps=1.5)
    with pytest.raises(P.ProtocolError):
        P.CoresetSpec(k=2, fidelity="wat")
    with pytest.raises(P.ProtocolError):
        P.SignalRef(name="")
    # ragged arrays coerce to object arrays and are rejected, not 500s
    with pytest.raises(P.ProtocolError):
        P.LossQuery.from_payload({"signal": {"name": "s"},
                                  "rects": [[0, 1], [0, 1, 2, 3]],
                                  "labels": [1.0]})
    with pytest.raises(P.ProtocolError):
        P.LossQuery.from_payload({"signal": {"name": "s"},
                                  "rects": [["a", "b", "c", "d"]],
                                  "labels": [1.0]})
    # missing required field
    with pytest.raises(P.ProtocolError):
        P.LossQuery.from_payload({"signal": {"name": "s"}, "labels": [1.0]})


def test_unknown_payload_keys_are_ignored_for_forward_compat():
    d = {"signal": {"name": "s"}, "rects": [[0, 1, 0, 1]], "labels": [1.0],
         "some_future_field": 42}
    msg = P.LossQuery.from_payload(d)
    assert msg.signal.name == "s"
    # unknown keys inside NESTED messages must also be ignored (a v1.1 peer
    # adding an optional SignalRef/ErrorInfo field cannot break v1.0)
    d = {"signal": {"name": "s", "future_ref_field": 1},
         "rects": [[0, 1, 0, 1]], "labels": [1.0]}
    assert P.LossQuery.from_payload(d).signal.name == "s"
    env = P.ErrorResponse.from_payload(
        {"error": {"code": "bad_request", "message": "m", "future": True}})
    assert env.error.code == "bad_request"


def test_binary_codec_negotiation():
    # Accept parsing: zstd only when explicitly advertised
    assert P._Wire.accept_codec("application/x-repro-npz-v1") == "zlib"
    assert P._Wire.accept_codec(
        "application/x-repro-npz-v1;codec=zstd") == "zstd"
    assert P._Wire.accept_codec(
        "application/x-repro-npz-v1; codec=zstd") == "zstd"
    assert P._Wire.accept_codec(
        "application/x-repro-npz-v1;codec=zlib") == "zlib"
    # a pinned zlib frame is always stdlib-decodable
    msg = _spec()
    ctype, body = msg.to_wire("binary", binary_codec="zlib")
    assert body[4:5] == b"z"
    assert P.decode(ctype, body) == msg
    if P.zstandard is None:
        # asking for zstd on a zlib-only host is UnsupportedCodec (-> 415)
        with pytest.raises(P.UnsupportedCodec):
            msg.to_wire("binary", binary_codec="zstd")
