"""End-to-end behaviour tests for the paper's system.

The headline contract, exercised through the public API exactly as a user
would: compress once -> train/tune/evaluate many times -> results match the
full data within eps.
"""
import numpy as np

from repro.core import (fitting_loss, random_tree_segmentation, signal_coreset,
                        signal_coreset_to_size, true_loss)
from repro.data import patch_mask, piecewise_signal, sensor_matrix
from repro.trees import RandomForestRegressor, tune_k


def test_end_to_end_compress_train_evaluate():
    """quickstart flow: coreset -> Algorithm-5 queries -> forest training."""
    y = piecewise_signal(150, 200, k=15, noise=0.15, seed=0)
    cs = signal_coreset(y, k=15, eps=0.4)
    assert cs.compression_ratio() < 0.15

    rng = np.random.default_rng(0)
    for _ in range(5):
        q = random_tree_segmentation(150, 200, 15, rng)
        tl = true_loss(y, q.rects, q.labels)
        assert abs(fitting_loss(cs, q.rects, q.labels) - tl) <= 0.4 * tl

    Xc, yc, wc = cs.as_points()
    f = RandomForestRegressor(n_estimators=3, max_leaves=32).fit(
        Xc, yc, sample_weight=wc)
    # forest trained on the summary predicts the signal
    from repro.trees import signal_to_points
    Xf, yf = signal_to_points(y)
    mse = float(((f.predict(Xf) - yf) ** 2).mean())
    assert mse < float(np.var(yf)) * 0.5


def test_end_to_end_automl_pipeline():
    """§5 flow: missing-value protocol + tune k on the compression."""
    y = sensor_matrix(800, 15, seed=1)
    train, test = patch_mask(*y.shape, 0.3, 5, seed=2)
    res = tune_k(y, train, test, ks=[8, 64], coreset_k=32, target_frac=0.05,
                 n_estimators=3)
    # curves ordered the same way on full data and on the coreset
    full = res.losses["full"]
    core = res.losses["coreset"]
    assert (full[0] > full[1]) == (core[0] > core[1])


def test_size_targeting():
    y = piecewise_signal(200, 200, k=20, noise=0.2, seed=3)
    cs = signal_coreset_to_size(y, 20, 0.02)
    assert cs.compression_ratio() <= 0.02 * 1.05
