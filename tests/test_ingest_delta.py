"""Incremental ingest: delta-patched state must be indistinguishable from a
from-scratch rebuild — bitwise for the f64 integral images, loss-identical
for the merge-reduce coresets, and end-to-end through /v1/ingest:delta."""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.client import CoresetAPIError, CoresetClient
from repro.core import (PrefixStats, StreamingBuilder, fitting_loss,
                        random_tree_segmentation)
from repro.data import piecewise_signal
from repro.service import (CoresetEngine, ServiceMetrics, make_server,
                           serve_forever_in_thread)


def _bitwise_equal(a: PrefixStats, b: PrefixStats) -> bool:
    return (np.array_equal(a.p0, b.p0) and np.array_equal(a.p1, b.p1)
            and np.array_equal(a.p2, b.p2))


# ---------------------------------------------------- prefix-stats patching
def test_random_append_replace_sequence_bitwise_equals_rebuild():
    """Property-style: any interleaving of band appends and in-range row
    replacements through the delta path produces integral images bitwise
    equal to PrefixStats.build of the final dense signal."""
    rng = np.random.default_rng(0)
    m = 37                                       # off the 128-lane quantum
    for trial in range(8):
        first = rng.integers(1, 9)
        y = rng.normal(size=(first, m))
        ps = PrefixStats.build(y)
        for _ in range(rng.integers(3, 9)):
            if y.shape[0] >= 2 and rng.random() < 0.5:
                r0 = int(rng.integers(0, y.shape[0]))
                rows = int(rng.integers(1, y.shape[0] - r0 + 1))
                y[r0:r0 + rows] = rng.normal(size=(rows, m))
                ps = ps.patch_rows(r0, y[r0:])
            else:
                band = rng.normal(size=(int(rng.integers(1, 7)), m))
                y = np.vstack([y, band])
                ps = ps.append_rows(band)
        assert _bitwise_equal(ps, PrefixStats.build(y)), f"trial {trial}"


@pytest.mark.parametrize("r0,rows", [(0, 3), (9, 1), (11, 1), (0, 12), (4, 8)])
def test_patch_rows_awkward_placements_bitwise(r0, rows):
    """1-row bands, a band at row 0, a band ending at the last row, and the
    whole signal at once — every placement is a bitwise-exact patch."""
    rng = np.random.default_rng(1)
    y = rng.normal(size=(12, 129))               # m % 128 != 0
    ps = PrefixStats.build(y)
    y[r0:r0 + rows] = rng.normal(size=(rows, 129))
    got = ps.patch_rows(r0, y[r0:])
    assert _bitwise_equal(got, PrefixStats.build(y))


def test_patch_rows_copy_leaves_previous_arrays_untouched():
    rng = np.random.default_rng(2)
    y = rng.normal(size=(10, 8))
    ps = PrefixStats.build(y)
    before = ps.p1.copy()
    y2 = y.copy()
    y2[3:6] = 0.0
    ps2 = ps.patch_rows(3, y2[3:], copy=True)
    assert ps2 is not ps
    np.testing.assert_array_equal(ps.p1, before)     # reader-held arrays safe
    assert _bitwise_equal(ps2, PrefixStats.build(y2))


def test_patch_rows_validates_inputs():
    ps = PrefixStats.build(np.zeros((4, 5)))
    with pytest.raises(ValueError):
        ps.patch_rows(0, np.zeros((2, 7)))           # column mismatch
    with pytest.raises(ValueError):
        ps.patch_rows(5, np.zeros((1, 5)))           # offset beyond n


# ----------------------------------------------- streaming builder equivalence
def test_streaming_replace_sequence_equivalent_to_rebuild():
    """A random sequence of inserts and band replacements must yield a
    coreset whose Algorithm-5 losses match a from-scratch StreamingBuilder
    fed the final bands — within 1e-12 on the f64 oracle path (the flush
    replays the exact cascade, so fingerprints match too)."""
    rng = np.random.default_rng(3)
    m = 33
    sizes = [7, 1, 16, 9, 1, 14]                     # awkward: 1-row bands
    bands = [rng.normal(size=(s, m)) for s in sizes]
    sb = StreamingBuilder(m=m, k=4, eps=0.3)
    for b in bands:
        sb.insert_band(b)
    for idx in (0, 3, 5, 3):                          # first/last/repeat
        bands[idx] = rng.normal(size=bands[idx].shape)
        sb.replace_band(idx, bands[idx])
    cs = sb.result()

    fresh = StreamingBuilder(m=m, k=4, eps=0.3)
    for b in bands:
        fresh.insert_band(b)
    want = fresh.result()
    assert cs.fingerprint() == want.fingerprint()
    n = sum(sizes)
    for _ in range(4):
        q = random_tree_segmentation(n, m, 4, rng)
        a = fitting_loss(cs, q.rects, q.labels)
        b = fitting_loss(want, q.rects, q.labels)
        assert abs(a - b) <= 1e-12 * max(abs(b), 1.0)


def test_streaming_insert_after_replace_flushes_first():
    """Regression: an insert whose cascade would merge a dirty bucket must
    settle the pending replacement first — otherwise the stale leaf gets
    baked into a clean higher-level bucket that no flush can repair."""
    rng = np.random.default_rng(12)
    m = 20
    bands = [rng.normal(size=(8, m)) for _ in range(2)]
    sb = StreamingBuilder(m=m, k=3, eps=0.3)
    for b in bands:
        sb.insert_band(b)
    bands[0] = rng.normal(size=(8, m))
    sb.replace_band(0, bands[0])          # level-1 bucket goes dirty
    bands += [rng.normal(size=(8, m)) for _ in range(2)]
    sb.insert_band(bands[2])
    sb.insert_band(bands[3])              # cascade absorbs the dirty bucket
    cs = sb.result()
    fresh = StreamingBuilder(m=m, k=3, eps=0.3)
    for b in bands:
        fresh.insert_band(b)
    assert cs.fingerprint() == fresh.result().fingerprint()


def test_streaming_replace_validates_and_counts_dirty():
    rng = np.random.default_rng(4)
    sb = StreamingBuilder(m=10, k=3, eps=0.3)
    for _ in range(4):
        sb.insert_band(rng.normal(size=(8, 10)))
    with pytest.raises(ValueError):
        sb.replace_band(1, rng.normal(size=(9, 10)))  # wrong row count
    assert sb.dirty_buckets == 0
    sb.replace_band(1, rng.normal(size=(8, 10)))
    assert sb.dirty_buckets == 1                      # one bucket, not all
    flushed = sb.flush_dirty()
    assert flushed >= 1 and sb.dirty_buckets == 0
    assert sb.flush_dirty() == 0                      # idempotent
    assert sb.buckets_recompressed_total == flushed


# ------------------------------------------------------- engine + HTTP layer
N, M = 80, 40


def _server():
    eng = CoresetEngine(workers=2, metrics=ServiceMetrics())
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    return eng, srv, f"http://127.0.0.1:{srv.server_address[1]}"


def test_ingest_delta_streamed_recaches_and_matches_scratch():
    eng, srv, base = _server()
    try:
        rng = np.random.default_rng(5)
        y = piecewise_signal(N, M, 5, noise=0.15, seed=5)
        cl = CoresetClient(base)
        for i in range(0, N, 16):
            cl.ingest("st", y[i:i + 16])
        cl.build("st", 5, 0.3)
        y2 = y.copy()
        y2[16:32] = rng.normal(size=(16, M))
        r = cl.ingest_delta("st", y2[16:32], row0=16)
        assert r.mode == "replace" and r.rows == 16
        assert r.entries_recached == 1                # old entry re-cached
        assert r.buckets_recompressed >= 1
        # the re-cached entry serves the new version without a rebuild
        b = cl.build("st", 5, 0.3)
        assert b.served_from == "exact"

        fresh = CoresetEngine(workers=1, metrics=ServiceMetrics())
        try:
            for i in range(0, N, 16):
                fresh.ingest_band("scratch", y2[i:i + 16])
            want, _, _ = fresh.get_coreset("scratch", 5, 0.3)
            got, _, how = eng.get_coreset("st", 5, 0.3)
            assert how == "exact"
            assert got.fingerprint() == want.fingerprint()
            q = random_tree_segmentation(N, M, 5, rng)
            lg = fitting_loss(got, q.rects, q.labels)
            lw = fitting_loss(want, q.rects, q.labels)
            assert abs(lg - lw) <= 1e-9 * max(abs(lw), 1.0)
        finally:
            fresh.close()
    finally:
        srv.shutdown()
        eng.close()


def test_ingest_delta_dense_patch_matches_scratch_build():
    """Replacing an arbitrary row window of a registered (dense) signal
    patches the integral images via delta_sat; the next build must equal a
    from-scratch engine's build of the final signal bit for bit."""
    eng = CoresetEngine(workers=1, metrics=ServiceMetrics())
    fresh = CoresetEngine(workers=1, metrics=ServiceMetrics())
    try:
        rng = np.random.default_rng(6)
        y = piecewise_signal(N, M, 5, noise=0.15, seed=6)
        eng.register_signal("d", y)
        eng.get_coreset("d", 5, 0.3)
        assert eng.signal("d").stats is None          # builds don't pin stats
        y2 = y.copy()
        y2[50:57] = rng.normal(size=(7, M))           # band-unaligned window
        r = eng.ingest_delta("d", y2[50:57], row0=50)
        assert r["mode"] == "replace" and not r["streamed"]
        got, _, _ = eng.get_coreset("d", 5, 0.3)
        st = eng.signal("d")
        assert _bitwise_equal(st.stats, PrefixStats.build(y2))
        fresh.register_signal("d", y2)
        want, _, _ = fresh.get_coreset("d", 5, 0.3)
        assert got.fingerprint() == want.fingerprint()
    finally:
        eng.close()
        fresh.close()


def test_ingest_delta_dense_recaches_through_scheduler():
    # dense specs re-run the partition, so they re-cache asynchronously via
    # the BuildScheduler — the entry must appear without any further query
    import time
    eng = CoresetEngine(workers=2, metrics=ServiceMetrics())
    try:
        y = piecewise_signal(N, M, 5, noise=0.15, seed=10)
        eng.register_signal("d", y)
        eng.get_coreset("d", 5, 0.3)
        r = eng.ingest_delta("d", np.zeros((8, M)), row0=40)
        assert r["mode"] == "replace" and r["entries_recached"] == 1
        version = eng.signal("d").version
        deadline = time.time() + 30.0
        while time.time() < deadline:
            entry, kind = eng.cache.lookup("d", version, 5, 0.3, record=False)
            if entry is not None:
                break
            time.sleep(0.05)
        assert kind == "exact"
        _, _, how = eng.get_coreset("d", 5, 0.3)
        assert how in ("exact", "coalesced")
    finally:
        eng.close()


def test_ingest_delta_append_equals_ingest():
    # the version is a content fold seeded by the name: the delta append and
    # the plain ingest of the same bytes must land on the same version
    eng = CoresetEngine(workers=1, metrics=ServiceMetrics())
    other = CoresetEngine(workers=1, metrics=ServiceMetrics())
    try:
        y = piecewise_signal(48, M, 4, noise=0.2, seed=7)
        eng.ingest_band("a", y[:24])
        r = eng.ingest_delta("a", y[24:])              # row0 omitted: append
        assert r["mode"] == "append" and r["n"] == 48 and r["row0"] == 24
        other.ingest_band("a", y[:24])
        other.ingest_band("a", y[24:])
        assert eng.signal("a").version == other.signal("a").version
    finally:
        eng.close()
        other.close()


def test_ingest_delta_counters_in_stats_and_prometheus():
    eng, srv, base = _server()
    try:
        y = piecewise_signal(64, M, 4, noise=0.2, seed=8)
        cl = CoresetClient(base)
        for i in range(0, 64, 16):
            cl.ingest("st", y[i:i + 16])
        cl.build("st", 4, 0.3)
        cl.ingest_delta("st", np.zeros((16, M)), row0=16)
        counters = cl.stats()["metrics"]["counters"]
        for key in ("ingest_delta_bands", "ingest_delta_replaces",
                    "ingest_delta_buckets_recompressed",
                    "ingest_delta_recached", "ingest_delta_rebuilds_avoided"):
            assert counters.get(key, 0) >= 1, key
        text = cl.metrics_text()
        assert "coreset_ingest_delta_bands" in text
        assert "coreset_ingest_delta_buckets_recompressed" in text
        assert "coreset_ingest_delta_seconds" in text   # latency histogram
        # the new ops are in the /v1/stats backend snapshot
        snap = cl.stats()["ops_backends"]
        assert "delta_sat" in snap and "streaming_compress" in snap
    finally:
        srv.shutdown()
        eng.close()


def test_ingest_delta_http_validation_envelopes():
    eng, srv, base = _server()

    def post_raw(path, payload):
        req = urllib.request.Request(
            base + path, data=json.dumps(payload).encode(),
            headers={"Content-Type": "application/json"})
        urllib.request.urlopen(req, timeout=60).close()

    try:
        cl = CoresetClient(base)
        y = piecewise_signal(32, 8, 3, noise=0.2, seed=9)
        cl.ingest("st", y[:16])
        cl.ingest("st", y[16:])
        # unknown signal: 404, not an implicit create
        with pytest.raises(CoresetAPIError) as exc:
            cl.ingest_delta("nope", np.zeros((2, 8)), row0=0)
        assert exc.value.http == 404 and exc.value.code == "not_found"
        # column mismatch / misaligned offset / row overflow: 400 envelope
        for band, row0 in ((np.zeros((16, 5)), 0),   # wrong column count
                           (np.zeros((16, 8)), 3),   # not a band start
                           (np.zeros((20, 8)), 16)):  # runs past the end
            with pytest.raises(CoresetAPIError) as exc:
                cl.ingest_delta("st", band, row0=row0)
            assert exc.value.http == 400 and exc.value.code == "bad_request"
        # ragged / non-numeric / non-finite straight through HTTP
        for bad in ([[1.0, 2.0], [3.0]], [["a", "b"]], [[1.0, float("nan")]]):
            with pytest.raises(urllib.error.HTTPError) as exc:
                post_raw("/v1/ingest:delta",
                         {"type": "ingest_delta", "signal": {"name": "st"},
                          "band": bad, "row0": 0})
            assert exc.value.code == 400
            env = json.loads(exc.value.read())
            assert env["error"]["code"] == "bad_request"
        # the legacy /ingest shim rejects a mismatched band with 400 too
        # (never a 500 from deep inside PrefixStats)
        with pytest.raises(urllib.error.HTTPError) as exc:
            post_raw("/ingest", {"name": "st", "band": [[1.0, 2.0]]})
        assert exc.value.code == 400
        assert json.loads(exc.value.read())["error"]["code"] == "bad_request"
        assert cl.healthz()["status"] == "ok"
    finally:
        srv.shutdown()
        eng.close()
