"""Weighted CART / forest / GBDT solvers."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.trees import (DecisionTreeRegressor, GradientBoostingRegressor,
                         RandomForestRegressor)


def test_cart_fits_axis_separable_data_exactly():
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(500, 2))
    y = np.where(X[:, 0] > 0.5, np.where(X[:, 1] > 0.3, 3.0, -1.0), 0.5)
    t = DecisionTreeRegressor(max_leaves=8).fit(X, y)
    assert np.abs(t.predict(X) - y).max() < 1e-9
    assert t.n_leaves <= 8


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_weighted_equals_duplicated(seed):
    """Integer sample weights == literal row duplication (CART invariance)."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(size=(60, 2))
    y = rng.normal(size=60)
    w = rng.integers(1, 4, size=60)
    from repro.trees import apply_bins, quantile_bins
    edges = quantile_bins(X, 64)          # shared binning (duplication would
    codes = apply_bins(X, edges)          # otherwise shift the quantiles)
    t_w = DecisionTreeRegressor(max_leaves=6, max_bins=64).fit(
        X, y, sample_weight=w.astype(float), bins=(edges, codes))
    Xd = np.repeat(X, w, axis=0)
    yd = np.repeat(y, w)
    t_d = DecisionTreeRegressor(max_leaves=6, max_bins=64).fit(
        Xd, yd, bins=(edges, np.repeat(codes, w, axis=0)))
    q = rng.uniform(size=(40, 2))
    assert np.allclose(t_w.predict(q), t_d.predict(q), atol=1e-9)


def test_max_leaves_budget_respected():
    rng = np.random.default_rng(1)
    X = rng.uniform(size=(400, 3))
    y = rng.normal(size=400)
    for k in (2, 5, 17):
        t = DecisionTreeRegressor(max_leaves=k).fit(X, y)
        assert t.n_leaves <= k


def test_leaf_rectangles_tile_the_domain():
    rng = np.random.default_rng(2)
    X = rng.uniform(0, 10, size=(300, 2))
    y = np.sin(X[:, 0]) + X[:, 1]
    t = DecisionTreeRegressor(max_leaves=9).fit(X, y)
    rects, vals = t.leaf_rectangles(np.zeros(2), np.full(2, 10.0))
    area = sum((r[2] - r[0]) * (r[3] - r[1]) for r in rects)
    assert np.isclose(area, 100.0)
    assert len(vals) == t.n_leaves


def test_forest_and_gbdt_reduce_loss():
    rng = np.random.default_rng(3)
    X = rng.uniform(size=(800, 2))
    y = np.sin(5 * X[:, 0]) * np.cos(3 * X[:, 1]) + 0.05 * rng.normal(size=800)
    base = ((y - y.mean()) ** 2).mean()
    f = RandomForestRegressor(n_estimators=8, max_leaves=32, random_state=0).fit(X, y)
    g = GradientBoostingRegressor(n_estimators=20, max_leaves=8).fit(X, y)
    assert ((f.predict(X) - y) ** 2).mean() < 0.3 * base
    assert ((g.predict(X) - y) ** 2).mean() < 0.3 * base


def test_histogram_jax_backend_matches_numpy():
    rng = np.random.default_rng(4)
    X = rng.uniform(size=(300, 2))
    y = np.where(X[:, 0] > 0.4, 1.0, -2.0) + 0.01 * rng.normal(size=300)
    t_np = DecisionTreeRegressor(max_leaves=5, max_bins=32).fit(X, y)
    t_jx = DecisionTreeRegressor(max_leaves=5, max_bins=32,
                                 hist_backend="jax").fit(X, y)
    q = rng.uniform(size=(50, 2))
    assert np.allclose(t_np.predict(q), t_jx.predict(q), atol=1e-4)
