"""Merge-reduce: compose / recompress / streaming / sharded construction."""
import numpy as np

from repro.core import (StreamingBuilder, fitting_loss, random_tree_segmentation,
                        recompress, sharded_coreset, signal_coreset, true_loss)
from repro.data import piecewise_signal


def _err(cs, y, seg):
    tl = true_loss(y, seg.rects, seg.labels)
    return abs(fitting_loss(cs, seg.rects, seg.labels) - tl) / max(tl, 1e-12)


def test_compose_equals_union_semantics():
    rng = np.random.default_rng(0)
    y = piecewise_signal(80, 60, 6, noise=0.15, seed=0)
    cs = sharded_coreset(y, 6, 0.3, num_bands=4)
    assert np.isclose(cs.total_mass(), y.size)
    for _ in range(6):
        q = random_tree_segmentation(80, 60, 6, rng)
        assert _err(cs, y, q) <= 0.3


def test_recompress_shrinks_and_keeps_guarantee():
    rng = np.random.default_rng(1)
    y = piecewise_signal(90, 70, 8, noise=0.2, seed=1)
    cs = sharded_coreset(y, 8, 0.3, num_bands=6, share_tolerance=False)
    rc = recompress(cs)
    assert rc.size <= cs.size
    assert np.isclose(rc.total_mass(), y.size)
    q = random_tree_segmentation(90, 70, 8, rng)
    assert _err(rc, y, q) <= 0.6   # two eps layers of merge-reduce


def test_streaming_builder_bounded_and_accurate():
    rng = np.random.default_rng(2)
    y = piecewise_signal(120, 50, 6, noise=0.15, seed=2)
    sb = StreamingBuilder(m=50, k=6, eps=0.3)
    for i in range(0, 120, 20):
        sb.insert_band(y[i:i + 20])
    cs = sb.result()
    assert np.isclose(cs.total_mass(), y.size)
    q = random_tree_segmentation(120, 50, 6, rng)
    assert _err(cs, y, q) <= 0.6


def test_shared_tolerance_matches_single_build_size():
    y = piecewise_signal(100, 80, 10, noise=0.2, seed=3)
    full = signal_coreset(y, 10, 0.3)
    sh = sharded_coreset(y, 10, 0.3, num_bands=4)   # share_tolerance=True
    assert sh.size <= 3 * full.size
