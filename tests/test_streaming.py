"""Merge-reduce: compose / recompress / streaming / sharded construction."""
import numpy as np

from repro.core import (StreamingBuilder, fitting_loss, random_tree_segmentation,
                        recompress, sharded_coreset, signal_coreset, true_loss)
from repro.data import piecewise_signal


def _err(cs, y, seg):
    tl = true_loss(y, seg.rects, seg.labels)
    return abs(fitting_loss(cs, seg.rects, seg.labels) - tl) / max(tl, 1e-12)


def test_compose_equals_union_semantics():
    rng = np.random.default_rng(0)
    y = piecewise_signal(80, 60, 6, noise=0.15, seed=0)
    cs = sharded_coreset(y, 6, 0.3, num_bands=4)
    assert np.isclose(cs.total_mass(), y.size)
    for _ in range(6):
        q = random_tree_segmentation(80, 60, 6, rng)
        assert _err(cs, y, q) <= 0.3


def test_recompress_shrinks_and_keeps_guarantee():
    rng = np.random.default_rng(1)
    y = piecewise_signal(90, 70, 8, noise=0.2, seed=1)
    cs = sharded_coreset(y, 8, 0.3, num_bands=6, share_tolerance=False)
    rc = recompress(cs)
    assert rc.size <= cs.size
    assert np.isclose(rc.total_mass(), y.size)
    q = random_tree_segmentation(90, 70, 8, rng)
    assert _err(rc, y, q) <= 0.6   # two eps layers of merge-reduce


def test_streaming_builder_bounded_and_accurate():
    rng = np.random.default_rng(2)
    y = piecewise_signal(120, 50, 6, noise=0.15, seed=2)
    sb = StreamingBuilder(m=50, k=6, eps=0.3)
    for i in range(0, 120, 20):
        sb.insert_band(y[i:i + 20])
    cs = sb.result()
    assert np.isclose(cs.total_mass(), y.size)
    q = random_tree_segmentation(120, 50, 6, rng)
    assert _err(cs, y, q) <= 0.6


def test_compose_is_order_invariant_under_row_offsets():
    """compose() is exact concatenation: feeding the per-band coresets in a
    shuffled order (with matching offsets) must give identical losses and
    identical (sorted) block geometry."""
    from repro.core import compose
    y = piecewise_signal(64, 40, 5, noise=0.15, seed=4)
    bounds = [(0, 16), (16, 40), (40, 64)]
    parts = [signal_coreset(y[a:b], 5, 0.3) for a, b in bounds]
    offs = [a for a, _ in bounds]
    cs_sorted = compose(parts, offs, n_total=64)
    order = [2, 0, 1]
    cs_shuf = compose([parts[i] for i in order], [offs[i] for i in order],
                      n_total=64)
    key = lambda cs: np.lexsort(cs.rects.T[::-1])  # noqa: E731
    np.testing.assert_array_equal(cs_sorted.rects[key(cs_sorted)],
                                  cs_shuf.rects[key(cs_shuf)])
    rng = np.random.default_rng(4)
    q = random_tree_segmentation(64, 40, 5, rng)
    assert np.isclose(fitting_loss(cs_sorted, q.rects, q.labels),
                      fitting_loss(cs_shuf, q.rects, q.labels))
    # offsets must keep every block inside the stacked domain
    for cs in (cs_sorted, cs_shuf):
        assert cs.rects[:, 0].min() == 0 and cs.rects[:, 1].max() == 64


def test_streaming_cascade_offsets_tile_the_domain():
    """Uneven bands force multi-level bucket cascades; without recompression
    the merged rects must tile [0,n) x [0,m) exactly (area and mass checks
    catch any mis-anchored row offset) and moments must match the signal."""
    n, m = 110, 30
    y = piecewise_signal(n, m, 5, noise=0.1, seed=5)
    sb = StreamingBuilder(m=m, k=5, eps=0.3, recompress_levels=False)
    sizes = [10, 30, 15, 25, 20, 10]   # 6 bands -> buckets at levels 1 and 2
    r = 0
    for s in sizes:
        sb.insert_band(y[r:r + s])
        r += s
    assert sb.rows_seen == n and sb.max_level >= 1
    cs = sb.result()
    areas = ((cs.rects[:, 1] - cs.rects[:, 0])
             * (cs.rects[:, 3] - cs.rects[:, 2]))
    assert int(areas.sum()) == n * m               # tiling: no gap/overlap
    assert np.isclose(cs.total_mass(), n * m)
    assert np.isclose(cs.moments[:, 0].sum(), n * m)
    assert np.isclose(cs.moments[:, 1].sum(), y.sum())
    assert np.isclose(cs.moments[:, 2].sum(), (y * y).sum())
    # per-row-band mass: every original band contributes exactly rows*m
    for (a, b) in [(0, 10), (40, 55), (90, 110)]:
        covered = ((np.minimum(cs.rects[:, 1], b) - np.maximum(cs.rects[:, 0], a)).clip(0)
                   * (cs.rects[:, 3] - cs.rects[:, 2]))
        assert int(covered.sum()) == (b - a) * m


def test_recompress_after_out_of_order_compose_keeps_moments():
    """recompress over a shuffled-compose union: the weighted re-raster must
    preserve global mass/M1 and stay within the two-layer eps bound."""
    from repro.core import compose
    rng = np.random.default_rng(6)
    y = piecewise_signal(96, 32, 6, noise=0.15, seed=6)
    bounds = [(48, 96), (0, 48)]                    # deliberately unsorted
    parts = [signal_coreset(y[a:b], 6, 0.3) for a, b in bounds]
    cs = compose(parts, [a for a, _ in bounds], n_total=96)
    rc = recompress(cs)
    assert np.isclose(rc.total_mass(), y.size)
    assert np.isclose(rc.moments[:, 1].sum(), cs.moments[:, 1].sum())
    q = random_tree_segmentation(96, 32, 6, rng)
    assert _err(rc, y, q) <= 0.6


def test_shared_tolerance_matches_single_build_size():
    y = piecewise_signal(100, 80, 10, noise=0.2, seed=3)
    full = signal_coreset(y, 10, 0.3)
    sh = sharded_coreset(y, 10, 0.3, num_bands=4)   # share_tolerance=True
    assert sh.size <= 3 * full.size
