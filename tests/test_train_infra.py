"""Training infra: optimizer, microbatching, compression, checkpoint/restart,
fault tolerance, elastic planning, sharding specs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.data.tokens import TokenStream
from repro.models import init_params
from repro.train import (AdamWConfig, adamw_apply, adamw_init,
                         compress_with_feedback, dequantize_int8, ef_init,
                         make_train_step, quantize_int8)


def test_adamw_decreases_quadratic():
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_apply(ocfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


@pytest.mark.slow   # multi-second training soak; `-m "not slow"` skips it
def test_microbatch_grads_equivalent():
    cfg = dataclasses.replace(reduced_config(ARCHS["qwen2-0.5b"]),
                              dtype="float32", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    stream = TokenStream(cfg.vocab, 4, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    outs = []
    for mb in (1, 2, 4):
        step = make_train_step(cfg, AdamWConfig(total_steps=10),
                               num_microbatches=mb)
        p, o, m = jax.jit(step)(params, opt, batch)
        outs.append(float(m["loss"]))
    assert np.allclose(outs[0], outs[1], rtol=1e-5)
    assert np.allclose(outs[0], outs[2], rtol=1e-5)


def test_int8_quantization_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)) * 5)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_steps():
    """EF: the accumulated transmitted signal converges to the true sum."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=64) * 0.1)}
    err = ef_init(g)
    sent = np.zeros(64)
    for t in range(50):
        quant, err = compress_with_feedback(g, err)
        q, s = quant["w"]
        sent += np.asarray(dequantize_int8(q, s))
    true = np.asarray(g["w"]) * 50
    assert np.abs(sent - true).max() <= float(np.abs(np.asarray(g["w"])).max()) * 1.5


def test_checkpoint_roundtrip_and_gc(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path, max_to_keep=2, async_save=False)
    state = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
             "nested": {"b": np.float32(7.0)}, "step": 3}
    for s in (1, 2, 3):
        mgr.save(s, state)
    assert mgr.all_steps() == [2, 3]
    assert mgr.latest_step() == 3
    out = mgr.restore(3, state)
    np.testing.assert_array_equal(out["a"], state["a"])
    assert float(out["nested"]["b"]) == 7.0


@pytest.mark.slow   # multi-second training soak; `-m "not slow"` skips it
def test_train_resume_is_deterministic(tmp_path):
    """Crash at step 7, resume, final params == uninterrupted run."""
    from repro.launch.train import train_loop
    cfg = dataclasses.replace(reduced_config(ARCHS["qwen2-0.5b"]),
                              dtype="float32", remat=False, n_layers=1,
                              d_model=64, vocab=128, n_heads=2, n_kv_heads=1,
                              d_ff=128)
    common = dict(steps=10, batch=2, seq_len=16, save_every=5, log_every=100)
    ref = train_loop(cfg, ckpt_dir=str(tmp_path / "ref"), **common)
    # crashy run: fails at step 7, supervision restores from step 5
    crashy = train_loop(cfg, ckpt_dir=str(tmp_path / "crash"), fail_at=7,
                        **common)
    # (fail_at fires once per python closure state; supervise replays 7..9)
    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(crashy["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)


def test_heartbeat_monitor_flags_failed_and_stragglers():
    from repro.runtime import HeartbeatMonitor
    mon = HeartbeatMonitor(deadline_s=10.0, lag_factor=3.0)
    t = 1000.0
    for step in range(8):
        for w in ("w0", "w1", "w2"):
            if w == "w2" and step >= 3:
                continue     # w2 stops reporting
            mon.report(w, step, now=t)
            t += 1.0
    out = mon.check(now=t + 5.0)   # w0/w1 reported ~2s ago, w2 ~16s ago
    assert "w2" in out["failed"] or "w2" in out["stragglers"]
    assert "w0" not in out["failed"]


def test_elastic_plan_mesh_keeps_tp_degree():
    from repro.runtime import plan_mesh
    mesh = plan_mesh(n_healthy=1, model_size=1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    with pytest.raises(RuntimeError):
        plan_mesh(n_healthy=0, model_size=1)


def test_sharding_specs_on_abstract_production_mesh():
    """Spec logic against AbstractMesh(16, 16): model dims sharded when
    divisible, norms replicated, ZeRO-1 adds a data axis."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import compat_abstract_mesh, opt_specs, param_specs
    mesh = compat_abstract_mesh((16, 16), ("data", "model"))
    cfg = ARCHS["yi-9b"]
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(params, mesh)
    assert specs["embed"]["table"] == P("model", None)
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, None, "model", None)
    assert specs["layers"]["mlp"]["wi"]["w"] == P(None, None, "model")
    assert specs["final_ln"]["scale"] == P(None)
    ospecs = opt_specs(params, mesh)
    # ZeRO-1 shards the first replicated divisible dim (the layer stack here)
    assert ospecs["m"]["layers"]["attn"]["wq"]["w"] == P("data", None, "model", None)
    # granite MQA: kv head = 1 -> fall back to sharding head_dim (128/16)
    cfg_g = ARCHS["granite-20b"]
    params_g = jax.eval_shape(lambda: init_params(cfg_g, jax.random.PRNGKey(0)))
    specs_g = param_specs(params_g, mesh)
    assert specs_g["layers"]["attn"]["wk"]["w"] == P(None, None, None, "model")
    assert specs_g["layers"]["attn"]["wq"]["w"] == P(None, None, "model", None)
