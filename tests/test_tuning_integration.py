"""§5 protocol integration: tuning on coreset vs uniform vs full (small)."""
import numpy as np

from repro.data import patch_mask, piecewise_signal, sensor_matrix
from repro.trees import signal_to_points, tune_k


def test_tune_k_end_to_end_quality_parity():
    y = sensor_matrix(600, 15, seed=0)
    train, test = patch_mask(*y.shape, 0.3, 5, seed=1)
    res = tune_k(y, train, test, ks=[8, 32], eps=0.4, coreset_k=64,
                 n_estimators=3)
    assert set(res.losses) == {"full", "coreset", "uniform"}
    assert res.sizes["coreset"] < res.sizes["full"]
    assert res.sizes["uniform"] == res.sizes["coreset"]
    # coreset-trained quality within 2x of full-data quality (tiny forests;
    # the benchmark suite measures the real curves)
    assert min(res.losses["coreset"]) <= 2.0 * min(res.losses["full"])


def test_signal_to_points_masks():
    y = piecewise_signal(10, 12, 3, seed=0)
    mask = np.zeros((10, 12), bool)
    mask[2, 3] = True
    X, yy = signal_to_points(y, mask)
    assert X.shape == (1, 2) and yy[0] == y[2, 3]
    assert (X[0] == [2, 3]).all()
