"""Per-kernel shape/dtype sweeps: pallas (interpret=True) vs pure-jnp refs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fitting_loss import ops as fl_ops, ref as fl_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.histsplit import ops as h_ops, ref as h_ref
from repro.kernels.sat2d import ops as sat_ops, ref as sat_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("shape", [(8, 8), (130, 70), (256, 256), (1, 300),
                                   (257, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_sat2d_shapes_dtypes(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    got = sat_ops.sat(x)
    want = sat_ref.sat2d_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-3)


def test_sat_moments_channels():
    y = jnp.asarray(RNG.normal(size=(90, 40)), jnp.float32)
    got = sat_ops.sat_moments(y)
    want = sat_ref.sat_moments_ref(y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-3)


@pytest.mark.parametrize("P,F,B", [(64, 1, 16), (700, 5, 32), (1030, 3, 256)])
def test_histsplit_sweep(P, F, B):
    codes = RNG.integers(0, B, size=(P, F)).astype(np.uint8)
    w = RNG.uniform(0.1, 2, P)
    y = RNG.normal(size=P)
    got = np.asarray(h_ops.histograms(codes, w, w * y, w * y * y, B))
    want = np.asarray(h_ref.histograms_ref(
        jnp.asarray(codes.astype(np.int32)), jnp.asarray(w, jnp.float32),
        jnp.asarray(w * y, jnp.float32), jnp.asarray(w * y * y, jnp.float32), B))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # histogram totals preserve mass
    np.testing.assert_allclose(got[:, :, 0].sum(axis=1), w.sum(), rtol=1e-5)


@pytest.mark.parametrize("B,Hq,Hkv,Lq,Lk,D", [
    (2, 4, 4, 64, 64, 32),     # MHA
    (2, 4, 2, 100, 100, 32),   # GQA
    (1, 8, 1, 96, 96, 64),     # MQA
    (2, 4, 2, 1, 64, 32),      # decode
    (1, 2, 2, 300, 300, 16),   # padded tiles
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, Lq, Lk, D, causal):
    if Lq == 1 and not causal:
        pytest.skip("non-causal decode not used")
    q = jnp.asarray(RNG.normal(size=(B, Hq, Lq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Lk, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Lk, D)), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, causal=causal)
    want = fa_ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), jnp.bfloat16)
    got = np.asarray(fa_ops.flash_attention(q, k, v).astype(jnp.float32))
    want = np.asarray(fa_ref.attention_ref(q, k, v).astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_fitting_loss_kernel_matches_core_and_ref():
    from repro.core import fitting_loss, random_tree_segmentation, signal_coreset
    from repro.data import piecewise_signal
    y = piecewise_signal(60, 70, 6, noise=0.2, seed=0)
    cs = signal_coreset(y, 6, 0.3)
    rng = np.random.default_rng(1)
    for k in (3, 9):
        q = random_tree_segmentation(60, 70, k, rng)
        core = fitting_loss(cs, q.rects, q.labels)
        kern = float(fl_ops.coreset_loss(cs, q.rects, q.labels))
        ref = float(fl_ref.fitting_loss_ref(
            jnp.asarray(cs.rects, jnp.float32), jnp.asarray(cs.labels, jnp.float32),
            jnp.asarray(cs.weights, jnp.float32),
            jnp.asarray(q.rects, jnp.float32), jnp.asarray(q.labels, jnp.float32)))
        assert abs(kern - core) / core < 1e-3
        assert abs(ref - core) / core < 1e-3


def test_model_chunked_attention_matches_pallas_kernel():
    """The XLA chunked-flash path (dry-run) == the Pallas kernel (TPU path)."""
    from repro.models.attention import chunked_attention
    q = jnp.asarray(RNG.normal(size=(2, 4, 128, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 128, 32)), jnp.float32)
    xla = chunked_attention(q, k, v, causal=True, q_chunk=64, k_chunk=32)
    pal = chunked_attention(q, k, v, causal=True, impl="pallas")
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pal),
                               rtol=2e-3, atol=2e-3)
