"""Distributed serving plane (repro.cluster): a ClusterEngine scattering
row-band builds to ShardWorkers must compose coresets **bitwise
fingerprint-equal** to the single-host thread-pool path, forward deltas in
O(changed rows), survive a worker kill by degrading to local band builds
(200s, not 5xx), heal/rejoin through the content-addressed no_band /
stale_band path, and carry ONE trace id across every RPC hop with the
gather span linking each worker's root (S3).  Workers run in-process with
PRIVATE tracers — two roots continuing one trace id in the same ring
buffer would collide — which also lets the tests inspect the worker side
of a propagated trace directly."""
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro import obs
from repro.cluster import (ClusterEngine, ShardWorker, WorkerClient,
                           WorkerRPCError, make_worker_server)
from repro.core import random_tree_segmentation
from repro.data import piecewise_signal
from repro.service import CoresetEngine, ServiceMetrics

N, M, K, EPS = 96, 64, 5, 0.3


def _start_worker(i: int, port: int = 0):
    w = ShardWorker(worker_id=f"w{i}")
    tracer = obs.Tracer()
    srv = make_worker_server(w, port=port, tracer=tracer)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return SimpleNamespace(worker=w, tracer=tracer, server=srv,
                           port=srv.server_address[1],
                           url=f"http://127.0.0.1:{srv.server_address[1]}")


@pytest.fixture
def cluster():
    nodes = [_start_worker(i) for i in range(3)]
    coord = ClusterEngine([n.url for n in nodes], workers=2, reprobe_s=0.2,
                          rpc_timeout=10.0, metrics=ServiceMetrics())
    # the single-host reference: same band count -> same layout, same bytes
    single = CoresetEngine(num_bands=3, workers=2, metrics=ServiceMetrics())
    c = SimpleNamespace(nodes=nodes, coord=coord, single=single)
    yield c
    coord.close()
    single.close()
    for n in nodes:
        _stop(n)


def _stop(node) -> None:
    node.server.shutdown()
    node.server.server_close()   # release the port (kill/rejoin reuses it)


def _y(seed=7):
    return piecewise_signal(N, M, K, noise=0.15, seed=seed)


# ------------------------------------------------------------------- parity
def test_cluster_fingerprint_and_loss_parity(cluster):
    y = _y()
    cluster.coord.register_signal("sig", y)
    cluster.single.register_signal("sig", y)
    cs_c, _, _ = cluster.coord.get_coreset("sig", K, EPS)
    cs_s, _, _ = cluster.single.get_coreset("sig", K, EPS)
    assert cs_c.fingerprint() == cs_s.fingerprint()   # bitwise composition
    # every worker served (no degraded fallback hid a dead worker)
    assert cluster.coord.metrics.get("cluster_degraded_builds") == 0
    assert cluster.coord.metrics.get("cluster_gathers") == 1
    for n in cluster.nodes:
        assert n.worker.metrics.get("worker_band_builds") == 1
    # loss answers ride the identical coreset -> bitwise equal
    rng = np.random.default_rng(11)
    for _ in range(4):
        q = random_tree_segmentation(N, M, K, rng)
        lc = cluster.coord.tree_loss("sig", q.rects, q.labels, eps=EPS)
        ls = cluster.single.tree_loss("sig", q.rects, q.labels, eps=EPS)
        assert abs(lc["loss"] - ls["loss"]) <= 1e-9
        assert lc["fingerprint"] == ls["fingerprint"]


def test_cluster_batch_query_parity(cluster):
    y = _y(8)
    cluster.coord.register_signal("sig", y)
    cluster.single.register_signal("sig", y)
    rng = np.random.default_rng(12)
    segs = [random_tree_segmentation(N, M, K, rng) for _ in range(6)]
    br = np.stack([s.rects for s in segs])
    bl = np.stack([s.labels for s in segs])
    rc = cluster.coord.tree_loss_batch("sig", br, bl, eps=EPS)
    rs = cluster.single.tree_loss_batch("sig", br, bl, eps=EPS)
    assert np.max(np.abs(rc["losses"] - rs["losses"])) <= 1e-9
    assert rc["fingerprint"] == rs["fingerprint"]


def test_worker_build_cache_serves_repeat_gathers(cluster):
    cluster.coord.register_signal("sig", _y(9))
    cluster.coord.get_coreset("sig", K, EPS)
    # drop only the coordinator's cache; worker band caches must answer
    cluster.coord.cache.invalidate_signal("sig", keep_version=None)
    cluster.coord.get_coreset("sig", K, EPS)
    assert cluster.coord.metrics.get("cluster_band_cache_hits") == 3
    for n in cluster.nodes:
        assert n.worker.metrics.get("worker_build_cache_hits") == 1


# ------------------------------------------------------------- delta writes
def test_delta_forward_patches_workers_and_keeps_parity(cluster):
    y = _y(10)
    cluster.coord.register_signal("sig", y)
    cluster.single.register_signal("sig", y)
    cluster.coord.get_coreset("sig", K, EPS)
    patch = np.full((8, M), 2.5)
    cluster.coord.ingest_delta("sig", patch, row0=40)   # band 1 rows
    cluster.single.ingest_delta("sig", patch, row0=40)
    assert cluster.coord.metrics.get("cluster_deltas_forwarded") == 1
    # only the owning worker saw rows; its slab hash now matches the
    # coordinator's post-patch band (content-addressed consistency)
    deltas = [n.worker.metrics.get("worker_deltas_applied")
              for n in cluster.nodes]
    assert deltas == [0, 1, 0]
    time.sleep(0.6)    # the dense re-cache build is async (BuildScheduler)
    cs_c, _, _ = cluster.coord.get_coreset("sig", K, EPS)
    cs_s, _, _ = cluster.single.get_coreset("sig", K, EPS)
    assert cs_c.fingerprint() == cs_s.fingerprint()
    assert cluster.coord.metrics.get("cluster_degraded_builds") == 0


def test_stale_worker_heals_by_reassign(cluster):
    y = _y(11)
    cluster.coord.register_signal("sig", y)
    # corrupt one worker's slab behind the coordinator's back
    from repro.cluster.rpc import BandAssignRequest
    from repro.service import protocol as P
    cluster.nodes[0].worker.assign(BandAssignRequest(
        signal=P.SignalRef(name="sig"), row0=0,
        band=np.ones((32, M)), band_hash=""))
    cs_c, _, _ = cluster.coord.get_coreset("sig", K, EPS)
    single = cluster.single
    single.register_signal("sig", y)
    cs_s, _, _ = single.get_coreset("sig", K, EPS)
    assert cs_c.fingerprint() == cs_s.fingerprint()
    assert cluster.coord.metrics.get(
        'cluster_band_heals{code="stale_band"}') == 1
    assert cluster.coord.metrics.get("cluster_degraded_builds") == 0


# ------------------------------------------------- kill / degrade / rejoin
def test_worker_kill_degrades_then_rejoins(cluster):
    y = _y(12)
    coord = cluster.coord
    coord.register_signal("sig", y)
    cluster.single.register_signal("sig", y)
    cs0, _, _ = coord.get_coreset("sig", K, EPS)

    victim = cluster.nodes[1]
    _stop(victim)
    coord.cache.invalidate_signal("sig", keep_version=None)
    cs1, _, _ = coord.get_coreset("sig", K, EPS)      # 200-path, no raise
    assert cs1.fingerprint() == cs0.fingerprint()     # degraded == identical
    assert coord.metrics.get("cluster_degraded_builds") == 1
    assert coord.metrics.get_gauge("cluster_worker_up",
                                   worker=victim.url) == 0.0

    # inside the cooldown the dead worker is skipped without a socket wait
    coord.cache.invalidate_signal("sig", keep_version=None)
    t0 = time.perf_counter()
    coord.get_coreset("sig", K, EPS)
    assert time.perf_counter() - t0 < coord.rpc_timeout / 2
    assert coord.metrics.get("cluster_degraded_builds") == 2

    # restart EMPTY on the same port: rejoin = no_band 404 -> assign -> serve
    fresh = _start_worker(99, port=victim.port)
    try:
        time.sleep(coord.reprobe_s + 0.05)
        coord.cache.invalidate_signal("sig", keep_version=None)
        cs2, _, _ = coord.get_coreset("sig", K, EPS)
        assert cs2.fingerprint() == cs0.fingerprint()
        assert coord.metrics.get("cluster_degraded_builds") == 2  # no new
        assert coord.metrics.get("cluster_worker_rejoins") == 1
        assert coord.metrics.get(
            'cluster_band_heals{code="no_band"}') == 1
        assert coord.metrics.get_gauge("cluster_worker_up",
                                       worker=victim.url) == 1.0
        assert fresh.worker.metrics.get("worker_band_builds") == 1
    finally:
        _stop(fresh)


# -------------------------------------------------------- trace hops (S3)
def test_trace_id_spans_coordinator_and_worker_hops(cluster):
    coord = cluster.coord
    coord.register_signal("sig", _y(13))
    root = obs.start_trace("test.build")
    with obs.TRACER.attach(root):
        coord.get_coreset("sig", K, EPS)
    root.end()
    t = obs.TRACER.get(root.trace_id)
    assert t is not None
    gathers = [s for s in t["spans"] if s["name"] == "cluster.gather"]
    assert len(gathers) == 1
    rpcs = [s for s in t["spans"] if s["name"] == "cluster.rpc"]
    assert len(rpcs) == 3
    # every worker continued the SAME trace id: its private tracer finished
    # a trace under root.trace_id whose root is the band:build route
    linked_ids = {li["span_id"] for li in gathers[0].get("links", ())}
    assert len(linked_ids) == 3                      # gather fan-in links
    for n in cluster.nodes:
        # the worker finalizes its root span AFTER flushing the RPC reply,
        # so bound-wait for the trace to finish rather than racing it
        wt = n.tracer.get(root.trace_id, wait_s=2.0)
        assert wt is not None
        names = {s["name"] for s in wt["spans"]}
        assert "POST /v1/worker/band:build" in names
        assert "worker.band_build" in names
        # the response traceparent the coordinator linked IS a worker span
        worker_span_ids = {s["span_id"] for s in wt["spans"]}
        assert linked_ids & worker_span_ids


def test_worker_error_envelope_carries_trace_headers(cluster):
    client = WorkerClient(cluster.nodes[0].url)
    root = obs.start_trace("test.err")
    with obs.TRACER.attach(root):
        with pytest.raises(WorkerRPCError) as ei:
            client.build("ghost", 0, 32, "deadbeef", K, EPS, 1e-3)
    root.end()
    assert ei.value.code == "no_band"
    assert ei.value.http == 404
    # X-Coreset-Trace-Id on the ERROR envelope names the propagated trace
    assert ei.value.trace_id == root.trace_id


# ----------------------------------------------------------- telemetry (S6)
def test_cluster_metrics_gauges_histograms_and_stats(cluster):
    coord = cluster.coord
    coord.register_signal("sig", _y(14))
    root = obs.start_trace("test.metrics")
    with obs.TRACER.attach(root):
        coord.get_coreset("sig", K, EPS)
    root.end()
    text = coord.metrics.render()
    assert "# TYPE coreset_cluster_worker_up gauge" in text
    for n in cluster.nodes:
        assert f'coreset_cluster_worker_up{{worker="{n.url}"}} 1' in text
    # per-worker RPC latency histograms + the gather histogram, with the
    # traced build attached as an exemplar
    assert "coreset_cluster_rpc_seconds_bucket" in text
    assert "coreset_cluster_gather_seconds_bucket" in text
    assert f'trace_id="{root.trace_id}"' in text
    snap = coord.stats()
    assert snap["cluster"]["role"] == "coordinator"
    assert [p["up"] for p in snap["cluster"]["peers"]] == [True] * 3
    assert snap["cluster"]["gathers"] == 1
    assert snap["metrics"]["gauges"]   # gauges surfaced in /v1/stats
    # worker-side: its own /metrics exposition works too
    wtext = cluster.nodes[0].worker.metrics.render()
    assert "coreset_worker_band_builds" in wtext
    assert "# TYPE coreset_worker_bands_held gauge" in wtext
