"""Algorithm 5: the vectorized smoothed assignment vs a literal reference."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (fitting_loss, overlap_counts, random_tree_segmentation,
                        signal_coreset)
from repro.data import piecewise_signal


def literal_smoothed_loss(cs, seg_rects, seg_labels):
    """The paper's while-loop (Algorithm 5 lines 9-25), verbatim."""
    total = 0.0
    z_all = overlap_counts(cs.rects, np.asarray(seg_rects))
    for b in range(cs.num_blocks):
        u = list(cs.weights[b].astype(float))
        labels = list(cs.labels[b].astype(float))
        i = 0
        for l_idx in range(len(seg_labels)):
            z = float(z_all[b, l_idx])
            lam = float(seg_labels[l_idx])
            while z >= 1e-12 and i < 4:
                if u[i] <= z + 1e-12:
                    total += u[i] * (lam - labels[i]) ** 2
                    z -= u[i]
                    u[i] = 0.0
                    i += 1
                else:
                    total += z * (lam - labels[i]) ** 2
                    u[i] -= z
                    z = 0.0
    return total


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_vectorized_matches_literal_while_loop(seed, k):
    rng = np.random.default_rng(seed)
    y = piecewise_signal(24, 30, 4, noise=0.3, seed=seed % 17)
    cs = signal_coreset(y, 4, 0.3)
    q = random_tree_segmentation(24, 30, k, rng)
    fast = fitting_loss(cs, q.rects, q.labels)
    slow = literal_smoothed_loss(cs, q.rects, q.labels)
    assert np.isclose(fast, slow, rtol=1e-8, atol=1e-6)


def test_single_leaf_is_exact_moment_formula():
    y = piecewise_signal(30, 30, 3, noise=0.2, seed=0)
    cs = signal_coreset(y, 3, 0.3)
    lam = 0.7
    rects = np.array([[0, 30, 0, 30]])
    expect = float(((y - lam) ** 2).sum())
    assert np.isclose(fitting_loss(cs, rects, np.array([lam])), expect,
                      rtol=1e-9)


def test_batched_jax_eval_matches_numpy():
    from repro.core import fitting_loss_batched
    rng = np.random.default_rng(1)
    y = piecewise_signal(40, 40, 5, noise=0.2, seed=1)
    cs = signal_coreset(y, 5, 0.3)
    segs = [random_tree_segmentation(40, 40, 5, rng) for _ in range(4)]
    sr = np.stack([s.rects for s in segs])
    sl = np.stack([s.labels for s in segs])
    batched = fitting_loss_batched(cs, sr, sl)
    seq = np.array([fitting_loss(cs, s.rects, s.labels) for s in segs])
    assert np.allclose(batched, seq, rtol=1e-4)
