"""v2 chunked compress streaming: length-prefixed npz segments over HTTP
chunked transfer-encoding, negotiated with ``Accept: <binary>;v=2``.  The
stream must round-trip bitwise, reject reordered / miscounted / corrupted
segments terminally (ProtocolError) while a mid-segment EOF is the
retryable ``StreamTruncated``; the HTTP layer must serve >= 4 chunks for a
multi-chunk coreset and degrade silently to the buffered v1 body for v1
clients; the client must honor ``Retry-After`` on 503."""
import http.server
import io
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

import repro.client.client as client_mod
from repro.client import CoresetAPIError, CoresetClient, TransportError
from repro.data import piecewise_signal
from repro.service import (CoresetEngine, ServiceMetrics, make_server,
                           serve_forever_in_thread)
from repro.service import protocol as P


def _resp(points, seed=0):
    rng = np.random.default_rng(seed)
    return P.CompressResponse(
        k=5, eps_eff=0.25, served_from="built", fingerprint="ab" * 16,
        size=points, blocks=max(points // 7, 1), nbytes=points * 32,
        compression_ratio=0.5, truncated=False,
        X=rng.random((points, 2)) * 100, y=rng.random(points),
        w=rng.random(points) + 0.5)


def _segments(resp, chunk_points):
    return list(P.compress_stream_segments(resp, chunk_points=chunk_points))


def _decode(blob: bytes):
    return P.read_compress_stream(io.BytesIO(blob).read)


# ------------------------------------------------------------- negotiation
def test_accept_stream_negotiation():
    assert P.accept_stream(f"{P.CONTENT_TYPE_BINARY};v=2")
    assert P.accept_stream(f"{P.CONTENT_TYPE_BINARY}; v=2, */*")
    assert P.accept_stream(P.CONTENT_TYPE_STREAM)
    assert not P.accept_stream(P.CONTENT_TYPE_BINARY)
    assert not P.accept_stream("application/json;v=2")
    assert not P.accept_stream(None)
    assert not P.accept_stream("")


# -------------------------------------------------------------- round-trip
@pytest.mark.parametrize("points,chunk_points,want_chunks",
                         [(0, 64, 0), (1, 64, 1), (64, 64, 1),
                          (65, 64, 2), (1000, 64, 16), (257, 64, 5)])
def test_stream_round_trips_bitwise(points, chunk_points, want_chunks):
    resp = _resp(points, seed=points)
    segs = _segments(resp, chunk_points)
    assert segs[0].startswith(P.STREAM_MAGIC)
    got, chunks = _decode(b"".join(segs))
    assert chunks == want_chunks
    for f in ("k", "eps_eff", "served_from", "fingerprint", "size", "blocks",
              "nbytes", "compression_ratio", "truncated"):
        assert getattr(got, f) == getattr(resp, f)
    np.testing.assert_array_equal(got.X, resp.X)
    np.testing.assert_array_equal(got.y, resp.y)
    np.testing.assert_array_equal(got.w, resp.w)
    assert got.X.dtype == np.float64 and got.X.shape == (points, 2)


def test_stream_of_large_coreset_is_many_segments():
    resp = _resp(100_001)
    segs = _segments(resp, P.STREAM_CHUNK_POINTS)
    # magic+header, ceil(100001/32768)=4 chunks, trailer
    assert len(segs) == 1 + 4 + 1
    got, chunks = _decode(b"".join(segs))
    assert chunks == 4
    np.testing.assert_array_equal(got.y, resp.y)


# ------------------------------------------------------ stream corruptions
def test_truncated_stream_is_retryable_error():
    blob = b"".join(_segments(_resp(300), 64))
    for cut in (0, 2, len(P.STREAM_MAGIC) + 2, len(blob) // 2, len(blob) - 1):
        with pytest.raises(P.StreamTruncated):
            _decode(blob[:cut])


def test_reordered_chunks_rejected():
    segs = _segments(_resp(300), 64)       # header, 5 chunks, trailer
    segs[1], segs[2] = segs[2], segs[1]
    with pytest.raises(P.ProtocolError) as exc:
        _decode(b"".join(segs))
    assert not isinstance(exc.value, P.StreamTruncated)


def test_corrupt_frame_byte_rejected():
    segs = _segments(_resp(300), 64)
    bad = bytearray(segs[1])
    bad[len(bad) // 2] ^= 0xFF             # inside the npz+zlib payload
    segs[1] = bytes(bad)
    with pytest.raises(P.ProtocolError) as exc:
        _decode(b"".join(segs))
    assert not isinstance(exc.value, P.StreamTruncated)


def test_digest_and_count_mismatches_rejected():
    resp = _resp(300)
    segs = _segments(resp, 64)
    forged = P._segment(P.CompressTrailer(chunks=5, points=300,
                                          digest="00" * 16), "zlib")
    with pytest.raises(P.ProtocolError, match="digest"):
        _decode(b"".join(segs[:-1]) + forged)
    forged = P._segment(P.CompressTrailer(chunks=4, points=300,
                                          digest="00" * 16), "zlib")
    with pytest.raises(P.ProtocolError):
        _decode(b"".join(segs[:-1]) + forged)


def test_bad_magic_rejected():
    blob = b"".join(_segments(_resp(10), 64))
    with pytest.raises(P.ProtocolError):
        _decode(b"XXXX" + blob[4:])


# ---------------------------------------------------------------- HTTP e2e
N, M = 96, 48


def _server(**kw):
    eng = CoresetEngine(workers=2, metrics=ServiceMetrics())
    srv = make_server(eng, **kw)
    serve_forever_in_thread(srv)
    return eng, srv, f"http://127.0.0.1:{srv.server_address[1]}"


def test_http_v2_stream_matches_v1_buffered():
    # small chunk size so a modest coreset spans >= 4 chunks on the wire
    eng, srv, base = _server(stream_chunk_points=16)
    try:
        y = piecewise_signal(N, M, 6, noise=0.15, seed=3)
        v1 = CoresetClient(base, encoding="binary", stream=False)
        v1.register_signal("s", values=y)
        r1 = v1.compress("s", 6, 0.25, max_points=256)
        assert v1.last_stream_chunks == 0
        v2 = CoresetClient(base, encoding="binary")       # stream=True
        r2 = v2.compress("s", 6, 0.25, max_points=256)
        assert v2.last_stream_chunks >= 4
        assert r2.fingerprint == r1.fingerprint
        np.testing.assert_array_equal(r2.X, r1.X)
        np.testing.assert_array_equal(r2.y, r1.y)
        np.testing.assert_array_equal(r2.w, r1.w)
        assert eng.metrics.get("http_stream_responses") == 1
        assert eng.metrics.get("http_stream_segments") >= 6
        # JSON clients never negotiate the stream
        rj = CoresetClient(base, encoding="json").compress("s", 6, 0.25,
                                                           max_points=256)
        np.testing.assert_allclose(rj.X, r1.X)
        assert eng.metrics.get("http_stream_responses") == 1
        # non-compress binary endpoints still answer buffered v1 bodies
        b = v2.build("s", 6, 0.25)
        assert b.fingerprint == r1.fingerprint
    finally:
        srv.shutdown()
        eng.close()


def test_http_default_chunking_on_large_coreset():
    # acceptance: a >= 4 MB coreset streams in >= 4 DEFAULT-size chunks and
    # the client-decoded output is identical to the buffered v1 body
    eng, srv, base = _server()
    try:
        cl = CoresetClient(base, encoding="binary")
        y = np.random.default_rng(9).random((256, 256)) * 8.0   # block-rich
        cl.register_signal("big", values=y)
        r = cl.compress("big", 3, 0.01, max_points=1 << 20)
        assert r.X.shape[0] > 4 * P.STREAM_CHUNK_POINTS
        assert r.X.nbytes + r.y.nbytes + r.w.nbytes >= 4 << 20
        assert cl.last_stream_chunks >= 4
        v1 = CoresetClient(base, encoding="binary", stream=False)
        r1 = v1.compress("big", 3, 0.01, max_points=1 << 20)   # cached now
        np.testing.assert_array_equal(r.X, r1.X)
        np.testing.assert_array_equal(r.y, r1.y)
        np.testing.assert_array_equal(r.w, r1.w)
    finally:
        srv.shutdown()
        eng.close()


# -------------------------------------------------------------- Retry-After
class _FlakyHandler(http.server.BaseHTTPRequestHandler):
    fails = 2

    def log_message(self, *a):  # noqa: D102
        pass

    def do_POST(self):  # noqa: N802
        self.rfile.read(int(self.headers.get("Content-Length", 0)))
        srv = self.server
        if srv.seen < self.fails:
            srv.seen += 1
            body = b'{"type": "error", "error": {"code": "unavailable", ' \
                   b'"message": "warming up"}}'
            self.send_response(503)
            self.send_header("Retry-After", "0.5")
        else:
            body = b'{"type": "error", "error": {"code": "not_found", ' \
                   b'"message": "nope"}}'
            self.send_response(404)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)


def test_client_honors_retry_after_on_503(monkeypatch):
    srv = http.server.ThreadingHTTPServer(("127.0.0.1", 0), _FlakyHandler)
    srv.seen = 0
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    sleeps = []
    monkeypatch.setattr(
        client_mod, "time",
        SimpleNamespace(sleep=sleeps.append, time=time.time,
                        perf_counter=time.perf_counter,
                        monotonic=time.monotonic))
    try:
        cl = CoresetClient(f"http://127.0.0.1:{srv.server_address[1]}",
                           retries=3, backoff=0.01)
        with pytest.raises(CoresetAPIError) as exc:
            cl.build("s", 4, 0.3)
        assert exc.value.http == 404            # retried past both 503s
        assert sleeps == [0.5, 0.5]             # Retry-After > tiny backoff
        assert cl.last_retry_after == 0.5
    finally:
        srv.shutdown()
        srv.server_close()


def test_client_backoff_wins_over_smaller_retry_after(monkeypatch):
    srv = http.server.ThreadingHTTPServer(("127.0.0.1", 0), _FlakyHandler)
    srv.seen = 0
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    sleeps = []
    monkeypatch.setattr(
        client_mod, "time",
        SimpleNamespace(sleep=sleeps.append, time=time.time,
                        perf_counter=time.perf_counter,
                        monotonic=time.monotonic))
    monkeypatch.setattr(_FlakyHandler, "fails", 1)
    try:
        cl = CoresetClient(f"http://127.0.0.1:{srv.server_address[1]}",
                           retries=2, backoff=2.0)
        with pytest.raises(CoresetAPIError):
            cl.build("s", 4, 0.3)
        assert sleeps == [2.0]                  # max(backoff, Retry-After)
    finally:
        srv.shutdown()
        srv.server_close()
