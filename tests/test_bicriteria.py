"""Lemma 5/10: the bi-criteria sigma must lower-bound opt_k(D)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bicriteria, optimal_tree_dp, segment_1d_dp


@st.composite
def tiny_signal(draw):
    n = draw(st.integers(3, 7))
    m = draw(st.integers(3, 7))
    seed = draw(st.integers(0, 100_000))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["noise", "blocks", "smooth"]))
    if kind == "noise":
        return rng.normal(size=(n, m))
    if kind == "smooth":
        return np.add.outer(np.linspace(0, 1, n), np.linspace(0, 2, m))
    y = np.zeros((n, m))
    y[: n // 2] = rng.normal()
    y[n // 2:] = rng.normal()
    return y + 0.05 * rng.normal(size=(n, m))


@settings(max_examples=25, deadline=None)
@given(tiny_signal(), st.integers(1, 3))
def test_sigma_lower_bounds_optimal_tree(y, k):
    """opt over k-TREES >= opt over k-segmentations >= sigma.

    (The DP oracle optimizes over trees; every tree is a segmentation, so
    opt_tree >= opt_seg >= sigma must hold for certified sigma.)"""
    res = bicriteria(y, k)
    opt_tree, _ = optimal_tree_dp(y, k)
    assert res.sigma <= opt_tree + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_sigma_lower_bounds_1d_dp(seed, k):
    """Single-row signals: exact 1D k-segmentation DP as the oracle."""
    rng = np.random.default_rng(seed)
    y = rng.normal(size=(1, 24))
    res = bicriteria(y, k)
    opt, _ = segment_1d_dp(y[0], k)
    assert res.sigma <= opt + 1e-6


def test_paper_fidelity_mode_runs_to_completion():
    rng = np.random.default_rng(0)
    y = rng.normal(size=(24, 18))
    res = bicriteria(y, 2, fidelity="paper")
    assert res.sigma >= 0.0
    assert res.n_iterations >= 1


def test_weighted_moments_path_matches_dense():
    rng = np.random.default_rng(3)
    y = rng.normal(size=(16, 12))
    dense = bicriteria(y, 2)
    mom = (np.ones_like(y), y, y * y)
    viamom = bicriteria(None, 2, moments=mom)
    assert np.isclose(dense.sigma, viamom.sigma)
    assert dense.n_iterations == viamom.n_iterations
