"""Per-arch smoke tests (required by the brief): reduced config of the same
family, one forward + one train step on CPU, shape and finiteness asserts;
plus decode-vs-forward consistency and SSM chunking invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import decode_step, forward, init_cache, init_params
from repro.train import AdamWConfig, adamw_init, make_train_step

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B, L, train=False):
    rng = np.random.default_rng(0)
    if cfg.frontend == "audio_codebooks":
        toks = rng.integers(0, cfg.vocab, size=(B, L, cfg.n_codebooks))
        b = {"tokens": jnp.asarray(toks, jnp.int32)}
        if train:
            b["targets"] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=(B, L, cfg.n_codebooks)), jnp.int32)
    elif cfg.frontend == "vision_stub":
        b = {"patch_embeds": jnp.asarray(
                rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16),
             "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, size=(B, L - cfg.n_patches)), jnp.int32)}
        if train:
            b["targets"] = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, L)),
                                       jnp.int32)
    else:
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, L)),
                                   jnp.int32)}
        if train:
            b["targets"] = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, L)),
                                       jnp.int32)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced_config(ARCHS[arch])
    params = init_params(cfg, RNG)
    B, L = 2, 16
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, _batch(cfg, B, L))
    expect = ((B, L, cfg.n_codebooks, cfg.vocab)
              if cfg.frontend == "audio_codebooks" else (B, L, cfg.vocab))
    assert logits.shape == expect
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = make_train_step(cfg, AdamWConfig(total_steps=10))
    opt = adamw_init(params)
    p2, o2, m = jax.jit(step)(params, opt, _batch(cfg, B, L, train=True))
    assert np.isfinite(float(m["loss"]))
    assert int(o2["step"]) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "granite-20b", "falcon-mamba-7b",
                                  "zamba2-1.2b", "musicgen-medium"])
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(reduced_config(ARCHS[arch]), dtype="float32",
                              remat=False)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, L = 2, 10
    b = _batch(cfg, B, L)
    full, _ = forward(cfg, params, b)
    cache = init_cache(cfg, B, L)
    outs = []
    dec = jax.jit(lambda p, c, bb: decode_step(cfg, p, c, bb))
    for t in range(L):
        tok = {"tokens": b["tokens"][:, t:t + 1]}
        lg, cache = dec(params, cache, tok)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_decode_matches_forward_with_high_capacity():
    cfg = dataclasses.replace(reduced_config(ARCHS["deepseek-v2-236b"]),
                              dtype="float32", remat=False, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, L = 2, 8
    b = _batch(cfg, B, L)
    full, _ = forward(cfg, params, b)
    cache = init_cache(cfg, B, L)
    outs = []
    for t in range(L):
        lg, cache = decode_step(cfg, params, cache,
                                {"tokens": b["tokens"][:, t:t + 1]})
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(full),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("version,arch", [(1, "falcon-mamba-7b"),
                                          (2, "zamba2-1.2b")])
def test_ssm_chunk_size_invariance(version, arch):
    """The chunked recurrence is exact for any chunk size."""
    cfg = dataclasses.replace(reduced_config(ARCHS[arch]), dtype="float32",
                              remat=False, attn_every=0)
    params = init_params(cfg, jax.random.PRNGKey(2))
    b = _batch(cfg, 2, 24)
    outs = []
    for chunk in (4, 8, 24):
        c = dataclasses.replace(cfg, ssm_chunk=chunk)
        outs.append(np.asarray(forward(c, params, b)[0]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


def test_unroll_mode_matches_scan_mode():
    """The dry-run costing path must compute the same function."""
    for arch in ("qwen2-0.5b", "falcon-mamba-7b"):
        cfg = dataclasses.replace(reduced_config(ARCHS[arch]), dtype="float32",
                                  remat=False)
        params = init_params(cfg, jax.random.PRNGKey(3))
        b = _batch(cfg, 2, 16)
        scan, _ = forward(cfg, params, b, unroll=False)
        unrl, _ = forward(cfg, params, b, unroll=True)
        np.testing.assert_allclose(np.asarray(scan), np.asarray(unrl),
                                   rtol=2e-4, atol=2e-4)
