import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single device; only launch/dryrun.py forces 512 host devices.
