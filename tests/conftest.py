import os
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

# Tier-1 selection tests assert the *static* dispatch heuristics; a warm
# developer tuning cache (~/.cache/repro/autotune.json) must not flip them.
# Point the autotune cache at a fresh per-run path unless the environment
# already pins one; tuning tests repoint it again via monkeypatch.
os.environ.setdefault(
    "REPRO_AUTOTUNE_CACHE",
    os.path.join(tempfile.gettempdir(),
                 f"repro_test_autotune_{os.getpid()}.json"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single device; only launch/dryrun.py forces 512 host devices.

# The property-test modules import hypothesis at module scope; without it
# installed they are 7 hard collection errors that abort the whole run.
# Degrade to a collect-time skip instead: detect the importers by source
# scan (no hardcoded list to drift) and ignore them, reporting which.
try:
    import hypothesis  # noqa: F401
    _NO_HYPOTHESIS: list[str] = []
except ModuleNotFoundError:
    _HERE = pathlib.Path(__file__).resolve().parent
    _NO_HYPOTHESIS = sorted(
        p.name for p in _HERE.glob("test_*.py")
        if "from hypothesis" in p.read_text() or "import hypothesis" in p.read_text()
    )
    collect_ignore = list(_NO_HYPOTHESIS)


def pytest_report_header(config):
    if _NO_HYPOTHESIS:
        return (f"hypothesis not installed: skipping {len(_NO_HYPOTHESIS)} "
                f"property-test modules ({', '.join(_NO_HYPOTHESIS)})")
    return None
