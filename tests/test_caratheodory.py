"""Corollary 17: exact <=4-point moment representations."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import block_representatives, caratheodory_reduce


@st.composite
def blocks(draw):
    n_blocks = draw(st.integers(1, 5))
    sizes = [draw(st.integers(1, 40)) for _ in range(n_blocks)]
    seed = draw(st.integers(0, 100_000))
    rng = np.random.default_rng(seed)
    style = draw(st.sampled_from(["normal", "constant", "two-valued", "heavy"]))
    ys, bids = [], []
    for b, sz in enumerate(sizes):
        if style == "constant":
            v = np.full(sz, rng.normal())
        elif style == "two-valued":
            v = rng.choice(rng.normal(size=2), size=sz)
        elif style == "heavy":
            v = rng.standard_cauchy(size=sz)
        else:
            v = rng.normal(size=sz)
        ys.append(v)
        bids.append(np.full(sz, b))
    return np.concatenate(ys), np.concatenate(bids).astype(np.int64), n_blocks


@settings(max_examples=80, deadline=None)
@given(blocks())
def test_exact_moments_nonneg_weights_support_in_block(case):
    y, bid, nb = case
    labels, weights, moments = block_representatives(y, bid, nb)
    assert (weights >= 0).all()
    assert labels.shape == (nb, 4) and weights.shape == (nb, 4)
    for b in range(nb):
        blk = y[bid == b]
        scale = max(np.abs(blk).max(), 1.0)
        # exact (M0, M1, M2) matching
        assert np.isclose(weights[b].sum(), blk.size, rtol=1e-9)
        assert np.isclose((weights[b] * labels[b]).sum(), blk.sum(),
                          rtol=1e-7, atol=1e-7 * scale)
        assert np.isclose((weights[b] * labels[b] ** 2).sum(), (blk ** 2).sum(),
                          rtol=1e-6, atol=1e-6 * scale ** 2)
        # support labels are labels of the block (C_B subset of B)
        for lab in labels[b]:
            assert np.isclose(np.abs(blk - lab).min(), 0.0, atol=1e-9 * scale)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(5, 30))
def test_caratheodory_reduce_oracle(seed, n):
    """The classic iterative elimination keeps weighted sums exactly."""
    rng = np.random.default_rng(seed)
    y = rng.normal(size=n)
    P = np.stack([y, y * y, np.ones(n)], axis=1)
    w = rng.uniform(0.1, 2.0, size=n)
    keep, w2 = caratheodory_reduce(P, w)
    assert keep.size <= 4
    assert np.allclose(P[keep].T @ w2, P.T @ w, rtol=1e-6, atol=1e-6)
