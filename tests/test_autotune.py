"""repro.ops.autotune — tuning-cache lifecycle (round-trip; corrupt, wrong
schema version, and stale kernel fingerprint all fall back to heuristics),
promotion rules (a tuned backend must have beaten the numpy oracle, pinned
ops additionally need a compensated-parity certificate, interpret-mode
Pallas never auto-promotes off-TPU), selection precedence (override and env
beat tuned entries, ``REPRO_OPS_PRECISION=f64`` holds the pin), dispatch
counters, and compensated-f32 parity at awkward shapes (off tile/chunk
quantum, single-bin histograms, zero-weight rows through padded blocks)."""
import json

import numpy as np
import pytest

from repro import ops
from repro.ops import autotune

RNG = np.random.default_rng(7)


@pytest.fixture()
def tune_cache(tmp_path, monkeypatch):
    """A private cache file per test; the module cache reloads on repoint."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV_VAR, str(path))
    autotune.reset_cache()
    yield path
    autotune.reset_cache()


def _seed_entry(op, backend, size, *, us=10.0, numpy_us=100.0, config=None,
                rel_err=None):
    """Plant a measured-looking entry at size's bucket; returns the bucket."""
    bucket = autotune.shape_bucket(size)
    entry = {"config": config or {}, "us": us, "numpy_us": numpy_us,
             "size": int(size), "bucket": bucket}
    if rel_err is not None:
        entry["rel_err"] = rel_err
    autotune.get_cache().put(op, backend, bucket, entry)
    return bucket


# fitting_loss_batched is NOT precision-pinned and its static threshold is
# 1 << 16, so at this size the heuristics say numpy — any accelerator
# selection below can only have come from the tuning cache
_OP, _SIZE = "fitting_loss_batched", 1024


# --------------------------------------------------------------- lifecycle
def test_cache_round_trip(tune_cache):
    _seed_entry(_OP, "xla", _SIZE, config={"tile_b": 256})
    saved = autotune.get_cache().save()
    assert saved == tune_cache
    autotune.reset_cache()
    cache = autotune.get_cache()
    assert cache.loaded_from_disk
    entry = cache.get(_OP, "xla", autotune.shape_bucket(_SIZE))
    assert entry is not None and entry["config"] == {"tile_b": 256}


def test_corrupt_cache_falls_back_cleanly(tune_cache):
    tune_cache.write_text("{corrupt json")
    before = autotune.counters_snapshot()["cache_load_errors"]
    autotune.reset_cache()
    cache = autotune.get_cache()
    assert not cache.entries and not cache.loaded_from_disk
    assert autotune.counters_snapshot()["cache_load_errors"] == before + 1
    # dispatch must survive on heuristics
    assert ops.select_backend(_OP, _SIZE) == "numpy"
    np.testing.assert_allclose(
        ops.sat_moments([[1.0, 2.0], [3.0, 4.0]])[0, -1, -1], 4.0)


@pytest.mark.parametrize("doc", [
    {"version": 999, "fingerprint": None, "entries": {}},       # wrong schema
    {"version": autotune.SCHEMA_VERSION, "fingerprint": "0" * 12,
     "entries": {}},                                            # stale kernels
], ids=["schema-version", "kernel-fingerprint"])
def test_stale_cache_discarded(tune_cache, doc):
    if doc["fingerprint"] is None:
        doc["fingerprint"] = autotune.kernel_fingerprint()
    doc["entries"] = {autotune.TuneCache.key(
        _OP, "xla", autotune.device_kind(), autotune.shape_bucket(_SIZE)):
        {"config": {}, "us": 1.0, "numpy_us": 100.0}}
    tune_cache.write_text(json.dumps(doc))
    before = autotune.counters_snapshot()["cache_load_errors"]
    autotune.reset_cache()
    assert not autotune.get_cache().entries
    assert autotune.counters_snapshot()["cache_load_errors"] == before + 1
    assert ops.select_backend(_OP, _SIZE) == "numpy"


# ---------------------------------------------------------------- promotion
def test_promotion_requires_beating_numpy(tune_cache):
    _seed_entry(_OP, "xla", _SIZE, us=500.0, numpy_us=100.0)   # oracle won
    assert ops.select_backend(_OP, _SIZE) == "numpy"
    _seed_entry(_OP, "xla", _SIZE, us=10.0, numpy_us=100.0)    # tuned win
    before = autotune.counters_snapshot()["tuned_dispatch"]
    assert ops.select_backend(_OP, _SIZE) == "xla"
    assert autotune.counters_snapshot()["tuned_dispatch"] == before + 1
    # a different bucket is a cold miss: heuristics again
    assert ops.select_backend(_OP, 1 << 20) == "xla"   # static threshold
    assert ops.select_backend(_OP, 64) == "numpy"


def test_interpret_pallas_never_promoted_off_tpu(tune_cache):
    _seed_entry(_OP, "pallas", _SIZE, us=1.0, numpy_us=100.0)
    want = "pallas" if autotune.device_kind() == "tpu" else "numpy"
    assert ops.select_backend(_OP, _SIZE) == want


def test_override_and_env_beat_tuned(tune_cache, monkeypatch):
    _seed_entry(_OP, "xla", _SIZE, us=10.0, numpy_us=100.0)
    assert ops.select_backend(_OP, _SIZE) == "xla"
    monkeypatch.setenv(ops.ENV_VAR, "numpy")
    assert ops.select_backend(_OP, _SIZE) == "numpy"
    monkeypatch.delenv(ops.ENV_VAR)
    with ops.backend_override("numpy"):
        assert ops.select_backend(_OP, _SIZE) == "numpy"
    assert ops.select_backend(_OP, _SIZE) == "xla"


def test_disable_env_kills_tuned_selection(tune_cache, monkeypatch):
    _seed_entry(_OP, "xla", _SIZE, us=10.0, numpy_us=100.0)
    monkeypatch.setenv(autotune.DISABLE_ENV_VAR, "0")
    assert autotune.tuned_backend(_OP, _SIZE) is None
    assert ops.select_backend(_OP, _SIZE) == "numpy"
    assert autotune.plan(_OP, "xla", _SIZE) == {}


def test_pinned_promotion_needs_parity_certificate(tune_cache):
    # hist_split is precision-pinned: a win alone must NOT lift the pin
    size = 40_000 * 4
    _seed_entry("hist_split", "xla", size, us=10.0, numpy_us=100.0,
                config={"variant": "flat", "compensated": False})
    assert ops.select_backend("hist_split", size) == "numpy"
    # compensated but failing the certificate: pin still holds
    _seed_entry("hist_split", "xla", size, us=10.0, numpy_us=100.0,
                config={"variant": "chunked", "compensated": True},
                rel_err=5e-6)
    assert ops.select_backend("hist_split", size) == "numpy"
    # compensated with a passing certificate: promoted, and counted
    before = autotune.counters_snapshot()["promoted_f32"]
    _seed_entry("hist_split", "xla", size, us=10.0, numpy_us=100.0,
                config={"variant": "chunked", "compensated": True},
                rel_err=2e-8)
    assert ops.select_backend("hist_split", size) == "xla"
    assert autotune.counters_snapshot()["promoted_f32"] == before + 1


def test_precision_mode_f64_and_fast(tune_cache, monkeypatch):
    size = 40_000 * 4
    _seed_entry("hist_split", "xla", size, us=10.0, numpy_us=100.0,
                config={"variant": "chunked", "compensated": True},
                rel_err=2e-8)
    assert ops.select_backend("hist_split", size) == "xla"
    monkeypatch.setenv(autotune.PRECISION_ENV_VAR, "f64")   # escape hatch
    assert ops.select_backend("hist_split", size) == "numpy"
    # fast mode waives the certificate entirely (documented TPU trade-off)
    monkeypatch.setenv(autotune.PRECISION_ENV_VAR, "fast")
    _seed_entry("hist_split", "xla", size, us=10.0, numpy_us=100.0,
                config={"variant": "flat", "compensated": False})
    assert ops.select_backend("hist_split", size) == "xla"


def test_plan_serves_config_and_counts(tune_cache):
    before = autotune.counters_snapshot()
    assert autotune.plan(_OP, "xla", _SIZE) == {}           # cold miss
    _seed_entry(_OP, "xla", _SIZE, config={"tile_b": 512})
    assert autotune.plan(_OP, "xla", _SIZE) == {"tile_b": 512}
    assert autotune.plan(_OP, "numpy", _SIZE) == {}         # oracle untouched
    after = autotune.counters_snapshot()
    assert after["cache_miss"] == before["cache_miss"] + 1
    assert after["cache_hit"] == before["cache_hit"] + 1


def test_tune_op_records_winner_and_certificate(tune_cache):
    winners = autotune.tune_op("sat_moments", budget="quick")
    assert "xla" in winners
    entry = winners["xla"]
    assert entry["us"] > 0 and entry["numpy_us"] > 0
    assert "rel_err" in entry and "config" in entry
    bucket = entry["bucket"]
    assert autotune.get_cache().get("sat_moments", "xla", bucket) == entry
    # the quick budget must include a compensated candidate measurement
    # somewhere in the recorded winner or its search space
    assert any(c.get("compensated") for c in
               autotune.SEARCH_SPACE["sat_moments"]["xla"])


# -------------------------------------------- compensated-f32 parity, edges
def _rel(got, want):
    return autotune._scaled_rel_err(got, want)


def test_compensated_sat_parity_off_tile_quantum():
    # 131 x 67: off the 128-row tile quantum, large offset so plain f32
    # cumsum error is visible while the two-float path stays certified
    y = RNG.normal(size=(131, 67)) + 1e6
    want = ops.sat_moments(y, backend="numpy")
    got = ops.sat_moments(y, backend="xla", config={"compensated": True})
    assert _rel(got, want) <= autotune.PARITY_RTOL


def test_compensated_delta_sat_parity():
    y = RNG.normal(size=(34, 257)) + 1e5      # odd band, off-quantum width
    carry = ops.sat_moments(y[:1], backend="numpy")[:, 0, :]
    want = ops.delta_sat(carry, y[1:], backend="numpy")
    got = ops.delta_sat(carry, y[1:], backend="xla",
                        config={"compensated": True})
    assert _rel(got, want) <= autotune.PARITY_RTOL


def _hist_problem(P, F, B, zero_frac=0.0):
    codes = RNG.integers(0, B, size=(P, F)).astype(np.uint8)
    w = RNG.uniform(0.5, 1.5, P)
    if zero_frac:
        w[RNG.random(P) < zero_frac] = 0.0    # zero-weight rows must vanish
    yv = RNG.normal(size=P) + 100.0
    return codes, w, w * yv, w * yv * yv


@pytest.mark.parametrize("config", [
    {"variant": "chunked", "compensated": True},
    {"variant": "partials", "compensated": True, "tile_p": 512},
], ids=["xla-chunked", "pallas-partials"])
def test_compensated_hist_parity_awkward_shapes(config):
    backend = "pallas" if config["variant"] == "partials" else "xla"
    # P=4097: off both the 512 Pallas tile and the 8192 XLA chunk quantum,
    # so the padded tail blocks (zero-weight by construction) are exercised
    codes, w, wy, wy2 = _hist_problem(4097, 3, 16, zero_frac=0.1)
    want = ops.hist_split(codes, w, wy, wy2, 16, backend="numpy")
    got = ops.hist_split(codes, w, wy, wy2, 16, backend=backend,
                         config=config)
    assert _rel(got, want) <= autotune.PARITY_RTOL


@pytest.mark.parametrize("config", [
    {"variant": "chunked", "compensated": True},
    {"variant": "partials", "compensated": True, "tile_p": 512},
], ids=["xla-chunked", "pallas-partials"])
def test_compensated_hist_parity_single_bin(config):
    backend = "pallas" if config["variant"] == "partials" else "xla"
    codes, w, wy, wy2 = _hist_problem(1023, 2, 1)    # n_bins=1 degenerate
    want = ops.hist_split(codes, w, wy, wy2, 1, backend="numpy")
    got = ops.hist_split(codes, w, wy, wy2, 1, backend=backend,
                         config=config)
    assert _rel(got, want) <= autotune.PARITY_RTOL


# ------------------------------------------------------------ service plane
def test_engine_stats_surface_autotune(tune_cache):
    from repro.service.engine import CoresetEngine
    _seed_entry(_OP, "xla", _SIZE, us=10.0, numpy_us=100.0)
    assert ops.select_backend(_OP, _SIZE) == "xla"   # bump tuned_dispatch
    eng = CoresetEngine(cache_bytes=1 << 20, workers=1)
    try:
        st = eng.stats()
        assert st["ops_autotune"]["entries"] == 1
        assert st["ops_autotune"]["enabled"] is True
        counters = st["metrics"]["counters"]
        assert counters.get("ops_autotune_tuned_dispatch", 0) >= 1
        # render must expose the family for Prometheus scrapes
        eng.sync_autotune_metrics()
        assert "ops_autotune_tuned_dispatch" in eng.metrics.render()
    finally:
        eng.close()
