"""Cross-request query coalescing: the QueryScheduler must fuse concurrent
same-key loss queries into one batched dispatch with bitwise-faithful
answers, honour per-request deadlines without poisoning the batch, never
fuse across fusion keys (mixed k), and drain cleanly on engine shutdown."""
import threading
import time

import numpy as np
import pytest

from repro.client import CoresetAPIError, CoresetClient
from repro.core import random_tree_segmentation
from repro.data import piecewise_signal
from repro.service import (BuildScheduler, CoresetEngine, DeadlineExceeded,
                           QueryScheduler, ServiceMetrics, make_server,
                           serve_forever_in_thread)

N, M, K = 96, 64, 5


def _engine(**kw):
    kw.setdefault("workers", 2)
    kw.setdefault("metrics", ServiceMetrics())
    return CoresetEngine(**kw)


def _trees(n, seed=0, k=K):
    rng = np.random.default_rng(seed)
    return [random_tree_segmentation(N, M, k, rng) for _ in range(n)]


# --------------------------------------------------------------- unit level
def test_scheduler_fuses_within_window_and_scatters():
    sched = QueryScheduler(window=0.05, max_fuse=16)
    calls = []

    def execute(rects3, labels2):
        calls.append(rects3.shape)
        return np.arange(rects3.shape[0], dtype=np.float64)

    futs = [sched.submit(("key",), np.zeros((2, 4), np.int64),
                         np.zeros(2), execute) for _ in range(5)]
    out = [f.result(timeout=5) for f in futs]
    assert calls == [(5, 2, 4)]                 # ONE fused dispatch
    assert [loss for loss, _ in out] == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert all(fused == 5 for _, fused in out)  # every rider sees the batch
    sched.shutdown()


def test_scheduler_pads_mixed_leaf_counts_with_zero_area_rects():
    sched = QueryScheduler(window=0.05, max_fuse=16)
    seen = {}

    def execute(rects3, labels2):
        seen["rects"] = rects3.copy()
        return np.zeros(rects3.shape[0])

    fa = sched.submit(("k",), np.ones((2, 4), np.int64), np.ones(2), execute)
    fb = sched.submit(("k",), np.ones((4, 4), np.int64), np.ones(4), execute)
    fa.result(timeout=5), fb.result(timeout=5)
    r = seen["rects"]
    assert r.shape == (2, 4, 4)                 # padded to the max leaf count
    assert (r[0, 2:] == 0).all()                # zero-area padding rows
    sched.shutdown()


def test_scheduler_full_tile_flushes_early():
    sched = QueryScheduler(window=30.0, max_fuse=3)   # window would hang
    execute = lambda r, l: np.zeros(r.shape[0])  # noqa: E731
    futs = [sched.submit(("k",), np.zeros((1, 4), np.int64), np.zeros(1),
                         execute) for _ in range(3)]
    t0 = time.perf_counter()
    for f in futs:
        f.result(timeout=5)
    assert time.perf_counter() - t0 < 5          # flushed on full, not window
    assert sched.metrics.get('query_flushes{reason="full"}') == 1
    sched.shutdown()


def test_scheduler_deadline_expiry_fails_request_not_batch():
    sched = QueryScheduler(window=10.0, max_fuse=16, deadline_margin=0.0)
    execute = lambda r, l: np.full(r.shape[0], 7.0)  # noqa: E731
    keep = sched.submit(("k",), np.zeros((1, 4), np.int64), np.zeros(1),
                        execute)
    doomed = sched.submit(("k",), np.zeros((1, 4), np.int64), np.zeros(1),
                          execute,
                          deadline=time.perf_counter() + 0.05)
    # the doomed request's deadline trims the 10s window down to ~50ms; by
    # the time the flusher dispatches, its deadline has passed — it fails,
    # the co-queued request still serves
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=5)
    loss, fused = keep.result(timeout=5)
    assert loss == 7.0 and fused == 1
    assert sched.metrics.get("query_deadline_expired") == 1
    assert sched.metrics.get('query_flushes{reason="deadline"}') == 1
    sched.shutdown()


def test_scheduler_shutdown_drains_pending_queries():
    sched = QueryScheduler(window=60.0, max_fuse=16)
    execute = lambda r, l: np.full(r.shape[0], 3.0)  # noqa: E731
    fut = sched.submit(("k",), np.zeros((1, 4), np.int64), np.zeros(1),
                       execute)
    sched.shutdown()                             # must flush, not strand
    assert fut.result(timeout=5)[0] == 3.0
    assert sched.metrics.get('query_flushes{reason="drain"}') == 1
    with pytest.raises(RuntimeError):
        sched.submit(("k",), np.zeros((1, 4), np.int64), np.zeros(1), execute)


def test_scheduler_dispatches_inline_when_pool_rejects_popped_bucket():
    """Shutdown racing a full-tile pop must not strand the bucket: if the
    worker pool refuses the dispatch, it runs inline on the submitting
    thread and every rider's future still resolves."""
    sched = QueryScheduler(window=30.0, max_fuse=2)
    sched._pool.shutdown(wait=True)              # simulate the lost race
    execute = lambda r, l: np.arange(r.shape[0], dtype=float)  # noqa: E731
    futs = [sched.submit(("k",), np.zeros((1, 4), np.int64), np.zeros(1),
                         execute) for _ in range(2)]   # fills the tile
    assert [f.result(timeout=5)[0] for f in futs] == [0.0, 1.0]
    sched.shutdown()


def test_scheduler_executor_error_propagates_to_all_riders():
    sched = QueryScheduler(window=0.02, max_fuse=16)

    def execute(rects3, labels2):
        raise RuntimeError("kernel fell over")

    futs = [sched.submit(("k",), np.zeros((1, 4), np.int64), np.zeros(1),
                         execute) for _ in range(3)]
    for f in futs:
        with pytest.raises(RuntimeError, match="kernel fell over"):
            f.result(timeout=5)
    sched.shutdown()


def test_build_scheduler_skips_builds_every_waiter_abandoned():
    metrics = ServiceMetrics()
    sched = BuildScheduler(max_workers=1, batch_window=0.001, metrics=metrics)
    ran = []
    blocker, _ = sched.submit(("a",), lambda: (time.sleep(0.15), ran.append("a")))
    doomed, _ = sched.submit(("b",), lambda: ran.append("b"),
                             deadline=time.perf_counter() + 0.05)
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=5)                 # worker was busy past it
    blocker.result(timeout=5)
    assert ran == ["a"]                          # the dead build never ran
    assert metrics.get("builds_expired") == 1
    sched.shutdown()


# ------------------------------------------------------------- engine level
def test_engine_coalesces_concurrent_same_signal_queries():
    eng = _engine(query_window=0.05, query_max_fuse=16)
    eng.register_signal("s", piecewise_signal(N, M, K, noise=0.1, seed=1))
    eng.get_coreset("s", K, 0.3)
    trees = _trees(8, seed=2)
    serial = [eng.tree_loss("s", t.rects, t.labels, eps=0.3,
                            coalesce=False)["loss"] for t in trees]
    calls0 = eng.metrics.get("loss_scoring_calls")
    results = [None] * 8
    barrier = threading.Barrier(8)

    def worker(i):
        barrier.wait()
        t = trees[i]
        results[i] = eng.tree_loss("s", t.rects, t.labels, eps=0.3)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    dispatches = eng.metrics.get("loss_scoring_calls") - calls0
    assert dispatches < 8                        # fewer dispatches than queries
    assert eng.metrics.get("query_coalesced_total") == 8 - dispatches
    # bitwise: the numpy batched backend scores each tree through the exact
    # fitting_loss the uncoalesced path runs
    for i in range(8):
        assert results[i]["loss"] == serial[i]
        assert results[i]["fused_batch_size"] >= 1
    eng.close()


def test_engine_mixed_k_same_signal_never_fused():
    eng = _engine(query_window=0.1, query_max_fuse=16)
    eng.register_signal("s", piecewise_signal(N, M, K, noise=0.1, seed=3))
    for k in (4, 5):
        eng.get_coreset("s", k, 0.3)
    t = _trees(1, seed=4, k=4)[0]
    calls0 = eng.metrics.get("loss_scoring_calls")
    out = [None, None]
    barrier = threading.Barrier(2)

    def worker(slot, k):
        barrier.wait()
        out[slot] = eng.tree_loss("s", t.rects, t.labels, eps=0.3, k=k)

    threads = [threading.Thread(target=worker, args=(i, 4 + i))
               for i in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    # different k => different coreset => different fusion key: two
    # dispatches, no cross-contamination of the (k, eps) guarantee
    assert eng.metrics.get("loss_scoring_calls") - calls0 == 2
    assert out[0]["fused_batch_size"] == 1
    assert out[1]["fused_batch_size"] == 1
    assert out[0]["fingerprint"] != out[1]["fingerprint"]
    eng.close()


def test_engine_close_drains_inflight_queries():
    eng = _engine(query_window=30.0)             # window alone would strand
    eng.register_signal("s", piecewise_signal(N, M, K, noise=0.1, seed=5))
    eng.get_coreset("s", K, 0.3)
    t = _trees(1, seed=6)[0]
    box = {}

    def worker():
        box["r"] = eng.tree_loss("s", t.rects, t.labels, eps=0.3)

    th = threading.Thread(target=worker)
    th.start()
    # wait until the query is actually queued, then shut down
    for _ in range(500):
        if eng.queries.in_flight():
            break
        time.sleep(0.005)
    eng.close()
    th.join(timeout=10)
    assert not th.is_alive()
    assert box["r"]["fused_batch_size"] == 1
    ref = eng.metrics                             # engine is closed; counters live on
    assert ref.get('query_flushes{reason="drain"}') == 1


def test_engine_concurrency_hammer_losses_bitwise_vs_serial():
    """Property-style: threads hammering one signal with random trees and
    two k values must see bitwise-identical losses to the serial
    uncoalesced path, no matter how the scheduler batches them."""
    eng = _engine(query_window=0.004, query_max_fuse=8)
    eng.register_signal("s", piecewise_signal(N, M, K, noise=0.12, seed=7))
    for k in (4, 5):
        eng.get_coreset("s", k, 0.3)
    rng = np.random.default_rng(8)
    jobs = []
    for _ in range(48):
        k = int(rng.choice([4, 5]))
        t = random_tree_segmentation(N, M, k, rng)
        jobs.append((k, t))
    serial = [eng.tree_loss("s", t.rects, t.labels, eps=0.3, k=k,
                            coalesce=False)["loss"] for k, t in jobs]
    results = [None] * len(jobs)

    def worker(idx):
        k, t = jobs[idx]
        results[idx] = eng.tree_loss("s", t.rects, t.labels, eps=0.3,
                                     k=k)["loss"]

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(jobs))]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=60)
    assert results == serial                     # bitwise, every single one
    eng.close()


# --------------------------------------------------------------- HTTP level
def test_http_deadline_expiry_in_window_504_batch_survives():
    eng = _engine(query_window=0.25, query_max_fuse=16)
    eng.queries.deadline_margin = 0.0            # flush exactly at deadline
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    try:
        keeper = CoresetClient(base, retries=0)
        keeper.register_signal("s", piecewise_signal(N, M, K, seed=9))
        keeper.build("s", K, 0.3)
        t = _trees(1, seed=10)[0]
        box = {}

        def keep_worker():
            box["ok"] = keeper.query_loss("s", t.rects, t.labels, eps=0.3)

        th = threading.Thread(target=keep_worker)
        th.start()                               # waits out the 250ms window
        for _ in range(500):                     # until it is really queued
            if eng.queries.in_flight():
                break
            time.sleep(0.005)
        doomed = CoresetClient(base, retries=0)
        with pytest.raises(CoresetAPIError) as ei:
            # joins the keeper's bucket, trims flush to its own 60ms
            # deadline, and by dispatch time has expired
            doomed.query_loss("s", t.rects, t.labels, eps=0.3,
                              deadline_ms=60)
        assert ei.value.http == 504
        assert ei.value.code == "deadline_exceeded"
        th.join(timeout=30)
        # the co-batched request was served, not poisoned: same answer the
        # uncoalesced escape hatch gives
        ref = keeper.query_loss("s", t.rects, t.labels, eps=0.3,
                                coalesce=False)
        assert box["ok"].loss == ref.loss
        assert box["ok"].fused_batch_size == 1
        assert eng.metrics.get("query_deadline_expired") == 1
    finally:
        srv.shutdown()
        eng.close()


def test_http_coalesce_off_and_deadline_ok_roundtrip():
    eng = _engine(query_window=0.02)
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    try:
        c = CoresetClient(base, retries=0, deadline_ms=30_000)
        c.register_signal("s", piecewise_signal(N, M, K, seed=11))
        t = _trees(1, seed=12)[0]
        on = c.query_loss("s", t.rects, t.labels, eps=0.3)
        off = c.query_loss("s", t.rects, t.labels, eps=0.3, coalesce=False)
        assert on.loss == off.loss               # escape hatch parity
        assert on.backend and off.backend
        snap = eng.stats()
        assert snap["query_coalescing"]["enabled"]
        assert "queries_in_flight" in snap
    finally:
        srv.shutdown()
        eng.close()


def test_burst_delta_matches_sequential_deltas_and_batches_leaf_builds():
    rng = np.random.default_rng(13)
    bands = [rng.normal(size=(16, 24)) for _ in range(4)]
    new0, new2 = rng.normal(size=(16, 24)), rng.normal(size=(16, 24))

    eng_a = _engine()
    eng_b = _engine()
    for eng in (eng_a, eng_b):
        for b in bands:
            eng.ingest_band("s", b)
        eng.get_coreset("s", 3, 0.3)             # live merge-reduce builder
    # engine A: one burst; engine B: the same deltas one by one
    ra = eng_a.ingest_delta("s", np.concatenate([new0, new2]),
                            row0s=[0, 32], rows=[16, 16])
    eng_b.ingest_delta("s", new0, row0=0)
    rb = eng_b.ingest_delta("s", new2, row0=32)
    assert ra["mode"] == "burst" and ra["deltas"] == 2
    assert ra["version"] == rb["version"]        # same content fold
    ca, _, _ = eng_a.get_coreset("s", 3, 0.3)
    cb, _, _ = eng_b.get_coreset("s", 3, 0.3)
    assert ca.fingerprint() == cb.fingerprint()  # identical merge-reduce state
    assert eng_a.metrics.get("ingest_delta_leaf_builds_batched") == 2
    assert eng_a.metrics.get("query_fanout_batches") == 1
    eng_a.close()
    eng_b.close()


def test_burst_delta_is_atomic_on_mid_burst_validation_failure():
    """A malformed delta anywhere in a burst must reject the WHOLE burst:
    no version bump, no band mutation, and live builders still serve the
    pre-burst content (the review repro: a committed first delta with a
    skipped leaf swap served stale losses under the new version)."""
    rng = np.random.default_rng(14)
    bands = [rng.normal(size=(16, 24)) for _ in range(2)]
    eng = _engine()
    for b in bands:
        eng.ingest_band("s", b)
    eng.get_coreset("s", 3, 0.3)                 # live builder
    rects = np.array([[0, 32, 0, 24]])
    before = eng.tree_loss("s", rects, [0.1], eps=0.3, k=3)
    version0 = eng.signal("s").version
    with pytest.raises(ValueError, match="does not start an ingested band"):
        eng.ingest_delta("s", np.concatenate([rng.normal(size=(16, 24)),
                                              rng.normal(size=(16, 24))]),
                         row0s=[0, 3], rows=[16, 16])   # row0=3 misaligned
    assert eng.signal("s").version == version0   # nothing committed
    after = eng.tree_loss("s", rects, [0.1], eps=0.3, k=3)
    assert after["loss"] == before["loss"]
    assert after["fingerprint"] == before["fingerprint"]
    eng.close()


def test_burst_delta_rejects_rows_without_row0s():
    eng = _engine()
    eng.ingest_band("s", np.ones((16, 8)))
    with pytest.raises(ValueError, match="rows requires row0s"):
        eng.ingest_delta("s", np.ones((16, 8)), rows=[8, 8])
    eng.close()


# ------------------------------------------------- batched submissions (S2)
def test_scheduler_batch_submission_fuses_with_singles():
    """A client batch and a single query under one key must ride ONE fused
    dispatch; the batch future scatters its (T,) slice, the single its
    scalar, and every rider reports the total tree count."""
    sched = QueryScheduler(window=0.05, max_fuse=16)
    calls = []

    def execute(rects3, labels2):
        calls.append(rects3.shape)
        return np.arange(rects3.shape[0], dtype=np.float64)

    fb = sched.submit_batch(("k",), np.zeros((3, 2, 4), np.int64),
                            np.zeros((3, 2)), execute)
    fs = sched.submit(("k",), np.zeros((2, 4), np.int64), np.zeros(2),
                      execute)
    losses, fused_b = fb.result(timeout=5)
    loss, fused_s = fs.result(timeout=5)
    assert calls == [(4, 2, 4)]                  # ONE dispatch, 3+1 trees
    assert list(losses) == [0.0, 1.0, 2.0] and loss == 3.0
    assert fused_b == fused_s == 4
    # coalesced counts co-travelling REQUESTS (2 riders -> 1 coalesced)
    assert sched.metrics.get("query_coalesced_total") == 1
    sched.shutdown()


def test_scheduler_batch_fills_tile_and_flushes_early():
    sched = QueryScheduler(window=30.0, max_fuse=4)   # window would hang
    execute = lambda r, l: np.zeros(r.shape[0])  # noqa: E731
    fut = sched.submit_batch(("k",), np.zeros((4, 1, 4), np.int64),
                             np.zeros((4, 1)), execute)
    t0 = time.perf_counter()
    losses, fused = fut.result(timeout=5)
    assert time.perf_counter() - t0 < 5          # tree count filled the tile
    assert fused == 4 and losses.shape == (4,)
    assert sched.metrics.get('query_flushes{reason="full"}') == 1
    sched.shutdown()


def test_engine_batch_query_coalesces_with_concurrent_single():
    """/v1/query/loss:batch routed through the QueryScheduler: bitwise the
    uncoalesced answers, and a concurrent single against the same coreset
    fuses into the SAME dispatch (query_coalesced_total moves)."""
    y = piecewise_signal(N, M, K, noise=0.1, seed=5)
    eng = _engine(query_window=0.05)
    eng.register_signal("s", y)
    segs = _trees(6, seed=21)
    br = np.stack([s.rects for s in segs])
    bl = np.stack([s.labels for s in segs])
    ref = eng.tree_loss_batch("s", br, bl, eps=0.3, coalesce=False)
    c0 = eng.metrics.get("query_coalesced_total")
    d0 = eng.metrics.get("query_fused_dispatches")
    out = {}

    def batch():
        out["b"] = eng.tree_loss_batch("s", br, bl, eps=0.3)

    def single():
        out["s"] = eng.tree_loss("s", segs[0].rects, segs[0].labels, eps=0.3)

    threads = [threading.Thread(target=batch),
               threading.Thread(target=single)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert np.array_equal(out["b"]["losses"], ref["losses"])   # bitwise
    assert out["s"]["loss"] == ref["losses"][0]
    assert eng.metrics.get("query_fused_dispatches") - d0 == 1
    assert eng.metrics.get("query_coalesced_total") - c0 == 1
    assert out["b"]["fused_batch_size"] == out["s"]["fused_batch_size"] == 7
    eng.close()


def test_http_batch_coalesce_flag_round_trips():
    """The wire-level coalesce=False escape hatch on /v1/query/loss:batch
    still answers identically (it scores inline, off the scheduler)."""
    y = piecewise_signal(N, M, K, noise=0.1, seed=6)
    eng = _engine()
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    try:
        cl = CoresetClient(base, encoding="binary")
        cl.register_signal("s", values=y)
        segs = _trees(4, seed=31)
        br = np.stack([s.rects for s in segs])
        bl = np.stack([s.labels for s in segs])
        r_on = cl.query_loss_batch("s", br, bl, eps=0.3)
        f0 = eng.metrics.get("query_fused_dispatches")
        r_off = cl.query_loss_batch("s", br, bl, eps=0.3, coalesce=False)
        assert np.array_equal(r_on.losses, r_off.losses)
        assert eng.metrics.get("query_fused_dispatches") == f0  # inline
    finally:
        srv.shutdown()
        eng.close()
