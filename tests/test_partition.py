"""Algorithms 1-2: slice partition and balanced partition invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import PrefixStats, balanced_partition, slice_partition


def naive_slice_partition(ps, r0, r1, sigma):
    """The paper's linear greedy scan (reference for the binary search)."""
    m = ps.shape[1]
    out = []
    c0 = 0
    while c0 < m:
        if ps.opt1(r0, r1, c0, c0 + 1) > sigma:
            rr = r0
            while rr < r1:
                re = rr + 1
                while re < r1 and ps.opt1(rr, re + 1, c0, c0 + 1) <= sigma:
                    re += 1
                out.append((rr, re, c0, c0 + 1))
                rr = re
            c0 += 1
        else:
            ce = c0 + 1
            while ce < m and ps.opt1(r0, r1, c0, ce + 1) <= sigma:
                ce += 1
            out.append((r0, r1, c0, ce))
            c0 = ce
    return out


@st.composite
def small_signal(draw):
    n = draw(st.integers(2, 10))
    m = draw(st.integers(2, 14))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    scale = draw(st.sampled_from([0.1, 1.0, 5.0]))
    return rng.normal(size=(n, m)) * scale


@settings(max_examples=40, deadline=None)
@given(small_signal(), st.sampled_from([0.0, 0.05, 0.5, 5.0, 100.0]))
def test_slice_partition_matches_naive_greedy(y, sigma):
    ps = PrefixStats.build(y)
    n = y.shape[0]
    got = slice_partition(ps, 0, n, sigma)
    ref = naive_slice_partition(ps, 0, n, sigma)
    assert got == ref


@settings(max_examples=40, deadline=None)
@given(small_signal(), st.sampled_from([0.0, 0.1, 1.0, 20.0]),
       st.integers(1, 8))
def test_balanced_partition_tiles_and_respects_tolerance(y, tol, max_slices):
    ps = PrefixStats.build(y)
    part = balanced_partition(ps, tol, max_slices)
    n, m = y.shape
    raster = part.block_id_raster(n, m)        # raises if not a tiling
    assert raster.min() >= 0
    r = part.rects
    opt1s = ps.opt1(r[:, 0], r[:, 1], r[:, 2], r[:, 3])
    assert (opt1s <= tol + 1e-9).all()


def test_balanced_partition_constant_signal_is_one_block():
    y = np.full((20, 30), 3.25)
    ps = PrefixStats.build(y)
    part = balanced_partition(ps, 0.0, 16)
    assert part.num_blocks == 1
