"""PrefixStats: O(1) rectangle moments vs brute force; monotone opt1."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import PrefixStats


def brute_opt1(y, r0, r1, c0, c1, mask=None):
    blk = y[r0:r1, c0:c1]
    if mask is not None:
        sel = mask[r0:r1, c0:c1]
        blk = blk[sel]
    blk = np.asarray(blk, float).ravel()
    if blk.size == 0:
        return 0.0
    return float(((blk - blk.mean()) ** 2).sum())


@st.composite
def signal_and_rect(draw):
    n = draw(st.integers(2, 12))
    m = draw(st.integers(2, 12))
    y = draw(st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                      min_size=n * m, max_size=n * m))
    y = np.asarray(y, np.float64).reshape(n, m)
    r0 = draw(st.integers(0, n - 1)); r1 = draw(st.integers(r0 + 1, n))
    c0 = draw(st.integers(0, m - 1)); c1 = draw(st.integers(c0 + 1, m))
    return y, (r0, r1, c0, c1)


@settings(max_examples=60, deadline=None)
@given(signal_and_rect())
def test_opt1_matches_bruteforce(case):
    y, (r0, r1, c0, c1) = case
    ps = PrefixStats.build(y)
    assert np.isclose(ps.opt1(r0, r1, c0, c1), brute_opt1(y, r0, r1, c0, c1),
                      rtol=1e-8, atol=1e-6)
    assert np.isclose(ps.opt1_scalar(r0, r1, c0, c1),
                      brute_opt1(y, r0, r1, c0, c1), rtol=1e-8, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(signal_and_rect())
def test_opt1_monotone_in_extension(case):
    """The property the binary-search greedy relies on."""
    y, (r0, r1, c0, c1) = case
    ps = PrefixStats.build(y)
    m = y.shape[1]
    vals = [float(ps.opt1(r0, r1, c0, c)) for c in range(c0 + 1, m + 1)]
    assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:]))


def test_masked_and_weighted_moments():
    rng = np.random.default_rng(0)
    y = rng.normal(size=(9, 7))
    mask = rng.uniform(size=(9, 7)) < 0.6
    ps = PrefixStats.build(y, mask=mask)
    assert np.isclose(ps.opt1(0, 9, 0, 7), brute_opt1(y, 0, 9, 0, 7, mask))
    s0, s1, s2 = ps.sums(2, 8, 1, 6)
    sel = mask[2:8, 1:6]
    assert np.isclose(s0, sel.sum())
    assert np.isclose(s1, y[2:8, 1:6][sel].sum())


def test_transpose_consistency():
    rng = np.random.default_rng(1)
    y = rng.normal(size=(6, 11))
    ps = PrefixStats.build(y)
    pt = ps.transpose()
    assert np.isclose(ps.opt1(1, 5, 2, 9), pt.opt1(2, 9, 1, 5))


def test_max_col_extent_matches_linear_scan():
    rng = np.random.default_rng(2)
    y = rng.normal(size=(5, 40)) * np.linspace(0.1, 3, 40)[None, :]
    ps = PrefixStats.build(y)
    for sigma in (0.1, 1.0, 10.0, 100.0):
        for c0 in (0, 7, 33):
            got = ps.max_col_extent(0, 5, c0, sigma)
            # linear reference
            ref = c0
            for c in range(c0 + 1, 41):
                if ps.opt1(0, 5, c0, c) <= sigma:
                    ref = c
                else:
                    break
            assert got == ref
