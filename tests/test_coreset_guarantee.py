"""Theorem 8 / Definition 3: the (k, eps) guarantee, end to end.

Three layers of evidence:
  * piecewise-constant signals: sigma = 0 -> the coreset is EXACT for every
    segmentation (zero-tolerance blocks);
  * random/noisy signals: |loss_C(s) - loss_D(s)| <= eps * loss_D(s) for
    random k-trees AND for near-optimal greedy trees (the adversarial case);
  * mass/moment conservation invariants.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (PrefixStats, fitting_loss, greedy_tree,
                        random_tree_segmentation, signal_coreset, true_loss)
from repro.data import piecewise_signal


def rel_err(cs, y, seg, ps=None):
    tl = true_loss(y, seg.rects, seg.labels, ps=ps)
    cl = fitting_loss(cs, seg.rects, seg.labels)
    return abs(cl - tl) / max(tl, 1e-12), tl


def test_piecewise_constant_coreset_is_exact():
    """opt_k(D) = 0 -> certified sigma = 0 -> zero-tolerance blocks -> the
    coreset reproduces every segmentation loss exactly.  (The default
    sigma_mode="auto" adds a heuristic floor from the greedy tree and is
    near-exact only; certified mode has the hard guarantee.)"""
    rng = np.random.default_rng(0)
    y = piecewise_signal(48, 64, 6, noise=0.0, seed=1)
    cs = signal_coreset(y, 6, 0.3, sigma_mode="certified")
    ps = PrefixStats.build(y)
    for t in range(10):
        q = random_tree_segmentation(48, 64, 6, rng)
        tl = true_loss(y, q.rects, q.labels, ps=ps)
        cl = fitting_loss(cs, q.rects, q.labels)
        assert np.isclose(cl, tl, rtol=1e-9, atol=1e-6), (cl, tl)


@pytest.mark.parametrize("eps", [0.4, 0.2, 0.1])
@pytest.mark.parametrize("k,n,m,noise", [(10, 120, 150, 0.1),
                                         (40, 150, 120, 0.25)])
def test_eps_guarantee_random_and_greedy_trees(eps, k, n, m, noise):
    rng = np.random.default_rng(7)
    y = piecewise_signal(n, m, k, noise=noise, seed=5)
    cs = signal_coreset(y, k, eps)
    ps = PrefixStats.build(y)
    errs = []
    for _ in range(12):
        q = random_tree_segmentation(n, m, k, rng)
        e, _ = rel_err(cs, y, q, ps)
        errs.append(e)
    g = greedy_tree(ps, k)
    ge, _ = rel_err(cs, y, g, ps)
    assert max(errs) <= eps, f"random-tree err {max(errs)} > eps {eps}"
    assert ge <= eps, f"greedy-tree err {ge} > eps {eps}"


def test_mass_and_moment_conservation():
    y = piecewise_signal(60, 60, 8, noise=0.2, seed=2)
    cs = signal_coreset(y, 8, 0.25)
    assert np.isclose(cs.total_mass(), 3600)
    assert np.allclose(cs.weights.sum(1), cs.moments[:, 0])
    assert np.allclose((cs.weights * cs.labels).sum(1), cs.moments[:, 1],
                       atol=1e-6)
    assert np.allclose((cs.weights * cs.labels ** 2).sum(1), cs.moments[:, 2],
                       atol=1e-5)
    # the constant-fit loss of the whole signal is reproduced exactly
    whole = np.array([[0, 60, 0, 60]])
    mu = np.array([y.mean()])
    assert np.isclose(fitting_loss(cs, whole, mu),
                      true_loss(y, whole, mu), rtol=1e-9)


def test_size_shrinks_with_eps():
    y = piecewise_signal(150, 150, 12, noise=0.15, seed=3)
    sizes = [signal_coreset(y, 12, e).size for e in (0.1, 0.2, 0.4)]
    assert sizes[0] >= sizes[1] >= sizes[2]


def test_masked_construction_only_counts_observed_cells():
    rng = np.random.default_rng(4)
    y = piecewise_signal(40, 50, 5, noise=0.1, seed=6)
    mask = rng.uniform(size=y.shape) < 0.7
    cs = signal_coreset(y, 5, 0.3, mask=mask)
    assert np.isclose(cs.total_mass(), mask.sum())


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 1000))
def test_guarantee_property_random_signals(seed):
    """Pure-noise signals (no structure at all), eps = 0.3."""
    rng = np.random.default_rng(seed)
    y = rng.normal(size=(64, 80))
    k, eps = 6, 0.3
    cs = signal_coreset(y, k, eps)
    ps = PrefixStats.build(y)
    q = random_tree_segmentation(64, 80, k, rng)
    e, _ = rel_err(cs, y, q, ps)
    assert e <= eps
