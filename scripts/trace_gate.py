#!/usr/bin/env python
"""ci_smoke ``trace`` gate: end-to-end tracing MUST hold across the stack.

Boots the full HTTP service in-process, drives it through the typed SDK,
and asserts the observability pipeline end to end:

  * **propagation** — the client-minted W3C ``traceparent`` id IS the
    server-side trace id (echoed in ``X-Coreset-Trace-Id`` and retrievable
    at ``GET /v1/trace/{id}``);
  * **taxonomy** — a single coalesced ``/v1/query/loss`` trace contains the
    http root, ``query.scheduler_wait``, and (via its linked fused-dispatch
    trace) an ``ops.dispatch`` span carrying op/backend attributes;
  * **coverage** — the root span's direct children account for >= 80% of
    its wall time (the trace explains where the request went, it does not
    just bracket it);
  * **linking** — a barrier-released burst of concurrent same-signal
    queries produces >= 2 request traces linked to ONE shared
    ``query.fused_dispatch`` trace;
  * **export** — ``?format=chrome`` returns structurally valid Chrome
    trace-event JSON (Perfetto-loadable: X events with ts/dur, process
    metadata, flow events along links).

Run:  python scripts/trace_gate.py [--n 8] [--window 0.1]
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import threading

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.client import CoresetClient  # noqa: E402
from repro.core.segmentation import random_tree_segmentation  # noqa: E402
from repro.data.signals import piecewise_signal  # noqa: E402
from repro.service import (CoresetEngine, make_server,  # noqa: E402
                           serve_forever_in_thread)

MIN_COVERAGE = 0.80


def span_names(trace: dict) -> list[str]:
    return [s["name"] for s in trace["spans"]]


def root_of(trace: dict) -> dict:
    # the root is the span whose span_id no other span claims as parent of
    # itself — i.e. the one with no in-trace parent
    ids = {s["span_id"] for s in trace["spans"]}
    roots = [s for s in trace["spans"]
             if s.get("parent_id") not in ids]
    assert len(roots) == 1, f"expected one root, got {len(roots)}"
    return roots[0]


def child_coverage(trace: dict) -> float:
    """Fraction of the root span's duration covered by its direct
    children (union of their intervals, so overlap is not double-counted)."""
    root = root_of(trace)
    if root["duration_us"] <= 0:
        return 1.0
    kids = [s for s in trace["spans"]
            if s.get("parent_id") == root["span_id"]]
    ivals = sorted((s["start_us"], s["start_us"] + s["duration_us"])
                   for s in kids)
    covered, cursor = 0.0, None
    for a, b in ivals:
        if cursor is None or a > cursor:
            covered += b - a
            cursor = b
        elif b > cursor:
            covered += b - cursor
            cursor = b
    return covered / root["duration_us"]


def check_chrome(doc: dict) -> list[str]:
    errs = []
    if "traceEvents" not in doc or not isinstance(doc["traceEvents"], list):
        return ["chrome export missing traceEvents list"]
    evs = doc["traceEvents"]
    xs = [e for e in evs if e.get("ph") == "X"]
    if not xs:
        errs.append("chrome export has no complete (X) events")
    for e in xs:
        for field in ("name", "ts", "dur", "pid", "tid"):
            if field not in e:
                errs.append(f"X event missing {field!r}: {e}")
                break
    if not any(e.get("ph") == "M" and e.get("name") == "process_name"
               for e in evs):
        errs.append("chrome export missing process_name metadata")
    if not any(e.get("ph") == "s" for e in evs):
        errs.append("chrome export missing flow (link) events")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8,
                    help="concurrent queries for the link check")
    ap.add_argument("--window", type=float, default=0.1,
                    help="server batching window (generous: CI boxes jitter)")
    ap.add_argument("--rows", type=int, default=160)
    ap.add_argument("--cols", type=int, default=96)
    ap.add_argument("--k", type=int, default=6)
    args = ap.parse_args()
    n = int(args.n)

    eng = CoresetEngine(query_window=args.window, query_max_fuse=n, workers=4)
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"

    y = piecewise_signal(args.rows, args.cols, args.k, noise=0.15, seed=7)
    cl = CoresetClient(base, retries=0)
    cl.register_signal("gate", y)
    cl.build("gate", args.k, 0.3)   # pre-build: traces measure the query path

    rng = np.random.default_rng(7)
    tree = random_tree_segmentation(args.rows, args.cols, args.k, rng)

    fails: list[str] = []

    # ---- 1. propagation: client-minted id == server trace id
    r = cl.query_loss("gate", tree.rects, tree.labels, eps=0.3)
    sent_id = (cl.last_traceparent or "").split("-")[1] \
        if cl.last_traceparent else ""
    if not sent_id or cl.last_trace_id != sent_id:
        fails.append(f"traceparent did not propagate: sent {sent_id!r}, "
                     f"server answered {cl.last_trace_id!r}")
    query_tid = cl.last_trace_id
    trace = cl.trace(query_tid)
    names = span_names(trace)
    print(f"[trace_gate] trace {trace['trace_id'][:8]}: {names}")

    # ---- 2. taxonomy: required spans, in the trace or its linked traces
    if not any(nm.startswith("POST /v1/query/loss") for nm in names):
        fails.append(f"no http root span in {names}")
    if "query.scheduler_wait" not in names:
        fails.append(f"no query.scheduler_wait span in {names}")
    linked = trace.get("linked_traces", [])
    linked_spans = [s for lt in linked for s in lt["spans"]]
    fused = [s for s in linked_spans if s["name"] == "query.fused_dispatch"]
    if not fused:
        fails.append("request trace links to no query.fused_dispatch trace")
    dispatches = [s for s in trace["spans"] + linked_spans
                  if s["name"] == "ops.dispatch"]
    if not dispatches:
        fails.append("no ops.dispatch span anywhere in the trace graph")
    elif not all(s.get("attrs", {}).get("op")
                 and s.get("attrs", {}).get("backend") for s in dispatches):
        fails.append(f"ops.dispatch span missing op/backend attrs: "
                     f"{[s.get('attrs') for s in dispatches]}")

    # ---- 3. coverage: direct children explain >= 80% of the root
    cov = child_coverage(trace)
    print(f"[trace_gate] root child coverage {cov:.1%} "
          f"(required >= {MIN_COVERAGE:.0%})")
    if cov < MIN_COVERAGE:
        fails.append(f"child spans cover only {cov:.1%} of the request root")

    # ---- 4. linking: a concurrent burst shares ONE fused-dispatch trace
    trees = [random_tree_segmentation(args.rows, args.cols, args.k, rng)
             for _ in range(n)]
    tids: list = [None] * n
    barrier = threading.Barrier(n)

    def worker(i: int) -> None:
        client = CoresetClient(base, retries=0)
        barrier.wait()
        client.query_loss("gate", trees[i].rects, trees[i].labels, eps=0.3)
        tids[i] = client.last_trace_id

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    if any(t is None for t in tids):
        fails.append("concurrent burst: some requests never completed")
    else:
        # fused trace id each request linked to, counted across the burst
        fused_of: dict[str, int] = {}
        for tid in tids:
            tr = cl.trace(tid)
            for s in tr["spans"]:
                for link in s.get("links", ()):
                    if any(lt["trace_id"] == link["trace_id"]
                           and lt["root"] == "query.fused_dispatch"
                           for lt in tr.get("linked_traces", [])):
                        fused_of[link["trace_id"]] = \
                            fused_of.get(link["trace_id"], 0) + 1
        best = max(fused_of.values(), default=0)
        print(f"[trace_gate] burst of {n}: fused-trace fan-in {fused_of} "
              f"(best {best}, required >= 2)")
        if best < 2:
            fails.append("no fused-dispatch trace is linked from >= 2 "
                         "request traces")

    # ---- 5. chrome export is structurally valid
    chrome = cl.trace(query_tid, format="chrome")
    errs = check_chrome(chrome)
    if errs:
        fails.extend(errs)
    else:
        print(f"[trace_gate] chrome export: "
              f"{len(chrome['traceEvents'])} events OK")

    srv.shutdown()
    eng.close()

    for f in fails:
        print(f"[trace_gate] FAIL: {f}")
    print(f"[trace_gate] {'PASS' if not fails else 'FAIL'}")
    return 0 if not fails else 1


if __name__ == "__main__":
    sys.exit(main())
