#!/usr/bin/env python
"""ci_smoke ``cluster`` gate: the distributed serving plane, end to end.

Boots the cluster the way an operator would — 1 coordinator + 3 workers as
SEPARATE PROCESSES via ``python -m repro.launch.serve_coresets --role ...``
— drives the coordinator's full v1 API through the typed SDK, and asserts
the invariants the plane is built on:

  * a coreset gathered from 3 remote band builds is **bitwise
    fingerprint-equal** to the single-host thread-pool build, and every
    loss answer is within 1e-9 of the single-host engine;
  * killing a worker degrades gracefully: requests keep answering 200
    (never a 5xx storm), the composed coreset keeps the SAME fingerprint
    (the coordinator rebuilds the orphaned band locally with the identical
    tolerance), and only ``cluster.degraded_builds`` moves;
  * restarting an EMPTY worker on the same port rejoins it: the
    content-addressed no_band/stale_band heal re-assigns the slab, no new
    degraded builds happen, and ``cluster.worker_rejoins`` ticks.

Run:  python scripts/cluster_gate.py [--reprobe 0.5]
"""
from __future__ import annotations

import argparse
import os
import pathlib
import re
import subprocess
import sys
import threading
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np  # noqa: E402

from repro.client import CoresetClient  # noqa: E402
from repro.core.segmentation import random_tree_segmentation  # noqa: E402
from repro.data.signals import piecewise_signal  # noqa: E402
from repro.service import CoresetEngine  # noqa: E402

N, M, K, EPS = 96, 64, 6, 0.3
_URL_RE = re.compile(r"listening on (http://[\d.]+:\d+)")


class _Proc:
    """A serve_coresets subprocess plus a drain thread over its stdout."""

    def __init__(self, role_args: list[str]):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                     if env.get("PYTHONPATH") else "")
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.serve_coresets", *role_args],
            cwd=ROOT, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True, bufsize=1)
        self.lines: list[str] = []
        self.url: str | None = None
        threading.Thread(target=self._drain, daemon=True).start()

    def _drain(self) -> None:
        for line in self.proc.stdout:
            self.lines.append(line)
            m = _URL_RE.search(line)
            if m and self.url is None:
                self.url = m.group(1)

    def wait_url(self, timeout: float = 60.0) -> str:
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < timeout:
            if self.url:
                return self.url
            if self.proc.poll() is not None:
                break
            time.sleep(0.02)
        raise RuntimeError("subprocess never reported its URL:\n"
                           + "".join(self.lines))

    def kill(self) -> None:
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=10)


def _port(url: str) -> int:
    return int(url.rsplit(":", 1)[1])


def _parity(client: CoresetClient, single: CoresetEngine, name: str,
            y: np.ndarray, errors: list[str], *, queries: int = 4) -> None:
    """Register + build + query ``name`` on both planes; any fingerprint or
    loss divergence is appended to ``errors``."""
    client.register_signal(name, values=y)
    single.register_signal(name, y)
    rb = client.build(name, K, EPS)
    cs, _, _ = single.get_coreset(name, K, EPS)
    if rb.fingerprint != cs.fingerprint():
        errors.append(f"{name}: cluster fingerprint {rb.fingerprint[:12]} != "
                      f"single-host {cs.fingerprint()[:12]}")
    rng = np.random.default_rng(hash(name) % (1 << 31))
    for _ in range(queries):
        q = random_tree_segmentation(N, M, K, rng)
        rc = client.query_loss(name, q.rects, q.labels, eps=EPS)
        ls = single.tree_loss(name, q.rects, q.labels, eps=EPS)["loss"]
        if abs(rc.loss - ls) > 1e-9:
            errors.append(f"{name}: loss off single-host by "
                          f"{abs(rc.loss - ls):.2e} > 1e-9")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reprobe", type=float, default=0.5,
                    help="coordinator down-worker cooldown seconds")
    ap.add_argument("--rpc-timeout", type=float, default=15.0)
    args = ap.parse_args()

    procs: list[_Proc] = []
    single = CoresetEngine(num_bands=3, workers=4)
    errors: list[str] = []
    try:
        workers = [_Proc(["--role", "worker", "--host", "127.0.0.1",
                          "--port", "0", "--worker-id", f"gate-w{i}"])
                   for i in range(3)]
        procs += workers
        peer_urls = [w.wait_url() for w in workers]
        coord = _Proc(["--role", "coordinator", "--host", "127.0.0.1",
                       "--port", "0", "--peers", ",".join(peer_urls),
                       "--reprobe-s", str(args.reprobe),
                       "--rpc-timeout", str(args.rpc_timeout)])
        procs.append(coord)
        base = coord.wait_url()
        client = CoresetClient(base, retries=0)
        print(f"[cluster_gate] coordinator {base}, workers "
              f"{[_port(u) for u in peer_urls]}")

        # ---- healthy plane: bitwise fingerprint + 1e-9 loss parity
        _parity(client, single, "sig", piecewise_signal(N, M, K, seed=7),
                errors)
        st = client.stats()["cluster"]
        if st["degraded_builds"] != 0:
            errors.append(f"healthy build degraded {st['degraded_builds']}x")
        if [p["up"] for p in st["peers"]] != [True] * 3:
            errors.append(f"healthy plane reports down peers: {st['peers']}")
        print(f"[cluster_gate] healthy: fingerprint parity OK, "
              f"gathers={st['gathers']} degraded=0")

        # ---- kill a worker: degrade, never 5xx, identical bytes
        victim = workers[1]
        victim_port = _port(peer_urls[1])
        victim.kill()
        _parity(client, single, "sig-degraded",
                piecewise_signal(N, M, K, seed=8), errors, queries=6)
        st = client.stats()["cluster"]
        degraded = st["degraded_builds"]
        if degraded < 1:
            errors.append("worker killed but no degraded build recorded")
        if all(p["up"] for p in st["peers"]):
            errors.append("killed worker still reported up")
        print(f"[cluster_gate] degraded: parity survives worker kill "
              f"(degraded_builds={degraded}, all requests 200)")

        # ---- rejoin: empty worker on the SAME port heals via re-assign
        fresh = _Proc(["--role", "worker", "--host", "127.0.0.1",
                       "--port", str(victim_port), "--worker-id", "gate-w1b"])
        procs.append(fresh)
        fresh.wait_url()
        time.sleep(args.reprobe + 0.2)   # let the cooldown lapse
        _parity(client, single, "sig-rejoin",
                piecewise_signal(N, M, K, seed=9), errors)
        st = client.stats()["cluster"]
        if st["degraded_builds"] != degraded:
            errors.append(f"rejoin still degraded: {st['degraded_builds']} "
                          f"builds vs {degraded} before restart")
        if st["worker_rejoins"] < 1:
            errors.append("restarted worker never marked rejoined")
        if not all(p["up"] for p in st["peers"]):
            errors.append(f"rejoined plane reports down peers: {st['peers']}")
        print(f"[cluster_gate] rejoin: worker back on :{victim_port}, "
              f"rejoins={st['worker_rejoins']}, degraded stayed {degraded}")
    except Exception as exc:  # noqa: BLE001
        errors.append(f"{type(exc).__name__}: {exc}")
    finally:
        for p in procs:
            p.kill()
        single.close()

    for e in errors:
        print(f"[cluster_gate] FAIL: {e}")
    print(f"[cluster_gate] {'PASS' if not errors else 'FAIL'}")
    return 0 if not errors else 1


if __name__ == "__main__":
    sys.exit(main())
