#!/usr/bin/env python
"""ci_smoke ``stream`` gate: cache re-anchoring + v2 chunked streaming.

Boots one in-process server and asserts the two acceptance invariants of
the version-aware write path and the v2 wire protocol:

  * **zero-rebuild re-anchor**: after a disjoint append delta against a
    streamed signal, every subsequent build/compress/loss is served off
    the re-anchored cache entry — ``coreset_builds`` does not move,
    ``cache_reanchored`` does, and the served coreset is **bitwise
    fingerprint-equal** to a from-scratch build of the grown signal;
  * **v2 streaming**: a >= 4 MB compress response negotiated with
    ``Accept: <binary>;v=2`` leaves the server as >= 4 default-size
    chunked segments, the client's incremental decode is identical to the
    buffered v1 body, and a truncated or corrupted stream is rejected as
    ``StreamTruncated`` (retryable) / ``ProtocolError`` (terminal), never
    silently mis-decoded.

Run:  python scripts/stream_gate.py
"""
from __future__ import annotations

import io
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np  # noqa: E402

from repro.client import CoresetClient  # noqa: E402
from repro.data.signals import piecewise_signal  # noqa: E402
from repro.service import (CoresetEngine, ServiceMetrics,  # noqa: E402
                           make_server, serve_forever_in_thread)
from repro.service import protocol as P  # noqa: E402

M, ROWS, K, EPS = 64, 12, 5, 0.3


def _gate(ok: bool, msg: str) -> None:
    if not ok:
        sys.exit(f"[stream_gate] FAIL: {msg}")


def check_reanchor(base: str, eng: CoresetEngine) -> None:
    cl = CoresetClient(base, encoding="binary")
    bands = [piecewise_signal(ROWS, M, K, noise=0.15, seed=s)
             for s in range(5)]
    for b in bands[:-1]:
        cl.ingest("gate-st", band=b)
    cl.build("gate-st", K, EPS)
    builds = eng.metrics.get("coreset_builds")
    r = cl.ingest_delta("gate-st", bands[-1])            # disjoint append
    _gate(r.entries_reanchored == 1,
          f"append did not re-anchor (entries_reanchored="
          f"{r.entries_reanchored})")
    b2 = cl.build("gate-st", K, EPS)
    comp = cl.compress("gate-st", K, EPS, max_points=1 << 20)
    _gate(b2.served_from == "exact" and comp.served_from == "exact",
          f"post-delta requests not served from cache "
          f"({b2.served_from}/{comp.served_from})")
    _gate(eng.metrics.get("coreset_builds") == builds,
          "re-anchored delta still triggered a rebuild")
    _gate(eng.metrics.get("cache_reanchored") == 1,
          "cache_reanchored counter did not move")
    # bitwise parity with a from-scratch build of the grown signal
    ref = CoresetEngine(workers=2, metrics=ServiceMetrics())
    try:
        for b in bands:
            ref.ingest_band("gate-st", b)
        cs_ref, _, _ = ref.get_coreset("gate-st", K, EPS)
        _gate(b2.fingerprint == cs_ref.fingerprint(),
              "re-anchored coreset is not bitwise equal to a fresh build")
    finally:
        ref.close()
    print(f"[stream_gate] re-anchor: 1 entry re-keyed, builds stayed at "
          f"{builds}, fingerprint {b2.fingerprint} == fresh build")


def check_stream(base: str, eng: CoresetEngine) -> None:
    # block-rich signal: >= 4 MB of weighted points at eps=0.01
    y = np.random.default_rng(9).random((256, 256)) * 8.0
    v1 = CoresetClient(base, encoding="binary", stream=False)
    v2 = CoresetClient(base, encoding="binary")
    v1.register_signal("gate-big", values=y)
    kw = dict(eps=0.01, max_points=1 << 20)
    r2 = v2.compress("gate-big", 3, **kw)
    nbytes = r2.X.nbytes + r2.y.nbytes + r2.w.nbytes
    _gate(nbytes >= 4 << 20, f"coreset too small to gate ({nbytes}B)")
    _gate(v2.last_stream_chunks >= 4,
          f"{nbytes}B compress streamed in {v2.last_stream_chunks} < 4 "
          f"chunks")
    _gate(eng.metrics.get("http_stream_responses") >= 1,
          "server never took the streaming path")
    r1 = v1.compress("gate-big", 3, **kw)
    for f in ("X", "y", "w"):
        _gate(np.array_equal(getattr(r1, f), getattr(r2, f)),
              f"v2-decoded {f} differs from the buffered v1 body")
    _gate(r1.fingerprint == r2.fingerprint, "fingerprint mismatch across "
                                            "protocol versions")
    # wire-level rejection: truncation is retryable, corruption terminal
    segs = list(P.compress_stream_segments(r2, chunk_points=4096))
    blob = b"".join(segs)
    try:
        P.read_compress_stream(io.BytesIO(blob[:len(blob) // 2]).read)
        _gate(False, "truncated stream decoded without error")
    except P.StreamTruncated:
        pass
    bad = bytearray(blob)
    bad[len(segs[0]) + 40] ^= 0xFF
    try:
        P.read_compress_stream(io.BytesIO(bytes(bad)).read)
        _gate(False, "corrupted stream decoded without error")
    except P.StreamTruncated:
        _gate(False, "corruption misclassified as retryable truncation")
    except P.ProtocolError:
        pass
    print(f"[stream_gate] stream: {nbytes >> 20} MB compress in "
          f"{v2.last_stream_chunks} chunks, v1/v2 bitwise equal, "
          f"truncation/corruption rejected")


def main() -> int:
    eng = CoresetEngine(workers=4, metrics=ServiceMetrics())
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    try:
        check_reanchor(base, eng)
        check_stream(base, eng)
    finally:
        srv.shutdown()
        eng.close()
    print("[stream_gate] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
