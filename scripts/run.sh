#!/usr/bin/env bash
# Production launcher for the coreset server: process-level runtime hygiene
# that cannot be set from inside Python, then exec the real entrypoint.
#
#   scripts/run.sh [serve_coresets args...]
#
# What it sets (all overridable from the caller's environment):
#
#   LD_PRELOAD=libtcmalloc          glibc malloc fragments badly under the
#                                   allocate-free churn of per-request numpy
#                                   buffers; tcmalloc's thread caches also
#                                   cut lock contention in the worker pool.
#                                   Skipped with a notice when absent.
#   TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD
#                                   raise the report threshold so routine
#                                   multi-GB SAT allocations do not spam
#                                   stderr on every large signal.
#   TF_CPP_MIN_LOG_LEVEL=4          silence the XLA/TSL C++ banner noise on
#                                   every worker boot.
#   JAX_COMPILATION_CACHE_DIR       persistent jit cache across restarts
#                                   (serve_coresets applies it via
#                                   jax.config at startup).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-17179869184}"
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-$HOME/.cache/repro/jax_cache}"

if [ -z "${LD_PRELOAD:-}" ]; then
  for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
            /usr/lib/libtcmalloc.so.4 \
            /opt/conda/lib/libtcmalloc.so.4; do
    if [ -e "$so" ]; then
      export LD_PRELOAD="$so"
      break
    fi
  done
  if [ -z "${LD_PRELOAD:-}" ]; then
    echo "[run.sh] tcmalloc not found: serving with glibc malloc" >&2
  fi
fi

exec python -m repro.launch.serve_coresets "$@"
