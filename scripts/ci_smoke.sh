#!/usr/bin/env bash
# CI smoke: tier-1 suite + the serve_coresets self-check + a 2-second
# closed-loop loadgen per wire encoding, so serving-path regressions fail
# fast.  The final gate asserts the v1 binary frame actually beats JSON on
# 512x512 signal registration (the ROADMAP's "JSON array parsing dominates"
# fix) using the per-mode results both runs merged into
# benchmarks/results/bench_service.json.
#
#   scripts/ci_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -q

echo "== serve_coresets smoke (concurrent SDK clients, both encodings) =="
python -m repro.launch.serve_coresets --smoke

echo "== bench_service loadgen smoke (2s, json encoding) =="
python benchmarks/bench_service.py --smoke --encoding json

echo "== bench_service loadgen smoke (2s, binary encoding) =="
python benchmarks/bench_service.py --smoke --encoding binary

echo "== binary-vs-json registration gate =="
python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_service.json")
res = json.loads(p.read_text())
missing = [m for m in ("json", "binary") if m not in res]
if missing:
    sys.exit(f"[ci_smoke] bench_service.json missing mode(s): {missing}")
j, b = res["json"]["register_seconds"], res["binary"]["register_seconds"]
nm = res["binary"]["register_nm"]
print(f"[ci_smoke] register {nm[0]}x{nm[1]}: json={1e3*j:.1f}ms "
      f"binary={1e3*b:.1f}ms (speedup {j/max(b,1e-9):.2f}x)")
if b >= j:
    sys.exit("[ci_smoke] FAIL: binary registration is not faster than JSON")
EOF

echo "== ci_smoke PASS =="
