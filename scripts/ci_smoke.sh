#!/usr/bin/env bash
# CI smoke, split into named stages so the pipeline can matrix them and a
# failed gate names its stage:
#
#   scripts/ci_smoke.sh [stage...]      # default: all stages, in order
#
#   lint      ruff check (skipped with a notice when ruff is absent)
#   tests     tier-1 pytest suite
#   ops       bench_ops backend sweep + batched-Pallas-vs-dense parity gate
#             (<= 1e-4 relative) + real 8-device-mesh parity + bench_ops
#             wall-clock regression gate vs benchmarks/baselines
#   delta     delta-ingest gates (delta-vs-rebuild loss parity <= 1e-9,
#             delta beats full re-ingest) + deprecation-warning-clean run
#   service   serve_coresets self-check + 2s closed-loop loadgen per wire
#             encoding + binary-beats-JSON registration gate + bench_service
#             regression gate
#   tune      kernel autotuning gates: quick-budget tune populates a cache,
#             round-trip + corrupt-cache fallback, env override beats tuned
#             selection, then bench_ops --tune + the autotune regression
#             suite (tuned accel beats numpy, compensated-f32 parity <=
#             1e-6, dispatch-consult overhead bounded)
#   coalesce  cross-request query coalescing gate: 16 concurrent same-signal
#             loss queries must fuse into <= 4 scoring dispatches with
#             per-request losses <= 1e-9 off the uncoalesced path
#   trace     end-to-end tracing gate: traceparent propagation, span
#             taxonomy (http -> scheduler wait -> linked fused dispatch ->
#             ops.dispatch), >= 80% root coverage, shared fused-trace
#             linking under a concurrent burst, valid Chrome export
#   stream    re-anchor + v2 streaming gate: disjoint-delta ingest serves
#             subsequent requests with zero rebuilds and bitwise parity,
#             >= 4 MB compress streams in >= 4 chunks identical to the v1
#             body; then the delta-mix/stream probes + their wall-clock,
#             miss-rate and encode-peak regression gates
#   cluster   distributed serving plane gate: 1 coordinator + 3 subprocess
#             workers, bitwise fingerprint parity vs the single-host build,
#             loss parity <= 1e-9, worker-kill -> degraded (200s, same
#             bytes) -> same-port rejoin; then the cluster loadgen smoke +
#             its wall-clock regression gate
#   qos       admission control / multi-tenant QoS gate: under 4x overload
#             with one hot tenant, admitted requests never 504, the hot
#             tenant is capped within +-20% of its weighted share, cold
#             p95 <= 2x unloaded; then the overload probe + its regression
#             gate (admit decision < 50us, 503 round-trip wall-clock)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# runtime hygiene (mirrors scripts/run.sh): persist jit compilations across
# stage processes — every stage re-imports jax, and recompiles of the same
# kernels otherwise dominate smoke wall time
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-${TMPDIR:-/tmp}/repro_jax_cache}"

stage_lint() {
  echo "== lint (ruff) =="
  if command -v ruff >/dev/null 2>&1; then
    ruff check .
  else
    echo "[ci_smoke] ruff not installed: lint stage skipped"
  fi
}

stage_tests() {
  echo "== tier-1 tests =="
  python -m pytest -q
}

stage_ops() {
  echo "== bench_ops backend sweep (numpy vs xla vs pallas-interpret) =="
  python -m benchmarks.bench_ops --fast

  echo "== batched-Pallas vs dense dispatched-path parity gate =="
  python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_ops.json")
res = json.loads(p.read_text())
rel = res["parity"]["batched_pallas_vs_dense_rel"]
print(f"[ci_smoke] batched pallas vs dense: rel={rel:.2e} "
      f"(blocks={res['parity']['coreset_blocks']}, "
      f"T={res['parity']['trees']}, K={res['parity']['leaves']})")
if rel > 1e-4:
    sys.exit(f"[ci_smoke] FAIL: batched kernel off dense path by {rel:.2e} > 1e-4")
EOF

  echo "== mesh-sharded batched fitting loss (8 devices, forced host mesh) =="
  # the parity logic lives once, in the test (it spawns its own subprocess
  # with XLA_FLAGS); this step just runs it by name so a smoke log shows it
  python -m pytest -q tests/test_ops.py -k mesh_sharded

  echo "== bench_ops wall-clock regression gate =="
  # the gate re-measures failing rows itself (per-row min over runs):
  # micro-timings are load-sensitive and one sample proves nothing
  python scripts/check_bench_regression.py ops
}

stage_delta() {
  echo "== delta-ingest gates: rebuild parity <= 1e-9, delta beats full rebuild =="
  python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_ops.json")
if not p.exists():
    sys.exit("[ci_smoke] FAIL: run the ops stage first (bench_ops.json missing)")
res = json.loads(p.read_text())
d = res["ingest_delta"]
print(f"[ci_smoke] delta ingest {d['band_rows']}x{d['m']} into "
      f"{d['n']}x{d['m']}: delta={d['delta_ms']:.1f}ms "
      f"rebuild={d['rebuild_ms']:.1f}ms (speedup {d['speedup']:.2f}x), "
      f"loss parity rel={d['loss_parity_rel']:.2e}")
if d["loss_parity_rel"] > 1e-9:
    sys.exit(f"[ci_smoke] FAIL: delta-built coreset off from-scratch build "
             f"by {d['loss_parity_rel']:.2e} > 1e-9")
if d["delta_ms"] >= d["rebuild_ms"]:
    sys.exit("[ci_smoke] FAIL: delta ingest is not faster than full rebuild")
EOF

  echo "== deprecation-warning-clean (coreset_loss_many shim fully migrated) =="
  # explicitly-named files bypass conftest's hypothesis-absent collect-ignore,
  # so mirror its guard here: drop the property-test module on bare containers
  python - <<'EOF'
import subprocess, sys
mods = ["tests/test_ops.py", "tests/test_streaming.py",
        "tests/test_ingest_delta.py"]
try:
    import hypothesis  # noqa: F401
    mods.insert(0, "tests/test_fitting_loss.py")
except ModuleNotFoundError:
    print("[ci_smoke] hypothesis absent: -W error run skips "
          "tests/test_fitting_loss.py (collect-ignored in tier-1 too)")
sys.exit(subprocess.call(
    [sys.executable, "-m", "pytest", "-q", "-W", "error::DeprecationWarning",
     *mods]))
EOF
}

stage_service() {
  echo "== serve_coresets smoke (concurrent SDK clients, both encodings) =="
  python -m repro.launch.serve_coresets --smoke

  echo "== bench_service loadgen smoke (2s, json encoding) =="
  python benchmarks/bench_service.py --smoke --encoding json

  echo "== bench_service loadgen smoke (2s, binary encoding) =="
  python benchmarks/bench_service.py --smoke --encoding binary

  echo "== binary-vs-json registration gate =="
  python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_service.json")
res = json.loads(p.read_text())
missing = [m for m in ("json", "binary") if m not in res]
if missing:
    sys.exit(f"[ci_smoke] bench_service.json missing mode(s): {missing}")
j, b = res["json"]["register_seconds"], res["binary"]["register_seconds"]
nm = res["binary"]["register_nm"]
print(f"[ci_smoke] register {nm[0]}x{nm[1]}: json={1e3*j:.1f}ms "
      f"binary={1e3*b:.1f}ms (speedup {j/max(b,1e-9):.2f}x)")
if b >= j:
    sys.exit("[ci_smoke] FAIL: binary registration is not faster than JSON")
EOF

  echo "== bench_service wall-clock regression gate =="
  python scripts/check_bench_regression.py service
}

stage_tune() {
  # the stage owns its cache file: CI must not read or write ~/.cache
  local tune_cache="${REPRO_AUTOTUNE_CACHE:-${TMPDIR:-/tmp}/repro_ci_autotune.json}"
  export REPRO_AUTOTUNE_CACHE="$tune_cache"
  rm -f "$tune_cache"

  echo "== kernel autotune: populate the tuning cache (quick budget) =="
  python -m repro.ops.autotune --budget quick

  echo "== tuning-cache round-trip, corrupt-cache fallback, override wins =="
  python - <<'EOF'
import json, os, pathlib, sys
from repro import ops
from repro.ops import autotune

cache = autotune.get_cache()
assert cache.loaded_from_disk and cache.entries, \
    f"tune run did not round-trip through {cache.path}"
print(f"[ci_smoke] tuning cache round-trip: {len(cache.entries)} entries "
      f"from {cache.path} (fingerprint {autotune.kernel_fingerprint()})")

# a tuned winner must exist for at least one op at its tuned bucket...
tuned = [(k.split("|")[0], v["size"]) for k, v in cache.entries.items()
         if ops.select_backend(k.split("|")[0], v["size"]) != "numpy"]
if not tuned:
    sys.exit("[ci_smoke] FAIL: no tuned selection fired at any tuned bucket")
op, size = tuned[0]
sel = ops.select_backend(op, size)
print(f"[ci_smoke] tuned selection: {op}@{size} -> {sel}")

# ...and every explicit pin must still beat it
os.environ[ops.ENV_VAR] = "numpy"
assert ops.select_backend(op, size) == "numpy", "env must beat tuned"
del os.environ[ops.ENV_VAR]
with ops.backend_override("numpy"):
    assert ops.select_backend(op, size) == "numpy", "override must beat tuned"
print("[ci_smoke] REPRO_OPS_BACKEND + backend_override beat tuned selection")

# a corrupt cache file must fall back to heuristics, never fail dispatch
path = pathlib.Path(cache.path)
backup = path.read_text()
path.write_text("{corrupt json")
autotune.reset_cache()
assert not autotune.get_cache().entries, "corrupt cache must load empty"
assert ops.select_backend(op, size) in ops.BACKENDS
ops.sat_moments([[1.0, 2.0], [3.0, 4.0]])      # dispatch survives
path.write_text(backup)
autotune.reset_cache()
errs = autotune.counters_snapshot()["cache_load_errors"]
assert errs >= 1, "corrupt load must be counted"
print(f"[ci_smoke] corrupt-cache fallback clean (cache_load_errors={errs})")
EOF

  echo "== bench_ops with tuning (--fast --tune) =="
  python -m benchmarks.bench_ops --fast --tune

  echo "== autotune regression gate (tuned accel win + parity + overhead) =="
  python scripts/check_bench_regression.py autotune
}

stage_coalesce() {
  echo "== cross-request query coalescing gate =="
  python scripts/coalesce_gate.py
}

stage_trace() {
  echo "== end-to-end tracing gate =="
  python scripts/trace_gate.py

  echo "== trace-retrieval race regression (20x repeat) =="
  # this test raced trace finalization (root span ends AFTER the response is
  # written) and only failed under load; Tracer.get now bounded-waits on a
  # condition variable.  Repeat it to keep the race from regressing silently
  for _ in $(seq 20); do
    python -m pytest -q -x tests/test_service.py \
      -k "trace_retrieval" >/dev/null \
      || { echo "[ci_smoke] FAIL: trace retrieval raced finalization"; exit 1; }
  done
  echo "[ci_smoke] 20/20 trace-retrieval repeats clean"
}

stage_stream() {
  echo "== cache re-anchor + v2 streaming gate =="
  python scripts/stream_gate.py

  echo "== bench_service delta-mix probe (2s) =="
  python benchmarks/bench_service.py --smoke --delta-mix 0.3

  echo "== bench_service stream probe =="
  python benchmarks/bench_service.py --smoke --stream

  echo "== stream wall-clock / miss-rate / encode-peak regression gate =="
  python scripts/check_bench_regression.py stream
}

stage_cluster() {
  echo "== distributed serving plane gate (1 coordinator + 3 workers) =="
  python scripts/cluster_gate.py

  echo "== bench_service cluster loadgen smoke (2s) =="
  python benchmarks/bench_service.py --smoke --cluster

  echo "== bench_service cluster wall-clock regression gate =="
  python scripts/check_bench_regression.py cluster
}

stage_qos() {
  echo "== admission control / multi-tenant QoS overload gate =="
  python scripts/overload_gate.py --smoke

  echo "== bench_service overload probe (admit-decision us + 503 cost) =="
  python benchmarks/bench_service.py --smoke --overload

  echo "== qos regression gate (admit < 50us, 503 round-trip wall-clock) =="
  python scripts/check_bench_regression.py qos
}

ALL_STAGES=(lint tests ops delta tune service coalesce trace stream cluster qos)
# bash 3.2 (macOS) treats an empty array as unbound under set -u, so pick
# the default stage list off $# instead of the array length
if [ $# -eq 0 ]; then
  STAGES=("${ALL_STAGES[@]}")
else
  STAGES=("$@")
fi

for stage in "${STAGES[@]}"; do
  case "$stage" in
    lint|tests|ops|delta|tune|service|coalesce|trace|stream|cluster|qos) "stage_${stage}" ;;
    *) echo "[ci_smoke] unknown stage '${stage}' (known: ${ALL_STAGES[*]})" >&2
       exit 2 ;;
  esac
done

echo "== ci_smoke PASS (${STAGES[*]}) =="
