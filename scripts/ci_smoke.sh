#!/usr/bin/env bash
# CI smoke: tier-1 suite + a 2-second closed-loop run against the coreset
# serving engine, so serving-path regressions fail fast.
#
#   scripts/ci_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -q

echo "== serve_coresets smoke (concurrent HTTP clients) =="
python -m repro.launch.serve_coresets --smoke

echo "== bench_service loadgen smoke (2s) =="
python benchmarks/bench_service.py --smoke

echo "== ci_smoke PASS =="
