#!/usr/bin/env bash
# CI smoke: tier-1 suite, the repro.ops backend sweep with its
# batched-Pallas-vs-dense parity gate (<= 1e-4 relative), the delta-ingest
# gates (delta-vs-rebuild loss parity <= 1e-9 and the delta write path
# beating a full re-ingest+re-SAT wall-clock), a deprecation-warning-clean
# run of the shim-adjacent test modules, the real 2-device-mesh
# batched-loss parity check, the serve_coresets self-check, and a 2-second
# closed-loop loadgen per wire encoding, so serving-path regressions fail
# fast.  The final gate asserts the v1 binary frame beats JSON on 512x512
# signal registration (the ROADMAP's "JSON array parsing dominates" fix)
# using the per-mode results both runs merged into
# benchmarks/results/bench_service.json.
#
#   scripts/ci_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -q

echo "== bench_ops backend sweep (numpy vs xla vs pallas-interpret) =="
python -m benchmarks.bench_ops --fast

echo "== batched-Pallas vs dense dispatched-path parity gate =="
python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_ops.json")
res = json.loads(p.read_text())
rel = res["parity"]["batched_pallas_vs_dense_rel"]
print(f"[ci_smoke] batched pallas vs dense: rel={rel:.2e} "
      f"(blocks={res['parity']['coreset_blocks']}, "
      f"T={res['parity']['trees']}, K={res['parity']['leaves']})")
if rel > 1e-4:
    sys.exit(f"[ci_smoke] FAIL: batched kernel off dense path by {rel:.2e} > 1e-4")
EOF

echo "== delta-ingest gates: rebuild parity <= 1e-9, delta beats full rebuild =="
python - <<'EOF'
import json, pathlib, sys
res = json.loads(pathlib.Path("benchmarks/results/bench_ops.json").read_text())
d = res["ingest_delta"]
print(f"[ci_smoke] delta ingest {d['band_rows']}x{d['m']} into "
      f"{d['n']}x{d['m']}: delta={d['delta_ms']:.1f}ms "
      f"rebuild={d['rebuild_ms']:.1f}ms (speedup {d['speedup']:.2f}x), "
      f"loss parity rel={d['loss_parity_rel']:.2e}")
if d["loss_parity_rel"] > 1e-9:
    sys.exit(f"[ci_smoke] FAIL: delta-built coreset off from-scratch build "
             f"by {d['loss_parity_rel']:.2e} > 1e-9")
if d["delta_ms"] >= d["rebuild_ms"]:
    sys.exit("[ci_smoke] FAIL: delta ingest is not faster than full rebuild")
EOF

echo "== deprecation-warning-clean (coreset_loss_many shim fully migrated) =="
# explicitly-named files bypass conftest's hypothesis-absent collect-ignore,
# so mirror its guard here: drop the property-test module on bare containers
python - <<'EOF'
import subprocess, sys
mods = ["tests/test_ops.py", "tests/test_streaming.py",
        "tests/test_ingest_delta.py"]
try:
    import hypothesis  # noqa: F401
    mods.insert(0, "tests/test_fitting_loss.py")
except ModuleNotFoundError:
    print("[ci_smoke] hypothesis absent: -W error run skips "
          "tests/test_fitting_loss.py (collect-ignored in tier-1 too)")
sys.exit(subprocess.call(
    [sys.executable, "-m", "pytest", "-q", "-W", "error::DeprecationWarning",
     *mods]))
EOF

echo "== mesh-sharded batched fitting loss (2 devices, forced host mesh) =="
# the parity logic lives once, in the test (it spawns its own subprocess
# with XLA_FLAGS); this step just runs it by name so a smoke log shows it
python -m pytest -q tests/test_ops.py -k mesh_sharded

echo "== serve_coresets smoke (concurrent SDK clients, both encodings) =="
python -m repro.launch.serve_coresets --smoke

echo "== bench_service loadgen smoke (2s, json encoding) =="
python benchmarks/bench_service.py --smoke --encoding json

echo "== bench_service loadgen smoke (2s, binary encoding) =="
python benchmarks/bench_service.py --smoke --encoding binary

echo "== binary-vs-json registration gate =="
python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_service.json")
res = json.loads(p.read_text())
missing = [m for m in ("json", "binary") if m not in res]
if missing:
    sys.exit(f"[ci_smoke] bench_service.json missing mode(s): {missing}")
j, b = res["json"]["register_seconds"], res["binary"]["register_seconds"]
nm = res["binary"]["register_nm"]
print(f"[ci_smoke] register {nm[0]}x{nm[1]}: json={1e3*j:.1f}ms "
      f"binary={1e3*b:.1f}ms (speedup {j/max(b,1e-9):.2f}x)")
if b >= j:
    sys.exit("[ci_smoke] FAIL: binary registration is not faster than JSON")
EOF

echo "== ci_smoke PASS =="
