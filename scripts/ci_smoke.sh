#!/usr/bin/env bash
# CI smoke, split into named stages so the pipeline can matrix them and a
# failed gate names its stage:
#
#   scripts/ci_smoke.sh [stage...]      # default: all stages, in order
#
#   lint      ruff check (skipped with a notice when ruff is absent)
#   tests     tier-1 pytest suite
#   ops       bench_ops backend sweep + batched-Pallas-vs-dense parity gate
#             (<= 1e-4 relative) + real 8-device-mesh parity + bench_ops
#             wall-clock regression gate vs benchmarks/baselines
#   delta     delta-ingest gates (delta-vs-rebuild loss parity <= 1e-9,
#             delta beats full re-ingest) + deprecation-warning-clean run
#   service   serve_coresets self-check + 2s closed-loop loadgen per wire
#             encoding + binary-beats-JSON registration gate + bench_service
#             regression gate
#   coalesce  cross-request query coalescing gate: 16 concurrent same-signal
#             loss queries must fuse into <= 4 scoring dispatches with
#             per-request losses <= 1e-9 off the uncoalesced path
#   trace     end-to-end tracing gate: traceparent propagation, span
#             taxonomy (http -> scheduler wait -> linked fused dispatch ->
#             ops.dispatch), >= 80% root coverage, shared fused-trace
#             linking under a concurrent burst, valid Chrome export
#   cluster   distributed serving plane gate: 1 coordinator + 3 subprocess
#             workers, bitwise fingerprint parity vs the single-host build,
#             loss parity <= 1e-9, worker-kill -> degraded (200s, same
#             bytes) -> same-port rejoin; then the cluster loadgen smoke +
#             its wall-clock regression gate
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage_lint() {
  echo "== lint (ruff) =="
  if command -v ruff >/dev/null 2>&1; then
    ruff check .
  else
    echo "[ci_smoke] ruff not installed: lint stage skipped"
  fi
}

stage_tests() {
  echo "== tier-1 tests =="
  python -m pytest -q
}

stage_ops() {
  echo "== bench_ops backend sweep (numpy vs xla vs pallas-interpret) =="
  python -m benchmarks.bench_ops --fast

  echo "== batched-Pallas vs dense dispatched-path parity gate =="
  python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_ops.json")
res = json.loads(p.read_text())
rel = res["parity"]["batched_pallas_vs_dense_rel"]
print(f"[ci_smoke] batched pallas vs dense: rel={rel:.2e} "
      f"(blocks={res['parity']['coreset_blocks']}, "
      f"T={res['parity']['trees']}, K={res['parity']['leaves']})")
if rel > 1e-4:
    sys.exit(f"[ci_smoke] FAIL: batched kernel off dense path by {rel:.2e} > 1e-4")
EOF

  echo "== mesh-sharded batched fitting loss (8 devices, forced host mesh) =="
  # the parity logic lives once, in the test (it spawns its own subprocess
  # with XLA_FLAGS); this step just runs it by name so a smoke log shows it
  python -m pytest -q tests/test_ops.py -k mesh_sharded

  echo "== bench_ops wall-clock regression gate =="
  # the gate re-measures failing rows itself (per-row min over runs):
  # micro-timings are load-sensitive and one sample proves nothing
  python scripts/check_bench_regression.py ops
}

stage_delta() {
  echo "== delta-ingest gates: rebuild parity <= 1e-9, delta beats full rebuild =="
  python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_ops.json")
if not p.exists():
    sys.exit("[ci_smoke] FAIL: run the ops stage first (bench_ops.json missing)")
res = json.loads(p.read_text())
d = res["ingest_delta"]
print(f"[ci_smoke] delta ingest {d['band_rows']}x{d['m']} into "
      f"{d['n']}x{d['m']}: delta={d['delta_ms']:.1f}ms "
      f"rebuild={d['rebuild_ms']:.1f}ms (speedup {d['speedup']:.2f}x), "
      f"loss parity rel={d['loss_parity_rel']:.2e}")
if d["loss_parity_rel"] > 1e-9:
    sys.exit(f"[ci_smoke] FAIL: delta-built coreset off from-scratch build "
             f"by {d['loss_parity_rel']:.2e} > 1e-9")
if d["delta_ms"] >= d["rebuild_ms"]:
    sys.exit("[ci_smoke] FAIL: delta ingest is not faster than full rebuild")
EOF

  echo "== deprecation-warning-clean (coreset_loss_many shim fully migrated) =="
  # explicitly-named files bypass conftest's hypothesis-absent collect-ignore,
  # so mirror its guard here: drop the property-test module on bare containers
  python - <<'EOF'
import subprocess, sys
mods = ["tests/test_ops.py", "tests/test_streaming.py",
        "tests/test_ingest_delta.py"]
try:
    import hypothesis  # noqa: F401
    mods.insert(0, "tests/test_fitting_loss.py")
except ModuleNotFoundError:
    print("[ci_smoke] hypothesis absent: -W error run skips "
          "tests/test_fitting_loss.py (collect-ignored in tier-1 too)")
sys.exit(subprocess.call(
    [sys.executable, "-m", "pytest", "-q", "-W", "error::DeprecationWarning",
     *mods]))
EOF
}

stage_service() {
  echo "== serve_coresets smoke (concurrent SDK clients, both encodings) =="
  python -m repro.launch.serve_coresets --smoke

  echo "== bench_service loadgen smoke (2s, json encoding) =="
  python benchmarks/bench_service.py --smoke --encoding json

  echo "== bench_service loadgen smoke (2s, binary encoding) =="
  python benchmarks/bench_service.py --smoke --encoding binary

  echo "== binary-vs-json registration gate =="
  python - <<'EOF'
import json, pathlib, sys
p = pathlib.Path("benchmarks/results/bench_service.json")
res = json.loads(p.read_text())
missing = [m for m in ("json", "binary") if m not in res]
if missing:
    sys.exit(f"[ci_smoke] bench_service.json missing mode(s): {missing}")
j, b = res["json"]["register_seconds"], res["binary"]["register_seconds"]
nm = res["binary"]["register_nm"]
print(f"[ci_smoke] register {nm[0]}x{nm[1]}: json={1e3*j:.1f}ms "
      f"binary={1e3*b:.1f}ms (speedup {j/max(b,1e-9):.2f}x)")
if b >= j:
    sys.exit("[ci_smoke] FAIL: binary registration is not faster than JSON")
EOF

  echo "== bench_service wall-clock regression gate =="
  python scripts/check_bench_regression.py service
}

stage_coalesce() {
  echo "== cross-request query coalescing gate =="
  python scripts/coalesce_gate.py
}

stage_trace() {
  echo "== end-to-end tracing gate =="
  python scripts/trace_gate.py
}

stage_cluster() {
  echo "== distributed serving plane gate (1 coordinator + 3 workers) =="
  python scripts/cluster_gate.py

  echo "== bench_service cluster loadgen smoke (2s) =="
  python benchmarks/bench_service.py --smoke --cluster

  echo "== bench_service cluster wall-clock regression gate =="
  python scripts/check_bench_regression.py cluster
}

ALL_STAGES=(lint tests ops delta service coalesce trace cluster)
# bash 3.2 (macOS) treats an empty array as unbound under set -u, so pick
# the default stage list off $# instead of the array length
if [ $# -eq 0 ]; then
  STAGES=("${ALL_STAGES[@]}")
else
  STAGES=("$@")
fi

for stage in "${STAGES[@]}"; do
  case "$stage" in
    lint|tests|ops|delta|service|coalesce|trace|cluster) "stage_${stage}" ;;
    *) echo "[ci_smoke] unknown stage '${stage}' (known: ${ALL_STAGES[*]})" >&2
       exit 2 ;;
  esac
done

echo "== ci_smoke PASS (${STAGES[*]}) =="
