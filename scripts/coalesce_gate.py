#!/usr/bin/env python
"""ci_smoke ``coalesce`` gate: concurrent same-signal loss queries MUST fuse.

Boots the full HTTP service in-process, fires N (default 16) concurrent
``/v1/query/loss`` requests for the same signal from N independent SDK
clients (each its own connection — the exact shape cross-request coalescing
exists for), and asserts:

  * the N requests consumed at most ``N // 4`` scoring dispatches
    (``loss_scoring_calls`` delta), i.e. ``query_coalesced_total`` grew by
    at least ``N - N // 4``;
  * every per-request loss is within 1e-9 (relative) of the uncoalesced
    path (``coalesce=False`` — the inline ``fitting_loss`` escape hatch);
  * responses report the fusion honestly (``fused_batch_size`` sums to the
    number of requests, every response names a backend).

Run:  python scripts/coalesce_gate.py [--n 16] [--window 0.1]
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import threading

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.client import CoresetClient  # noqa: E402
from repro.core.segmentation import random_tree_segmentation  # noqa: E402
from repro.data.signals import piecewise_signal  # noqa: E402
from repro.service import (CoresetEngine, make_server,  # noqa: E402
                           serve_forever_in_thread)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16,
                    help="concurrent same-signal loss queries")
    ap.add_argument("--window", type=float, default=0.1,
                    help="server batching window (generous: CI boxes jitter)")
    ap.add_argument("--rows", type=int, default=160)
    ap.add_argument("--cols", type=int, default=96)
    ap.add_argument("--k", type=int, default=6)
    args = ap.parse_args()
    n = int(args.n)

    eng = CoresetEngine(query_window=args.window, query_max_fuse=n, workers=4)
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"

    y = piecewise_signal(args.rows, args.cols, args.k, noise=0.15, seed=7)
    warm = CoresetClient(base, retries=0)
    warm.register_signal("gate", y)
    warm.build("gate", args.k, 0.3)   # pre-build: the gate measures QUERIES

    rng = np.random.default_rng(7)
    trees = [random_tree_segmentation(args.rows, args.cols, args.k, rng)
             for _ in range(n)]

    # ---- uncoalesced reference: the coalesce=off escape hatch, serially
    ref = [warm.query_loss("gate", t.rects, t.labels, eps=0.3,
                           coalesce=False).loss for t in trees]
    calls0 = eng.metrics.get("loss_scoring_calls")
    coal0 = eng.metrics.get("query_coalesced_total")

    # ---- N concurrent clients, one query each, barrier-released together
    results: list = [None] * n
    barrier = threading.Barrier(n)

    def worker(i: int) -> None:
        client = CoresetClient(base, retries=0)
        barrier.wait()
        t = trees[i]
        results[i] = client.query_loss("gate", t.rects, t.labels, eps=0.3)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)

    failures = [i for i, r in enumerate(results) if r is None]
    if failures:
        print(f"[coalesce_gate] FAIL: requests {failures} never completed")
        return 1

    dispatches = eng.metrics.get("loss_scoring_calls") - calls0
    coalesced = eng.metrics.get("query_coalesced_total") - coal0
    rel = max(
        abs(results[i].loss - ref[i]) / max(abs(ref[i]), 1e-30)
        for i in range(n))
    fused_sizes = sorted(r.fused_batch_size for r in results)
    backends = sorted({r.backend for r in results})

    max_dispatches = n // 4
    print(f"[coalesce_gate] {n} concurrent queries -> {dispatches} scoring "
          f"dispatches (allowed <= {max_dispatches}), "
          f"query_coalesced_total += {coalesced} "
          f"(required >= {n - max_dispatches})")
    print(f"[coalesce_gate] fused_batch_size: {fused_sizes}, "
          f"backends: {backends}, loss parity rel={rel:.2e}")

    srv.shutdown()
    eng.close()

    if dispatches > max_dispatches:
        print(f"[coalesce_gate] FAIL: {dispatches} scoring dispatches "
              f"> {max_dispatches} — coalescing is not fusing")
        return 1
    if coalesced < n - max_dispatches:
        print(f"[coalesce_gate] FAIL: only {coalesced} queries coalesced")
        return 1
    if rel > 1e-9:
        print(f"[coalesce_gate] FAIL: coalesced losses off the uncoalesced "
              f"path by {rel:.2e} > 1e-9")
        return 1
    # every request of an s-way fusion reports s, so the reported sizes sum
    # to sum(s_j^2) over batches, which is >= n + 2*coalesced whenever the
    # counters are honest ((s-1)(s-2) >= 0 per batch)
    if fused_sizes[0] < 1 or sum(fused_sizes) < n + 2 * coalesced:
        print("[coalesce_gate] FAIL: fused_batch_size under-reports the "
              "fusion the counters claim")
        return 1
    if any(not b for b in backends):
        print("[coalesce_gate] FAIL: response missing backend")
        return 1
    print("[coalesce_gate] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
