#!/usr/bin/env python
"""ci_smoke ``qos`` gate: admission control MUST hold its QoS contract
under overload.

Boots the full HTTP service in-process and runs two phases:

  1. **baseline** — no admission: a short closed loop measures the engine's
     unloaded capacity (rps) and the cold-tenant p95 latency floor;
  2. **overload** — admission on (total rate = ``RATE_FRAC`` x measured
     capacity, tenants hot=2 / cold1=1 / cold2=1): the hot tenant hammers
     an unthrottled closed loop (~4x its share of offered load) while the
     two cold tenants trickle well under their shares.

Gates (the ISSUE's acceptance criteria, verbatim):

  * **no admitted 504s** — every deadline-expired response is a refusal
    the admission layer failed to make; admitted work must finish;
  * **hot capped near its share** — the hot tenant's admitted throughput
    lands within ±20% of ``rate * w_hot / sum(w)`` (+ the one-time token
    burst): overload degrades the aggressor to its share, not to zero and
    not past its share;
  * **cold p95 protected** — cold-tenant p95 under overload <= 2x its
    unloaded p95: the aggressor's queue pressure never reaches the
    well-behaved tenants;
  * plus sanity: rejects carry Retry-After, and zero cold rejections (the
    colds offered under their shares, so refusing them would be unfair).

Run:  python scripts/overload_gate.py [--duration 3.0] [--smoke]
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import threading
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.client import AdmissionRejectedError, CoresetClient  # noqa: E402
from repro.core.segmentation import random_tree_segmentation  # noqa: E402
from repro.data.signals import piecewise_signal  # noqa: E402
from repro.service import (AdmissionConfig, AdmissionController,  # noqa: E402
                           CoresetEngine, make_server,
                           serve_forever_in_thread)

N, M, KMAX = 96, 64, 8
WEIGHTS = {"hot": 2.0, "cold1": 1.0, "cold2": 1.0}
RATE_FRAC = 0.5        # admitted rate = this fraction of measured capacity
BURST_S = 0.2
HOT_SHARE_TOL = 0.20   # +-20% around the hot tenant's configured share
COLD_P95_FACTOR = 2.0
DEADLINE_MS = 10_000.0   # generous: admitted work must ALWAYS make it


class TenantStats:
    def __init__(self, name: str):
        self.name = name
        self.ok = 0
        self.rejected = 0
        self.expired = 0           # 504s — must stay zero
        self.errors = 0
        self.latencies: list[float] = []
        self.retry_afters: list[float] = []
        self.lock = threading.Lock()


def p95(xs: list[float]) -> float:
    return float(np.percentile(xs, 95)) if xs else 0.0


def drive(base: str, stats: TenantStats, segs, stop: threading.Event,
          pace_s: float | None) -> None:
    """One closed-loop client thread: ``pace_s=None`` hammers (offered load
    bounded only by round-trip + reject turnaround), else one request per
    ``pace_s`` seconds."""
    cl = CoresetClient(base, tenant=stats.name, retries=0,
                       deadline_ms=DEADLINE_MS)
    rng = np.random.default_rng(hash(stats.name) % (2**32))
    while not stop.is_set():
        q = segs[int(rng.integers(len(segs)))]
        t0 = time.perf_counter()
        try:
            cl.query_loss("sig", q.rects, q.labels, eps=0.3)
            dt = time.perf_counter() - t0
            with stats.lock:
                stats.ok += 1
                stats.latencies.append(dt)
        except AdmissionRejectedError as exc:
            with stats.lock:
                stats.rejected += 1
                if exc.retry_after is not None:
                    stats.retry_afters.append(exc.retry_after)
            time.sleep(0.002)     # reject turnaround: keep offering ~fast
        except Exception as exc:  # noqa: BLE001
            code = getattr(exc, "code", "")
            with stats.lock:
                if code == "deadline_exceeded":
                    stats.expired += 1
                else:
                    stats.errors += 1
        if pace_s is not None:
            time.sleep(pace_s)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--duration", type=float, default=3.0,
                    help="overload phase seconds")
    ap.add_argument("--baseline", type=float, default=1.5,
                    help="unloaded capacity-measurement seconds")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="shorter phases (CI wall-clock)")
    args = ap.parse_args()
    if args.smoke:
        args.duration, args.baseline = 2.0, 1.0

    eng = CoresetEngine(workers=args.workers)
    srv = make_server(eng)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    failures: list[str] = []
    try:
        y = piecewise_signal(N, M, KMAX, noise=0.15, seed=7)
        setup = CoresetClient(base)
        setup.register_signal("sig", values=y)
        setup.build("sig", KMAX, 0.2)          # anchor: queries are cache hits
        rng = np.random.default_rng(1)
        segs = [random_tree_segmentation(N, M, 6, rng) for _ in range(16)]
        for q in segs[:4]:                     # warm the scoring path
            setup.query_loss("sig", q.rects, q.labels, eps=0.3)

        # ---- phase 1: unloaded capacity + cold p95 floor (no admission)
        bstats = TenantStats("baseline")
        stop = threading.Event()
        threads = [threading.Thread(target=drive,
                                    args=(base, bstats, segs, stop, None))
                   for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(args.baseline)
        stop.set()
        for t in threads:
            t.join()
        capacity = bstats.ok / args.baseline
        cold_p95_floor = p95(bstats.latencies)
        print(f"[overload_gate] baseline: capacity={capacity:.0f} rps  "
              f"p95={cold_p95_floor * 1e3:.2f} ms  (n={bstats.ok})")
        if capacity < 20:
            print("[overload_gate] SKIP: capacity too low to overload "
                  "meaningfully on this machine")
            return 0

        # ---- phase 2: admission on, one hot tenant at ~4x its share
        rate = RATE_FRAC * capacity
        ctl = AdmissionController(AdmissionConfig(
            tenants=dict(WEIGHTS), rate_rps=rate, burst_s=BURST_S,
            parallelism=args.workers))
        ctl.metrics = eng.metrics
        eng.admission = ctl
        wsum = sum(WEIGHTS.values())
        hot_share = rate * WEIGHTS["hot"] / wsum
        cold_share = rate * WEIGHTS["cold1"] / wsum
        # colds trickle at ~40% of their own share -> must never be refused
        cold_pace = 1.0 / max(cold_share * 0.4, 1.0)
        tstats = {name: TenantStats(name) for name in WEIGHTS}
        stop = threading.Event()
        threads = [threading.Thread(
            target=drive, args=(base, tstats["hot"], segs, stop, None))
            for _ in range(4)]
        threads += [threading.Thread(
            target=drive, args=(base, tstats[c], segs, stop, cold_pace))
            for c in ("cold1", "cold2")]
        for t in threads:
            t.start()
        time.sleep(args.duration)
        stop.set()
        for t in threads:
            t.join()

        hot = tstats["hot"]
        hot_rps = hot.ok / args.duration
        offered = sum(s.ok + s.rejected for s in tstats.values()) \
            / args.duration
        cold_lat = tstats["cold1"].latencies + tstats["cold2"].latencies
        cold_p95 = p95(cold_lat)
        print(f"[overload_gate] overload: offered={offered:.0f} rps "
              f"(~{offered / max(rate, 1e-9):.1f}x admitted rate {rate:.0f})")
        for name, s in tstats.items():
            print(f"[overload_gate]   {name}: ok={s.ok} rejected={s.rejected}"
                  f" expired_504={s.expired} errors={s.errors} "
                  f"p95={p95(s.latencies) * 1e3:.2f} ms")

        # gate 1: admitted requests never die at their deadline
        expired = sum(s.expired for s in tstats.values())
        if expired:
            failures.append(f"{expired} admitted requests returned 504 "
                            "deadline_exceeded under overload")
        errors = sum(s.errors for s in tstats.values())
        if errors:
            failures.append(f"{errors} unexpected errors under overload")

        # gate 2: hot tenant capped near its share (+ the one-time burst)
        burst_allowance = hot_share * BURST_S / args.duration
        lo = hot_share * (1.0 - HOT_SHARE_TOL)
        hi = hot_share * (1.0 + HOT_SHARE_TOL) + burst_allowance
        if not (lo <= hot_rps <= hi):
            failures.append(
                f"hot tenant admitted {hot_rps:.0f} rps, outside "
                f"[{lo:.0f}, {hi:.0f}] (share {hot_share:.0f} +-20%)")
        if hot.rejected == 0:
            failures.append("hot tenant was never pushed back — "
                            "the overload did not overload")

        # gate 3: cold p95 under overload bounded by the unloaded floor
        if cold_p95 > COLD_P95_FACTOR * max(cold_p95_floor, 1e-4):
            failures.append(
                f"cold p95 {cold_p95 * 1e3:.2f} ms > "
                f"{COLD_P95_FACTOR:.0f}x unloaded "
                f"{cold_p95_floor * 1e3:.2f} ms")
        cold_rej = tstats["cold1"].rejected + tstats["cold2"].rejected
        if cold_rej:
            failures.append(f"{cold_rej} cold-tenant requests rejected "
                            "despite offering under their shares")

        # sanity: pushback carried usable Retry-After hints
        if hot.retry_afters and min(hot.retry_afters) <= 0:
            failures.append("503 responses carried non-positive Retry-After")
        snap = eng.stats()["admission"]
        if snap["rejected_total"] != sum(s.rejected for s in tstats.values()):
            failures.append("admission snapshot disagrees with client-side "
                            "reject count")
    finally:
        srv.shutdown()
        eng.close()

    for f in failures:
        print(f"[overload_gate] FAIL: {f}")
    print(f"[overload_gate] {'PASS' if not failures else 'FAIL'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
