#!/usr/bin/env python
"""Bench regression gate: fresh results vs committed baselines.

Compares the wall-clock rows of a fresh ``benchmarks/results/*.json`` run
against the committed snapshots in ``benchmarks/baselines/`` and fails when
a gated row regressed by more than ``--factor`` (default 1.25 = +25%).

Gated rows (lower is better, all wall-clock):

  bench_ops.json       <op>.numpy.us_per_call   per canonical op
  bench_ops.json       autotune.hist_split_pallas_fix.fused_us (the
                       ``autotune`` suite: the fixed one-grid-axis Pallas
                       histogram kernel must not regress toward the legacy
                       F x P/TP pathology)
  bench_service.json   <mode>.register_seconds  per wire mode present
  bench_service.json   cluster.register_seconds + cluster.loss.p50_ms
                       (the ``cluster`` suite: loadgen over the distributed
                       plane — register includes the band scatter, loss p50
                       rides gather/compose-built coresets)
  bench_service.json   delta_mix.reanchor_hit_p50_ms +
                       stream.stream_compress_p50_ms (the ``stream`` suite:
                       builds served off a re-anchored cache entry, and the
                       v2 chunked compress transfer)
  bench_service.json   overload.rejected_rtt_p50_ms (the ``qos`` suite:
                       the HTTP round-trip of a 503 admission rejection)

Absolute rows (gated against a fixed limit, not a baseline ratio):

  bench_service.json   <mode>.tracing.overhead_frac < 0.05 — request
  tracing must cost under 5% on the loss-query p50 (the A/B probe in
  bench_service measures tracing-on vs tracing-off on the same server)
  bench_ops.json       autotune.best_accel.us_over_numpy < 1.0 — at least
  one op must have a tuned accelerator backend beating the numpy oracle at
  its large-shape bucket; autotune.compensated.{sat_moments,hist_split}
  .rel_err <= 1e-6 — the compensated-f32 paths must hold their parity
  certificate vs the f64 oracle; autotune.dispatch_overhead.tuned_select_us
  — the tuned-cache consult must stay microscopic on the dispatch hot path;
  delta_mix.post_reanchor_miss_rate <= 0.01 — a disjoint-delta re-anchor
  must leave subsequent builds as pure cache hits;
  stream.encode_peak_ratio <= 0.5 — the v2 chunked encoder's peak memory
  must stay a small fraction of the buffered v1 body's;
  overload.admit_decision_us < 50 — the admission decision (admit +
  release) sits on every admitted request's path and must stay microscopic

Noise handling — micro-timings on shared boxes swing well past 25% run to
run, so a single sample proves nothing:

  * rows below an absolute floor are skipped (scheduler noise, not signal);
  * on failure the gate RE-RUNS the suite's bench (up to ``--retries``
    times) and compares the per-row MINIMUM across runs — a true
    regression survives every re-measure, a load spike does not;
  * ``BENCH_REGRESSION_FACTOR`` loosens the factor for CI runners whose
    hardware differs from the baseline machine.

``--update`` refreshes the baselines from the fresh results instead of
comparing (run it after an intentional perf change, commit the diff).

Run:  python scripts/check_bench_regression.py [ops|service|all] [--update]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results"
BASELINES = ROOT / "benchmarks" / "baselines"

# (file, row path resolver, floor) — a resolver yields (row name, value)
_OPS_FLOOR_US = 500.0      # numpy per-call timings under 0.5 ms are noise
_SVC_FLOOR_S = 0.005       # registration under 5 ms likewise
_LOSS_FLOOR_MS = 1.0       # loss p50s under 1 ms are scheduler noise
_TRACING_OVERHEAD_MAX = 0.05   # spans must stay under 5% of loss-query p50


def _ops_rows(doc: dict):
    for op, backends in doc.items():
        if isinstance(backends, dict) and isinstance(
                backends.get("numpy"), dict) and \
                "us_per_call" in backends["numpy"]:
            yield f"{op}.numpy.us_per_call", float(
                backends["numpy"]["us_per_call"]), _OPS_FLOOR_US


def _service_rows(doc: dict):
    for mode, res in doc.items():
        if mode == "cluster":
            continue        # gated by the dedicated cluster suite
        if isinstance(res, dict) and "register_seconds" in res:
            yield f"{mode}.register_seconds", float(
                res["register_seconds"]), _SVC_FLOOR_S


def _cluster_rows(doc: dict):
    """Distributed-plane rows only: the ``cluster`` mode entry written by
    ``bench_service.py --cluster``.  Register includes the band scatter to
    3 workers; loss p50 is the query path over gather-composed coresets."""
    res = doc.get("cluster")
    if not isinstance(res, dict):
        return
    if "register_seconds" in res:
        yield ("cluster.register_seconds", float(res["register_seconds"]),
               _SVC_FLOOR_S)
    loss = res.get("loss")
    if isinstance(loss, dict) and "p50_ms" in loss:
        yield "cluster.loss.p50_ms", float(loss["p50_ms"]), _LOSS_FLOOR_MS


def _service_abs_rows(doc: dict):
    """(row, value, absolute limit): rows gated by a fixed ceiling rather
    than a baseline ratio.  Tracing overhead is a *fraction* already, so a
    relative factor against a near-zero baseline would be meaningless."""
    for mode, res in doc.items():
        tracing = res.get("tracing") if isinstance(res, dict) else None
        if isinstance(tracing, dict) and "overhead_frac" in tracing:
            yield (f"{mode}.tracing.overhead_frac",
                   float(tracing["overhead_frac"]), _TRACING_OVERHEAD_MAX)


def _autotune_rows(doc: dict):
    """Relative rows of the ``autotune`` section of bench_ops.json."""
    sec = doc.get("autotune")
    if not isinstance(sec, dict):
        return
    fix = sec.get("hist_split_pallas_fix")
    if isinstance(fix, dict) and "fused_us" in fix:
        yield ("autotune.hist_split_pallas_fix.fused_us",
               float(fix["fused_us"]), _OPS_FLOOR_US)


_PARITY_RTOL = 1e-6            # compensated-f32 certificate vs f64 oracle
_SELECT_OVERHEAD_MAX_US = 50.0  # tuned-consult cost per select_backend


def _autotune_abs_rows(doc: dict):
    """Absolute rows: the tuned-accel win, the compensated-parity
    certificates, and the dispatch-consult overhead (all lower-is-better,
    fixed ceilings)."""
    sec = doc.get("autotune")
    if not isinstance(sec, dict):
        return
    best = sec.get("best_accel")
    if isinstance(best, dict) and best.get("numpy_us"):
        # < 1.0 means a tuned accelerator backend beat the numpy oracle at
        # its large-shape bucket — the headline acceptance row
        yield ("autotune.best_accel.us_over_numpy",
               float(best["us"]) / float(best["numpy_us"]), 1.0)
    for op in ("sat_moments", "hist_split"):
        row = (sec.get("compensated") or {}).get(op)
        if isinstance(row, dict) and "rel_err" in row:
            yield (f"autotune.compensated.{op}.rel_err",
                   float(row["rel_err"]), _PARITY_RTOL)
    ovh = sec.get("dispatch_overhead")
    if isinstance(ovh, dict) and "tuned_select_us" in ovh:
        yield ("autotune.dispatch_overhead.tuned_select_us",
               float(ovh["tuned_select_us"]), _SELECT_OVERHEAD_MAX_US)


_MISS_RATE_MAX = 0.01          # post-re-anchor builds must be cache hits
_STREAM_PEAK_RATIO_MAX = 0.5   # v2 encode peak vs v1 buffered encode peak
_RATE_FLOOR_MS = 1.0           # sub-ms p50s are scheduler noise


def _stream_rows(doc: dict):
    """Relative rows of the ``delta_mix`` and ``stream`` mode entries
    written by ``bench_service.py --delta-mix`` / ``--stream``: the build
    latency served off a re-anchored entry, and the chunked-compress p50."""
    dm = doc.get("delta_mix")
    if isinstance(dm, dict) and dm.get("reanchor_hit_p50_ms") is not None:
        yield ("delta_mix.reanchor_hit_p50_ms",
               float(dm["reanchor_hit_p50_ms"]), _RATE_FLOOR_MS)
    st = doc.get("stream")
    if isinstance(st, dict) and "stream_compress_p50_ms" in st:
        yield ("stream.stream_compress_p50_ms",
               float(st["stream_compress_p50_ms"]), _RATE_FLOOR_MS)


def _stream_abs_rows(doc: dict):
    """Fixed ceilings: a disjoint-delta re-anchor must leave subsequent
    builds as pure cache hits, and the v2 encoder's peak memory must stay
    a small fraction of the buffered v1 body's."""
    dm = doc.get("delta_mix")
    if isinstance(dm, dict) and "post_reanchor_miss_rate" in dm:
        yield ("delta_mix.post_reanchor_miss_rate",
               float(dm["post_reanchor_miss_rate"]), _MISS_RATE_MAX)
    st = doc.get("stream")
    if isinstance(st, dict) and "encode_peak_ratio" in st:
        yield ("stream.encode_peak_ratio",
               float(st["encode_peak_ratio"]), _STREAM_PEAK_RATIO_MAX)


_ADMIT_DECISION_MAX_US = 50.0  # admit+release cycle every request pays
_REJECT_FLOOR_MS = 0.2         # 503 RTTs are small; sub-0.2ms is noise


def _qos_rows(doc: dict):
    """Relative rows of the ``overload`` mode entry written by
    ``bench_service.py --overload``: the HTTP round-trip of a 503
    rejection — saying no must stay cheap or overload pushback melts the
    server it is protecting."""
    ov = doc.get("overload")
    if isinstance(ov, dict) and ov.get("rejected_rtt_p50_ms") is not None:
        yield ("overload.rejected_rtt_p50_ms",
               float(ov["rejected_rtt_p50_ms"]), _REJECT_FLOOR_MS)


def _qos_abs_rows(doc: dict):
    """Fixed ceiling: the in-process admission decision (admit + release)
    must stay under 50us — it sits on EVERY admitted request's path."""
    ov = doc.get("overload")
    if isinstance(ov, dict) and "admit_decision_us" in ov:
        yield ("overload.admit_decision_us",
               float(ov["admit_decision_us"]), _ADMIT_DECISION_MAX_US)


_SUITES = {
    "ops": ("bench_ops.json", _ops_rows,
            [[sys.executable, "-m", "benchmarks.bench_ops", "--fast"]],
            None),
    "autotune": ("bench_ops.json", _autotune_rows,
                 [[sys.executable, "-m", "benchmarks.bench_ops", "--fast",
                   "--tune"]],
                 _autotune_abs_rows),
    "service": ("bench_service.json", _service_rows,
                [[sys.executable, "benchmarks/bench_service.py", "--smoke",
                  "--encoding", "json"],
                 [sys.executable, "benchmarks/bench_service.py", "--smoke",
                  "--encoding", "binary"]],
                _service_abs_rows),
    "cluster": ("bench_service.json", _cluster_rows,
                [[sys.executable, "benchmarks/bench_service.py", "--smoke",
                  "--cluster"]],
                None),
    "stream": ("bench_service.json", _stream_rows,
               [[sys.executable, "benchmarks/bench_service.py", "--smoke",
                 "--delta-mix", "0.3"],
                [sys.executable, "benchmarks/bench_service.py", "--smoke",
                 "--stream"]],
               _stream_abs_rows),
    "qos": ("bench_service.json", _qos_rows,
            [[sys.executable, "benchmarks/bench_service.py", "--smoke",
              "--overload"]],
            _qos_abs_rows),
}


def _rerun(suite: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    for cmd in _SUITES[suite][2]:
        subprocess.run(cmd, cwd=ROOT, env=env, check=True,
                       stdout=subprocess.DEVNULL)


def _check_suite(suite: str, factor: float, best: dict) -> list[str]:
    """One comparison pass; ``best`` accumulates the per-row minimum over
    every fresh run seen so far."""
    fname, rows_of, _, abs_rows_of = _SUITES[suite]
    fresh = json.loads((RESULTS / fname).read_text())
    for name, val, _ in rows_of(fresh):
        best[name] = min(val, best.get(name, val))
    base_rows = dict(
        (name, (val, floor)) for name, val, floor
        in rows_of(json.loads((BASELINES / fname).read_text())))
    failures, compared = [], 0
    for name, val, floor in rows_of(fresh):
        if name not in base_rows:
            continue
        base_val, _ = base_rows[name]
        val = best[name]
        if base_val < floor or val < floor:
            continue        # below the noise floor on either side
        compared += 1
        ratio = val / base_val
        status = "FAIL" if ratio > factor else "ok"
        print(f"[bench_regression] {suite}:{name} baseline={base_val:.1f}"
              f" best-fresh={val:.1f} ({ratio:.2f}x, allowed {factor:.2f}x)"
              f" {status}")
        if ratio > factor:
            failures.append(f"{suite}:{name} {ratio:.2f}x")
    if compared == 0:
        print(f"[bench_regression] WARN {suite}: no gated rows above "
              f"the noise floor — gate vacuous")
    if abs_rows_of is not None:
        # absolute rows: same best-of-remeasures discipline, fixed ceiling
        for name, val, limit in abs_rows_of(fresh):
            best[name] = min(val, best.get(name, val))
            val = best[name]
            status = "FAIL" if val > limit else "ok"
            print(f"[bench_regression] {suite}:{name} best-fresh={val:.4f} "
                  f"(absolute limit {limit}) {status}")
            if val > limit:
                failures.append(f"{suite}:{name} {val:.3f} > {limit}")
    return failures


def check(which: str, factor: float, update: bool, retries: int) -> int:
    suites = list(_SUITES) if which == "all" else [which]
    failed = []
    for suite in suites:
        fname = _SUITES[suite][0]
        fresh_p = RESULTS / fname
        base_p = BASELINES / fname
        if not fresh_p.exists():
            print(f"[bench_regression] SKIP {suite}: no fresh {fresh_p}")
            continue
        if update:
            BASELINES.mkdir(parents=True, exist_ok=True)
            base_p.write_text(fresh_p.read_text())
            print(f"[bench_regression] baseline updated: {base_p}")
            continue
        if not base_p.exists():
            print(f"[bench_regression] SKIP {suite}: no baseline {base_p} "
                  f"(run with --update to create it)")
            continue
        best: dict = {}
        failures = _check_suite(suite, factor, best)
        attempt = 0
        while failures and attempt < retries:
            attempt += 1
            print(f"[bench_regression] {suite}: {len(failures)} row(s) over "
                  f"budget — re-measuring ({attempt}/{retries}) to rule out "
                  f"machine load")
            _rerun(suite)
            failures = _check_suite(suite, factor, best)
        failed.extend(failures)
    if failed:
        print(f"[bench_regression] FAIL: {len(failed)} row(s) regressed "
              f"> {factor:.2f}x across every re-measure: {failed}")
        return 1
    if not update:
        print("[bench_regression] PASS")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("which", nargs="?", default="all",
                    choices=("ops", "autotune", "service", "cluster",
                             "stream", "qos", "all"))
    ap.add_argument("--update", action="store_true",
                    help="refresh baselines from fresh results")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_REGRESSION_FACTOR",
                                                 "1.25")),
                    help="allowed slowdown (default 1.25 = +25%%)")
    ap.add_argument("--retries", type=int, default=2,
                    help="re-measures before a regression is declared real")
    args = ap.parse_args()
    return check(args.which, args.factor, args.update, args.retries)


if __name__ == "__main__":
    sys.exit(main())
