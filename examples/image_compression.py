"""Image compression via k-segmentation on a coreset (paper §1: the MPEG4 /
quadtree use case).  A synthetic "image" is summarized once; the k-tree
solver is then tuned across many k values using only Algorithm-5 queries
against the coreset — never touching the full image again.

    PYTHONPATH=src python examples/image_compression.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import fitting_loss, signal_coreset, true_loss  # noqa: E402
from repro.data import smooth_field  # noqa: E402
from repro.trees import DecisionTreeRegressor  # noqa: E402


def main() -> None:
    img = smooth_field(256, 256, freq=5, noise=0.05, seed=3)
    cs = signal_coreset(img, k=256, eps=0.3)
    print(f"image 256x256 -> coreset {cs.size} points "
          f"({100 * cs.compression_ratio():.2f}%)")

    # tune the number of blocks k on the CORESET only
    Xc, yc, wc = cs.as_points()
    t0 = time.time()
    results = {}
    for k in (16, 64, 256, 1024):
        t = DecisionTreeRegressor(max_leaves=k).fit(Xc, yc, sample_weight=wc)
        rects, vals = t.leaf_rectangles(np.zeros(2), np.asarray(img.shape, float))
        # snap to integer cell grid for evaluation
        rects = np.round(rects[:, [0, 2, 1, 3]]).astype(np.int64)
        loss_via_coreset = fitting_loss(cs, rects, vals)
        loss_true = true_loss(img, rects, vals)
        psnr = 10 * np.log10(img.size * (img.max() - img.min()) ** 2
                             / max(loss_true, 1e-12))
        results[k] = (loss_via_coreset, loss_true, psnr)
        print(f"k={k:5d}: loss via coreset {loss_via_coreset:10.1f} | "
              f"true {loss_true:10.1f} | PSNR {psnr:5.1f} dB | "
              f"compression {k / img.size:.2%}")
    print(f"tuning on coreset took {time.time() - t0:.2f}s "
          f"(the image itself was only touched once, at build time)")


if __name__ == "__main__":
    main()
