"""Client SDK quickstart: drive a coreset server through the typed v1 API.

Boots an in-process server (swap ``base`` for a real deployment URL), then
walks the whole request path with ``repro.client.CoresetClient``: register
a signal over the binary wire format, build a coreset, score single and
fused-batch tree queries, fit a cached forest, and read the audit fields
(``fingerprint``, ``eps_eff``, ``served_from``) every response carries.

    PYTHONPATH=src python examples/client_quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.client import CoresetAPIError, CoresetClient  # noqa: E402
from repro.core import random_tree_segmentation, true_loss  # noqa: E402
from repro.data import piecewise_signal  # noqa: E402
from repro.service import CoresetEngine, make_server, serve_forever_in_thread  # noqa: E402


def main() -> None:
    engine = CoresetEngine(workers=4)
    srv = make_server(engine)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"

    # encoding="binary" (the default) ships arrays as compressed npz frames;
    # pass encoding="json" to watch readable bodies instead
    client = CoresetClient(base)

    # 1. register a 256x256 signal — no tolist(), no hand-rolled dicts
    y = piecewise_signal(256, 256, k=16, noise=0.15, seed=0)
    info = client.register_signal("demo", values=y)
    print(f"registered {info.name}: {info.n}x{info.m}, version {info.version}")

    # 2. build the anchor (k, eps)-coreset; the response is a typed dataclass
    b = client.build("demo", k=16, eps=0.25)
    print(f"coreset {b.fingerprint[:10]}… size={b.size} "
          f"({100 * b.compression_ratio:.2f}% of cells) "
          f"eps_eff={b.eps_eff} built in {b.build_seconds:.2f}s")

    # 3. single tree-loss query — served from the dominance cache
    rng = np.random.default_rng(1)
    seg = random_tree_segmentation(256, 256, 12, rng)
    r = client.query_loss("demo", seg.rects, seg.labels, eps=0.3)
    tl = true_loss(y, seg.rects, seg.labels)
    print(f"tree loss {r.loss:.1f} vs true {tl:.1f} "
          f"(rel err {abs(r.loss - tl) / tl:.2%}, served_from={r.served_from})")

    # 4. fused batch: 32 candidate trees in ONE request / ONE scoring call
    segs = [random_tree_segmentation(256, 256, 12, rng) for _ in range(32)]
    rb = client.query_loss_batch(
        "demo", np.stack([s.rects for s in segs]),
        np.stack([s.labels for s in segs]), eps=0.3)
    print(f"batch of {len(rb.losses)} trees: best loss {rb.losses.min():.1f} "
          f"({rb.scoring_calls} fused scoring call)")

    # 5. forest fit — repeat hits the model cache keyed by coreset fingerprint
    f1 = client.fit("demo", k=16, eps=0.25, n_estimators=5,
                    predict=[[1, 1], [254, 254]])
    f2 = client.fit("demo", k=16, eps=0.25, n_estimators=5,
                    predict=[[1, 1], [254, 254]])
    print(f"forest on {f1.train_size} weighted points: first={f1.model_cache}, "
          f"repeat={f2.model_cache}; predictions {np.round(f2.predictions, 2)}")

    # 6. delta ingest: stream a signal in bands, then replace ONE band —
    # only the changed rows cross the wire, the server patches its SAT and
    # recompresses just the dirty merge-reduce buckets, and the previously
    # cached coreset is re-cached under the new version
    for i in range(0, 128, 32):
        client.ingest("stream", y[i:i + 32])
    client.build("stream", k=8, eps=0.3)
    d = client.ingest_delta("stream", y[:32] * 0.5, row0=32)
    print(f"delta {d.mode} of rows [{d.row0}, {d.row0 + d.rows}): "
          f"{d.buckets_recompressed} bucket(s) recompressed, "
          f"{d.entries_recached} cache entr{'y' if d.entries_recached == 1 else 'ies'} "
          f"re-cached at version {d.version[:10]}…")
    b2 = client.build("stream", k=8, eps=0.3)
    print(f"post-delta build served_from={b2.served_from} (no rebuild)")

    # 7. structured errors: typed envelope, not a stack trace
    try:
        client.query_loss("no-such-signal", seg.rects, seg.labels, eps=0.3)
    except CoresetAPIError as exc:
        print(f"expected error: http={exc.http} code={exc.code}")

    srv.shutdown()
    engine.close()


if __name__ == "__main__":
    main()
