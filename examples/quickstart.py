"""Quickstart: build a (k, eps)-coreset, train a forest on it, compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import (fitting_loss, random_tree_segmentation,  # noqa: E402
                        signal_coreset, true_loss)
from repro.data import piecewise_signal  # noqa: E402
from repro.trees import RandomForestRegressor, signal_to_points  # noqa: E402


def main() -> None:
    # 1. a 300x400 signal with 25-piece ground truth + noise
    y = piecewise_signal(300, 400, k=25, noise=0.15, seed=0)

    # 2. the paper's contribution: a provable summary of the signal
    cs = signal_coreset(y, k=25, eps=0.3)
    print(f"coreset: {cs.size} weighted points "
          f"({100 * cs.compression_ratio():.2f}% of the {y.size} cells), "
          f"built in {cs.build_seconds:.2f}s")

    # 3. Definition 3 in action: any k-tree's loss is approximated
    rng = np.random.default_rng(1)
    seg = random_tree_segmentation(300, 400, 25, rng)
    tl = true_loss(y, seg.rects, seg.labels)
    cl = fitting_loss(cs, seg.rects, seg.labels)
    print(f"random 25-tree: true loss {tl:.1f}, coreset loss {cl:.1f} "
          f"(rel err {abs(cl - tl) / tl:.2%}, eps was 30%)")

    # 4. train forests on full data vs the coreset
    Xf, yf = signal_to_points(y)
    Xc, yc, wc = cs.as_points()
    f_full = RandomForestRegressor(n_estimators=5, max_leaves=64).fit(Xf, yf)
    f_core = RandomForestRegressor(n_estimators=5, max_leaves=64).fit(
        Xc, yc, sample_weight=wc)
    sse_full = float(((f_full.predict(Xf) - yf) ** 2).mean())
    sse_core = float(((f_core.predict(Xf) - yf) ** 2).mean())
    print(f"forest MSE on the signal: full-data {sse_full:.4f} vs "
          f"coreset-trained {sse_core:.4f} "
          f"(training set {len(yc)} vs {len(yf)} points)")


if __name__ == "__main__":
    main()
