"""AutoML on the coreset (paper §5 / Fig 4): tune max_leaves for a random
forest on the compressed data, compare with tuning on the full data.

    PYTHONPATH=src python examples/automl_tuning.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.data import patch_mask, sensor_matrix  # noqa: E402
from repro.trees import tune_k  # noqa: E402


def main() -> None:
    y = sensor_matrix(4000, 15, seed=0)           # Air-Quality-like matrix
    train, test = patch_mask(*y.shape, 0.3, 5, seed=1)
    res = tune_k(y, train, test, ks=[8, 16, 32, 64, 128, 256],
                 coreset_k=64, target_frac=0.03, n_estimators=8)
    print(f"{'method':10s} {'train size':>10s} {'best k':>7s} "
          f"{'best SSE':>10s} {'total s':>8s}")
    for name in res.losses:
        print(f"{name:10s} {res.sizes[name]:10d} {res.best_k[name]:7d} "
              f"{min(res.losses[name]):10.1f} {res.times[name]:8.2f}")
    sp = res.times["full"] / max(res.times["coreset"], 1e-9)
    print(f"\nspeedup of the tuning sweep (incl. one-off compression): "
          f"x{sp:.1f}")
    print("loss-vs-k curves (coreset tracks full):")
    for k, lf, lc in zip(res.ks, res.losses["full"], res.losses["coreset"]):
        print(f"  k={k:4d}: full {lf:9.1f} | coreset {lc:9.1f}")


if __name__ == "__main__":
    main()
