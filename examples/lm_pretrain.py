"""End-to-end LM training driver (deliverable (b)): a ~100M-parameter
qwen2-family model trained for a few hundred steps on the synthetic token
stream, with checkpointing + restart through the production code path.

Full-size invocation (TPU pod): drop --reduced overrides and pass
--production-mesh.  On this CPU container the default below finishes in
roughly half an hour; pass --steps 30 for a quick look.

    PYTHONPATH=src python examples/lm_pretrain.py --steps 300
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.launch.train import train_loop  # noqa: E402
from repro.models import init_params  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M-parameter member of the qwen2 family (GQA + QKV bias preserved)
    cfg = dataclasses.replace(
        get_arch("qwen2-0.5b"), n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=2, d_ff=2048, vocab=32000, dtype="float32", remat=False)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} GQA {cfg.n_heads}/{cfg.n_kv_heads})")

    state = train_loop(cfg, steps=args.steps, batch=args.batch,
                       seq_len=args.seq, ckpt_dir=args.ckpt, save_every=100,
                       log_every=10)
    ls = state["losses"]
    k = max(len(ls) // 10, 1)
    print(f"loss: {np.mean(ls[:k]):.3f} -> {np.mean(ls[-k:]):.3f} over "
          f"{len(ls)} steps (vocab {cfg.vocab}: random = "
          f"{np.log(cfg.vocab):.2f})")


if __name__ == "__main__":
    main()
