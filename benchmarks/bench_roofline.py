"""§Roofline: three-term roofline per (arch x shape) from the dry-run JSONs.

Hardware model (TPU v5e targets from the brief):
  peak   = 197e12 bf16 FLOP/s per chip
  hbm    = 819e9  B/s per chip
  link   = 50e9   B/s ICI per link (we charge the parsed per-chip collective
           result bytes against one link — a conservative single-link model;
           all-reduce ring traffic is ~2x the payload, all-gather ~1x, noted
           per kind in the JSON)

The dry-run's costing numbers (flops / bytes / collective bytes) are
*per-chip* quantities of the SPMD program, extrapolated over the layer loop
(see launch/dryrun.py), so:

  compute_s    = flops / peak
  memory_s     = bytes / hbm
  collective_s = ring_factor-weighted collective bytes / link

  bottleneck   = argmax of the three
  MFU estimate = (MODEL_FLOPS / chips / peak) / max(terms)
  useful ratio = MODEL_FLOPS / (flops * chips)     (remat/dispatch overhead)
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import get_arch, get_shape

from .common import RESULTS, emit, save_json

PEAK = 197e12
HBM = 819e9
LINK = 50e9
RING = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
        "all-to-all": 1.0, "collective-permute": 1.0}


def model_flops(arch: str, shape: str) -> float:
    cfg = get_arch(arch)
    sh = get_shape(shape)
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n * tokens
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * n * tokens
    tokens = sh["global_batch"]          # one new token per sequence
    return 2.0 * n * tokens


def analyze_cell(rec: dict) -> dict | None:
    cost = rec.get("costing")
    if not cost:
        return None
    chips = 1
    for s in rec["mesh_shape"]:
        chips *= s
    flops = cost["flops"]
    bytes_ = cost["bytes"]
    coll = sum(RING.get(k, 1.0) * v
               for k, v in cost["collectives_by_kind"].items())
    compute_s = flops / PEAK
    memory_s = bytes_ / HBM
    coll_s = coll / LINK
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda t: t[1])
    mf = model_flops(rec["arch"], rec["shape"])
    ideal_s = mf / chips / PEAK
    step_s = max(compute_s, memory_s, coll_s)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "bottleneck": dominant[0],
        "model_flops": mf,
        "useful_ratio": mf / max(flops * chips, 1e-9),
        "mfu_estimate": ideal_s / max(step_s, 1e-30),
        "peak_hbm_gib": rec["full"]["memory"]["peak_hbm_estimate"] / 2**30,
        "fits_16gib": rec["full"]["memory"]["peak_hbm_estimate"] < 16 * 2**30,
    }


def run(dryrun_dir: str | None = None, mesh: str = "single"):
    d = pathlib.Path(dryrun_dir or (RESULTS / "dryrun"))
    rows = []
    for p in sorted(d.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        row = analyze_cell(rec)
        if row:
            rows.append(row)
            emit(f"roofline/{row['arch']}/{row['shape']}", 0.0,
                 f"comp={row['compute_s']*1e3:.2f}ms;mem={row['memory_s']*1e3:.2f}ms;"
                 f"coll={row['collective_s']*1e3:.2f}ms;dom={row['bottleneck']};"
                 f"mfu~{row['mfu_estimate']:.2f};useful={row['useful_ratio']:.2f}")
    save_json("bench_roofline", rows)
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| MODEL/HLO | MFU est | peak GiB |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_estimate']:.2f} | {r['peak_hbm_gib']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table(run()))
