"""Shared benchmark plumbing: timing + CSV rows (name,us_per_call,derived)."""
from __future__ import annotations

import json
import pathlib
import sys
import time

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def save_json(name: str, obj) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(obj, indent=1, default=float))
    return p
