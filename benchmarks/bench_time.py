"""Fig 4 (bottom-right): total time to tune k — construction + sweep on the
compression vs the sweep on full data; reports the x-speedup."""
from __future__ import annotations

from repro.data import patch_mask, sensor_matrix
from repro.trees import tune_k

from .common import emit, save_json


def run(n: int = 9358, m: int = 15,
        ks=(8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512, 768),
        target_frac: float = 0.02, n_estimators: int = 24, seed: int = 0):
    """Defaults sized like the paper's sweep (50 k-values, 100-tree forests,
    N = 140k): construction is one-off, the sweep amortizes it."""
    y = sensor_matrix(n, m, seed=seed)
    train, test = patch_mask(n, m, 0.3, 5, seed=seed + 1)
    res = tune_k(y, train, test, ks=list(ks), coreset_k=64,
                 target_frac=target_frac, n_estimators=n_estimators)
    t_full = res.times["full"]
    t_core = res.times["coreset"]           # includes the one-off build
    t_unif = res.times["uniform"]
    speedup = t_full / max(t_core, 1e-9)
    emit("time/full", t_full * 1e6, f"sweep={len(ks)}k;sse={min(res.losses['full']):.1f}")
    emit("time/coreset", t_core * 1e6,
         f"speedup=x{speedup:.1f};size={res.sizes['coreset']};"
         f"sse={min(res.losses['coreset']):.1f}")
    emit("time/uniform", t_unif * 1e6, f"sse={min(res.losses['uniform']):.1f}")
    save_json("bench_time", {"times": res.times, "speedup": speedup,
                             "sizes": res.sizes,
                             "best_sse": {k: min(v) for k, v in res.losses.items()}})
    return speedup


if __name__ == "__main__":
    run()
