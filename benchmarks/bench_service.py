"""Closed-loop load generator for the coreset serving engine (v1 SDK).

Each client thread runs a closed loop (next request issued when the last
one returns).  By default the bench boots an in-process HTTP server and
drives it through the typed SDK (``repro.client.CoresetClient``) in the
encoding chosen with ``--encoding`` — so the measured path includes the
stdlib server plus the negotiated wire codec.  ``--http URL`` targets a
live server instead; ``--engine`` bypasses HTTP and calls the
``CoresetEngine`` directly (the PR-1 baseline mode).  Traffic mix mirrors
the §5 tuning workload:

  * 60% tree-loss queries for random <=k-leaf trees at mixed eps — after
    warm-up these are pure dominance/exact cache hits;
  * 10% fused loss:batch queries (8 segmentations per request) — the
    tuning-sweep inner loop as ONE engine scoring call;
  * 20% builds at randomly drawn (k, eps) — exercises coalescing + LRU;
  * 10% forest fits on the cached coreset points (model-cache path);
  * one background ingest thread appends row bands to a streamed signal
    and rebuilds it (StreamingBuilder path + cache invalidation).

Before the loop starts, registration of a 512x512 signal is timed per
encoding (``register_seconds``) — the ROADMAP's "JSON array parsing
dominates" metric.  Results merge into
``benchmarks/results/bench_service.json`` keyed by mode, so consecutive
runs with ``--encoding json`` and ``--encoding binary`` land side by side
for CI to compare.

  python benchmarks/bench_service.py                      # binary, 10 s
  python benchmarks/bench_service.py --encoding json
  python benchmarks/bench_service.py --smoke              # 2 s (CI)
  python benchmarks/bench_service.py --smoke --cluster    # distributed plane
  python benchmarks/bench_service.py --smoke --delta-mix 0.3  # re-anchor probe
  python benchmarks/bench_service.py --smoke --stream     # v2 streaming probe
  python benchmarks/bench_service.py --smoke --overload   # admission QoS probe

``--cluster`` swaps the single-host engine for the distributed serving
plane — 3 in-process ShardWorkers behind a ClusterEngine coordinator — so
the ``cluster`` row measures register-with-band-scatter and builds that
gather/compose remote band coresets, on the same traffic mix.
``--delta-mix`` and ``--stream`` are dedicated probe runs (they replace
the loadgen): the first measures the delta-write path split by whether it
re-anchored and the build latency served off a re-anchored entry, the
second the v2 chunked streaming encoder's peak memory and compress p50s
vs the buffered v1 body.  Both merge their own mode row into
``bench_service.json`` for the ``stream`` regression suite.
``--overload`` drives one hot + one cold tenant against an admission
controller set to half the measured capacity and records the
accept/reject split, per-tenant percentiles, the Retry-After
distribution, the in-process admit-decision cost and the 503 round-trip
cost — the last two feed the ``qos`` regression suite.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))

try:
    from .common import RESULTS, emit  # python -m benchmarks.bench_service
except ImportError:
    sys.path.insert(0, str(_ROOT / "benchmarks"))
    from common import RESULTS, emit  # python benchmarks/bench_service.py

from repro.client import CoresetClient  # noqa: E402
from repro.core.segmentation import random_tree_segmentation  # noqa: E402
from repro.data.signals import piecewise_signal  # noqa: E402
from repro.service import (CoresetEngine, ServiceMetrics, make_server,  # noqa: E402
                           serve_forever_in_thread)


class _EngineClient:
    """Direct in-process calls — the no-HTTP baseline."""

    def __init__(self, engine: CoresetEngine):
        self.engine = engine

    def loss(self, name, rects, labels, eps):
        return self.engine.tree_loss(name, rects, labels, eps=eps)

    def loss_batch(self, name, rects, labels, eps):
        return self.engine.tree_loss_batch(name, rects, labels, eps=eps)

    def build(self, name, k, eps):
        self.engine.get_coreset(name, k, eps)

    def fit(self, name, k, eps):
        self.engine.fit_forest(name, k=k, eps=eps, n_estimators=3)

    def ingest(self, name, band):
        self.engine.ingest_band(name, band)

    def register(self, name, values):
        self.engine.register_signal(name, values, replace=True)


class _SdkClient:
    """Typed v1 SDK over HTTP in the bench's chosen encoding."""

    def __init__(self, base: str, encoding: str):
        self.c = CoresetClient(base, encoding=encoding)

    def loss(self, name, rects, labels, eps):
        return self.c.query_loss(name, rects, labels, eps=eps)

    def loss_batch(self, name, rects, labels, eps):
        return self.c.query_loss_batch(name, rects, labels, eps=eps)

    def build(self, name, k, eps):
        self.c.build(name, k, eps)

    def fit(self, name, k, eps):
        self.c.fit(name, k, eps, n_estimators=3)

    def ingest(self, name, band):
        self.c.ingest(name, band=band)

    def register(self, name, values):
        # replace: rerunning the loadgen against a long-lived server must not
        # trip the duplicate-registration guard (409 conflict)
        self.c.register_signal(name, values, replace=True)


def _tracing_probe(n: int, m: int, k_max: int, *, queries: int = 150,
                   reps: int = 3) -> dict:
    """Tracing-on vs tracing-off A/B over sequential loss queries.

    Boots a dedicated in-process server with coalescing OFF (the batching
    window would swamp the span cost being measured) and runs the arms as
    INTERLEAVED pairs — each query fires once per arm, back to back on the
    same tree, with the arm order flipped every pair.  Sequential arm
    blocks read machine drift (thermal, page cache, a neighbour's load
    spike) as tracing overhead; pairing cancels anything slower than one
    request, and ``overhead_frac`` is the MEDIAN of the per-pair latency
    differences over the median off-arm latency — an estimator whose
    run-to-run spread is ~3x tighter than differencing two independent
    p50s.  Best (lowest) rep wins, so a whole bad stretch is dropped.
    ``overhead_frac`` is the gated number: scripts/check_bench_regression.py
    fails the service suite when tracing costs more than 5% on the
    loss-query p50.
    """
    from repro import obs

    engine = CoresetEngine(workers=4, coalesce=False)
    srv = make_server(engine)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    cl = CoresetClient(base, encoding="json")
    y = piecewise_signal(n, m, k_max, noise=0.15, seed=0)
    cl.register_signal("trace-probe", y, replace=True)
    cl.build("trace-probe", k_max, 0.2)
    rng = np.random.default_rng(12)
    trees = [random_tree_segmentation(n, m, k_max, rng) for _ in range(16)]
    for t in trees[:4]:   # warm the connection + cache path
        cl.query_loss("trace-probe", t.rects, t.labels, eps=0.2)
    # the probe usually runs right after the loadgen: drop its completed
    # traces (fresh ring buffer, no inherited working set) and collect its
    # garbage now so a mid-measurement gen2 pass doesn't land on one arm
    import gc
    obs.TRACER.clear()
    gc.collect()
    was_enabled = obs.TRACER.enabled
    best = {True: float("inf"), False: float("inf")}
    best_frac = float("inf")
    try:
        for _ in range(reps):
            lats = {True: [], False: []}
            diffs = []
            for i in range(queries):
                t = trees[i % len(trees)]
                arms = (True, False) if i % 2 == 0 else (False, True)
                pair = {}
                for arm in arms:
                    obs.set_enabled(arm)
                    t0 = time.perf_counter()
                    cl.query_loss("trace-probe", t.rects, t.labels, eps=0.2)
                    pair[arm] = time.perf_counter() - t0
                    lats[arm].append(pair[arm])
                diffs.append(pair[True] - pair[False])
            for arm in (True, False):
                ls = sorted(lats[arm])
                best[arm] = min(best[arm], ls[len(ls) // 2])
            diffs.sort()
            off_p50 = sorted(lats[False])[len(lats[False]) // 2]
            best_frac = min(best_frac,
                            diffs[len(diffs) // 2] / max(off_p50, 1e-12))
    finally:
        obs.set_enabled(was_enabled)
        srv.shutdown()
        engine.close()
    return {"on_p50_ms": 1e3 * best[True], "off_p50_ms": 1e3 * best[False],
            "overhead_frac": best_frac,
            "queries_per_arm": queries, "reps": reps}


def _delta_mix_probe(duration: float, m: int, k_max: int,
                     replace_frac: float, encoding: str = "binary") -> dict:
    """Delta-write workload: a streamed signal absorbing a mix of appends
    and in-place replaces, with a build after every delta.

    Appends alternate naturally between the metadata-only re-anchor path
    (even prior band count) and the invalidate+rebuild fallback (odd), and
    every replace invalidates — so one run measures both sides:

      * ``reanchor_ingest_p50_ms`` / ``rebuild_ingest_p50_ms``: the delta
        write itself, split by whether it re-anchored;
      * ``reanchor_hit_p50_ms``: the build AFTER a re-anchoring delta —
        the gated number; it must be a pure cache hit;
      * ``post_reanchor_miss_rate``: fraction of those builds NOT served
        ``exact`` — the zero-rebuild guarantee, gated at ~0.
    """
    metrics = ServiceMetrics()
    engine = CoresetEngine(workers=4, metrics=metrics)
    srv = make_server(engine)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    cl = CoresetClient(base, encoding=encoding)
    rows, gen = 8, 0
    rng = np.random.default_rng(7)

    def band(seed):
        return piecewise_signal(rows, m, 4, noise=0.15, seed=seed)

    def seed_signal():
        nonlocal gen
        gen += 1
        name = f"bench-delta-{gen}"
        cl.ingest(name, band=band(gen))
        cl.ingest(name, band=band(gen + 1))
        cl.build(name, k_max, 0.3)
        return name, 2

    name, nbands = seed_signal()
    counts = {"append": 0, "replace": 0, "reanchored": 0}
    ingest_lat = {"reanchor": [], "rebuild": []}
    hit_lat: list[float] = []
    misses = hits = 0
    deadline = time.perf_counter() + duration
    while time.perf_counter() < deadline:
        if nbands >= 64:                      # keep the signal bounded
            name, nbands = seed_signal()
        do_replace = rng.uniform() < replace_frac
        t0 = time.perf_counter()
        if do_replace:
            r0 = int(rng.integers(0, nbands)) * rows
            r = cl.ingest_delta(name, band(int(rng.integers(1 << 30))),
                                row0=r0)
            counts["replace"] += 1
        else:
            r = cl.ingest_delta(name, band(int(rng.integers(1 << 30))))
            counts["append"] += 1
            nbands += 1
        dt = time.perf_counter() - t0
        reanchored = r.entries_reanchored > 0
        counts["reanchored"] += int(reanchored)
        ingest_lat["reanchor" if reanchored else "rebuild"].append(dt)
        t0 = time.perf_counter()
        b = cl.build(name, k_max, 0.3)
        dt = time.perf_counter() - t0
        if reanchored:
            hit_lat.append(dt)
            if b.served_from == "exact":
                hits += 1
            else:
                misses += 1
    snap = metrics.snapshot()["counters"]
    srv.shutdown()
    engine.close()

    def p50(xs):
        return 1e3 * float(np.sort(xs)[len(xs) // 2]) if xs else None

    return {"mode": "delta_mix", "duration_s": duration,
            "replace_frac": replace_frac, "deltas": counts,
            "reanchor_ingest_p50_ms": p50(ingest_lat["reanchor"]),
            "rebuild_ingest_p50_ms": p50(ingest_lat["rebuild"]),
            "reanchor_hit_p50_ms": p50(hit_lat),
            "post_reanchor_miss_rate": misses / max(hits + misses, 1),
            "cache": {"reanchored": snap.get("cache_reanchored", 0),
                      "reanchor_candidates":
                          snap.get("cache_reanchor_candidates", 0),
                      "builds": snap.get("coreset_builds", 0)}}


def _stream_probe(points: int, reps: int = 15) -> dict:
    """v2 streaming vs v1 buffered on one block-rich compress response.

    Encode-side peak memory is the gated number: the buffered v1 body
    materializes raw npz + compressed frame at once, the v2 generator
    holds one chunk — ``encode_peak_ratio`` (tracemalloc peaks, stream
    over buffered) must stay well under 1.  HTTP p50s ride along from a
    small in-process server with a sub-chunk-size override so the
    latency row exercises real multi-segment transfers.
    """
    import tracemalloc

    from repro.service import protocol as P

    rng = np.random.default_rng(3)
    resp = P.CompressResponse(
        k=5, eps_eff=0.2, served_from="exact", fingerprint="cd" * 16,
        size=points, blocks=points // 4, nbytes=points * 32,
        compression_ratio=0.5, truncated=False,
        X=rng.random((points, 2)) * 512, y=rng.random(points),
        w=rng.random(points) + 0.5)
    tracemalloc.start()
    tracemalloc.reset_peak()
    t0 = time.perf_counter()
    _, frame = resp.to_wire("binary")
    buffered_encode_s = time.perf_counter() - t0
    buffered_bytes = len(frame)
    buffered_peak = tracemalloc.get_traced_memory()[1]
    del frame
    tracemalloc.reset_peak()
    t0 = time.perf_counter()
    stream_peak = max_segment = wire_bytes = chunks = 0
    for seg in P.compress_stream_segments(resp):
        wire_bytes += len(seg)
        max_segment = max(max_segment, len(seg))
        chunks += 1
        stream_peak = max(stream_peak, tracemalloc.get_traced_memory()[1])
        tracemalloc.reset_peak()
    stream_encode_s = time.perf_counter() - t0
    tracemalloc.stop()
    chunks -= 2                               # magic+header and trailer

    # HTTP p50s: cached compress served buffered (v1) vs streamed (v2)
    engine = CoresetEngine(workers=4, metrics=ServiceMetrics())
    srv = make_server(engine, stream_chunk_points=2048)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    y = np.random.default_rng(5).random((128, 128)) * 8.0
    v1 = CoresetClient(base, encoding="binary", stream=False)
    v2 = CoresetClient(base, encoding="binary")
    v1.register_signal("bench-stream-probe", y, replace=True)
    kw = dict(eps=0.03, max_points=1 << 20)
    v1.compress("bench-stream-probe", 4, **kw)     # warm the cache
    lats = {"buffered": [], "stream": []}
    for _ in range(reps):
        for arm, c in (("buffered", v1), ("stream", v2)):
            t0 = time.perf_counter()
            c.compress("bench-stream-probe", 4, **kw)
            lats[arm].append(time.perf_counter() - t0)
    http_chunks = v2.last_stream_chunks
    srv.shutdown()
    engine.close()

    def p50(xs):
        return 1e3 * float(np.sort(xs)[len(xs) // 2])

    return {"mode": "stream", "points": points, "chunks": chunks,
            "wire_bytes": wire_bytes, "buffered_bytes": buffered_bytes,
            "max_segment_bytes": max_segment,
            "encode_peak_bytes": {"buffered": buffered_peak,
                                  "stream": stream_peak},
            "encode_peak_ratio": stream_peak / max(buffered_peak, 1),
            "buffered_encode_ms": 1e3 * buffered_encode_s,
            "stream_encode_ms": 1e3 * stream_encode_s,
            "http_reps": reps, "http_stream_chunks": http_chunks,
            "stream_compress_p50_ms": p50(lats["stream"]),
            "buffered_compress_p50_ms": p50(lats["buffered"])}


def _overload_probe(duration: float, n: int, m: int, k_max: int,
                    hot_frac: float) -> dict:
    """Admission-control probe: one hot tenant over its share, one cold
    tenant under it, against a rate set to half the measured capacity.

    Three numbers matter downstream (the ``qos`` regression suite):

      * ``admit_decision_us`` — in-process cost of one admit+release cycle
        (the overhead EVERY admitted request pays), gated absolute < 50us;
      * ``rejected_rtt_p50_ms`` — HTTP round-trip of a 503 rejection (the
        cost of saying no), a relative wall-clock row;
      * the per-tenant accept/reject split, latency percentiles and the
        Retry-After distribution — recorded for eyeballing, not gated
        (scripts/overload_gate.py owns the QoS pass/fail).
    """
    from repro.client import AdmissionRejectedError
    from repro.service import AdmissionConfig, AdmissionController

    # ---- in-process micro-bench: the decision itself, uncontended
    ctl = AdmissionController(AdmissionConfig(tenants={"t": 1.0},
                                              rate_rps=1e9))
    reps = 5000
    for _ in range(500):                      # warm allocator + dicts
        with ctl.admit("loss", "t"):
            pass
    t0 = time.perf_counter()
    for _ in range(reps):
        with ctl.admit("loss", "t"):
            pass
    admit_us = 1e6 * (time.perf_counter() - t0) / reps

    # ---- HTTP phase: measure capacity bare, then admit at half of it
    metrics = ServiceMetrics()
    engine = CoresetEngine(workers=4, metrics=metrics)
    srv = make_server(engine)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"
    setup = CoresetClient(base, encoding="binary")
    y = piecewise_signal(n, m, k_max, noise=0.15, seed=0)
    setup.register_signal("bench-overload", y, replace=True)
    setup.build("bench-overload", k_max, 0.2)
    rng = np.random.default_rng(2)
    trees = [random_tree_segmentation(n, m, 6, rng) for _ in range(12)]
    for t in trees[:4]:
        setup.query_loss("bench-overload", t.rects, t.labels, eps=0.3)

    recs: dict[str, dict] = {}
    lock = threading.Lock()

    def drive(tenant: str, stop: threading.Event, pace_s: float | None):
        cl = CoresetClient(base, tenant=tenant, retries=0)
        r = recs.setdefault(tenant, {"ok": 0, "rejected": 0, "errors": 0,
                                     "lat": [], "retry_after": []})
        lrng = np.random.default_rng(abs(hash(tenant)) % (1 << 32))
        while not stop.is_set():
            q = trees[int(lrng.integers(len(trees)))]
            t0 = time.perf_counter()
            try:
                cl.query_loss("bench-overload", q.rects, q.labels, eps=0.3)
                dt = time.perf_counter() - t0
                with lock:
                    r["ok"] += 1
                    r["lat"].append(dt)
            except AdmissionRejectedError as exc:
                with lock:
                    r["rejected"] += 1
                    if exc.retry_after is not None:
                        r["retry_after"].append(exc.retry_after)
                time.sleep(0.002)
            except Exception:  # noqa: BLE001
                with lock:
                    r["errors"] += 1
            if pace_s is not None:
                time.sleep(pace_s)

    # unloaded capacity: short unthrottled burst with admission off
    stop = threading.Event()
    cap_threads = [threading.Thread(target=drive, args=("cap", stop, None))
                   for _ in range(4)]
    for t in cap_threads:
        t.start()
    cap_window = min(1.0, duration / 2)
    time.sleep(cap_window)
    stop.set()
    for t in cap_threads:
        t.join()
    capacity = recs["cap"]["ok"] / cap_window

    rate = 0.5 * capacity
    ctl = AdmissionController(AdmissionConfig(
        tenants={"hot": 2.0, "cold": 1.0}, rate_rps=rate, burst_s=0.2,
        parallelism=4))
    ctl.metrics = metrics
    engine.admission = ctl
    cold_share = rate / 3.0
    hot_threads = max(1, round(4 * hot_frac))
    stop = threading.Event()
    threads = [threading.Thread(target=drive, args=("hot", stop, None))
               for _ in range(hot_threads)]
    threads.append(threading.Thread(
        target=drive, args=("cold", stop, 1.0 / max(cold_share * 0.4, 1.0))))
    for t in threads:
        t.start()
    time.sleep(duration)
    stop.set()
    for t in threads:
        t.join()

    # the cost of saying no: shrink the rate to ~zero and time pure 503s
    ctl.config.rate_rps = 1e-9
    rej_cl = CoresetClient(base, tenant="hot", retries=0)
    rej_lat: list[float] = []
    for _ in range(80):
        q = trees[int(rng.integers(len(trees)))]
        t0 = time.perf_counter()
        try:
            rej_cl.query_loss("bench-overload", q.rects, q.labels, eps=0.3)
        except AdmissionRejectedError:
            rej_lat.append(time.perf_counter() - t0)
    snap = engine.stats()["admission"]
    srv.shutdown()
    engine.close()

    def pct(xs, q):
        return 1e3 * float(np.percentile(xs, q)) if xs else None

    tenants = {}
    for name in ("hot", "cold"):
        r = recs.get(name, {"ok": 0, "rejected": 0, "errors": 0, "lat": [],
                            "retry_after": []})
        offered = r["ok"] + r["rejected"]
        tenants[name] = {"ok": r["ok"], "rejected": r["rejected"],
                         "errors": r["errors"],
                         "accept_rate": r["ok"] / max(offered, 1),
                         "p50_ms": pct(r["lat"], 50),
                         "p95_ms": pct(r["lat"], 95)}
    ra = recs.get("hot", {}).get("retry_after", []) \
        + recs.get("cold", {}).get("retry_after", [])
    return {"mode": "overload", "duration_s": duration, "hot_frac": hot_frac,
            "capacity_rps": capacity, "admitted_rate_rps": rate,
            "admit_decision_us": admit_us,
            "rejected_rtt_p50_ms": pct(rej_lat, 50),
            "rejected_rtt_p95_ms": pct(rej_lat, 95),
            "rejected_samples": len(rej_lat),
            "tenants": tenants,
            "retry_after_s": {"count": len(ra),
                              "min": min(ra) if ra else None,
                              "p50": float(np.percentile(ra, 50)) if ra else None,
                              "p95": float(np.percentile(ra, 95)) if ra else None,
                              "max": max(ra) if ra else None},
            "admission": {"admitted_total": snap["admitted_total"],
                          "rejected_total": snap["rejected_total"],
                          "rejected_by_reason": snap["rejected_by_reason"]}}


def _time_registration(client, n: int, m: int, repeats: int = 3) -> float:
    """Best-of-``repeats`` wall-clock to register an (n, m) dense signal —
    isolates the wire codec + server parse cost (no coreset build)."""
    y = piecewise_signal(n, m, 8, noise=0.15, seed=42)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        client.register("bench-register-probe", y)
        best = min(best, time.perf_counter() - t0)
    return best


def run(duration: float, clients: int, n: int, m: int, k_max: int,
        http: str | None, encoding: str, engine_mode: bool,
        register_nm: tuple[int, int], cluster: bool = False) -> dict:
    metrics = ServiceMetrics()
    engine = None
    srv = None
    worker_srvs: list = []
    if engine_mode:
        engine = CoresetEngine(workers=4, metrics=metrics)
        client_fac = lambda: _EngineClient(engine)  # noqa: E731
        mode = "engine"
    elif cluster:
        # the distributed plane: 3 in-process ShardWorkers behind a
        # ClusterEngine coordinator, driven over HTTP like any other mode —
        # the measured path includes band scatter on register and the
        # gather/compose fan-in on every dense build
        from repro.cluster import ClusterEngine, ShardWorker, make_worker_server
        for i in range(3):
            wsrv = make_worker_server(ShardWorker(worker_id=f"bench-w{i}"))
            threading.Thread(target=wsrv.serve_forever, daemon=True).start()
            worker_srvs.append(wsrv)
        peer_urls = [f"http://127.0.0.1:{s.server_address[1]}"
                     for s in worker_srvs]
        engine = ClusterEngine(peer_urls, workers=4, metrics=metrics)
        srv = make_server(engine)
        serve_forever_in_thread(srv)
        base = f"http://127.0.0.1:{srv.server_address[1]}"
        client_fac = lambda: _SdkClient(base, encoding)  # noqa: E731
        mode = "cluster"
    else:
        if http:
            base = http
        else:
            engine = CoresetEngine(workers=4, metrics=metrics)
            srv = make_server(engine)
            serve_forever_in_thread(srv)
            base = f"http://127.0.0.1:{srv.server_address[1]}"
        client_fac = lambda: _SdkClient(base, encoding)  # noqa: E731
        mode = encoding

    y = piecewise_signal(n, m, k_max, noise=0.15, seed=0)
    setup = client_fac()
    reg_s = _time_registration(setup, *register_nm)
    setup.register("bench", y)
    setup.build("bench", k_max, 0.2)  # warm anchor coreset

    stop = threading.Event()
    counts = {"loss": 0, "loss_batch": 0, "build": 0, "fit": 0, "ingest": 0,
              "errors": 0}
    lat: dict[str, list[float]] = {op: [] for op in counts}
    lock = threading.Lock()

    def record(op, dt):
        with lock:
            counts[op] += 1
            lat[op].append(dt)

    def worker(cid: int):
        rng = np.random.default_rng(cid)
        cl = client_fac()
        while not stop.is_set():
            u = rng.uniform()
            t0 = time.perf_counter()
            try:
                if u < 0.6:
                    kq = int(rng.integers(3, k_max + 1))
                    q = random_tree_segmentation(n, m, kq, rng)
                    cl.loss("bench", q.rects, q.labels,
                            float(rng.choice([0.25, 0.3, 0.4])))
                    op = "loss"
                elif u < 0.7:
                    kq = int(rng.integers(3, k_max + 1))
                    segs = [random_tree_segmentation(n, m, kq, rng)
                            for _ in range(8)]
                    cl.loss_batch("bench",
                                  np.stack([s.rects for s in segs]),
                                  np.stack([s.labels for s in segs]),
                                  float(rng.choice([0.25, 0.3, 0.4])))
                    op = "loss_batch"
                elif u < 0.9:
                    cl.build("bench", int(rng.integers(2, k_max + 1)),
                             float(rng.choice([0.2, 0.25, 0.3])))
                    op = "build"
                else:
                    cl.fit("bench", k_max, 0.2)
                    op = "fit"
                record(op, time.perf_counter() - t0)
            except Exception:  # noqa: BLE001
                with lock:
                    counts["errors"] += 1

    def ingester():
        cl = client_fac()
        rng = np.random.default_rng(999)
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                band = piecewise_signal(8, m, 4, seed=int(rng.integers(1 << 30)))
                cl.ingest("bench-stream", band)
                cl.build("bench-stream", k_max, 0.3)
                record("ingest", time.perf_counter() - t0)
            except Exception:  # noqa: BLE001
                with lock:
                    counts["errors"] += 1
            stop.wait(0.25)

    threads = [threading.Thread(target=worker, args=(cid,), daemon=True)
               for cid in range(clients)]
    threads.append(threading.Thread(target=ingester, daemon=True))
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(duration)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    wall = time.perf_counter() - t_start

    total = sum(counts[op] for op in counts if op != "errors")
    out = {"mode": mode, "duration_s": wall, "clients": clients,
           "ops": dict(counts), "rps": total / wall,
           "register_seconds": reg_s,
           "register_nm": list(register_nm)}
    for op, xs in lat.items():
        if xs:
            xs = np.sort(xs)
            out[op] = {"p50_ms": 1e3 * float(xs[len(xs) // 2]),
                       "p99_ms": 1e3 * float(xs[min(len(xs) - 1, int(0.99 * len(xs)))]),
                       "count": len(xs)}
    if engine is not None:
        snap = metrics.snapshot()["counters"]
        hits = snap.get("cache_hit_exact", 0) + snap.get("cache_hit_dominated", 0)
        lookups = hits + snap.get("cache_miss", 0)
        out["cache"] = {"hit_rate": hits / max(lookups, 1),
                        "dominance_hits": snap.get("cache_hit_dominated", 0),
                        "builds": snap.get("coreset_builds", 0),
                        "coalesced": snap.get("builds_coalesced", 0),
                        "forest_hits": snap.get("forest_cache_hit", 0)}
        out["loss_scoring_calls"] = snap.get("loss_scoring_calls", 0)
        if cluster:
            out["cluster"] = {
                "workers": len(worker_srvs),
                "gathers": snap.get("cluster_gathers", 0),
                "bands_scattered": snap.get("cluster_bands_scattered", 0),
                "degraded_builds": snap.get("cluster_degraded_builds", 0),
                "band_cache_hits": snap.get("cluster_band_cache_hits", 0),
            }
        # cross-request query coalescing: how many loss queries rode along
        # in someone else's dispatch, and the scoring calls the fusion saved
        loss_served = counts["loss"]
        out["coalesce"] = {
            "loss_requests": loss_served,
            "coalesced_total": snap.get("query_coalesced_total", 0),
            "fused_dispatches": snap.get("query_fused_dispatches", 0),
            "flushes_window": snap.get('query_flushes{reason="window"}', 0),
            "flushes_full": snap.get('query_flushes{reason="full"}', 0),
            "flushes_deadline": snap.get('query_flushes{reason="deadline"}', 0),
        }
    if srv is not None:
        srv.shutdown()
    if engine is not None:
        engine.close()
    for wsrv in worker_srvs:
        wsrv.shutdown()
        wsrv.server_close()
    return out


def _save_merged(res: dict) -> pathlib.Path:
    """Merge this run under its mode key so JSON and binary runs land side
    by side in one file for CI to compare."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / "bench_service.json"
    merged = {}
    if p.exists():
        try:
            old = json.loads(p.read_text())
            # one-file-per-mode layout only; discard pre-v1 flat layouts
            if isinstance(old, dict) and all(
                    isinstance(v, dict) and "mode" in v for v in old.values()):
                merged = old
        except (json.JSONDecodeError, OSError):
            pass
    merged[res["mode"]] = res
    p.write_text(json.dumps(merged, indent=1, default=float))
    return p


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--m", type=int, default=96)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--encoding", choices=("json", "binary"), default="binary",
                    help="wire encoding the SDK clients negotiate")
    ap.add_argument("--http", default=None,
                    help="target a live server (e.g. http://127.0.0.1:8787) "
                         "instead of booting one in-process")
    ap.add_argument("--engine", action="store_true",
                    help="bypass HTTP and drive the CoresetEngine directly")
    ap.add_argument("--cluster", action="store_true",
                    help="drive the distributed plane: 3 in-process "
                         "ShardWorkers behind a ClusterEngine coordinator")
    ap.add_argument("--register-n", type=int, default=512,
                    help="rows of the registration-latency probe signal")
    ap.add_argument("--register-m", type=int, default=512,
                    help="cols of the registration-latency probe signal")
    ap.add_argument("--delta-mix", type=float, default=None, metavar="FRAC",
                    nargs="?", const=0.3,
                    help="run the delta-write probe instead of the loadgen: "
                         "FRAC of deltas are in-place replaces (invalidate), "
                         "the rest appends (re-anchor-eligible)")
    ap.add_argument("--stream", action="store_true",
                    help="run the v2-streaming probe instead of the loadgen "
                         "(encode peak memory + chunked compress p50)")
    ap.add_argument("--overload", type=float, default=None, metavar="HOT_FRAC",
                    nargs="?", const=0.75,
                    help="run the admission-control overload probe instead "
                         "of the loadgen: HOT_FRAC of the closed-loop "
                         "drivers belong to the hot tenant (accept/reject "
                         "split, per-tenant p50/p95, Retry-After "
                         "distribution, admit-decision us)")
    ap.add_argument("--smoke", action="store_true",
                    help="2-second CI run: 4 clients, small signal")
    args = ap.parse_args()
    if args.smoke:
        args.duration, args.clients, args.n, args.m = 2.0, 4, 96, 64

    if args.cluster and (args.engine or args.http):
        ap.error("--cluster boots its own plane; drop --engine/--http")
    probes = [args.delta_mix is not None, args.stream,
              args.overload is not None]
    if sum(probes) > 1:
        ap.error("--delta-mix / --stream / --overload are separate probe "
                 "runs")
    if any(probes) and (args.engine or args.http or args.cluster):
        ap.error("the probes boot their own server; drop "
                 "--engine/--http/--cluster")

    if args.delta_mix is not None:
        if not 0.0 <= args.delta_mix <= 1.0:
            ap.error("--delta-mix FRAC must be in [0, 1]")
        res = _delta_mix_probe(args.duration, args.m, args.k,
                               args.delta_mix, args.encoding)
        if res["reanchor_hit_p50_ms"] is not None:
            emit("service_reanchor_hit", 1e3 * res["reanchor_hit_p50_ms"],
                 f"miss_rate={res['post_reanchor_miss_rate']:.3f}")
        p = _save_merged(res)
        print(f"[bench_service] mode=delta_mix deltas={res['deltas']} "
              f"reanchor_hit_p50={res['reanchor_hit_p50_ms']}ms "
              f"miss_rate={res['post_reanchor_miss_rate']:.3f} -> {p}")
        if res["deltas"]["reanchored"] == 0:
            sys.exit("[bench_service] degenerate run: nothing re-anchored")
        return

    if args.overload is not None:
        if not 0.0 < args.overload < 1.0:
            ap.error("--overload HOT_FRAC must be in (0, 1)")
        res = _overload_probe(args.duration, args.n, args.m, args.k,
                              args.overload)
        emit("service_admit_decision", res["admit_decision_us"],
             f"rejected_rtt_p50={res['rejected_rtt_p50_ms']}ms")
        p = _save_merged(res)
        t = res["tenants"]
        print(f"[bench_service] mode=overload rate={res['admitted_rate_rps']:.0f}rps "
              f"hot ok={t['hot']['ok']} rej={t['hot']['rejected']} "
              f"cold ok={t['cold']['ok']} rej={t['cold']['rejected']} "
              f"admit={res['admit_decision_us']:.1f}us "
              f"rejected_rtt_p50={res['rejected_rtt_p50_ms']}ms -> {p}")
        if res["admission"]["rejected_total"] == 0:
            sys.exit("[bench_service] degenerate run: nothing was rejected")
        if res["rejected_samples"] == 0:
            sys.exit("[bench_service] degenerate run: 503 cost unmeasured")
        return

    if args.stream:
        res = _stream_probe(points=5 * 32768 + 11 if args.smoke
                            else 8 * 32768 + 11)
        emit("service_stream_compress", 1e3 * res["stream_compress_p50_ms"],
             f"chunks={res['http_stream_chunks']} "
             f"peak_ratio={res['encode_peak_ratio']:.2f}")
        p = _save_merged(res)
        print(f"[bench_service] mode=stream chunks={res['chunks']} "
              f"peak_ratio={res['encode_peak_ratio']:.2f} "
              f"stream_p50={res['stream_compress_p50_ms']:.2f}ms "
              f"buffered_p50={res['buffered_compress_p50_ms']:.2f}ms -> {p}")
        if res["chunks"] < 4 or res["http_stream_chunks"] < 4:
            sys.exit("[bench_service] degenerate run: stream did not chunk")
        return

    res = run(args.duration, args.clients, args.n, args.m, args.k,
              args.http, args.encoding, args.engine,
              (args.register_n, args.register_m), cluster=args.cluster)
    if args.http is None and not args.cluster:
        # tracing overhead A/B rides in the mode's result row (the results
        # file is keyed by mode and validated as such on merge)
        res["tracing"] = _tracing_probe(
            args.n, args.m, args.k,
            queries=100 if args.smoke else 150,
            reps=3)
        tr = res["tracing"]
        print(f"[bench_service] tracing p50 on={tr['on_p50_ms']:.2f}ms "
              f"off={tr['off_p50_ms']:.2f}ms "
              f"overhead={tr['overhead_frac']:+.1%}")
    emit("service_rps", 1e6 / max(res["rps"], 1e-9), f"rps={res['rps']:.1f}")
    emit("service_register", 1e6 * res["register_seconds"],
         f"mode={res['mode']} nm={res['register_nm']}")
    if "loss" in res:
        emit("service_loss_p50", 1e3 * res["loss"]["p50_ms"],
             f"p99_ms={res['loss']['p99_ms']:.2f}")
    p = _save_merged(res)
    print(f"[bench_service] mode={res['mode']} {res['rps']:.1f} req/s over "
          f"{res['duration_s']:.1f}s ({res['ops']}) "
          f"register({res['register_nm'][0]}x{res['register_nm'][1]})="
          f"{1e3 * res['register_seconds']:.1f}ms -> {p}")
    if res["ops"]["errors"]:
        sys.exit(f"[bench_service] {res['ops']['errors']} request errors")
    if res["ops"]["loss"] == 0 or res["ops"]["ingest"] == 0:
        sys.exit("[bench_service] degenerate run: no loss or ingest traffic")


if __name__ == "__main__":
    main()
