"""Closed-loop load generator for the coreset serving engine.

Each client thread runs a closed loop (next request issued when the last
one returns) against an in-process ``CoresetEngine`` by default, or against
a live HTTP server with ``--http URL`` (then the measured path includes the
stdlib server + JSON codec).  Traffic mix mirrors the §5 tuning workload:

  * 70% tree-loss queries for random <=k-leaf trees at mixed eps — after
    warm-up these are pure dominance/exact cache hits;
  * 20% builds at randomly drawn (k, eps) — exercises coalescing + LRU;
  * 10% forest fits on the cached coreset points;
  * one background ingest thread appends row bands to a streamed signal
    and rebuilds it (StreamingBuilder path + cache invalidation).

  python benchmarks/bench_service.py                # 10 s, 8 clients
  python benchmarks/bench_service.py --smoke        # 2 s, 4 clients (CI)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time
import urllib.request

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))

try:
    from .common import emit, save_json  # python -m benchmarks.bench_service
except ImportError:
    sys.path.insert(0, str(_ROOT / "benchmarks"))
    from common import emit, save_json  # python benchmarks/bench_service.py

from repro.core.segmentation import random_tree_segmentation  # noqa: E402
from repro.data.signals import piecewise_signal  # noqa: E402
from repro.service import CoresetEngine, ServiceMetrics  # noqa: E402


class _LocalClient:
    def __init__(self, engine: CoresetEngine):
        self.engine = engine

    def loss(self, name, rects, labels, eps):
        return self.engine.tree_loss(name, rects, labels, eps=eps)

    def build(self, name, k, eps):
        self.engine.get_coreset(name, k, eps)

    def fit(self, name, k, eps):
        self.engine.fit_forest(name, k=k, eps=eps, n_estimators=3)

    def ingest(self, name, band):
        self.engine.ingest_band(name, band)

    def register(self, name, values):
        self.engine.register_signal(name, values, replace=True)


class _HttpClient:
    def __init__(self, base: str):
        self.base = base.rstrip("/")

    def _post(self, path, payload):
        req = urllib.request.Request(
            self.base + path, data=json.dumps(payload).encode(),
            headers={"Content-Type": "application/json"}, method="POST")
        with urllib.request.urlopen(req, timeout=120) as r:
            return json.loads(r.read())

    def loss(self, name, rects, labels, eps):
        return self._post("/query/loss", {"name": name, "rects": rects.tolist(),
                                          "labels": labels.tolist(), "eps": eps})

    def build(self, name, k, eps):
        self._post("/build", {"name": name, "k": k, "eps": eps})

    def fit(self, name, k, eps):
        self._post("/query/fit", {"name": name, "k": k, "eps": eps,
                                  "n_estimators": 3})

    def ingest(self, name, band):
        self._post("/ingest", {"name": name, "band": band.tolist()})

    def register(self, name, values):
        # replace: rerunning the loadgen against a long-lived server must not
        # trip the duplicate-registration guard
        self._post("/signals", {"name": name, "values": values.tolist(),
                                "replace": True})


def run(duration: float, clients: int, n: int, m: int, k_max: int,
        http: str | None) -> dict:
    metrics = ServiceMetrics()
    engine = None
    if http:
        client_fac = lambda: _HttpClient(http)  # noqa: E731
    else:
        engine = CoresetEngine(workers=4, metrics=metrics)
        client_fac = lambda: _LocalClient(engine)  # noqa: E731

    y = piecewise_signal(n, m, k_max, noise=0.15, seed=0)
    setup = client_fac()
    setup.register("bench", y)
    setup.build("bench", k_max, 0.2)  # warm anchor coreset

    stop = threading.Event()
    counts = {"loss": 0, "build": 0, "fit": 0, "ingest": 0, "errors": 0}
    lat: dict[str, list[float]] = {op: [] for op in counts}
    lock = threading.Lock()

    def record(op, dt):
        with lock:
            counts[op] += 1
            lat[op].append(dt)

    def worker(cid: int):
        rng = np.random.default_rng(cid)
        cl = client_fac()
        while not stop.is_set():
            u = rng.uniform()
            t0 = time.perf_counter()
            try:
                if u < 0.7:
                    kq = int(rng.integers(3, k_max + 1))
                    q = random_tree_segmentation(n, m, kq, rng)
                    cl.loss("bench", q.rects, q.labels,
                            float(rng.choice([0.25, 0.3, 0.4])))
                    op = "loss"
                elif u < 0.9:
                    cl.build("bench", int(rng.integers(2, k_max + 1)),
                             float(rng.choice([0.2, 0.25, 0.3])))
                    op = "build"
                else:
                    cl.fit("bench", k_max, 0.2)
                    op = "fit"
                record(op, time.perf_counter() - t0)
            except Exception:  # noqa: BLE001
                with lock:
                    counts["errors"] += 1

    def ingester():
        cl = client_fac()
        rng = np.random.default_rng(999)
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                band = piecewise_signal(8, m, 4, seed=int(rng.integers(1 << 30)))
                cl.ingest("bench-stream", band)
                cl.build("bench-stream", k_max, 0.3)
                record("ingest", time.perf_counter() - t0)
            except Exception:  # noqa: BLE001
                with lock:
                    counts["errors"] += 1
            stop.wait(0.25)

    threads = [threading.Thread(target=worker, args=(cid,), daemon=True)
               for cid in range(clients)]
    threads.append(threading.Thread(target=ingester, daemon=True))
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(duration)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    wall = time.perf_counter() - t_start

    total = sum(counts[op] for op in ("loss", "build", "fit", "ingest"))
    out = {"duration_s": wall, "clients": clients, "ops": dict(counts),
           "rps": total / wall, "http": bool(http)}
    for op, xs in lat.items():
        if xs:
            xs = np.sort(xs)
            out[op] = {"p50_ms": 1e3 * float(xs[len(xs) // 2]),
                       "p99_ms": 1e3 * float(xs[min(len(xs) - 1, int(0.99 * len(xs)))]),
                       "count": len(xs)}
    if engine is not None:
        snap = metrics.snapshot()["counters"]
        hits = snap.get("cache_hit_exact", 0) + snap.get("cache_hit_dominated", 0)
        lookups = hits + snap.get("cache_miss", 0)
        out["cache"] = {"hit_rate": hits / max(lookups, 1),
                        "dominance_hits": snap.get("cache_hit_dominated", 0),
                        "builds": snap.get("coreset_builds", 0),
                        "coalesced": snap.get("builds_coalesced", 0)}
        engine.close()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--m", type=int, default=96)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--http", default=None,
                    help="target a live server (e.g. http://127.0.0.1:8787) "
                         "instead of the in-process engine")
    ap.add_argument("--smoke", action="store_true",
                    help="2-second CI run: 4 clients, small signal")
    args = ap.parse_args()
    if args.smoke:
        args.duration, args.clients, args.n, args.m = 2.0, 4, 96, 64

    res = run(args.duration, args.clients, args.n, args.m, args.k, args.http)
    emit("service_rps", 1e6 / max(res["rps"], 1e-9), f"rps={res['rps']:.1f}")
    if "loss" in res:
        emit("service_loss_p50", 1e3 * res["loss"]["p50_ms"],
             f"p99_ms={res['loss']['p99_ms']:.2f}")
    p = save_json("bench_service", res)
    print(f"[bench_service] {res['rps']:.1f} req/s over {res['duration_s']:.1f}s "
          f"({res['ops']}) -> {p}")
    if res["ops"]["errors"]:
        sys.exit(f"[bench_service] {res['ops']['errors']} request errors")
    if res["ops"]["loss"] == 0 or res["ops"]["ingest"] == 0:
        sys.exit("[bench_service] degenerate run: no loss or ingest traffic")


if __name__ == "__main__":
    main()
