"""Backend sweep for the repro.ops dispatch surface.

Times every registered backend (numpy oracle, jitted xla, Pallas —
interpret-mode on this CPU container, so its wall-times are kernel-body
semantics, not TPU timing) for each of the four canonical ops, and records
cross-backend parity deltas.  The batched-Pallas-vs-dense delta is the
number ``scripts/ci_smoke.sh`` gates on (<= 1e-4 relative): the serving
engine's /v1/query/loss:batch hot path rides the batched kernel on TPU, so
it must agree with the dense dispatched path it replaced.

Results merge into ``benchmarks/results/bench_ops.json`` keyed by op and
backend (existing keys from other runs are preserved).

  python -m benchmarks.bench_ops [--fast]
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

try:
    from .common import RESULTS, emit, timed   # python -m benchmarks.bench_ops
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
    from common import RESULTS, emit, timed    # python benchmarks/bench_ops.py

from repro import ops                                        # noqa: E402
from repro.core import random_tree_segmentation, signal_coreset  # noqa: E402
from repro.data import piecewise_signal                      # noqa: E402


def _merge_save(obj: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "bench_ops.json"
    merged = {}
    if path.exists():
        merged = json.loads(path.read_text())
    for op, per_backend in obj.items():
        if isinstance(per_backend, dict):
            merged.setdefault(op, {}).update(per_backend)
        else:
            merged[op] = per_backend
    path.write_text(json.dumps(merged, indent=1, default=float))


def _rel(a, b) -> float:
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-9)))


def run(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    results: dict = {}
    repeat = 2 if fast else 3

    def sweep(op_name, call, parity_of):
        per = {}
        ref = None
        for b in ops.BACKENDS:
            out, _ = timed(call, b)                    # warmup / compile
            out, dt = timed(call, b, repeat=repeat)
            if ref is None:
                ref = parity_of(out)                   # numpy runs first
            per[b] = {"us_per_call": dt * 1e6,
                      "rel_delta_vs_numpy": _rel(parity_of(out), ref)}
            emit(f"ops/{op_name}_{b}", dt * 1e6,
                 f"rel_vs_numpy={per[b]['rel_delta_vs_numpy']:.2e}")
        return per

    # ---- sat_moments
    n = 256 if fast else 768
    y = rng.normal(size=(n, n))
    results["sat_moments"] = sweep(
        "sat_moments", lambda b: ops.sat_moments(y, backend=b), lambda o: o)

    # ---- fitting_loss + fitting_loss_batched on one coreset
    ys = piecewise_signal(96 if fast else 160, 80 if fast else 120, 6,
                          noise=0.2, seed=0)
    cs = signal_coreset(ys, 6, 0.3)
    segs = [random_tree_segmentation(*ys.shape, 6, rng)
            for _ in range(4 if fast else 16)]
    sr = np.stack([s.rects for s in segs]).astype(np.float64)
    sl = np.stack([s.labels for s in segs])
    results["fitting_loss"] = sweep(
        "fitting_loss",
        lambda b: ops.fitting_loss(cs, segs[0].rects, segs[0].labels,
                                   backend=b),
        lambda o: o)
    results["fitting_loss_batched"] = sweep(
        "fitting_loss_batched",
        lambda b: ops.fitting_loss_batched(cs, sr, sl, backend=b),
        lambda o: o)

    # the CI gate: batched Pallas kernel vs the dense dispatched (xla) path
    dense = ops.fitting_loss_batched(cs, sr, sl, backend="xla")
    pallas = ops.fitting_loss_batched(cs, sr, sl, backend="pallas")
    gate = _rel(pallas, dense)
    results["parity"] = {
        "batched_pallas_vs_dense_rel": gate,
        "coreset_blocks": cs.num_blocks, "trees": int(sr.shape[0]),
        "leaves": int(sr.shape[1]),
    }
    emit("ops/parity_batched_pallas_vs_dense", 0.0, f"rel={gate:.2e}")

    # ---- hist_split
    P, F, B = (50_000, 4, 64) if fast else (200_000, 8, 256)
    codes = rng.integers(0, B, size=(P, F)).astype(np.uint8)
    w = rng.uniform(0.5, 1.5, P)
    yv = rng.normal(size=P)
    results["hist_split"] = sweep(
        "hist_split",
        lambda b: ops.hist_split(codes, w, w * yv, w * yv * yv, B, backend=b),
        lambda o: o)

    # selection state alongside the numbers (what auto would pick here)
    results["selection"] = {op: s["selected"]
                            for op, s in ops.snapshot().items()}
    _merge_save(results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(fast=ap.parse_args().fast)
