"""Backend sweep for the repro.ops dispatch surface.

Times every registered backend (numpy oracle, jitted xla, Pallas —
interpret-mode on this CPU container, so its wall-times are kernel-body
semantics, not TPU timing) for each of the four canonical ops, and records
cross-backend parity deltas.  The batched-Pallas-vs-dense delta is the
number ``scripts/ci_smoke.sh`` gates on (<= 1e-4 relative): the serving
engine's /v1/query/loss:batch hot path rides the batched kernel on TPU, so
it must agree with the dense dispatched path it replaced.

Results merge into ``benchmarks/results/bench_ops.json`` keyed by op and
backend (existing keys from other runs are preserved).  ``--tune`` populates
the kernel autotune cache (``repro.ops.autotune``) before the sweep, so the
accelerator rows run with their tuned configurations and the ``autotune``
section can gate on them; every backend row carries selection provenance
(host/device, tuned config, cache hit/miss).

  python -m benchmarks.bench_ops [--fast] [--tune]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

try:
    from .common import RESULTS, emit, timed   # python -m benchmarks.bench_ops
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
    from common import RESULTS, emit, timed    # python benchmarks/bench_ops.py

from repro import ops                                        # noqa: E402
from repro.core import random_tree_segmentation, signal_coreset  # noqa: E402
from repro.data import piecewise_signal                      # noqa: E402
from repro.ops import autotune                               # noqa: E402


def _merge_save(obj: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "bench_ops.json"
    merged = {}
    if path.exists():
        merged = json.loads(path.read_text())
    for op, per_backend in obj.items():
        if isinstance(per_backend, dict):
            merged.setdefault(op, {}).update(per_backend)
        else:
            merged[op] = per_backend
    path.write_text(json.dumps(merged, indent=1, default=float))


def _rel(a, b) -> float:
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-9)))


def _ingest_delta_gate(n: int, m: int, band_rows: int) -> dict:
    """Delta write path vs legacy full re-ingest on an (n, m) registered
    signal: the delta ships/patches ``band_rows`` rows (delta_sat + version
    fold), the legacy path re-registers all n rows and re-SATs from scratch.
    Also records the loss parity of the delta-built coreset against a
    from-scratch build of the final signal (ci_smoke gates both numbers)."""
    import time

    from repro.core import fitting_loss, random_tree_segmentation
    from repro.service import CoresetEngine, ServiceMetrics

    rng = np.random.default_rng(7)
    y = piecewise_signal(n, m, 8, noise=0.15, seed=2)
    band = rng.normal(size=(band_rows, m))
    y2 = y.copy()
    y2[n - band_rows:] = band
    k, eps = 8, 0.3

    eng = CoresetEngine(workers=1, metrics=ServiceMetrics())
    scratch = CoresetEngine(workers=1, metrics=ServiceMetrics())
    try:
        eng.register_signal("sig", y)
        eng.signal("sig").ensure_stats()   # steady state: SAT materialized
        t0 = time.perf_counter()
        eng.ingest_delta("sig", band, row0=n - band_rows)
        delta_s = time.perf_counter() - t0
        cs_delta, _, _ = eng.get_coreset("sig", k, eps)

        scratch.register_signal("sig", y2)
        cs_scratch, _, _ = scratch.get_coreset("sig", k, eps)

        # legacy full re-ingest of the same mutation: all n rows over the
        # registration path + a from-scratch re-SAT of the new state
        t0 = time.perf_counter()
        eng.register_signal("sig", y2, replace=True)
        eng.signal("sig").ensure_stats()
        rebuild_s = time.perf_counter() - t0

        q = random_tree_segmentation(n, m, k, rng)
        ld = fitting_loss(cs_delta, q.rects, q.labels)
        ls = fitting_loss(cs_scratch, q.rects, q.labels)
        parity = abs(ld - ls) / max(abs(ls), 1e-12)
        return {"n": n, "m": m, "band_rows": band_rows,
                "delta_ms": delta_s * 1e3, "rebuild_ms": rebuild_s * 1e3,
                "speedup": rebuild_s / max(delta_s, 1e-9),
                "loss_parity_rel": parity,
                "delta_fingerprint_matches": bool(
                    cs_delta.fingerprint() == cs_scratch.fingerprint())}
    finally:
        eng.close()
        scratch.close()


def run(fast: bool = False, tune: bool = False) -> dict:
    rng = np.random.default_rng(0)
    results: dict = {}
    repeat = 2 if fast else 3

    if tune:
        autotune.tune_all(budget="quick" if fast else "full")
        emit("ops/autotune_populate", 0.0,
             f"entries={len(autotune.get_cache().entries)}")

    def sweep(op_name, call, parity_of, size=None):
        per = {}
        ref = None
        for b in ops.BACKENDS:
            out, _ = timed(call, b)                    # warmup / compile
            out, dt = timed(call, b, repeat=repeat)
            if ref is None:
                ref = parity_of(out)                   # numpy runs first
            per[b] = {"us_per_call": dt * 1e6,
                      "rel_delta_vs_numpy": _rel(parity_of(out), ref)}
            if b != "numpy" and size is not None:
                # selection provenance: the tuned config this row ran with
                # (what the backend's autotune.plan consult returned) and
                # whether it was a cache hit at this shape bucket
                cfg = autotune.plan(op_name, b, size)
                per[b]["tuned_config"] = cfg
                per[b]["tune_cache"] = "hit" if cfg else "miss"
            emit(f"ops/{op_name}_{b}", dt * 1e6,
                 f"rel_vs_numpy={per[b]['rel_delta_vs_numpy']:.2e}")
        if size is not None:
            per["auto_backend"] = ops.selected_backend(op_name, size)
            per["shape_bucket"] = autotune.shape_bucket(size)
        return per

    # ---- sat_moments
    n = 256 if fast else 768
    y = rng.normal(size=(n, n))
    results["sat_moments"] = sweep(
        "sat_moments", lambda b: ops.sat_moments(y, backend=b), lambda o: o,
        size=3 * y.size)

    # ---- fitting_loss + fitting_loss_batched on one coreset
    ys = piecewise_signal(96 if fast else 160, 80 if fast else 120, 6,
                          noise=0.2, seed=0)
    cs = signal_coreset(ys, 6, 0.3)
    segs = [random_tree_segmentation(*ys.shape, 6, rng)
            for _ in range(4 if fast else 16)]
    sr = np.stack([s.rects for s in segs]).astype(np.float64)
    sl = np.stack([s.labels for s in segs])
    results["fitting_loss"] = sweep(
        "fitting_loss",
        lambda b: ops.fitting_loss(cs, segs[0].rects, segs[0].labels,
                                   backend=b),
        lambda o: o, size=ops.fitting_loss_size(cs, segs[0].rects))
    results["fitting_loss_batched"] = sweep(
        "fitting_loss_batched",
        lambda b: ops.fitting_loss_batched(cs, sr, sl, backend=b),
        lambda o: o, size=ops.fitting_loss_batched_size(cs, sr))

    # the CI gate: batched Pallas kernel vs the dense dispatched (xla) path
    dense = ops.fitting_loss_batched(cs, sr, sl, backend="xla")
    pallas = ops.fitting_loss_batched(cs, sr, sl, backend="pallas")
    gate = _rel(pallas, dense)
    results["parity"] = {
        "batched_pallas_vs_dense_rel": gate,
        "coreset_blocks": cs.num_blocks, "trees": int(sr.shape[0]),
        "leaves": int(sr.shape[1]),
    }
    emit("ops/parity_batched_pallas_vs_dense", 0.0, f"rel={gate:.2e}")

    # ---- hist_split
    P, F, B = (50_000, 4, 64) if fast else (200_000, 8, 256)
    codes = rng.integers(0, B, size=(P, F)).astype(np.uint8)
    w = rng.uniform(0.5, 1.5, P)
    yv = rng.normal(size=P)
    results["hist_split"] = sweep(
        "hist_split",
        lambda b: ops.hist_split(codes, w, w * yv, w * yv * yv, B, backend=b),
        lambda o: o, size=codes.size)

    # ---- delta_sat (the ingest patch: one band's worth of rows, not O(N))
    dn, dm, band_rows = (512, 256, 16) if fast else (2048, 512, 32)
    yd = rng.normal(size=(dn, dm))
    carry = ops.sat_moments(yd, backend="numpy")[:, dn - band_rows - 1, :]
    tail = yd[dn - band_rows:]
    results["delta_sat"] = sweep(
        "delta_sat", lambda b: ops.delta_sat(carry, tail, backend=b),
        lambda o: o, size=3 * tail.size)

    # ---- streaming_compress (batched recompress of two composed buckets)
    from repro.core import compose
    sn = 96 if fast else 192
    ys = piecewise_signal(sn, 64, 5, noise=0.15, seed=1)
    parts = [signal_coreset(ys[a:b], 5, 0.3)
             for a, b in ((0, sn // 2), (sn // 2, sn))]
    buckets = [compose(parts, [0, sn // 2], n_total=sn)] * 2
    results["streaming_compress"] = sweep(
        "streaming_compress",
        lambda b: ops.streaming_compress(buckets, backend=b),
        lambda o: np.concatenate([np.sort(c.moments, axis=None) for c in o]))

    # ---- ingest_delta end-to-end gate numbers (ci_smoke asserts on these):
    # delta-patching a band into a registered signal vs the legacy full
    # re-ingest (replace registration + from-scratch re-SAT), plus the loss
    # parity of the delta-built coreset against a from-scratch build
    results["ingest_delta"] = _ingest_delta_gate(dn, dm, band_rows)
    emit("ops/ingest_delta_vs_rebuild",
         results["ingest_delta"]["delta_ms"] * 1e3,
         f"rebuild_ms={results['ingest_delta']['rebuild_ms']:.1f} "
         f"parity={results['ingest_delta']['loss_parity_rel']:.2e}")

    # ---- autotune: tuned-vs-oracle gates, compensated parity certificates,
    # the hist_split Pallas fix before/after, and dispatch overhead
    results["autotune"] = _autotune_section(fast, codes, w, w * yv,
                                            w * yv * yv, B, y)

    # selection state alongside the numbers (what auto would pick here)
    results["selection"] = {op: s["selected"]
                            for op, s in ops.snapshot().items()}
    _merge_save(results)
    return results


def _autotune_section(fast, codes, w, wy, wy2, B, y) -> dict:
    """The rows ``check_bench_regression --suite autotune`` gates on.

    ``best_accel_ratio`` proves at least one op has a tuned accelerator
    backend beating the numpy oracle at its large-shape bucket (from the
    cache entries the tuner measured on this host; interpret-mode Pallas
    entries are excluded off-TPU, mirroring ``autotune.tuned_backend``).
    The ``compensated`` rows are fresh parity measurements — not replays of
    cached numbers — of the two-float paths against the f64 oracle.
    """
    sec: dict = {"provenance": {**autotune.snapshot(),
                                "host": autotune.host_fingerprint()}}

    # tuned accel vs oracle, from the measured cache entries
    cache = autotune.get_cache()
    if not cache.entries:
        # cold cache (bench run without --tune): measure, but do not persist
        autotune.tune_all(budget="quick", save=False)
    device = autotune.device_kind()
    best = None
    for key, e in cache.entries.items():
        op, backend, dev, _bucket = key.split("|")
        if dev != device or (backend == "pallas" and device != "tpu"):
            continue
        if not e.get("us") or not e.get("numpy_us"):
            continue
        ratio = e["numpy_us"] / e["us"]
        if best is None or ratio > best["ratio"]:
            best = {"ratio": ratio, "op": op, "backend": backend,
                    "bucket": e.get("bucket"), "config": e.get("config"),
                    "us": e["us"], "numpy_us": e["numpy_us"]}
    sec["best_accel"] = best or {"ratio": 0.0}
    sec["best_accel_ratio"] = (best or {}).get("ratio", 0.0)
    emit("ops/autotune_best_accel", (best or {}).get("us", 0.0),
         f"{(best or {}).get('op')}/{(best or {}).get('backend')} "
         f"ratio={sec['best_accel_ratio']:.2f}")

    # compensated-f32 parity certificates vs the f64 oracle (fresh runs)
    want = ops.sat_moments(y, backend="numpy")
    t0 = time.perf_counter()
    got = ops.sat_moments(y, backend="xla", config={"compensated": True})
    comp_us = (time.perf_counter() - t0) * 1e6
    plain = ops.sat_moments(y, backend="xla", config={"compensated": False})
    sec.setdefault("compensated", {})["sat_moments"] = {
        "rel_err": autotune._scaled_rel_err(got, want),
        "plain_rel_err": autotune._scaled_rel_err(plain, want),
        "us": comp_us, "backend": "xla", "shape": list(y.shape)}

    wanth = ops.hist_split(codes, w, wy, wy2, B, backend="numpy")
    t0 = time.perf_counter()
    goth = ops.hist_split(codes, w, wy, wy2, B, backend="pallas",
                          config={"variant": "partials", "tile_p": 2048})
    hist_us = (time.perf_counter() - t0) * 1e6
    gotx = ops.hist_split(codes, w, wy, wy2, B, backend="xla",
                          config={"variant": "chunked", "compensated": True})
    sec["compensated"]["hist_split"] = {
        "rel_err": autotune._scaled_rel_err(goth, wanth),
        "xla_chunked_rel_err": autotune._scaled_rel_err(gotx, wanth),
        "us": hist_us, "backend": "pallas", "variant": "partials",
        "shape": [int(codes.shape[0]), int(codes.shape[1]), int(B)]}
    for op_name, row in sec["compensated"].items():
        emit(f"ops/compensated_{op_name}", row["us"],
             f"rel_err={row['rel_err']:.2e}")

    # the hist_split Pallas pathology fix, before/after at the bench shape
    # (the old kernel ran F x P/TP grid steps with a (B, TP) @ (TP, S=8)
    # layout wasting 15/16 of the MXU output tile)
    def _hist_variant(variant):
        call = lambda: ops.hist_split(    # noqa: E731
            codes, w, wy, wy2, B, backend="pallas",
            config={"variant": variant, "tile_p": 2048 if variant != "legacy"
                    else 512})
        call()                                          # warmup / compile
        t0 = time.perf_counter()
        call()
        return (time.perf_counter() - t0) * 1e6
    legacy_us = _hist_variant("legacy")
    fused_us = _hist_variant("fused")
    sec["hist_split_pallas_fix"] = {
        "legacy_us": legacy_us, "fused_us": fused_us,
        "speedup": legacy_us / max(fused_us, 1e-9),
        "shape": [int(codes.shape[0]), int(codes.shape[1]), int(B)]}
    emit("ops/hist_split_pallas_fix", fused_us,
         f"legacy_us={legacy_us:.0f} speedup={legacy_us / max(fused_us, 1e-9):.1f}x")

    # dispatch overhead of the tuned consult (warm cache vs disabled)
    import os
    sz = int(codes.size)

    def _selects():
        t0 = time.perf_counter()
        for _ in range(2000):
            ops.select_backend("hist_split", sz)
        return (time.perf_counter() - t0) / 2000 * 1e6
    tuned_us = _selects()
    os.environ[autotune.DISABLE_ENV_VAR] = "off"
    try:
        untuned_us = _selects()
    finally:
        del os.environ[autotune.DISABLE_ENV_VAR]
    sec["dispatch_overhead"] = {
        "tuned_select_us": tuned_us, "untuned_select_us": untuned_us,
        "ratio": tuned_us / max(untuned_us, 1e-9)}
    emit("ops/autotune_select_overhead", tuned_us,
         f"untuned_us={untuned_us:.3f} "
         f"ratio={sec['dispatch_overhead']['ratio']:.2f}")
    return sec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--tune", action="store_true",
                    help="populate the kernel autotune cache before the "
                         "sweep (quick budget with --fast, full otherwise)")
    args = ap.parse_args()
    run(fast=args.fast, tune=args.tune)
