"""§4 "Coreset size": empirical |C| vs the worst-case theory bound
(k log N / eps)^O(1) — the paper's observation that practice is far smaller,
at the paper's own operating point (N ~ 140k, construction k = 2000 scaled
to this container, eps = 0.2)."""
from __future__ import annotations

import math

from repro.core import signal_coreset
from repro.data import sensor_matrix

from .common import emit, save_json, timed


def run(n: int = 9358, m: int = 15, k: int = 2000, eps: float = 0.2):
    y = sensor_matrix(n, m, seed=0)
    N = n * m
    cs, dt = timed(signal_coreset, y, k, eps)
    theory = (k * math.log(N)) ** 2 / eps ** 4   # a mild instance of the bound
    emit("size/paper_operating_point", dt * 1e6,
         f"N={N};|C|={cs.size};frac={cs.compression_ratio():.4f};"
         f"theory_bound~{theory:.2e};ratio={cs.size/theory:.2e}")
    # the paper's empirical stance: a ~1% summary still approximates
    # k=2000-leaf trees well (worst-case theory would predict > N)
    import numpy as np
    from repro.core import (PrefixStats, fitting_loss, random_tree_segmentation,
                            signal_coreset_to_size, true_loss)
    cs1, dt1 = timed(signal_coreset_to_size, y, 64, 0.01)
    ps = PrefixStats.build(y)
    rng = np.random.default_rng(0)
    errs = []
    for _ in range(10):
        q = random_tree_segmentation(n, m, k, rng)
        tl = true_loss(y, q.rects, q.labels, ps=ps)
        errs.append(abs(fitting_loss(cs1, q.rects, q.labels) - tl) / max(tl, 1e-12))
    emit("size/one_percent_empirical", dt1 * 1e6,
         f"frac={cs1.compression_ratio():.4f};"
         f"max_err_on_k2000_trees={max(errs):.4f}")
    save_json("bench_size", {"N": N, "size": cs.size,
                             "frac": cs.compression_ratio(),
                             "theory_bound": theory,
                             "build_seconds": dt,
                             "one_percent": {"frac": cs1.compression_ratio(),
                                             "max_err_k2000": max(errs)}})
    return cs.size


if __name__ == "__main__":
    run()
