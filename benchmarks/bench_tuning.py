"""Fig 4 (bottom-left): loss-vs-k tuning curves on coreset vs full data.

The headline claim: the curve computed on the (once-built) coreset tracks
the curve computed on the full data, so the argmin transfers.
"""
from __future__ import annotations

import numpy as np

from repro.data import patch_mask, sensor_matrix
from repro.trees import tune_k

from .common import emit, save_json


def run(n: int = 2500, m: int = 15, ks=(8, 16, 32, 64, 128, 256),
        target_frac: float = 0.05, seed: int = 0):
    y = sensor_matrix(n, m, seed=seed)
    train, test = patch_mask(n, m, 0.3, 5, seed=seed + 1)
    res = tune_k(y, train, test, ks=list(ks), coreset_k=64,
                 target_frac=target_frac, n_estimators=4)
    for name, ls in res.losses.items():
        emit(f"tuning/{name}", res.times[name] * 1e6,
             "curve=" + "|".join(f"{k}:{l:.0f}" for k, l in zip(res.ks, ls))
             + f";best_k={res.best_k[name]}")
    # curve agreement: Spearman-ish sign agreement between full and coreset
    full = np.array(res.losses["full"])
    core = np.array(res.losses["coreset"])
    agree = np.mean(np.sign(np.diff(full)) == np.sign(np.diff(core)))
    emit("tuning/curve_agreement", 0.0, f"monotone_agreement={agree:.2f};"
         f"best_full={res.best_k['full']};best_coreset={res.best_k['coreset']}")
    save_json("bench_tuning", {"ks": res.ks, "losses": res.losses,
                               "times": res.times, "best_k": res.best_k,
                               "agreement": float(agree)})
    return res


if __name__ == "__main__":
    run()
