"""Appendix A (Figs 5-7): blobs / moons / circles rasterized as signals —
coreset size and the SSE parity of trees trained on coreset vs full."""
from __future__ import annotations

import numpy as np

from repro.core import signal_coreset
from repro.data import blobs, circles, moons, rasterize
from repro.trees import DecisionTreeRegressor, signal_to_points

from .common import emit, save_json, timed


def run(res: int = 96, k: int = 64, eps: float = 0.35):
    gens = {"blobs": blobs(4000), "moons": moons(6000), "circles": circles(6000)}
    out = {}
    for name, (X, lab) in gens.items():
        y = rasterize(X, lab, res, res)
        cs, dt = timed(signal_coreset, y, k, eps)
        Xf, yf = signal_to_points(y)
        Xc, yc, wc = cs.as_points()
        t_full = DecisionTreeRegressor(max_leaves=k).fit(Xf, yf)
        t_core = DecisionTreeRegressor(max_leaves=k).fit(Xc, yc, sample_weight=wc)
        # class labels are discrete: compare decision surfaces (rounded
        # prediction accuracy) like the paper's appendix figures, plus MSE
        lab_true = np.round(yf)
        acc_full = float((np.round(t_full.predict(Xf)) == lab_true).mean())
        acc_core = float((np.round(t_core.predict(Xf)) == lab_true).mean())
        mse_full = float(((t_full.predict(Xf) - yf) ** 2).mean())
        mse_core = float(((t_core.predict(Xf) - yf) ** 2).mean())
        out[name] = {"frac": cs.compression_ratio(), "acc_full": acc_full,
                     "acc_coreset": acc_core, "mse_full": mse_full,
                     "mse_coreset": mse_core}
        emit(f"datasets/{name}", dt * 1e6,
             f"frac={cs.compression_ratio():.3f};acc_full={acc_full:.3f};"
             f"acc_coreset={acc_core:.3f};mse={mse_full:.4f}->{mse_core:.4f}")
    save_json("bench_datasets", out)
    return out


if __name__ == "__main__":
    run()
