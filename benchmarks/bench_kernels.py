"""Kernel micro-benches.  On this CPU container the Pallas kernels execute
under interpret=True (kernel-body semantics, not TPU timing), so wall-times
reported here are for the *jitted pure-jnp refs* (the XLA path the dry-run
compiles) plus correctness deltas vs the kernels; TPU timings come from the
roofline model in bench_roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.histsplit import ref as h_ref
from repro.kernels.sat2d import ref as sat_ref

from .common import emit, timed


def run():
    rng = np.random.default_rng(0)
    # sat2d ref (jitted) on a 2k x 2k signal
    y = jnp.asarray(rng.normal(size=(2048, 2048)), jnp.float32)
    f = jax.jit(sat_ref.sat_moments_ref)
    f(y).block_until_ready()
    _, dt = timed(lambda: f(y).block_until_ready(), repeat=3)
    emit("kernels/sat_moments_ref_2k", dt * 1e6,
         f"GB/s={(3*y.size*4*2)/dt/1e9:.2f}")

    # histsplit ref (jitted): 200k x 8 features x 256 bins
    P, F, B = 200_000, 8, 256
    codes = jnp.asarray(rng.integers(0, B, size=(P, F)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, P), jnp.float32)
    h = jax.jit(lambda c, a, b, d: h_ref.histograms_ref(c, a, b, d, B))
    h(codes, w, w, w).block_until_ready()
    _, dt = timed(lambda: h(codes, w, w, w).block_until_ready(), repeat=3)
    emit("kernels/histsplit_ref_200k", dt * 1e6,
         f"Melem/s={(P*F)/dt/1e6:.1f}")

    # flash attention: correctness delta kernel-vs-ref at a serving shape
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    ref = jax.jit(lambda q, k, v: fa_ref.attention_ref(q, k, v))
    ref(q, k, v).block_until_ready()
    _, dt = timed(lambda: ref(q, k, v).block_until_ready(), repeat=3)
    delta = float(jnp.max(jnp.abs(
        fa_ops.flash_attention(q, k, v) - ref(q, k, v))))
    flops = 4 * 8 * 512 * 512 * 64
    emit("kernels/attention_ref_512", dt * 1e6,
         f"GFLOP/s={flops/dt/1e9:.1f};kernel_max_delta={delta:.2e}")


if __name__ == "__main__":
    run()
