"""Theorem 8: construction time is O(Nk) — linearity in N (and the size
stays sub-linear)."""
from __future__ import annotations

import numpy as np

from repro.core import signal_coreset
from repro.data import piecewise_signal

from .common import emit, save_json, timed


def run(k: int = 25, eps: float = 0.3, sizes=((125, 150), (250, 300),
                                              (500, 600), (1000, 600))):
    rows = []
    for n, m in sizes:
        y = piecewise_signal(n, m, k, noise=0.15, seed=1)
        cs, dt = timed(signal_coreset, y, k, eps)
        rows.append({"N": n * m, "seconds": dt, "size": cs.size,
                     "frac": cs.compression_ratio()})
        emit(f"scaling/N={n*m}", dt * 1e6,
             f"size={cs.size};frac={cs.compression_ratio():.4f}")
    # linear fit in N: time ~ a + b N; report sublinearity of the exponent
    Ns = np.array([r["N"] for r in rows], float)
    ts = np.array([r["seconds"] for r in rows], float)
    slope = np.polyfit(np.log(Ns), np.log(ts), 1)[0]
    emit("scaling/exponent", 0.0, f"time~N^{slope:.2f} (O(Nk) predicts ~1)")
    save_json("bench_scaling", {"rows": rows, "exponent": float(slope)})
    return rows


if __name__ == "__main__":
    run()
