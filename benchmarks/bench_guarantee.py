"""Theorem 8 empirically: max relative error of FITTING-LOSS vs true loss
over random + near-optimal trees, per eps, per signal family."""
from __future__ import annotations

import numpy as np

from repro.core import (PrefixStats, fitting_loss, greedy_tree,
                        random_tree_segmentation, signal_coreset, true_loss)
from repro.data import piecewise_signal, sensor_matrix, smooth_field

from .common import emit, save_json, timed


def run(eps_grid=(0.4, 0.2, 0.1), k: int = 25, trees: int = 20, seed: int = 0):
    rng = np.random.default_rng(seed)
    signals = {
        "piecewise": piecewise_signal(250, 300, k, noise=0.15, seed=seed),
        "smooth": smooth_field(250, 300, noise=0.1, seed=seed),
        "sensor": sensor_matrix(1500, 15, seed=seed),
        "noise": rng.normal(size=(250, 300)),
    }
    out = {}
    for name, y in signals.items():
        ps = PrefixStats.build(y)
        g = greedy_tree(ps, k)
        gl = true_loss(y, g.rects, g.labels, ps=ps)
        for eps in eps_grid:
            cs, t_build = timed(signal_coreset, y, k, eps)
            errs = []
            for _ in range(trees):
                q = random_tree_segmentation(*y.shape, k, rng)
                tl = true_loss(y, q.rects, q.labels, ps=ps)
                errs.append(abs(fitting_loss(cs, q.rects, q.labels) - tl)
                            / max(tl, 1e-12))
            gerr = abs(fitting_loss(cs, g.rects, g.labels) - gl) / gl
            worst = max(max(errs), gerr)
            ok = worst <= eps
            out[f"{name}/eps={eps}"] = {
                "max_rel_err": worst, "greedy_err": gerr,
                "size_frac": cs.compression_ratio(), "within_eps": ok}
            emit(f"guarantee/{name}/eps={eps}", t_build * 1e6,
                 f"max_err={worst:.4f};frac={cs.compression_ratio():.4f};"
                 f"ok={ok}")
    save_json("bench_guarantee", out)
    assert all(v["within_eps"] for v in out.values()), "eps guarantee violated"
    return out


if __name__ == "__main__":
    run()
