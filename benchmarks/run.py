# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
#   Fig 4 top          -> bench_compression   (coreset vs uniform SSE)
#   Fig 4 bottom-left  -> bench_tuning        (loss-vs-k curves transfer)
#   Fig 4 bottom-right -> bench_time          (x-speedup of tuning)
#   Theorem 8          -> bench_guarantee     (empirical eps), bench_scaling
#                         (O(Nk) time), bench_size (|C| << theory)
#   Appendix A         -> bench_datasets      (blobs/moons/circles)
#   kernels            -> bench_kernels
#   §Roofline          -> bench_roofline      (needs dry-run JSONs)
#
# ``--fast`` shrinks problem sizes ~4x for CI-style runs.
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: compression,tuning,time,guarantee,"
                         "scaling,size,datasets,kernels,ops,roofline")
    args = ap.parse_args()
    from . import (bench_compression, bench_datasets, bench_guarantee,
                   bench_kernels, bench_ops, bench_roofline, bench_scaling,
                   bench_size, bench_time, bench_tuning)

    fast = args.fast
    jobs = {
        "guarantee": lambda: bench_guarantee.run(
            eps_grid=(0.4, 0.2) if fast else (0.4, 0.2, 0.1),
            trees=8 if fast else 20),
        "compression": lambda: bench_compression.run(
            n=1500 if fast else 3000,
            fracs=(0.02, 0.05) if fast else (0.01, 0.02, 0.05, 0.10),
            n_estimators=3 if fast else 5),
        "tuning": lambda: bench_tuning.run(
            n=1200 if fast else 2500, ks=(8, 32, 128) if fast else
            (8, 16, 32, 64, 128, 256)),
        "time": (lambda: bench_time.run(n=2000, ks=(8, 32, 128),
                                        n_estimators=4)) if fast \
        else bench_time.run,
        "scaling": lambda: bench_scaling.run(
            sizes=((125, 150), (250, 300), (500, 600)) if fast else
            ((125, 150), (250, 300), (500, 600), (1000, 600))),
        "size": lambda: bench_size.run(n=3000 if fast else 9358,
                                       k=500 if fast else 2000),
        "datasets": lambda: bench_datasets.run(res=64 if fast else 96),
        "kernels": bench_kernels.run,
        "ops": lambda: bench_ops.run(fast=fast),
        "roofline": bench_roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(jobs)
    failed = []
    for name, job in jobs.items():
        if name not in only:
            continue
        try:
            job()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0.0,ERROR={e!r}")
            traceback.print_exc()
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
