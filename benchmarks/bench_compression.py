"""Fig 4 (top): test-set SSE of forests trained on coreset vs uniform sample
of equal size, across compression sizes (the paper's x-axis); full-data
forest as the floor."""
from __future__ import annotations

import numpy as np

from repro.core import signal_coreset_to_size
from repro.data import patch_mask, sensor_matrix
from repro.trees import (RandomForestRegressor, signal_to_points,
                         uniform_sample)

from .common import emit, save_json, timed


def run(n: int = 3000, m: int = 15, k_model: int = 128, coreset_k: int = 64,
        fracs=(0.01, 0.02, 0.05, 0.10), n_estimators: int = 5, seed: int = 0):
    y = sensor_matrix(n, m, seed=seed)
    train, test = patch_mask(n, m, 0.3, 5, seed=seed + 1)
    X_tr, y_tr = signal_to_points(y, train)
    X_te, y_te = signal_to_points(y, test)
    rng = np.random.default_rng(seed)

    def forest_sse(X, yy, w):
        f = RandomForestRegressor(n_estimators=n_estimators,
                                  max_leaves=k_model, random_state=0)
        f.fit(X, yy, sample_weight=w)
        return float(((f.predict(X_te) - y_te) ** 2).sum())

    full_sse, t_full = timed(forest_sse, X_tr, y_tr, None)
    emit("compression/full", t_full * 1e6, f"sse={full_sse:.1f};size={len(y_tr)}")

    rows = {"full": {"sse": full_sse, "size": len(y_tr)}, "points": []}
    for frac in fracs:
        cs, t_build = timed(signal_coreset_to_size, y, coreset_k, frac,
                            mask=train)
        Xc, yc, wc = cs.as_points()
        c_sse, t_c = timed(forest_sse, Xc, yc, wc)
        Xu, yu, wu = uniform_sample(X_tr, y_tr, len(yc), rng)
        u_sse, t_u = timed(forest_sse, Xu, yu, wu)
        got = len(yc) / len(y_tr)
        rows["points"].append({"frac": got, "size": len(yc),
                               "coreset_sse": c_sse, "uniform_sse": u_sse,
                               "build_s": t_build})
        emit(f"compression/frac={frac}", (t_build + t_c) * 1e6,
             f"got={got:.3f};coreset_sse={c_sse:.1f};uniform_sse={u_sse:.1f};"
             f"full_sse={full_sse:.1f}")
    save_json("bench_compression", rows)
    return rows


if __name__ == "__main__":
    run()
