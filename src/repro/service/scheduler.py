"""Continuous-batching build scheduler.

Coreset builds are the expensive path (O(Nk) over the signal); concurrent
clients routinely ask for the same (signal, k, eps) — a tuning sweep fans
out dozens of identical build-then-query requests.  The scheduler gives the
serving layer three things:

  * **coalescing** — identical in-flight build keys share one future, so a
    thundering herd pays for one build;
  * **micro-batching** — requests are drained from the queue in small
    windows (``batch_window`` seconds) and dispatched together, which keeps
    the worker pool saturated without a lock per request;
  * **bounded concurrency** — at most ``max_workers`` builds run at once;
    each build itself fans row bands out via ``core.sharded`` (thread pool
    over band builds; NumPy releases the GIL in the hot loops), so total
    parallelism is workers x bands.

The design follows the continuous-batching front of ``launch/serve.py`` but
for *builds* instead of decode steps: arrivals during a window join the
current batch instead of waiting for a full one.
"""
from __future__ import annotations

import concurrent.futures as _fut
import queue
import threading
import time
from typing import Callable

from repro import obs

from .metrics import ServiceMetrics
from .query_scheduler import DeadlineExceeded

__all__ = ["BuildScheduler"]

_SHUTDOWN = object()


class BuildScheduler:
    def __init__(self, max_workers: int = 4, batch_window: float = 0.004,
                 max_batch: int = 32, metrics: ServiceMetrics | None = None):
        self.metrics = metrics or ServiceMetrics()
        self.batch_window = float(batch_window)
        self.max_batch = int(max_batch)
        self._pool = _fut.ThreadPoolExecutor(max_workers=max_workers,
                                             thread_name_prefix="coreset-build")
        self._queue: queue.SimpleQueue = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._pending: dict[tuple, _fut.Future] = {}
        # key -> latest waiter deadline; absent = at least one forever-waiter
        self._deadlines: dict[tuple, float] = {}
        self._closed = False
        self._collector = threading.Thread(target=self._collect_loop,
                                           name="coreset-batcher", daemon=True)
        self._collector.start()

    # ---------------------------------------------------------------- submit
    def submit(self, key: tuple, fn: Callable[[], object], *,
               deadline: float | None = None) -> tuple[_fut.Future, bool]:
        """Enqueue a build; returns (future, created).

        ``created`` is False when an identical key was already in flight and
        the caller was coalesced onto its future.  ``deadline`` (absolute
        ``time.perf_counter()``) lets the worker skip a build every waiter
        has already abandoned: joining an in-flight key extends its deadline
        to the latest waiter's (None = wait forever), so a build is only
        dropped when ALL its waiters expired.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is shut down")
            existing = self._pending.get(key)
            if existing is not None:
                if key in self._deadlines:
                    if deadline is None:   # a forever-waiter joined: never drop
                        del self._deadlines[key]
                    else:
                        self._deadlines[key] = max(self._deadlines[key],
                                                   deadline)
                self.metrics.inc("builds_coalesced")
                return existing, False
            fut: _fut.Future = _fut.Future()
            self._pending[key] = fut
            if deadline is not None:
                self._deadlines[key] = deadline
            # enqueue under the lock: shutdown() also takes it before posting
            # the sentinel, so an accepted item can never land behind
            # _SHUTDOWN and leave its future forever unresolved.  The
            # submitter's current span rides along: worker threads don't
            # inherit contextvars, so the build span re-parents explicitly
            self._queue.put((key, fn, fut, time.perf_counter(),
                             obs.current_span()))
        self.metrics.inc("builds_enqueued")
        return fut, True

    # --------------------------------------------------------- batching loop
    def _collect_loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is _SHUTDOWN:
                return
            batch = [item]
            deadline = time.perf_counter() + self.batch_window
            while len(batch) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _SHUTDOWN:
                    self._dispatch(batch)
                    return
                batch.append(nxt)
            self._dispatch(batch)

    def _dispatch(self, batch: list) -> None:
        self.metrics.inc("build_batches")
        self.metrics.inc("build_batch_items", len(batch))  # mean size = items/batches
        for key, fn, fut, enq_t, parent in batch:
            self.metrics.observe("build_queue_wait", time.perf_counter() - enq_t)
            self._pool.submit(self._run_one, key, fn, fut, parent)

    def _run_one(self, key: tuple, fn: Callable, fut: _fut.Future,
                 parent=None) -> None:
        with self._lock:
            dl = self._deadlines.get(key)
            expired = dl is not None and time.perf_counter() > dl
            if expired:
                # every waiter's deadline already passed: don't burn a
                # worker on a build nobody will read.  The key is popped
                # UNDER the same lock as the check, so a late submit cannot
                # coalesce onto the doomed future after the drop decision —
                # it starts a fresh build instead
                self._pending.pop(key, None)
                self._deadlines.pop(key, None)
        span = obs.child_span("build.run", parent=parent,
                              attrs={"key": str(key)})
        if expired:
            self.metrics.inc("builds_expired")
            if span:
                span.set_attr("outcome", "deadline_expired")
                span.end()
            fut.set_exception(DeadlineExceeded(
                "every waiter's deadline expired before the build started"))
            return
        if not fut.set_running_or_notify_cancel():
            if span:
                span.set_attr("outcome", "cancelled")
                span.end()
            return
        try:
            with obs.attach(span), self.metrics.timed("build"):
                result = fn()
        except BaseException as exc:  # propagate to every coalesced waiter
            self.metrics.inc("builds_failed")
            if span:
                span.set_attr("outcome", type(exc).__name__)
            fut.set_exception(exc)
        else:
            self.metrics.inc("builds_completed")
            if span:
                span.set_attr("outcome", "ok")
            fut.set_result(result)
        finally:
            span.end()
            with self._lock:
                self._pending.pop(key, None)
                self._deadlines.pop(key, None)

    # -------------------------------------------------------------- shutdown
    def in_flight(self) -> int:
        with self._lock:
            return len(self._pending)

    def load(self) -> dict:
        """Queue-pressure snapshot for admission control / the overload
        gate: coalesced build keys pending (submitted, not yet finished)
        and how many of them carry at least one waiter deadline."""
        with self._lock:
            return {"pending": len(self._pending),
                    "with_deadline": sum(d is not None
                                         for d in self._deadlines.values())}

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._queue.put(_SHUTDOWN)
        if wait:
            self._collector.join(timeout=5.0)
        self._pool.shutdown(wait=wait)
