"""Admission control & multi-tenant QoS: refuse un-meetable work on arrival.

The schedulers already fail doomed requests *at* their deadline (a 504 after
the queue wait proved fatal) — correct, but wasteful under overload: the
request still occupied queue slots, batching windows, and a pool thread
before dying.  This module moves the refusal to the front door.  An
:class:`AdmissionController` sits between HTTP decode and the engine
handlers (and between a cluster coordinator and its scatter RPCs) and makes
one O(1) decision per request:

  * **deadline guard** — per request class (``(kind, signal)``, the stable
    prefix of the QueryScheduler's fusion key) it tracks an EWMA of admitted
    end-to-end service time and the count of admitted-but-unfinished
    requests.  Predicted completion is ``ewma * (1 + depth / parallelism)``
    — the classic M/M/c shortcut: your own service time plus your share of
    draining everyone already ahead of you.  If the request carries a
    ``deadline_ms`` smaller than that, it is refused NOW (503
    ``overloaded``/``deadline_unmeetable``) instead of timing out at the
    deadline (504) — same outcome for the caller, none of the wasted work.
  * **weighted fair share** — each tenant (``X-Coreset-Tenant`` header, SDK
    ``tenant=`` arg, else ``"default"``) owns a token bucket refilled at
    ``rate_rps * w_t / sum(w)`` and an in-flight cap sized the same way, so
    a hot tenant degrades to *its* share instead of starving the rest.
    Weights come from config; unknown tenants join lazily at
    ``default_weight`` (shares are recomputed against the live weight sum,
    so a new tenant dilutes everyone proportionally, never to zero).

Every rejection carries a **Retry-After** hint: for rate rejections the time
until one token refills, for load rejections the predicted drain time —
both non-decreasing in queue depth, so well-behaved SDKs (ours honors
Retry-After since PR 9) back off harder exactly when the server is deeper
under water.  Rejections never consume tokens: a retry storm cannot starve
the tenant's own future capacity.

Admitted work is untouched — the controller returns a :class:`Ticket` and
steps aside; coalescing, degraded mode, and the bytes of every response are
bitwise-identical to an engine without admission (gated by
``tests/test_admission.py``).  The decision itself is gated < 50µs in
``check_bench_regression.py`` (``qos`` suite).

Stdlib-only, same constraint as the rest of the serving layer.
"""
from __future__ import annotations

import contextvars
import threading
import time

__all__ = [
    "AdmissionConfig", "AdmissionController", "AdmissionRejected",
    "Ticket", "current_ticket", "DEFAULT_TENANT",
]

DEFAULT_TENANT = "default"

# the admission ticket of THIS thread of execution: set by the HTTP layer
# after it admits a request, read by inner layers (cluster coordinator) so
# one request is charged exactly once however many engine hops it makes
_TICKET: contextvars.ContextVar["Ticket | None"] = \
    contextvars.ContextVar("repro_admission_ticket", default=None)


def current_ticket() -> "Ticket | None":
    return _TICKET.get()


class AdmissionRejected(Exception):
    """Refused on arrival.  Maps to HTTP 503 + ``Retry-After`` with an
    ``overloaded`` envelope — distinct from 504 ``deadline_exceeded``,
    which is reserved for ADMITTED work that died at its deadline."""

    def __init__(self, reason: str, tenant: str, retry_after: float,
                 message: str):
        super().__init__(message)
        self.reason = reason
        self.tenant = tenant
        self.retry_after = retry_after
        self.message = message


class AdmissionConfig:
    """Static policy.  ``rate_rps``/``max_inflight`` are TOTALS split across
    tenants by weight; ``None`` disables that check entirely."""

    __slots__ = ("enabled", "tenants", "default_weight", "rate_rps",
                 "burst_s", "max_inflight", "alpha", "parallelism",
                 "deadline_guard")

    def __init__(self, *, enabled: bool = True,
                 tenants: dict[str, float] | None = None,
                 default_weight: float = 1.0,
                 rate_rps: float | None = None,
                 burst_s: float = 1.0,
                 max_inflight: int | None = None,
                 alpha: float = 0.2,
                 parallelism: int = 4,
                 deadline_guard: bool = True):
        self.enabled = bool(enabled)
        self.tenants = dict(tenants or {})
        self.default_weight = float(default_weight)
        self.rate_rps = None if rate_rps is None else float(rate_rps)
        self.burst_s = float(burst_s)
        self.max_inflight = None if max_inflight is None else int(max_inflight)
        self.alpha = float(alpha)
        self.parallelism = max(1, int(parallelism))
        self.deadline_guard = bool(deadline_guard)
        for name, w in self.tenants.items():
            if float(w) <= 0.0:
                raise ValueError(f"tenant {name!r} weight must be > 0")

    @classmethod
    def parse_tenants(cls, spec: str | None) -> dict[str, float]:
        """``"hot=2,cold=1"`` → ``{"hot": 2.0, "cold": 1.0}`` (CLI flag)."""
        out: dict[str, float] = {}
        for part in (spec or "").split(","):
            part = part.strip()
            if not part:
                continue
            name, _, w = part.partition("=")
            out[name.strip()] = float(w) if w else 1.0
        return out


class _Tenant:
    __slots__ = ("name", "weight", "tokens", "refill_at", "inflight",
                 "admitted", "rejected")

    def __init__(self, name: str, weight: float, now: float):
        self.name = name
        self.weight = weight
        self.tokens = -1.0          # sentinel: bucket fills on first refill
        self.refill_at = now
        self.inflight = 0
        self.admitted = 0
        self.rejected = 0


class _Class:
    __slots__ = ("ewma_s", "depth")

    def __init__(self):
        self.ewma_s: float | None = None
        self.depth = 0


class Ticket:
    """Proof of admission.  ``done()`` (idempotent) releases the in-flight
    slots and feeds the observed service time back into the class EWMA —
    including for requests that later failed: their queue occupancy was
    real, and the predictor must see it."""

    __slots__ = ("_ctl", "_tenant", "_cls", "_t0", "_done", "_token")

    def __init__(self, ctl: "AdmissionController", tenant: _Tenant,
                 cls: _Class, t0: float):
        self._ctl = ctl
        self._tenant = tenant
        self._cls = cls
        self._t0 = t0
        self._done = False
        self._token = None

    def done(self) -> None:
        if self._done:
            return
        self._done = True
        self._ctl._finish(self, self._ctl._clock() - self._t0)

    # ---- contextvar plumbing: make this ticket current on the thread so
    # inner engine hops (cluster scatter) do not re-admit the same request
    def __enter__(self) -> "Ticket":
        self._token = _TICKET.set(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._token is not None:
            _TICKET.reset(self._token)
            self._token = None
        self.done()
        return False


class AdmissionController:
    """One lock, O(1) state per (tenant, class); ``admit`` is the only hot
    path and stays well under the 50µs CI gate.  ``clock`` is injectable so
    the fair-share property tests run on a fake clock."""

    def __init__(self, config: AdmissionConfig | None = None, *,
                 metrics=None, clock=time.perf_counter):
        self.config = config or AdmissionConfig()
        self.metrics = metrics
        self._clock = clock
        self._lock = threading.Lock()
        now = clock()
        self._tenants: dict[str, _Tenant] = {
            name: _Tenant(name, float(w), now)
            for name, w in self.config.tenants.items()}
        self._weight_sum = sum(t.weight for t in self._tenants.values())
        self._classes: dict[tuple, _Class] = {}
        self._admitted_total = 0
        self._rejected_total = 0
        self._rejected_by_reason: dict[str, int] = {}

    # ------------------------------------------------------------------ admit
    def admit(self, kind: str, tenant: str | None = None, *,
              deadline_ms: float | None = None,
              signal: str | None = None) -> Ticket:
        """Admit or raise :class:`AdmissionRejected`.  ``kind`` is the
        request kind (``loss_query``, ``build``, ...), ``signal`` the target
        signal name — together the service-time class."""
        cfg = self.config
        name = tenant or DEFAULT_TENANT
        now = self._clock()
        with self._lock:
            ten = self._tenants.get(name)
            if ten is None:
                ten = self._tenants[name] = \
                    _Tenant(name, cfg.default_weight, now)
                self._weight_sum += ten.weight
            share = ten.weight / self._weight_sum if self._weight_sum else 1.0

            if not cfg.enabled:
                return self._admit_locked(ten, kind, signal, now)

            # 1) per-tenant in-flight cap (weighted slice of the total)
            if cfg.max_inflight is not None:
                cap = max(1, round(cfg.max_inflight * share))
                if ten.inflight >= cap:
                    # drain time for the tenant's own backlog: its in-flight
                    # work through its slice of the pool — non-decreasing in
                    # depth by construction
                    est = self._ewma_of(kind, signal)
                    retry = max(0.01, (ten.inflight - cap + 1) * est
                                / max(1.0, cfg.parallelism * share))
                    self._reject_locked(ten, name, "tenant_inflight", retry)

            # 2) deadline guard: predicted completion vs the caller's
            #    budget.  Runs BEFORE the token bucket so a doomed request
            #    does not burn the tenant's rate capacity on its way out.
            if cfg.deadline_guard and deadline_ms is not None:
                cls = self._classes.get((kind, signal))
                if cls is not None and cls.ewma_s is not None:
                    predicted = cls.ewma_s * \
                        (1.0 + cls.depth / cfg.parallelism)
                    if predicted > deadline_ms / 1e3:
                        retry = max(0.01, cls.ewma_s * cls.depth
                                    / cfg.parallelism)
                        self._reject_locked(
                            ten, name, "deadline_unmeetable", retry)

            # 3) per-tenant token bucket (weighted slice of the total rate).
            #    Rejections never consume tokens: a retry storm cannot eat
            #    the tenant's own future capacity.
            if cfg.rate_rps is not None:
                rate = cfg.rate_rps * share
                cap_tokens = max(1.0, rate * cfg.burst_s)
                if ten.tokens < 0.0:            # first sight: full bucket
                    ten.tokens = cap_tokens
                else:
                    ten.tokens = min(
                        cap_tokens,
                        ten.tokens + (now - ten.refill_at) * rate)
                ten.refill_at = now
                if ten.tokens < 1.0:
                    retry = max(0.01, (1.0 - ten.tokens) / rate)
                    self._reject_locked(ten, name, "tenant_rate", retry)
                ten.tokens -= 1.0

            return self._admit_locked(ten, kind, signal, now)

    def _admit_locked(self, ten: _Tenant, kind: str, signal: str | None,
                      now: float) -> Ticket:
        cls = self._classes.get((kind, signal))
        if cls is None:
            cls = self._classes[(kind, signal)] = _Class()
        ten.inflight += 1
        ten.admitted += 1
        cls.depth += 1
        self._admitted_total += 1
        m = self.metrics
        if m is not None:
            m.inc("admission_admitted_total", tenant=ten.name)
        return Ticket(self, ten, cls, now)

    def _reject_locked(self, ten: _Tenant, name: str, reason: str,
                       retry_after: float):
        ten.rejected += 1
        self._rejected_total += 1
        self._rejected_by_reason[reason] = \
            self._rejected_by_reason.get(reason, 0) + 1
        m = self.metrics
        if m is not None:
            m.inc("admission_rejected_total", reason=reason, tenant=name)
        raise AdmissionRejected(
            reason, name, retry_after,
            f"admission refused for tenant {name!r}: {reason} "
            f"(retry after {retry_after:.3f}s)")

    def _ewma_of(self, kind: str, signal: str | None) -> float:
        cls = self._classes.get((kind, signal))
        if cls is not None and cls.ewma_s is not None:
            return cls.ewma_s
        return 0.05                             # cold-start guess: 50ms

    # ----------------------------------------------------------------- finish
    def _finish(self, ticket: Ticket, dur_s: float) -> None:
        a = self.config.alpha
        with self._lock:
            ten, cls = ticket._tenant, ticket._cls
            ten.inflight = max(0, ten.inflight - 1)
            cls.depth = max(0, cls.depth - 1)
            if cls.ewma_s is None:
                cls.ewma_s = dur_s
            else:
                cls.ewma_s += a * (dur_s - cls.ewma_s)
        m = self.metrics
        if m is not None:
            m.set_gauge("admission_tenant_inflight", ten.inflight,
                        tenant=ten.name)
            m.observe("admission_service_time", dur_s, tenant=ten.name)

    # ------------------------------------------------------------------ stats
    def snapshot(self) -> dict:
        with self._lock:
            tenants = {
                name: {"weight": t.weight,
                       "share": t.weight / self._weight_sum
                       if self._weight_sum else 1.0,
                       "inflight": t.inflight,
                       "tokens": round(max(t.tokens, 0.0), 3),
                       "admitted": t.admitted, "rejected": t.rejected}
                for name, t in self._tenants.items()}
            classes = {
                f"{kind}:{signal or '*'}": {
                    "ewma_ms": None if c.ewma_s is None
                    else round(c.ewma_s * 1e3, 3),
                    "depth": c.depth}
                for (kind, signal), c in self._classes.items()}
            return {
                "enabled": self.config.enabled,
                "rate_rps": self.config.rate_rps,
                "max_inflight": self.config.max_inflight,
                "parallelism": self.config.parallelism,
                "admitted_total": self._admitted_total,
                "rejected_total": self._rejected_total,
                "rejected_by_reason": dict(self._rejected_by_reason),
                "tenants": tenants,
                "classes": classes,
            }
