"""Cross-request query coalescing — the BuildScheduler pattern for *reads*.

The PR 3 batched (T-tile, B-tile) Pallas fitting-loss kernel only earns its
T axis when many trees arrive in one dispatch.  A single client can hand us
that batch through ``/v1/query/loss:batch``, but production traffic is the
other shape: many *connections*, each carrying one tree against the same
hot signal.  Served naively that is one ``fitting_loss`` dispatch per
connection — the kernel's fixed cost (dispatch, transfer, tile fill) paid N
times for work one fused evaluation covers.

``QueryScheduler`` closes that gap server-side:

  * **enqueue** — incoming loss queries are bucketed by *fusion key*
    ``(coreset fingerprint, k, eps, backend)``: only queries that would
    score against the SAME cached coreset on the SAME backend may fuse
    (mixed-k queries resolve different coresets, so they never share a
    bucket);
  * **window** — a bucket waits a small batching window (default 2 ms) for
    co-travellers, flushing early when the T tile fills (``max_fuse``) or
    when waiting longer would push a request past its deadline;
  * **fuse** — the bucket's trees are padded to a common leaf count with
    zero-area rects (which contribute exactly zero loss — the smoothed
    assignment consumes no weight over an empty cumulative-area interval)
    and dispatched as ONE ``fitting_loss_batched`` evaluation;
  * **scatter** — per-request losses return to their futures, each response
    reporting the ``fused_batch_size`` it rode in.

Deadline semantics: a request whose deadline expires while queued fails
with :class:`DeadlineExceeded` (HTTP 504) *without* poisoning the batch —
the remaining requests still serve.  A request whose deadline is nearer
than the window trims the bucket's flush time instead of waiting.

The window is a deliberate latency-for-throughput trade: EVERY query —
including a solitary one with no co-traveller — waits up to ``window``
(default 2 ms) before dispatch.  Against the serving path's typical
multi-ms query latencies that is amortization, not overhead; a
latency-critical client with known-uncontended traffic opts out per
request (``coalesce=False``) or engine-wide and scores inline.

The same worker pool doubles as a generic fan-out (:meth:`map_fanout`):
``CoresetEngine.ingest_delta`` batches a delta burst's per-band leaf
``signal_coreset`` rebuilds through one submission instead of N sequential
builds.
"""
from __future__ import annotations

import concurrent.futures as _fut
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro import obs

from .metrics import ServiceMetrics

__all__ = ["QueryScheduler", "DeadlineExceeded", "FUSED_SIZE_BOUNDS"]

# fused-batch-size histogram buckets: powers of two up to well past any
# sane T tile
FUSED_SIZE_BOUNDS = (1, 2, 4, 8, 16, 32, 64, 128)


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before its result was produced.  The
    HTTP layer maps this (and result-wait timeouts) to 504 with the uniform
    error envelope."""


class _Pending:
    """One enqueued loss submission: its tree(s) plus where the answer goes.

    Storage is uniformly 3-D — ``rects`` (C, K, 4) / ``labels`` (C, K) with
    ``count`` = C trees — so a single query (C=1, the /query/loss path) and
    a client batch (C=T, the /query/loss:batch path) ride the SAME fusion
    buckets; ``batch`` only decides the result shape (scalar vs (C,) array).

    ``span`` is the request trace's ``query.scheduler_wait`` span, opened at
    enqueue on the submitting thread and ended when the answer (or the
    deadline error) reaches the future — so the request trace shows exactly
    how long it sat in the batching window, and carries the link to the
    fused dispatch span it rode in."""

    __slots__ = ("rects", "labels", "count", "batch", "deadline", "future",
                 "span")

    def __init__(self, rects: np.ndarray, labels: np.ndarray,
                 deadline: float | None, *, batch: bool = False):
        self.rects = rects
        self.labels = labels
        self.count = int(rects.shape[0])
        self.batch = batch
        self.deadline = deadline
        self.future: _fut.Future = _fut.Future()
        self.span = obs.child_span("query.scheduler_wait")

    def finish_span(self, **attrs) -> None:
        if self.span:
            for k, v in attrs.items():
                self.span.set_attr(k, v)
            self.span.end()


class _Bucket:
    """Queries sharing one fusion key, waiting out the batching window."""

    __slots__ = ("key", "execute", "items", "size", "flush_at", "window_at",
                 "trimmed")

    def __init__(self, key: tuple, execute: Callable, window: float,
                 now: float):
        self.key = key
        self.execute = execute
        self.items: list[_Pending] = []
        self.size = 0                   # total TREES queued (sum of counts)
        self.window_at = now + window   # the untrimmed window expiry
        self.flush_at = self.window_at
        self.trimmed = False            # a deadline pulled flush_at forward


class QueryScheduler:
    """Fuse concurrent same-key loss queries into batched dispatches.

    ``execute`` callables are supplied per submission (the engine closes
    them over the resolved coreset + pinned backend); the first submission
    of a bucket wins, which is safe because the fusion key already pins
    everything the executor depends on.
    """

    def __init__(self, *, window: float = 0.002, max_fuse: int = 16,
                 max_workers: int = 4, deadline_margin: float = 0.001,
                 metrics: ServiceMetrics | None = None):
        self.metrics = metrics or ServiceMetrics()
        self.window = float(window)
        self.max_fuse = int(max_fuse)
        self.deadline_margin = float(deadline_margin)
        self._pool = _fut.ThreadPoolExecutor(max_workers=max_workers,
                                             thread_name_prefix="coreset-query")
        self._cond = threading.Condition()
        self._buckets: dict[tuple, _Bucket] = {}
        self._closed = False
        self._flusher = threading.Thread(target=self._flush_loop,
                                         name="query-batcher", daemon=True)
        self._flusher.start()

    # ---------------------------------------------------------------- submit
    def submit(self, key: tuple, rects: np.ndarray, labels: np.ndarray,
               execute: Callable[[np.ndarray, np.ndarray], np.ndarray], *,
               deadline: float | None = None) -> _fut.Future:
        """Enqueue one (K, 4)/(K,) loss query under ``key``.

        Returns a future resolving to ``(loss, fused_batch_size)``.
        ``execute(rects3, labels2)`` must return the (T,) losses of the
        padded batch in ONE dispatch.  ``deadline`` is an absolute
        ``time.perf_counter()`` instant.
        """
        rects = np.ascontiguousarray(rects, np.int64).reshape(-1, 4)
        labels = np.ascontiguousarray(labels, np.float64).ravel()
        item = _Pending(rects[None], labels[None], deadline)
        return self._enqueue(key, execute, item)

    def submit_batch(self, key: tuple, rects: np.ndarray, labels: np.ndarray,
                     execute: Callable[[np.ndarray, np.ndarray], np.ndarray],
                     *, deadline: float | None = None) -> _fut.Future:
        """Enqueue a client batch of T trees — (T, K, 4)/(T, K) — into the
        SAME fusion bucket single queries use (the key pins coreset
        fingerprint + backend, so co-travelling singles and batches score
        identically).  Returns a future resolving to ``((T,) losses,
        fused_batch_size)`` where ``fused_batch_size`` counts every tree of
        the fused dispatch this batch rode in."""
        rects = np.ascontiguousarray(rects, np.int64)
        labels = np.ascontiguousarray(labels, np.float64)
        if rects.ndim != 3 or rects.shape[-1] != 4 or \
                labels.shape != rects.shape[:2]:
            raise ValueError("batch needs rects (T, K, 4) and labels (T, K)")
        item = _Pending(rects, labels, deadline, batch=True)
        return self._enqueue(key, execute, item)

    def _enqueue(self, key: tuple, execute: Callable,
                 item: _Pending) -> _fut.Future:
        now = time.perf_counter()
        deadline = item.deadline
        if deadline is not None and deadline <= now:
            item.finish_span(outcome="deadline_expired_pre_enqueue")
            item.future.set_exception(DeadlineExceeded(
                "deadline expired before the query was enqueued"))
            self.metrics.inc("query_deadline_expired")
            return item.future
        full = None
        with self._cond:
            if self._closed:
                raise RuntimeError("query scheduler is shut down")
            bucket = self._buckets.get(key)
            if bucket is None:
                bucket = self._buckets[key] = _Bucket(
                    key, execute, self.window, now)
            bucket.items.append(item)
            bucket.size += item.count
            if deadline is not None:
                cutoff = max(now, deadline - self.deadline_margin)
                if cutoff < bucket.flush_at:
                    bucket.flush_at = cutoff
                    bucket.trimmed = True
            if bucket.size >= self.max_fuse:
                full = self._buckets.pop(key)
            else:
                self._cond.notify()
        if full is not None:
            self._submit_dispatch(full, "full")
        return item.future

    def _submit_dispatch(self, bucket: _Bucket, reason: str) -> None:
        """Hand a popped bucket to the worker pool — or, if the pool
        refuses (shutdown raced the pop), dispatch inline on the calling
        thread: a popped bucket is invisible to the flusher and the drain,
        so failing to dispatch it would strand its futures and hang every
        deadline-less waiter forever."""
        try:
            self._pool.submit(self._dispatch, bucket, reason)
        except BaseException:
            self._dispatch(bucket, reason)

    # ----------------------------------------------------------- flush logic
    def _flush_loop(self) -> None:
        while True:
            due: list[_Bucket] = []
            with self._cond:
                if self._closed and not self._buckets:
                    return
                now = time.perf_counter()
                next_at = None
                for key in list(self._buckets):
                    b = self._buckets[key]
                    if b.flush_at <= now or self._closed:
                        due.append(self._buckets.pop(key))
                    elif next_at is None or b.flush_at < next_at:
                        next_at = b.flush_at
                if not due:
                    self._cond.wait(None if next_at is None
                                    else max(next_at - now, 0.0))
                    continue
            for b in due:
                reason = ("drain" if self._closed
                          else "deadline" if b.trimmed and b.flush_at < b.window_at
                          else "window")
                self._submit_dispatch(b, reason)

    def _dispatch(self, bucket: _Bucket, reason: str) -> None:
        """Fuse a bucket into one batched evaluation and scatter results."""
        self.metrics.inc("query_flushes", reason=reason)
        now = time.perf_counter()
        live: list[_Pending] = []
        for it in bucket.items:
            if it.deadline is not None and it.deadline <= now:
                # expired while queued: fail THIS request, serve the rest
                it.finish_span(outcome="deadline_expired_in_window")
                it.future.set_exception(DeadlineExceeded(
                    "deadline expired inside the batching window"))
                self.metrics.inc("query_deadline_expired")
            else:
                live.append(it)
        if not live:
            return
        total = sum(it.count for it in live)    # trees in the fused dispatch
        # the fused dispatch is shared work with N parents, which a span
        # tree cannot express: it gets its OWN trace, cross-linked both
        # ways — every request's wait span links to the fused span, and the
        # fused span links back to each request — so /v1/trace/{request}
        # resolves straight to the batch it rode in (and vice versa)
        req_ctxs = [it.span.context for it in live if it.span]
        fused = obs.start_trace(
            "query.fused_dispatch", links=req_ctxs,
            attrs={"reason": reason, "batch_size": total,
                   "requests": len(live)}) if req_ctxs \
            else obs.NOOP
        if fused:
            for it in live:
                it.span.add_link(fused.context, kind="fused_dispatch")
                it.span.set_attr("fused_trace_id", fused.trace_id)
        try:
            if len(live) == 1:
                rects3 = live[0].rects
                labels2 = live[0].labels
            else:
                kmax = max(it.rects.shape[1] for it in live)
                # zero-area padding rects consume no weight in the smoothed
                # assignment, so padded leaves contribute exactly 0 loss
                rects3 = np.zeros((total, kmax, 4), np.int64)
                labels2 = np.zeros((total, kmax), np.float64)
                off = 0
                for it in live:
                    rects3[off:off + it.count, :it.rects.shape[1]] = it.rects
                    labels2[off:off + it.count, :it.labels.shape[1]] = \
                        it.labels
                    off += it.count
            # attach the fused span so the ops.dispatch span underneath
            # nests in the fused trace, not in the flusher thread's void
            with obs.attach(fused):
                losses = np.asarray(bucket.execute(rects3, labels2),
                                    np.float64)
            if losses.shape != (total,):
                raise RuntimeError(
                    f"fused executor returned shape {losses.shape}, "
                    f"expected ({total},)")
        except BaseException as exc:
            self.metrics.inc("query_fused_failed")
            if fused:
                fused.set_attr("error", type(exc).__name__)
                fused.end()
            for it in live:
                it.finish_span(outcome="fused_dispatch_failed")
                it.future.set_exception(exc)
            return
        if fused:
            fused.end()
        self.metrics.inc("query_fused_dispatches")
        # co-travelling REQUESTS (not trees): a lone client batch of T trees
        # coalesced nothing; a batch joined by one single coalesced one
        self.metrics.inc("query_coalesced_total", len(live) - 1)
        self.metrics.observe("query_fused_batch_size", total,
                             bounds=FUSED_SIZE_BOUNDS, unit="")
        off = 0
        for it in live:
            it.finish_span(outcome="ok", fused_batch_size=total)
            if it.batch:
                it.future.set_result(
                    (losses[off:off + it.count].copy(), total))
            else:
                it.future.set_result((float(losses[off]), total))
            off += it.count

    # ---------------------------------------------------------------- fanout
    def map_fanout(self, fns: Sequence[Callable[[], object]]) -> list:
        """Run ``fns`` on the worker pool as ONE batched submission and
        return their results in order — the delta-burst leaf-rebuild path
        (N per-band ``signal_coreset`` builds in one fan-out instead of N
        sequential calls).  Falls back to inline execution once closed so
        shutdown-time callers still complete."""
        fns = list(fns)
        if not fns:
            return []
        self.metrics.inc("query_fanout_batches")
        self.metrics.inc("query_fanout_items", len(fns))
        with self._cond:
            closed = self._closed
        if closed or len(fns) == 1:
            return [fn() for fn in fns]
        futs = [self._pool.submit(fn) for fn in fns]
        return [f.result() for f in futs]

    # ------------------------------------------------------------- lifecycle
    def in_flight(self) -> int:
        with self._cond:
            return sum(len(b.items) for b in self._buckets.values())

    def load(self) -> dict:
        """Queue-pressure snapshot for admission control / the overload
        gate: queries still waiting in batching windows and how many fusion
        buckets they spread across (depth concentrated in one bucket drains
        in one dispatch; spread across many it drains serially)."""
        with self._cond:
            return {"queued": sum(len(b.items)
                                  for b in self._buckets.values()),
                    "buckets": len(self._buckets)}

    def shutdown(self, wait: bool = True) -> None:
        """Drain: every queued query is flushed (reason="drain") and served
        before the pool stops accepting work."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        if wait:
            self._flusher.join(timeout=5.0)
        self._pool.shutdown(wait=wait)
