"""Versioned HTTP front for the CoresetEngine (v1 typed protocol).

``http.server.ThreadingHTTPServer`` — one OS thread per connection; the
numpy-heavy work releases the GIL and builds are bounded by the scheduler's
worker pool, so a plain threading server sustains the closed-loop loadgen
without an async stack (and without any non-baked-in dependency).

v1 routes (bodies are ``service.protocol`` messages, negotiated between
JSON and the binary npz frame via ``Content-Type`` / ``Accept``):

  POST /v1/signals            RegisterRequest    -> SignalInfo
  POST /v1/ingest             IngestRequest      -> SignalInfo
  POST /v1/ingest:delta       IngestDeltaRequest -> IngestDeltaResponse
  POST /v1/build              BuildRequest       -> BuildResponse
  POST /v1/query/loss         LossQuery         -> LossResponse
  POST /v1/query/loss:batch   BatchLossQuery    -> BatchLossResponse
  POST /v1/query/fit          FitRequest        -> FitResponse
  POST /v1/query/compress     CompressRequest   -> CompressResponse
  GET  /v1/healthz            liveness + basic gauges (JSON)
  GET  /v1/stats              full JSON snapshot (signals, cache, latency)
  GET  /v1/metrics            Prometheus text exposition
  GET  /v1/traces:recent      newest-first completed-trace summaries (?limit=)
  GET  /v1/trace/{id}         one trace + linked traces (?format=chrome for
                              Perfetto-loadable trace-event JSON)

Every request runs under a trace: the handler continues the caller's W3C
``traceparent`` when one arrives (the SDK injects it) or mints a fresh
trace, and every response carries ``traceparent`` + ``X-Coreset-Trace-Id``
headers so clients can fetch the server-side trace of any response —
including errors.  An optional JSON-lines access log (``make_server``'s
``access_log``/``slow_ms``, off by default) records one line per request
(or per slow request) with its trace id.

Every status >= 400 carries the uniform envelope
``{"type": "error", "error": {"code", "message"}}`` with code in
{bad_request, not_found, conflict, payload_too_large, unsupported_media,
deadline_exceeded, overloaded, internal}.  Requests carrying
``deadline_ms`` that miss their deadline (build queue wait, query batching
window) fail 504 ``deadline_exceeded`` without disturbing the batch they
were queued in.  When admission control is on (``make_server`` engines
constructed with ``admission=``), requests may instead be refused ON
ARRIVAL with 503 ``overloaded`` + a fractional-seconds ``Retry-After``
header and ``reason``/``tenant``/``retry_after`` fields in the envelope;
the tenant comes from ``X-Coreset-Tenant`` (default tenant otherwise).

The pre-v1 unversioned routes (``/signals``, ``/ingest``, ``/build``,
``/query/*``, ``/healthz``, ``/stats``, ``/metrics``) remain as thin
deprecated shims: their flat-dict request schema is translated to the typed
messages, they delegate to the same handlers, and every response carries
``Deprecation: true`` plus a ``Link: </v1/...>; rel="successor-version"``
header.  New clients should use ``repro.client.CoresetClient``.

``synthetic`` payloads ({"kind": "piecewise"|"smooth", n, m, k?, noise?,
seed?}) generate the signal server-side — the loadgen path, so benchmarks
can measure the serving engine rather than the wire codec.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs

import numpy as np

from repro import obs

from . import protocol as P
from .admission import DEFAULT_TENANT, AdmissionRejected
from .engine import CoresetEngine, UnknownSignalError
from .protocol import ProtocolError, UnsupportedCodec
from .query_scheduler import DeadlineExceeded

__all__ = ["make_server", "serve_forever_in_thread", "ApiError"]

_MAX_BODY = 256 << 20
_TRACE_WAIT_S = 0.25   # bounded wait for an in-flight trace to finalize

# concurrent.futures.TimeoutError aliases builtins.TimeoutError on 3.11+,
# but is a distinct class before — catch whichever this runtime has
from concurrent.futures import TimeoutError as _FutTimeout  # noqa: E402


class ApiError(Exception):
    """Handler-raised error with a definite HTTP status + envelope code."""

    def __init__(self, http: int, code: str, message: str,
                 retry_after: float | None = None):
        super().__init__(message)
        self.http = http
        self.code = code
        self.retry_after = retry_after


def _synthetic(spec: dict) -> np.ndarray:
    from repro.data.signals import piecewise_signal, smooth_field
    if not isinstance(spec, dict):
        raise ProtocolError("'synthetic' must be an object")
    kind = spec.get("kind", "piecewise")
    try:
        n, m = int(spec["n"]), int(spec["m"])
    except (KeyError, TypeError, ValueError):
        raise ProtocolError("synthetic spec needs integer 'n' and 'm'") from None
    seed = int(spec.get("seed", 0))
    if kind == "piecewise":
        return piecewise_signal(n, m, int(spec.get("k", 8)),
                                noise=float(spec.get("noise", 0.15)), seed=seed)
    if kind == "smooth":
        return smooth_field(n, m, noise=float(spec.get("noise", 0.1)), seed=seed)
    raise ProtocolError(f"unknown synthetic kind {kind!r}")


def _values_from(values: np.ndarray | None, synthetic: dict | None,
                 field: str) -> np.ndarray:
    """Resolve a dense payload: the typed array field (already dtype/ndim
    validated by the protocol coercers — ragged or non-numeric input fails
    decode with a 400 envelope, never a 500) or a server-side generator."""
    if values is not None:
        if values.ndim != 2 or values.size == 0:
            raise ProtocolError(f"{field!r} must be a non-empty 2-D array")
        if not np.isfinite(values).all():
            raise ProtocolError(f"{field!r} must be finite (NaN/inf found)")
        return np.asarray(values, np.float64)
    if synthetic is not None:
        return _synthetic(synthetic)
    raise ProtocolError(f"need {field!r} or 'synthetic'")


# ------------------------------------------------------------- v1 handlers
def _h_register(eng: CoresetEngine, msg: P.RegisterRequest) -> P.SignalInfo:
    values = _values_from(msg.values, msg.synthetic, "values")
    try:
        info = eng.register_signal(msg.signal.name, values, replace=msg.replace)
    except ValueError as exc:
        if "already registered" in str(exc):
            raise ApiError(409, "conflict", str(exc)) from None
        raise
    return _signal_info(info)


def _h_ingest(eng: CoresetEngine, msg: P.IngestRequest) -> P.SignalInfo:
    band = _values_from(msg.band, msg.synthetic, "band")
    return _signal_info(eng.ingest_band(msg.signal.name, band))


def _deadline_of(msg) -> float | None:
    """Absolute perf_counter deadline from a request's ``deadline_ms``
    budget (clocked from handler entry, i.e. request receipt)."""
    ms = getattr(msg, "deadline_ms", None)
    if ms is None:
        return None
    ms = float(ms)
    if ms <= 0:
        raise ProtocolError("deadline_ms must be > 0")
    return time.perf_counter() + ms / 1e3


def _h_ingest_delta(eng: CoresetEngine, msg: P.IngestDeltaRequest,
                    ) -> P.IngestDeltaResponse:
    band = _values_from(msg.band, None, "band")
    row0 = int(msg.row0) if msg.row0 is not None else None
    r = eng.ingest_delta(msg.signal.name, band, row0=row0,
                         row0s=msg.row0s, rows=msg.rows)
    return P.IngestDeltaResponse(**r)


def _signal_info(info: dict) -> P.SignalInfo:
    return P.SignalInfo(
        name=info["name"], n=int(info["n"]),
        m=int(info["m"]) if info["m"] is not None else None,
        bands=int(info["bands"]), streamed=bool(info["streamed"]),
        version=info["version"],
        builders=[list(b) for b in info["builders"]])


def _h_build(eng: CoresetEngine, msg: P.BuildRequest) -> P.BuildResponse:
    cs, eps_eff, how = eng.get_coreset(msg.signal.name, msg.spec.k,
                                       msg.spec.eps,
                                       deadline=_deadline_of(msg))
    return P.BuildResponse(
        fingerprint=cs.fingerprint(), eps_eff=float(eps_eff), served_from=how,
        size=int(cs.size), blocks=int(cs.num_blocks), nbytes=int(cs.nbytes),
        compression_ratio=float(cs.compression_ratio()),
        certified=bool(cs.certified), build_seconds=float(cs.build_seconds))


def _h_loss(eng: CoresetEngine, msg: P.LossQuery) -> P.LossResponse:
    eps = msg.spec.eps if msg.spec is not None else 0.2
    k = msg.spec.k if msg.spec is not None else None
    r = eng.tree_loss(msg.signal.name, msg.rects, msg.labels, eps=eps, k=k,
                      deadline=_deadline_of(msg),
                      coalesce=bool(msg.coalesce))
    return P.LossResponse(
        loss=r["loss"], k=r["k"], eps=r["eps"], eps_eff=r["eps_eff"],
        served_from=r["served_from"], fingerprint=r["fingerprint"],
        coreset_size=r["coreset_size"],
        fused_batch_size=r["fused_batch_size"], backend=r["backend"])


def _h_loss_batch(eng: CoresetEngine, msg: P.BatchLossQuery,
                  ) -> P.BatchLossResponse:
    eps = msg.spec.eps if msg.spec is not None else 0.2
    k = msg.spec.k if msg.spec is not None else None
    r = eng.tree_loss_batch(msg.signal.name, msg.rects, msg.labels,
                            eps=eps, k=k, deadline=_deadline_of(msg),
                            coalesce=bool(msg.coalesce))
    return P.BatchLossResponse(
        losses=r["losses"], k=r["k"], eps=r["eps"], eps_eff=r["eps_eff"],
        served_from=r["served_from"], fingerprint=r["fingerprint"],
        coreset_size=r["coreset_size"], scoring_calls=r["scoring_calls"],
        fused_batch_size=r["fused_batch_size"])


def _h_fit(eng: CoresetEngine, msg: P.FitRequest) -> P.FitResponse:
    r = eng.fit_forest(
        msg.signal.name, k=msg.spec.k, eps=msg.spec.eps,
        n_estimators=int(msg.n_estimators),
        max_leaves=int(msg.max_leaves) if msg.max_leaves is not None else None,
        predict=msg.predict, seed=int(msg.seed),
        deadline=_deadline_of(msg))
    return P.FitResponse(
        k=r["k"], eps=r["eps"], eps_eff=r["eps_eff"],
        served_from=r["served_from"], fingerprint=r["fingerprint"],
        train_size=r["train_size"], n_estimators=r["n_estimators"],
        model_cache=r["model_cache"],
        predictions=(np.asarray(r["predictions"], np.float64)
                     if "predictions" in r else None))


def _h_compress(eng: CoresetEngine, msg: P.CompressRequest,
                ) -> P.CompressResponse:
    r = eng.compress(
        msg.signal.name, k=msg.spec.k,
        eps=None if msg.target_frac is not None else msg.spec.eps,
        target_frac=(float(msg.target_frac)
                     if msg.target_frac is not None else None),
        style=msg.style, max_points=int(msg.max_points),
        deadline=_deadline_of(msg))
    pts = r["points"]
    return P.CompressResponse(
        k=r["k"], eps_eff=r["eps_eff"], served_from=r["served_from"],
        fingerprint=r["fingerprint"], size=r["size"], blocks=r["blocks"],
        nbytes=r["nbytes"], compression_ratio=r["compression_ratio"],
        truncated=r["truncated"],
        X=np.asarray(pts["X"], np.float64).reshape(-1, 2),
        y=np.asarray(pts["y"], np.float64),
        w=np.asarray(pts["w"], np.float64))


# (request message class, handler) per v1 POST route
_V1_POST = {
    "/v1/signals": (P.RegisterRequest, _h_register),
    "/v1/ingest": (P.IngestRequest, _h_ingest),
    "/v1/ingest:delta": (P.IngestDeltaRequest, _h_ingest_delta),
    "/v1/build": (P.BuildRequest, _h_build),
    "/v1/query/loss": (P.LossQuery, _h_loss),
    "/v1/query/loss:batch": (P.BatchLossQuery, _h_loss_batch),
    "/v1/query/fit": (P.FitRequest, _h_fit),
    "/v1/query/compress": (P.CompressRequest, _h_compress),
}
_V1_GET = frozenset({"/v1/healthz", "/v1/stats", "/v1/metrics"})

# deprecated unversioned path -> v1 successor (the ":"-suffixed fused/delta
# routes are v1-only: no pre-v1 client ever spoke them)
_V1_ONLY = frozenset({"/v1/query/loss:batch", "/v1/ingest:delta"})
_LEGACY = {p[len("/v1"):]: p for p in (*_V1_POST, *_V1_GET)
           if p not in _V1_ONLY}

_ROUTES = frozenset((*_V1_POST, *_V1_GET, *_LEGACY))


# --------------------------------------------- legacy flat-dict translation
def _req(body: dict, field: str):
    try:
        return body[field]
    except KeyError:
        raise ProtocolError(f"missing field {field!r}") from None


def _legacy_spec(body: dict, *, k_default: int | None = None) -> P.CoresetSpec:
    k = body.get("k", k_default)
    if k is None:
        raise ProtocolError("missing field 'k'")
    return P.CoresetSpec(k=int(k), eps=float(body.get("eps", 0.2)))


def _legacy_to_msg(path: str, body: dict) -> P._Wire:
    if not isinstance(body, dict):
        raise ProtocolError("body must be a JSON object")
    ref = P.SignalRef(name=str(_req(body, "name")))
    arr2 = P._arr(np.float64, ndim=2, allow_none=True)
    if path == "/signals":
        return P.RegisterRequest(
            signal=ref, values=arr2(body.get("values")),
            synthetic=body.get("synthetic"),
            replace=bool(body.get("replace", False)))
    if path == "/ingest":
        return P.IngestRequest(signal=ref, band=arr2(body.get("band")),
                               synthetic=body.get("synthetic"))
    if path == "/build":
        return P.BuildRequest(signal=ref, spec=_legacy_spec(body))
    if path == "/query/loss":
        rects = P._arr(np.int64, ndim=2)(_req(body, "rects"))
        spec = None
        if "k" in body or "eps" in body:
            spec = _legacy_spec(body, k_default=max(rects.shape[0], 1))
        return P.LossQuery(signal=ref, rects=rects,
                           labels=P._arr(np.float64, ndim=1)(_req(body, "labels")),
                           spec=spec)
    if path == "/query/fit":
        return P.FitRequest(
            signal=ref, spec=_legacy_spec(body),
            n_estimators=int(body.get("n_estimators", 10)),
            max_leaves=(int(body["max_leaves"])
                        if "max_leaves" in body else None),
            predict=arr2(body.get("predict")),
            seed=int(body.get("seed", 0)))
    if path == "/query/compress":
        return P.CompressRequest(
            signal=ref, spec=_legacy_spec(body),
            target_frac=(float(body["target_frac"])
                         if "target_frac" in body else None),
            style=str(body.get("style", "mean")),
            max_points=int(body.get("max_points", 4096)))
    raise ProtocolError(f"no legacy translation for {path}")


def _legacy_payload(resp: P._Wire) -> dict:
    """Shape a typed response like the pre-v1 flat JSON bodies: no "type"
    tag, ``served_from`` also published under its old name ``cache``, and
    compress points re-nested under "points" — so a legacy client's
    ``r["cache"]`` / ``r["points"]["X"]`` keep working behind the shim."""
    # drop nulls: pre-v1 bodies omitted absent keys (e.g. fit responses
    # only carried "predictions" when predict points were sent)
    payload = {k: v.tolist() if isinstance(v, np.ndarray) else v
               for k, v in resp.to_payload().items() if v is not None}
    payload.pop("type", None)
    if "served_from" in payload:
        payload["cache"] = payload["served_from"]
    if isinstance(resp, P.CompressResponse):
        payload["points"] = {"X": payload.pop("X"), "y": payload.pop("y"),
                             "w": payload.pop("w")}
    return payload


class _Handler(BaseHTTPRequestHandler):
    engine: CoresetEngine  # set by make_server on the subclass
    protocol_version = "HTTP/1.1"
    access_log = None      # file-like; make_server sets it (None = off)
    slow_ms: float | None = None   # only log requests slower than this
    stream_chunk_points: int = P.STREAM_CHUNK_POINTS   # v2 points/chunk
    _log_lock: threading.Lock = threading.Lock()
    _span = None           # this request's root span (per-request, set early)
    _status = 0

    # silence per-request stderr logging; the access log (opt-in) and
    # metrics carry the signal
    def log_message(self, fmt, *args):  # noqa: D102
        pass

    # ------------------------------------------------------------- plumbing
    def _reply(self, code: int, body: bytes, content_type: str,
               deprecated_for: str | None = None,
               retry_after: float | None = None):
        if code >= 400:
            # an error may leave the request body unread (oversized payload,
            # JSON abort) — reusing the keep-alive connection would parse the
            # leftover bytes as the next request line; close instead
            self.close_connection = True
        self._status = code
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        sp = self._span
        if sp is not None:
            # every response — errors included — names its server-side
            # trace, so a client can always fetch /v1/trace/{id}
            self.send_header("traceparent",
                             obs.format_traceparent(sp.trace_id, sp.span_id))
            self.send_header("X-Coreset-Trace-Id", sp.trace_id)
        if retry_after is not None:
            # fractional seconds: RFC 9110 says integer delay-seconds, but
            # sub-second backoff is the whole point at ms-scale requests —
            # our SDK float()s the header, and integer-only parsers reading
            # "0.25" as garbage fall back to their own schedule, which is
            # exactly the no-header behavior
            self.send_header("Retry-After", f"{max(retry_after, 0.001):.3f}")
        if deprecated_for is not None:
            self.send_header("Deprecation", "true")
            self.send_header("Link",
                             f'<{deprecated_for}>; rel="successor-version"')
        self.end_headers()
        self.wfile.write(body)

    def _reply_msg(self, code: int, msg: P._Wire, encoding: str,
                   deprecated_for: str | None = None,
                   retry_after: float | None = None):
        # binary responses use the codec the client's Accept advertised
        # ("zlib" unless it explicitly said codec=zstd), so a zlib-only
        # client never receives a frame it cannot decode.  The advertised
        # codec is an upper bound, never a demand: a zstd-less server
        # degrades to zlib silently — the handler already ran, so raising
        # here would 415 a request whose state change was committed
        codec = None
        if encoding == "binary":
            codec = P._Wire.accept_codec(self.headers.get("Accept", ""))
            if codec == "zstd" and P.zstandard is None:
                codec = "zlib"
        ctype, body = msg.to_wire(encoding, binary_codec=codec)
        self._reply(code, body, ctype, deprecated_for, retry_after)

    def _reply_compress_stream(self, resp: P.CompressResponse) -> None:
        """v2 negotiated compress: write the response as one transfer-
        encoding chunk per protocol segment, each flushed before the next
        is encoded — server-side peak memory for the wire path is
        O(stream_chunk_points), not O(response points).

        Headers are committed before the first segment, so a mid-stream
        failure cannot be converted into an error envelope; the connection
        is torn down instead and the client's incremental decoder reports
        ``StreamTruncated`` (which it treats as retryable).
        """
        codec = P._Wire.accept_codec(self.headers.get("Accept", ""))
        if codec == "zstd" and P.zstandard is None:
            codec = "zlib"
        self._status = 200
        self.send_response(200)
        self.send_header("Content-Type", P.CONTENT_TYPE_STREAM)
        self.send_header("Transfer-Encoding", "chunked")
        sp = self._span
        if sp is not None:
            self.send_header("traceparent",
                             obs.format_traceparent(sp.trace_id, sp.span_id))
            self.send_header("X-Coreset-Trace-Id", sp.trace_id)
        self.end_headers()
        segments = 0
        try:
            for seg in P.compress_stream_segments(
                    resp, chunk_points=self.stream_chunk_points,
                    binary_codec=codec):
                self.wfile.write(b"%x\r\n" % len(seg) + seg + b"\r\n")
                self.wfile.flush()
                segments += 1
            self.wfile.write(b"0\r\n\r\n")
        except OSError:
            # client went away mid-stream; nothing to salvage on this
            # connection, and the headers are long gone
            self.close_connection = True
            self.engine.metrics.inc("http_stream_aborts")
            return
        self.engine.metrics.inc("http_stream_responses")
        self.engine.metrics.inc("http_stream_segments", segments)

    def _reply_json(self, code: int, payload,
                    content_type: str = "application/json",
                    deprecated_for: str | None = None):
        body = (payload if isinstance(payload, bytes)
                else json.dumps(payload).encode())
        self._reply(code, body, content_type, deprecated_for)

    def _error(self, http: int, code: str, message: str,
               deprecated_for: str | None = None, *,
               retry_after: float | None = None,
               tenant: str | None = None, reason: str | None = None):
        # errors are always JSON: the envelope must stay readable even when
        # the request's binary frame was the thing that failed to parse
        env = P.ErrorResponse(error=P.ErrorInfo(
            code=code, message=message, retry_after=retry_after,
            tenant=tenant, reason=reason))
        self._reply_msg(http, env, "json", deprecated_for,
                        retry_after=retry_after)

    def _admitted(self, eng: CoresetEngine, msg: P._Wire):
        """Front-door admission for one decoded request.  Returns a context
        manager: the admission Ticket (made current for the handler call, so
        inner engine hops — cluster scatter — are charged exactly once and
        its exit feeds the observed service time back into the predictor),
        or a no-op when the engine runs without admission.  Raises
        :class:`AdmissionRejected` → 503 + Retry-After before any engine
        work happens."""
        ctl = eng.admission
        if ctl is None:
            return contextlib.nullcontext()
        tenant = (self.headers.get("X-Coreset-Tenant")
                  or getattr(msg, "tenant", None) or DEFAULT_TENANT)
        sig = getattr(msg, "signal", None)
        ticket = ctl.admit(msg.kind, tenant,
                           deadline_ms=getattr(msg, "deadline_ms", None),
                           signal=sig.name if sig is not None else None)
        sp = self._span
        if sp:
            sp.set_attr("tenant", tenant)
        return ticket

    def _body(self) -> bytes:
        length = int(self.headers.get("Content-Length", 0))
        if length > _MAX_BODY:
            raise ApiError(413, "payload_too_large",
                           f"body of {length} bytes exceeds {_MAX_BODY}")
        return self.rfile.read(length) if length else b""

    def _accept_encoding(self) -> str:
        accept = self.headers.get("Accept", "")
        return "binary" if P.CONTENT_TYPE_BINARY in accept else "json"

    # -------------------------------------------------------------- routing
    def _route(self, method: str) -> None:
        eng = self.engine
        path, _, query = self.path.partition("?")
        path = path.rstrip("/") or "/"
        t0 = time.perf_counter()
        # latency metric label: client-supplied paths outside the route table
        # collapse to one bucket, else a URL scanner grows a histogram per
        # probed path and bloats every /metrics scrape; the dynamic trace
        # route collapses its id for the same reason
        if path in _ROUTES or path == "/v1/traces:recent":
            metric_route = f"{method} {path}"
        elif path.startswith("/v1/trace/"):
            metric_route = f"{method} /v1/trace/{{id}}"
        else:
            metric_route = f"{method} <unmatched>"
        successor = _LEGACY.get(path)      # non-None => deprecated shim
        v1_path = successor or path
        out_enc = self._accept_encoding()
        # continue the caller's trace (SDK-injected traceparent) or mint one
        root = obs.start_trace(metric_route,
                               traceparent=self.headers.get("traceparent"))
        self._span = root if root else None
        self._status = 0
        try:
            with obs.attach(root):
                if method == "GET" and v1_path in _V1_GET:
                    self._get(eng, v1_path, successor)
                elif method == "GET" and (path == "/v1/traces:recent"
                                          or path.startswith("/v1/trace/")):
                    self._get_trace(path, query)
                elif method == "POST" and v1_path in _V1_POST:
                    msg_cls, handler = _V1_POST[v1_path]
                    raw = self._body()
                    if successor is not None:
                        # legacy flat-dict schema; JSON only, like the old API
                        msg = _legacy_to_msg(path, json.loads(raw or b"{}"))
                        with self._admitted(eng, msg):
                            resp = handler(eng, msg)
                        self._reply_json(200, _legacy_payload(resp),
                                         deprecated_for=successor)
                    else:
                        ctype = self.headers.get("Content-Type", "")
                        if (ctype.split(";")[0].strip().lower() not in
                                ("", P.CONTENT_TYPE_JSON, P.CONTENT_TYPE_BINARY)):
                            raise ApiError(415, "unsupported_media",
                                           f"unsupported Content-Type {ctype!r}")
                        msg = P.decode(ctype, raw, expect=msg_cls)
                        with self._admitted(eng, msg):
                            resp = handler(eng, msg)
                        if (v1_path == "/v1/query/compress"
                                and out_enc == "binary"
                                and P.accept_stream(
                                    self.headers.get("Accept"))):
                            # Accept carried ";v=2": stream the response as
                            # length-prefixed segments over chunked
                            # transfer-encoding instead of one buffered
                            # frame (protocol.py, "v2 chunked streaming")
                            self._reply_compress_stream(resp)
                        else:
                            self._reply_msg(200, resp, out_enc)
                else:
                    eng.metrics.inc("http_404")
                    self._error(404, "not_found", f"no route {method} {path}")
                    return
            eng.metrics.inc("http_200")
            if successor is not None:
                eng.metrics.inc("http_deprecated")
        except AdmissionRejected as exc:
            # refused ON ARRIVAL (503 overloaded + Retry-After): the request
            # never touched the engine.  Distinct from 504 deadline_exceeded,
            # which is admitted work dying at its deadline.
            eng.metrics.inc("http_503")
            if root:
                root.set_attr("admission.rejected", True)
                root.set_attr("admission.reason", exc.reason)
                root.set_attr("admission.tenant", exc.tenant)
            self._error(503, "overloaded", exc.message, successor,
                        retry_after=exc.retry_after, tenant=exc.tenant,
                        reason=exc.reason)
        except ApiError as exc:
            eng.metrics.inc(f"http_{exc.http}")
            self._error(exc.http, exc.code, str(exc), successor,
                        retry_after=exc.retry_after)
        except UnknownSignalError as exc:
            # the one *intentional* KeyError (engine signal lookup); stray
            # KeyErrors from handler bugs still surface as 500 internal
            eng.metrics.inc("http_404")
            self._error(404, "not_found", str(exc.args[0] if exc.args else exc),
                        successor)
        except UnsupportedCodec as exc:
            # zstd frame on a zlib-only host: 415 tells the SDK to
            # renegotiate down to JSON, unlike a 400 which means bad request
            eng.metrics.inc("http_415")
            self._error(415, "unsupported_media", str(exc), successor)
        except (DeadlineExceeded, _FutTimeout) as exc:
            # the request's deadline_ms budget ran out (build queue wait or
            # query batching window) — a definite server-side timeout, not
            # a malformed request; the batch it was queued in still serves
            eng.metrics.inc("http_504")
            self._error(504, "deadline_exceeded",
                        str(exc) or "request deadline exceeded", successor)
        except (ProtocolError, ValueError, TypeError,
                json.JSONDecodeError) as exc:
            eng.metrics.inc("http_400")
            self._error(400, "bad_request", f"{type(exc).__name__}: {exc}",
                        successor)
        except Exception as exc:  # pragma: no cover - defensive 500
            eng.metrics.inc("http_500")
            self._error(500, "internal", f"{type(exc).__name__}: {exc}",
                        successor)
        finally:
            dt = time.perf_counter() - t0
            if root:
                root.set_attr("http.status", self._status)
                root.end()
            self._span = None
            # exemplar: a slow bucket in the latency histogram names a
            # concrete retrievable trace instead of an anonymous aggregate
            eng.metrics.observe(f"http {metric_route}", dt,
                                exemplar=root.trace_id if root else None)
            self._access_log_line(method, path, dt,
                                  root.trace_id if root else None)

    def _access_log_line(self, method: str, path: str, dt: float,
                         trace_id: str | None) -> None:
        """One structured JSON line per request (or per slow request when
        ``slow_ms`` filters) — opt-in, see ``make_server``."""
        fp = self.access_log
        if fp is None:
            return
        dur_ms = dt * 1e3
        slow = self.slow_ms is not None and dur_ms >= self.slow_ms
        if self.slow_ms is not None and not slow:
            return
        rec = {"ts": round(time.time(), 6), "method": method, "path": path,
               "status": self._status, "duration_ms": round(dur_ms, 3)}
        if trace_id:
            rec["trace_id"] = trace_id
        if slow:
            rec["slow"] = True
        line = json.dumps(rec) + "\n"
        try:
            with self._log_lock:   # interleaved lines from handler threads
                fp.write(line)
                fp.flush()
        except (OSError, ValueError):   # closed/full log must not 500 requests
            pass

    def _get_trace(self, path: str, query: str) -> None:
        """The trace-retrieval routes (JSON only; ids are dynamic path
        segments, so these live outside the static route table)."""
        params = parse_qs(query)
        if path == "/v1/traces:recent":
            try:
                limit = int(params.get("limit", ["50"])[0])
            except ValueError:
                raise ApiError(400, "bad_request",
                               "limit must be an integer") from None
            self._reply_json(200, {"traces": obs.TRACER.recent(limit)})
            return
        trace_id = path[len("/v1/trace/"):]
        fmt = params.get("format", ["json"])[0]
        # grace for the reply-before-finalize window: a request's response
        # is written BEFORE its root span ends (observation must not gate
        # the reply), so a client fetching its own trace straight off the
        # response headers can beat finalization by microseconds.  The wait
        # only engages for ids the tracer knows are in flight — unknown ids
        # still 404 immediately.
        if fmt == "chrome":
            body = obs.TRACER.chrome_json(trace_id, wait_s=_TRACE_WAIT_S)
            if body is None:
                raise ApiError(404, "not_found",
                               f"unknown trace {trace_id!r}")
            self._reply_json(200, body)
            return
        if fmt != "json":
            raise ApiError(400, "bad_request",
                           f"unknown trace format {fmt!r} "
                           "(expected json or chrome)")
        doc = obs.TRACER.get(trace_id, wait_s=_TRACE_WAIT_S)
        if doc is None:
            raise ApiError(404, "not_found", f"unknown trace {trace_id!r}")
        self._reply_json(200, doc)

    def _get(self, eng: CoresetEngine, v1_path: str,
             successor: str | None) -> None:
        if v1_path == "/v1/healthz":
            snap = eng.metrics.snapshot()
            self._reply_json(200, {
                "status": "ok", "protocol": P.PROTOCOL_VERSION,
                "uptime_s": snap["uptime_s"],
                "signals": len(eng.list_signals()),
                "cache_entries": len(eng.cache),
                "cache_bytes": eng.cache.nbytes,
                "builds_in_flight": eng.scheduler.in_flight()},
                deprecated_for=successor)
        elif v1_path == "/v1/stats":
            self._reply_json(200, eng.stats(), deprecated_for=successor)
        else:  # /v1/metrics
            eng.sync_autotune_metrics()   # scrape sees fresh ops_autotune_*
            self._reply_json(200, eng.metrics.render().encode(),
                             content_type="text/plain; version=0.0.4",
                             deprecated_for=successor)

    def do_GET(self):  # noqa: N802
        self._route("GET")

    def do_POST(self):  # noqa: N802
        self._route("POST")


def make_server(engine: CoresetEngine, host: str = "127.0.0.1",
                port: int = 0, *, access_log=None,
                slow_ms: float | None = None,
                stream_chunk_points: int | None = None) -> ThreadingHTTPServer:
    """Bind a ThreadingHTTPServer to (host, port); port 0 = ephemeral.

    ``access_log`` (a writable text file object, e.g. an opened path or
    ``sys.stderr``) turns on the JSON-lines access log: one object per
    request with method, path, status, duration_ms and trace_id.
    ``slow_ms`` filters it to requests at or above that duration — the
    slow-request log.  Both default off; the handler never logs otherwise.
    ``stream_chunk_points`` overrides the points-per-chunk of v2 streamed
    compress responses (default ``protocol.STREAM_CHUNK_POINTS``).
    """
    handler = type("CoresetHandler", (_Handler,), {
        "engine": engine, "access_log": access_log,
        "slow_ms": float(slow_ms) if slow_ms is not None else None,
        "stream_chunk_points": (int(stream_chunk_points)
                                if stream_chunk_points is not None
                                else P.STREAM_CHUNK_POINTS),
        "_log_lock": threading.Lock()})
    srv = _Server((host, port), handler)
    return srv


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    # a barrier-released burst of concurrent clients (the coalescing gate,
    # cluster gathers) overflows socketserver's default listen backlog of 5
    # into kernel RSTs when the accept loop lags; give the queue real depth
    request_queue_size = 128


def serve_forever_in_thread(srv: ThreadingHTTPServer) -> threading.Thread:
    t = threading.Thread(target=srv.serve_forever, name="coreset-http",
                         daemon=True)
    t.start()
    return t
