"""Stdlib HTTP/JSON front for the CoresetEngine.

``http.server.ThreadingHTTPServer`` — one OS thread per connection; the
numpy-heavy work releases the GIL and builds are bounded by the scheduler's
worker pool, so a plain threading server sustains the closed-loop loadgen
without an async stack (and without any non-baked-in dependency).

Routes (all request/response bodies are JSON):

  POST /signals           {"name", "values": [[..]] | "synthetic": {...}}
  POST /ingest            {"name", "band": [[..]] | "synthetic": {...}}
  POST /build             {"name", "k", "eps"}
  POST /query/loss        {"name", "rects": [[r0,r1,c0,c1]..], "labels": [..],
                           "eps"?, "k"?}
  POST /query/fit         {"name", "k", "eps"?, "n_estimators"?, "max_leaves"?,
                           "predict"?: [[i,j]..], "seed"?}
  POST /query/compress    {"name", "k", "eps"? | "target_frac"?, "style"?,
                           "max_points"?}
  GET  /healthz           liveness + basic gauges
  GET  /stats             full JSON snapshot (signals, cache, latency)
  GET  /metrics           Prometheus text exposition

``synthetic`` payloads ({"kind": "piecewise"|"smooth", n, m, k?, noise?,
seed?}) generate the signal server-side — the loadgen path, so benchmarks
measure the serving engine rather than JSON array parsing.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from .engine import CoresetEngine

__all__ = ["make_server", "serve_forever_in_thread"]

_MAX_BODY = 64 << 20
_ROUTES = frozenset({"/healthz", "/stats", "/metrics", "/signals", "/ingest",
                     "/build", "/query/loss", "/query/fit", "/query/compress"})


def _synthetic(spec: dict) -> np.ndarray:
    from repro.data.signals import piecewise_signal, smooth_field
    kind = spec.get("kind", "piecewise")
    n, m = int(spec["n"]), int(spec["m"])
    seed = int(spec.get("seed", 0))
    if kind == "piecewise":
        return piecewise_signal(n, m, int(spec.get("k", 8)),
                                noise=float(spec.get("noise", 0.15)), seed=seed)
    if kind == "smooth":
        return smooth_field(n, m, noise=float(spec.get("noise", 0.1)), seed=seed)
    raise ValueError(f"unknown synthetic kind {kind!r}")


def _values_from(body: dict, field: str) -> np.ndarray:
    if field in body:
        return np.asarray(body[field], np.float64)
    if "synthetic" in body:
        return _synthetic(body["synthetic"])
    raise ValueError(f"need {field!r} or 'synthetic'")


class _Handler(BaseHTTPRequestHandler):
    engine: CoresetEngine  # set by make_server on the subclass
    protocol_version = "HTTP/1.1"

    # silence per-request stderr logging; metrics carry the signal
    def log_message(self, fmt, *args):  # noqa: D102
        pass

    # ------------------------------------------------------------- plumbing
    def _reply(self, code: int, payload, content_type: str = "application/json"):
        body = (payload if isinstance(payload, bytes)
                else json.dumps(payload).encode())
        if code >= 400:
            # an error may leave the request body unread (oversized payload,
            # JSON abort) — reusing the keep-alive connection would parse the
            # leftover bytes as the next request line; close instead
            self.close_connection = True
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length", 0))
        if length > _MAX_BODY:
            raise ValueError("request body too large")
        raw = self.rfile.read(length) if length else b"{}"
        return json.loads(raw or b"{}")

    def _route(self, method: str) -> None:
        eng = self.engine
        path = self.path.split("?", 1)[0].rstrip("/") or "/"
        t0 = time.perf_counter()
        route = f"{method} {path}"
        # latency metric label: client-supplied paths outside the route table
        # collapse to one bucket, else a URL scanner grows a histogram per
        # probed path and bloats every /metrics scrape
        metric_route = route if path in _ROUTES else f"{method} <unmatched>"
        try:
            if method == "GET" and path == "/healthz":
                snap = eng.metrics.snapshot()
                self._reply(200, {"status": "ok", "uptime_s": snap["uptime_s"],
                                  "signals": len(eng.list_signals()),
                                  "cache_entries": len(eng.cache),
                                  "cache_bytes": eng.cache.nbytes,
                                  "builds_in_flight": eng.scheduler.in_flight()})
            elif method == "GET" and path == "/stats":
                self._reply(200, eng.stats())
            elif method == "GET" and path == "/metrics":
                self._reply(200, eng.metrics.render().encode(),
                            content_type="text/plain; version=0.0.4")
            elif method == "POST" and path == "/signals":
                b = self._body()
                info = eng.register_signal(b["name"], _values_from(b, "values"),
                                           replace=bool(b.get("replace", False)))
                self._reply(200, info)
            elif method == "POST" and path == "/ingest":
                b = self._body()
                self._reply(200, eng.ingest_band(b["name"], _values_from(b, "band")))
            elif method == "POST" and path == "/build":
                b = self._body()
                cs, eps_eff, how = eng.get_coreset(
                    b["name"], int(b["k"]), float(b.get("eps", 0.2)))
                self._reply(200, {"fingerprint": cs.fingerprint(),
                                  "size": cs.size, "blocks": cs.num_blocks,
                                  "nbytes": cs.nbytes, "eps_eff": eps_eff,
                                  "compression_ratio": cs.compression_ratio(),
                                  "certified": cs.certified, "cache": how,
                                  "build_seconds": cs.build_seconds})
            elif method == "POST" and path == "/query/loss":
                b = self._body()
                self._reply(200, eng.tree_loss(
                    b["name"], b["rects"], b["labels"],
                    eps=float(b.get("eps", 0.2)),
                    k=int(b["k"]) if "k" in b else None))
            elif method == "POST" and path == "/query/fit":
                b = self._body()
                self._reply(200, eng.fit_forest(
                    b["name"], k=int(b["k"]), eps=float(b.get("eps", 0.2)),
                    n_estimators=int(b.get("n_estimators", 10)),
                    max_leaves=int(b["max_leaves"]) if "max_leaves" in b else None,
                    predict=b.get("predict"), seed=int(b.get("seed", 0))))
            elif method == "POST" and path == "/query/compress":
                b = self._body()
                self._reply(200, eng.compress(
                    b["name"], k=int(b["k"]),
                    eps=float(b["eps"]) if "eps" in b else None,
                    target_frac=float(b["target_frac"]) if "target_frac" in b else None,
                    style=b.get("style", "mean"),
                    max_points=int(b.get("max_points", 4096))))
            else:
                eng.metrics.inc("http_404")
                self._reply(404, {"error": f"no route {route}"})
                return
            eng.metrics.inc("http_200")
        except (KeyError, ValueError, TypeError, json.JSONDecodeError) as exc:
            eng.metrics.inc("http_400")
            self._reply(400, {"error": f"{type(exc).__name__}: {exc}"})
        except Exception as exc:  # pragma: no cover - defensive 500
            eng.metrics.inc("http_500")
            self._reply(500, {"error": f"{type(exc).__name__}: {exc}"})
        finally:
            eng.metrics.observe(f"http {metric_route}", time.perf_counter() - t0)

    def do_GET(self):  # noqa: N802
        self._route("GET")

    def do_POST(self):  # noqa: N802
        self._route("POST")


def make_server(engine: CoresetEngine, host: str = "127.0.0.1",
                port: int = 0) -> ThreadingHTTPServer:
    """Bind a ThreadingHTTPServer to (host, port); port 0 = ephemeral."""
    handler = type("CoresetHandler", (_Handler,), {"engine": engine})
    srv = ThreadingHTTPServer((host, port), handler)
    srv.daemon_threads = True
    return srv


def serve_forever_in_thread(srv: ThreadingHTTPServer) -> threading.Thread:
    t = threading.Thread(target=srv.serve_forever, name="coreset-http",
                         daemon=True)
    t.start()
    return t
