"""CoresetEngine — coreset-as-a-service over named signals.

The serving model (ROADMAP north star, paper §5 use-case):

  * clients **register** signals (dense matrices) or **ingest** row bands
    into an append-only stream;
  * (k, eps)-coresets are built **lazily** on first demand, through the
    batching ``BuildScheduler`` — dense signals fan row bands out via the
    ``core.sharded`` path, streamed signals route through the merge-reduce
    ``StreamingBuilder``;
  * **tree-loss / forest-fit / compression** queries are answered from the
    ``DominanceCache``: any cached (k', eps') coreset with k' >= k and
    eps'_eff <= eps serves the request without a rebuild (the paper's
    "every tree" guarantee as a cache-hit rule).

Every response carries the coreset fingerprint and its honest eps_eff so a
client can tell exactly which guarantee it was served under.
"""
from __future__ import annotations

import collections
import hashlib
import threading
import time

import numpy as np

from repro import obs, ops
from repro.ops import autotune
from repro.core.coreset import SignalCoreset, signal_coreset, signal_coreset_to_size
from repro.core.sharded import (MESH_BACKEND, fitting_loss_batched,
                                sharded_coreset)
from repro.core.streaming import StreamingBuilder
from repro.trees.forest import RandomForestRegressor

from .admission import AdmissionController
from .cache import CacheEntry, DominanceCache, _eps_key, spans_intersect
from .metrics import ServiceMetrics
from .query_scheduler import QueryScheduler
from .scheduler import BuildScheduler

__all__ = ["CoresetEngine", "SignalState", "UnknownSignalError"]


class UnknownSignalError(KeyError):
    """Lookup of a signal name nobody registered — the HTTP layer maps this
    (and only this) KeyError to 404, so stray KeyErrors from bugs still
    surface as 500s instead of masquerading as not_found."""


class _BuilderSlot:
    """A per-(k, eps) StreamingBuilder plus how many of the signal's bands it
    has consumed.  ``lock`` serializes feeding/result; band ranges are claimed
    under the signal lock while holding it, so insertion order always matches
    ingest order."""

    __slots__ = ("builder", "consumed", "lock")

    def __init__(self, builder: StreamingBuilder):
        self.builder = builder
        self.consumed = 0
        self.lock = threading.Lock()


class SignalState:
    """One named signal: dense matrix and/or band stream.

    ``version`` is a running content hash (chained per band), so the cache
    key is well-defined: the same bytes ingested in the same order always
    map to the same version, and any mutation bumps it; a band replacement
    recomputes the same fold over the new band sequence.

    Ingest only appends to ``bands`` (O(1) under the lock); the per-(k, eps)
    merge-reduce builders catch up lazily on the build path, outside this
    lock, so /healthz, /stats and concurrent ingests never stall behind a
    coreset build.

    ``stats`` holds the signal's three integral images — dense signals
    only: materialized once at the first delta write (pinning ~3x the
    signal's bytes is only worth it for signals that mutate), patched
    *incrementally* through the ``repro.ops.delta_sat`` op on every later
    write — O(changed rows) instead of the O(N) from-scratch re-SAT, and
    bitwise identical to one on the f64 oracle — and reused by dense
    builds via :meth:`stats_snapshot`.  Streamed signals build through
    per-band merge-reduce and never read them, so going streamed drops
    them.
    """

    MAX_BUILDERS = 8   # LRU cap: (k, eps) come from client requests, so an
                       # unbounded dict would leak one merge-reduce state per
                       # distinct pair; evicted slots rebuild by band replay

    def __init__(self, name: str):
        self.name = name
        self.lock = threading.RLock()
        self.bands: list[np.ndarray] = []
        self.m: int | None = None
        self.n: int = 0
        self.version = hashlib.blake2b(name.encode(), digest_size=12).hexdigest()
        self.builders: "collections.OrderedDict[tuple[int, float], _BuilderSlot]" = \
            collections.OrderedDict()
        self.streamed = False
        self.stats = None   # lazily-materialized PrefixStats (delta-patched)

    def append(self, band: np.ndarray, *, streamed: bool) -> None:
        band = np.ascontiguousarray(band, np.float64)
        if band.ndim != 2 or band.size == 0:
            raise ValueError("band must be a non-empty 2D array")
        with self.lock:
            if self.m is None:
                self.m = band.shape[1]
            elif band.shape[1] != self.m:
                raise ValueError(f"band has {band.shape[1]} columns, signal has {self.m}")
            old_n = self.n
            self.bands.append(band)
            self.n += band.shape[0]
            self.streamed = self.streamed or streamed or len(self.bands) > 1
            h = hashlib.blake2b(digest_size=12)
            h.update(self.version.encode())
            h.update(band.tobytes())
            self.version = h.hexdigest()
            if self.streamed:
                # only dense builds consume the images; streamed signals
                # build through per-band merge-reduce, so maintaining (and
                # pinning) full-signal stats would be pure waste
                self.stats = None
            elif self.stats is not None:
                # O(band) continuation of the integral images (delta_sat)
                self.stats = self.stats.patch_rows(old_n, band)

    def band_starts(self) -> list[int]:
        starts, r = [], 0
        for b in self.bands:
            starts.append(r)
            r += b.shape[0]
        return starts

    def replace_rows(self, row0: int, band: np.ndarray) -> int | None:
        """Replace rows [row0, row0 + rows) with ``band`` (the delta-ingest
        write path).  Streamed signals require the replacement to align with
        an ingested band (whole-band swap — the merge-reduce leaves map 1:1
        to ingested bands); single-band dense signals accept any in-range
        row window.  Returns the replaced band's index (None for the dense
        in-place case).  Raises ValueError on any misalignment — the HTTP
        layer turns that into the uniform 400 envelope.
        """
        band = np.ascontiguousarray(band, np.float64)
        if band.ndim != 2 or band.size == 0:
            raise ValueError("band must be a non-empty 2D array")
        rows = band.shape[0]
        with self.lock:
            if self.m is None:
                raise ValueError(f"signal {self.name!r} holds no data yet")
            if band.shape[1] != self.m:
                raise ValueError(f"band has {band.shape[1]} columns, "
                                 f"signal has {self.m}")
            if not (0 <= row0 and row0 + rows <= self.n):
                raise ValueError(f"rows [{row0}, {row0 + rows}) outside "
                                 f"signal of {self.n} rows")
            if self.streamed:
                starts = self.band_starts()
                try:
                    idx = starts.index(row0)
                except ValueError:
                    raise ValueError(
                        f"row offset {row0} does not start an ingested band "
                        f"(starts: {starts})") from None
                if self.bands[idx].shape[0] != rows:
                    raise ValueError(
                        f"band {idx} holds {self.bands[idx].shape[0]} rows, "
                        f"replacement has {rows}")
                self.bands[idx] = band
                band_index = idx
                self.stats = None   # streamed: nothing reads the images
            else:
                # single dense band: patch the row window on a FRESH array,
                # never in place — a concurrent build snapshots the previous
                # array under this lock and keeps reading it outside, so an
                # in-place write would tear its data (same reason the stats
                # patch below uses copy=True).  The copy + suffix re-SAT +
                # version refold are the documented dense-replace trade-off
                # (O(N) bandwidth, no O(N) recompute; streamed replaces
                # stay O(band)).
                base = np.array(self.bands[0], np.float64, copy=True)
                base[row0:row0 + rows] = band
                self.bands[0] = base
                band_index = None
            if band_index is None and self.stats is not None:
                # dense only — rows below the patch shift their prefixes
                # too: re-run the delta op over the suffix (copy=True: a
                # concurrent build may still be reading the previous images)
                tail = self.bands[0][row0:]
                self.stats = self.stats.patch_rows(row0, tail, copy=True)
            # version is the same fold appends maintain, over the new bands
            h = hashlib.blake2b(self.name.encode(), digest_size=12)
            version = h.hexdigest()
            for b in self.bands:
                h2 = hashlib.blake2b(digest_size=12)
                h2.update(version.encode())
                h2.update(b.tobytes())
                version = h2.hexdigest()
            self.version = version
        return band_index

    def dense_locked(self) -> np.ndarray:
        if len(self.bands) == 1:
            return self.bands[0]
        return np.concatenate(self.bands, axis=0)

    def dense(self) -> np.ndarray:
        with self.lock:
            return self.dense_locked()

    def stats_snapshot(self, version: str | None = None):
        """The materialized integral images, or None — never materializes.
        Dense builds reuse the images only for signals whose first delta
        write already paid for them: pinning ~3x the signal's bytes on
        every dense signal just in case would not amortize."""
        with self.lock:
            if self.stats is None or self.stats.shape != (self.n, self.m):
                return None
            if version is not None and self.version != version:
                return None
            return self.stats

    def ensure_stats(self, version: str | None = None):
        """Materialize the integral images by chaining ``delta_sat`` over
        the stored bands (bitwise equal to a from-scratch build on the f64
        oracle).  Returns None when ``version`` no longer matches — the
        caller's snapshot went stale and must not mix arrays and stats."""
        with self.lock:
            if version is not None and self.version != version:
                return None
            if self.stats is not None and self.stats.shape == (self.n, self.m):
                return self.stats
            bands = list(self.bands)
            v = self.version
        from repro.core.stats import PrefixStats
        ps = None
        for band in bands:   # outside the lock: O(N) chain, O(band) steps
            ps = PrefixStats.build(band) if ps is None else ps.append_rows(band)
        with self.lock:
            if self.version == v:
                self.stats = ps
        return ps if version in (None, v) else None

    def info(self) -> dict:
        with self.lock:
            return {"name": self.name, "n": self.n, "m": self.m,
                    "bands": len(self.bands), "streamed": self.streamed,
                    "version": self.version,
                    "builders": sorted(self.builders)}


class CoresetEngine:
    MAX_FOREST_CACHE = 32   # fitted forests are MB-scale; keep a small LRU

    def __init__(self, *, cache_bytes: int = 256 << 20, workers: int = 4,
                 num_bands: int = 4, batch_window: float = 0.004,
                 query_window: float = 0.002, query_max_fuse: int = 16,
                 coalesce: bool = True,
                 metrics: ServiceMetrics | None = None, mesh=None,
                 admission: "AdmissionController | None" = None):
        self.metrics = metrics or ServiceMetrics()
        # optional front-door admission control (service/admission.py):
        # consulted by the HTTP layer and the cluster coordinator, never by
        # the engine's own compute paths — admitted work runs bit-identically
        # to an engine without it
        self.admission = admission
        if admission is not None and admission.metrics is None:
            admission.metrics = self.metrics
        self.cache = DominanceCache(cache_bytes, metrics=self.metrics)
        self.scheduler = BuildScheduler(max_workers=workers,
                                        batch_window=batch_window,
                                        metrics=self.metrics)
        # cross-request loss-query coalescing (the BuildScheduler pattern
        # applied to reads); ``coalesce=False`` turns the engine-wide
        # default off, and every query can opt out per-request
        self.queries = QueryScheduler(window=query_window,
                                      max_fuse=query_max_fuse,
                                      max_workers=workers,
                                      metrics=self.metrics)
        self.coalesce_queries = bool(coalesce)
        self.num_bands = int(num_bands)
        self.mesh = mesh   # optional jax mesh for fused batch scoring
        self._signals: dict[str, SignalState] = {}
        self._lock = threading.Lock()
        # fit results are deterministic given (coreset fingerprint,
        # hyperparams, seed): identical re-fits are pure cache hits.
        # value: (fitted forest, train_size)
        self._forests: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self._forests_lock = threading.Lock()
        # last autotune counter values already folded into self.metrics —
        # autotune's counters are process-global monotonic, ServiceMetrics
        # counters are per-engine, so each sync adds only the delta
        self._autotune_synced: dict[str, int] = {}

        # ops-dispatch profiling: the registry's hook seam feeds per-(op,
        # backend, shape-bucket) wall time into THIS engine's metrics, so
        # /metrics and /v1/stats show where dispatches actually go and what
        # they cost — including dispatches made from library code the engine
        # never sees directly (per-band builds, streaming recompression)
        def _on_dispatch(op: str, backend: str, size, seconds: float,
                         _m=self.metrics) -> None:
            bucket = obs.profile.shape_bucket(size)
            _m.inc("ops_dispatch_total", op=op, backend=backend,
                   bucket=bucket)
            sp = obs.current_span()
            _m.observe("ops_dispatch", seconds, op=op, backend=backend,
                       bucket=bucket,
                       exemplar=sp.trace_id if sp else None)

        self._profile_hook = _on_dispatch
        obs.profile.add_hook(self._profile_hook)

    # ---------------------------------------------------------------- ingest
    def register_signal(self, name: str, values: np.ndarray, *,
                        replace: bool = False) -> dict:
        """Register a dense signal under ``name`` (one-shot build path)."""
        # build + validate the full state BEFORE publishing: a malformed
        # payload must neither poison the name nor (with replace) destroy
        # the existing signal
        st = SignalState(name)
        st.append(np.asarray(values, np.float64), streamed=False)
        with self._lock:
            if name in self._signals and not replace:
                raise ValueError(f"signal {name!r} already registered")
            self._signals[name] = st
        # a replaced signal's old-version entries can never serve again
        self.cache.invalidate_signal(name, keep_version=st.version)
        self.metrics.inc("signals_registered")
        return st.info()

    def ingest_band(self, name: str, band: np.ndarray) -> dict:
        """Append a row band to ``name`` (created on first ingest).  O(1):
        the per-(k, eps) StreamingBuilders catch up on the new bands at the
        next build/query, off the ingest path."""
        band = np.asarray(band, np.float64)
        with self._lock:
            st = self._signals.get(name)
            created = st is None
            if created:
                st = SignalState(name)
        with self.metrics.timed("ingest"):
            st.append(band, streamed=True)   # validates; raises before publish
        with self._lock:
            winner = self._signals.setdefault(name, st) if created \
                else self._signals.get(name)
        if winner is not st:
            # lost a creation race, or register_signal(replace=True) swapped
            # the state mid-append: replay into the live signal so the
            # acknowledged write is never silently dropped
            return self.ingest_band(name, band)
        # stale-version entries can never serve again; free their bytes now
        self.cache.invalidate_signal(name, keep_version=st.version)
        self.metrics.inc("bands_ingested")
        return st.info()

    def ingest_delta(self, name: str, band, *, row0: int | None = None,
                     row0s: list | None = None,
                     rows: list | None = None) -> dict:
        """Delta write path: patch an existing signal with only the changed
        rows (``POST /v1/ingest:delta``).

        * ``row0 is None`` (or == current n): append — the stream's normal
          growth, O(band) state update.
        * otherwise: replace rows [row0, row0+rows).  The signal's integral
          images are patched through the dispatched ``delta_sat`` op, live
          merge-reduce builders swap just the affected leaf and mark its
          bucket dirty (``streaming_compress`` recompresses only those), and
          every cache entry the old version held is re-cached under the new
          version — synchronously for streamed specs (a cheap dirty-bucket
          flush), through the BuildScheduler for dense specs (a partition
          re-run does not belong on the write path) — instead of the legacy
          full re-ingest that re-SATs and re-compresses from scratch.

        **Burst form**: ``row0s``/``rows`` describe MANY deltas in one call
        — ``band`` is then the row-wise concatenation of ``len(row0s)``
        bands of ``rows[i]`` rows each, and ``row0s[i]`` places band i
        (None appends).  The per-band leaf ``signal_coreset`` rebuilds of
        every live merge-reduce builder fan out over the QueryScheduler's
        worker pool as ONE batched submission instead of N sequential
        builds, and the whole burst re-caches / recompresses once.

        Unknown signals 404 (a delta against nothing is a client bug, not an
        implicit create); malformed bands raise ValueError -> 400 envelope.
        """
        import contextlib

        band = np.ascontiguousarray(band, np.float64)
        if band.ndim != 2 or band.size == 0:
            raise ValueError("delta band must be a non-empty 2D array")
        if row0s is not None:
            if row0 is not None:
                raise ValueError("pass either row0 or row0s, not both")
            if rows is None or len(rows) != len(row0s) or not row0s:
                raise ValueError("burst needs matching non-empty row0s/rows")
            rows = [int(r) for r in rows]
            if any(r < 1 for r in rows):
                raise ValueError("every burst band needs >= 1 rows")
            if sum(rows) != band.shape[0]:
                raise ValueError(
                    f"rows {rows} sum to {sum(rows)}, band has "
                    f"{band.shape[0]} rows")
            pieces = np.split(band, np.cumsum(rows)[:-1], axis=0)
            deltas = [(None if r0 is None else int(r0), b)
                      for r0, b in zip(row0s, pieces)]
        elif rows is not None:
            raise ValueError("rows requires row0s (the burst form needs both)")
        else:
            deltas = [(None if row0 is None else int(row0), band)]
        st = self.signal(name)
        buckets0 = self._buckets_recompressed(st)
        recached = 0
        # only a true replace reads the integral images; an explicit
        # row0 == n is an append, whose streamed flip would discard them
        if any(r0 is not None and r0 != st.n
               for r0, _ in deltas) and not st.streamed:
            # first dense delta pays the one-off SAT materialization here
            # (outside the heavy lock section); every later replace patches
            # it in O(changed rows) and every later build skips its re-SAT
            st.ensure_stats()
        modes: list[str] = []
        applied: list[int] = []
        replaced: list[tuple[int, np.ndarray]] = []   # (band_index, band)
        dense_replaces = 0
        reanchored = 0
        with self.metrics.timed("ingest_delta"):
            # hold EVERY live builder lock across the mutation + leaf swap
            # (slot.lock before st.lock, the documented order): a concurrent
            # _build_streamed must not snapshot the bumped version while a
            # builder still carries the old leaf — it would cache stale
            # content under the new version.  Slots created concurrently are
            # safe either way: they replay the bands they read under st.lock.
            with st.lock:
                slots = list(st.builders.values())
            with contextlib.ExitStack() as stack:
                for slot in slots:
                    stack.enter_context(slot.lock)
                with st.lock:
                    # a malformed delta must reject the WHOLE burst before
                    # the first mutation: the loop below applies deltas in
                    # place, so a mid-burst validation failure would commit
                    # the earlier writes while skipping the leaf swaps and
                    # cache invalidation that follow (the single-delta path
                    # validates exactly where it applies, so it needs no
                    # pre-flight)
                    if len(deltas) > 1:
                        self._validate_burst_locked(st, deltas)
                    # entries live under the signal's PRE-burst version:
                    # capture their specs before the first mutation bumps it
                    prev_specs = self.cache.specs_for(name, st.version)
                    old_version, old_n = st.version, st.n
                    old_streamed, old_bands = st.streamed, len(st.bands)
                    for r0, b in deltas:
                        # mode decision and placement are atomic with the
                        # write: an explicit row0 == n is an append only if
                        # n still is n
                        if r0 is None or r0 == st.n:
                            modes.append("append")
                            applied.append(st.n)
                            st.append(b, streamed=True)
                            # per-(k, eps) builders consume the new band
                            # lazily at the next build, like /v1/ingest
                        else:
                            modes.append("replace")
                            applied.append(r0)
                            idx = st.replace_rows(r0, b)
                            if idx is not None:
                                replaced.append((idx, b))
                            else:
                                dense_replaces += 1
                    # version after OUR deltas, read under the same lock
                    # hold that applied them — re-anchored entries must be
                    # keyed to exactly this state, not whatever st.version
                    # says after a concurrent writer slips in
                    post_version = st.version
                if replaced:
                    # swap each replaced leaf in every builder that already
                    # consumed it — builders keep their merge-reduce state
                    # instead of a from-scratch replay.  The per-(builder,
                    # band) leaf signal_coreset builds are pure functions of
                    # (band bytes, k, eps): fan them out over the query
                    # scheduler's pool as ONE batched submission, then swap
                    # the finished leaves in under the held locks.
                    swaps = [(slot, idx, b)
                             for slot in slots
                             for idx, b in replaced
                             if slot.consumed > idx]
                    leaves = self.queries.map_fanout(
                        [lambda s=slot, bb=b: signal_coreset(
                            bb, s.builder.k, s.builder.eps)
                         for slot, _, b in swaps])
                    if swaps:
                        self.metrics.inc("ingest_delta_leaf_builds_batched",
                                         len(swaps))
                    for (slot, idx, b), leaf_cs in zip(swaps, leaves):
                        slot.builder.replace_band(idx, b, _leaf_cs=leaf_cs)
                        self.metrics.inc("ingest_delta_rebuilds_avoided")
                if dense_replaces and st.stats is not None:
                    # dense signal: the patched integral images spare the
                    # next build its O(N) re-SAT
                    self.metrics.inc("ingest_delta_rebuilds_avoided",
                                     dense_replaces)
                if (prev_specs and modes == ["append"] and old_streamed
                        and old_bands >= 2 and old_bands % 2 == 0):
                    # re-anchor fast path: a pure append touches rows the
                    # cached blocks provably do not cover, and with an even
                    # prior band count the merge-reduce cascade stays cold,
                    # so the fresh-build result is exactly "cached arrays +
                    # the new band's leaf blocks".  Splice in metadata time
                    # and re-key to the post-append version — no rebuild.
                    # (Builder locks are still held here: the eager feed
                    # below must not race a concurrent _build_streamed.)
                    reanchored = self._reanchor_append(
                        st, slots, old_version, post_version, old_n,
                        deltas[0][1], prev_specs, old_bands)
            if replaced:
                # close the slot-creation window: a slot born between the
                # snapshot above and the version bump may have consumed the
                # OLD band content (the consumed counter cannot see content
                # replacement).  One re-list suffices — slots created after
                # the bump replay the new bands.  Swapping a leaf that
                # already holds the new content is idempotent.
                seen = set(map(id, slots))
                with st.lock:
                    newcomers = [s for s in st.builders.values()
                                 if id(s) not in seen]
                for slot in newcomers:
                    with slot.lock:
                        for idx, b in replaced:
                            if slot.consumed > idx:
                                slot.builder.replace_band(idx, b)
            self.cache.invalidate_signal(name, keep_version=st.version)
            # re-cache what the old version served, under the new version:
            # streamed specs rebuild synchronously (a cheap dirty-bucket
            # recompress + compose); dense specs re-run the partition, so
            # they go through the BuildScheduler off the write path (and
            # coalesce with any concurrent query for the same coreset)
            version = st.version
            if "replace" in modes:
                for k, eps in prev_specs:
                    with st.lock:
                        live = (k, _eps_key(eps)) in st.builders
                    if live:
                        self._build_and_cache(st, version, k, eps)
                    else:
                        self.scheduler.submit(
                            (name, version, k, _eps_key(eps)),
                            lambda k=k, eps=eps: self._build_and_cache(
                                st, version, k, eps))
                    recached += 1
        buckets = self._buckets_recompressed(st) - buckets0
        self.metrics.inc("ingest_delta_bands", len(deltas))
        for mode in modes:
            self.metrics.inc(f"ingest_delta_{mode}s")
        if buckets:
            self.metrics.inc("ingest_delta_buckets_recompressed", buckets)
        if recached:
            self.metrics.inc("ingest_delta_recached", recached)
        info = st.info()
        return {"name": info["name"], "n": info["n"], "m": info["m"],
                "bands": info["bands"], "streamed": info["streamed"],
                "version": info["version"],
                "mode": modes[0] if len(modes) == 1 else "burst",
                "row0": applied[0], "rows": int(band.shape[0]),
                "deltas": len(deltas),
                "buckets_recompressed": int(buckets),
                "entries_recached": int(recached),
                "entries_reanchored": int(reanchored)}

    @staticmethod
    def _validate_burst_locked(st: SignalState, deltas: list) -> None:
        """Pre-flight every delta of a burst against a *simulated* walk of
        the signal's geometry (caller holds ``st.lock``), mirroring the
        checks ``append``/``replace_rows`` make — including appends growing
        ``n`` and flipping the signal streamed mid-burst — so nothing
        mutates unless the whole burst is applicable."""
        n = st.n
        starts = st.band_starts()
        band_rows = [b.shape[0] for b in st.bands]
        streamed = st.streamed
        for r0, b in deltas:
            rows = b.shape[0]
            if st.m is not None and b.shape[1] != st.m:
                raise ValueError(f"band has {b.shape[1]} columns, "
                                 f"signal has {st.m}")
            if r0 is None or r0 == n:
                starts.append(n)
                band_rows.append(rows)
                n += rows
                streamed = True   # delta appends always stream (see loop)
            else:
                if not (0 <= r0 and r0 + rows <= n):
                    raise ValueError(f"rows [{r0}, {r0 + rows}) outside "
                                     f"signal of {n} rows")
                if streamed or len(band_rows) > 1:
                    try:
                        idx = starts.index(r0)
                    except ValueError:
                        raise ValueError(
                            f"row offset {r0} does not start an ingested "
                            f"band (starts: {starts})") from None
                    if band_rows[idx] != rows:
                        raise ValueError(
                            f"band {idx} holds {band_rows[idx]} rows, "
                            f"replacement has {rows}")

    @staticmethod
    def _buckets_recompressed(st: SignalState) -> int:
        with st.lock:
            return sum(s.builder.buckets_recompressed_total
                       for s in st.builders.values())

    # ----------------------------------------------------- cache re-anchoring
    @staticmethod
    def _spliced_coreset(cs: SignalCoreset, leaf: SignalCoreset,
                         row0: int) -> SignalCoreset:
        """Append-splice: the cached composed coreset plus one new band's
        leaf coreset placed at ``row0``, folded EXACTLY as
        ``streaming.compose`` folds its items — so the result is bitwise
        identical to a fresh merge-reduce build of the grown signal.

        Why the fields fold this way: a fresh ``StreamingBuilder.result()``
        over the grown band set composes ``sorted(old bucket items) +
        [new leaf]``.  ``cs`` *is* ``compose(old items)``, and every compose
        fold is associative: eps/max_slices take max, sigma/tolerance take
        min, build_seconds sums, rects/labels/weights/moments concatenate in
        row order (``cs``'s rects are already absolute; the leaf's shift by
        ``row0``), and bicriteria comes from the first item in row order —
        unchanged, since the leaf sorts last.
        """
        rects = leaf.rects.copy()
        rects[:, 0] += row0
        rects[:, 1] += row0
        return SignalCoreset(
            n=int(row0 + leaf.n), m=cs.m, k=cs.k,
            eps=max(cs.eps, leaf.eps),
            rects=np.concatenate([cs.rects, rects], axis=0),
            labels=np.concatenate([cs.labels, leaf.labels], axis=0),
            weights=np.concatenate([cs.weights, leaf.weights], axis=0),
            moments=np.concatenate([cs.moments, leaf.moments], axis=0),
            sigma=min(cs.sigma, leaf.sigma),
            tolerance=min(cs.tolerance, leaf.tolerance),
            max_slices=max(cs.max_slices, leaf.max_slices),
            bicriteria=cs.bicriteria,
            build_seconds=cs.build_seconds + leaf.build_seconds,
            certified=bool(cs.certified and leaf.certified),
        )

    def _reanchor_append(self, st: SignalState, slots: list, old_version: str,
                         new_version: str, old_n: int, band: np.ndarray,
                         prev_specs: list, old_bands: int) -> int:
        """Re-key every old-version cache entry whose blocks are disjoint
        from the appended rows to ``new_version``, splicing in the new
        band's leaf blocks instead of rebuilding (O(entries x spans)
        metadata work + one leaf coreset per cached spec).

        Soundness gate (checked by the caller): the delta is a SINGLE
        append to a streamed signal with an EVEN prior band count.  In the
        merge-reduce binary counter an even count leaves level 0 empty, so
        inserting the new band cascades nothing — no bucket merges, no
        recompression, ``max_level`` (hence eps_eff) unchanged — and a
        fresh build is literally the old composition plus the new leaf.
        Odd counts (or replaces) change bucket contents and fall back to
        invalidate+rebuild.  Per-entry, ``row_spans`` disjointness is
        checked anyway: an entry with unknown provenance must not ride.

        Entries whose spec has a live builder that consumed exactly the
        pre-append bands also feed that builder the prebuilt leaf (caller
        holds the slot locks), so the next ``result()`` is a no-op replay.
        """
        rows = int(band.shape[0])
        taken: list[CacheEntry] = []
        for k, eps in prev_specs:
            entry = self.cache.take(st.name, old_version, k, eps)
            if entry is None:
                continue
            if spans_intersect(entry.row_spans, old_n, old_n + rows):
                # overlapping or unknown provenance: put it back for
                # invalidate_signal to drop (and count as a candidate
                # that fell back to the rebuild path)
                self.cache.put(entry)
                continue
            taken.append(entry)
        if not taken:
            return 0
        with self.metrics.timed("cache_reanchor"):
            # one leaf build per cached (k, eps) spec, batched over the
            # query pool — shared between the splice and the eager feed
            leaves = self.queries.map_fanout(
                [lambda e=e: signal_coreset(band, e.k, e.eps)
                 for e in taken])
            by_spec: dict[tuple, SignalCoreset] = {}
            for entry, leaf in zip(taken, leaves):
                spliced = self._spliced_coreset(entry.coreset, leaf, old_n)
                self.cache.put(CacheEntry(
                    signal=st.name, version=new_version, k=entry.k,
                    eps=entry.eps, eps_eff=entry.eps_eff, coreset=spliced,
                    nbytes=spliced.nbytes,
                    fingerprint=spliced.fingerprint(), hits=entry.hits,
                    build_seconds=float(spliced.build_seconds)))
                by_spec[(entry.k, _eps_key(entry.eps))] = leaf
            with st.lock:
                live = dict(st.builders)
            for slot in slots:
                key = (slot.builder.k, _eps_key(slot.builder.eps))
                leaf = by_spec.get(key)
                # feed only builders exactly at the pre-append state (a
                # lagging builder must replay bands in ingest order; a
                # slot no longer registered is already evicted)
                if (leaf is not None and live.get(key) is slot
                        and slot.consumed == old_bands):
                    slot.builder.insert_band(band, _leaf_cs=leaf)
                    slot.consumed += 1
        self.cache.mark_reanchored(len(taken))
        return len(taken)

    def signal(self, name: str) -> SignalState:
        with self._lock:
            st = self._signals.get(name)
        if st is None:
            raise UnknownSignalError(f"unknown signal {name!r}")
        return st

    def list_signals(self) -> list[dict]:
        with self._lock:
            states = list(self._signals.values())
        return [st.info() for st in states]

    # ----------------------------------------------------------------- build
    @staticmethod
    def _remaining(deadline: float | None,
                   timeout: float | None = None) -> float | None:
        """Seconds left until ``deadline`` (absolute perf_counter instant),
        folded with an optional plain timeout; None = wait forever."""
        if deadline is None:
            return timeout
        rem = max(deadline - time.perf_counter(), 0.0)
        return rem if timeout is None else min(timeout, rem)

    def get_coreset(self, name: str, k: int, eps: float, *,
                    timeout: float | None = None,
                    deadline: float | None = None,
                    ) -> tuple[SignalCoreset, float, str]:
        """Cached-or-built (k, eps)-coreset of the signal's current version.

        Returns (coreset, eps_eff, disposition) with disposition in
        {"exact", "dominated", "built", "coalesced"}.  ``deadline``
        propagates into the BuildScheduler: the build is skipped entirely
        when every waiter's deadline has already expired, and the wait here
        raises TimeoutError (HTTP 504) at the deadline.
        """
        k = int(k)
        eps = float(eps)
        if k < 1:
            raise ValueError("k must be >= 1")
        if not (0.0 < eps < 1.0):
            raise ValueError("eps must be in (0,1)")
        st = self.signal(name)
        version = st.version
        with obs.span("coreset.get", signal=name, k=k) as sp:
            # cache hits are the hot path: record the lookup as attrs on
            # coreset.get and only materialize a cache.lookup span on a
            # miss (the build path, already orders of magnitude slower)
            t0 = time.perf_counter()
            entry, kind = self.cache.lookup(name, version, k, eps)
            if entry is not None:
                sp.set_attr("disposition", kind)
                sp.set_attr("lookup_us",
                            round((time.perf_counter() - t0) * 1e6, 1))
                return entry.coreset, entry.eps_eff, kind
            lk = obs.child_span("cache.lookup",
                                attrs={"outcome": "miss"})
            if lk:
                lk.start_pc = t0
                lk.end()
            key = (name, version, k, _eps_key(eps))
            fut, created = self.scheduler.submit(
                key, lambda: self._build_and_cache(st, version, k, eps),
                deadline=deadline)
            entry = fut.result(timeout=self._remaining(deadline, timeout))
            sp.set_attr("disposition", "built" if created else "coalesced")
        return entry.coreset, entry.eps_eff, "built" if created else "coalesced"

    def _build_and_cache(self, st: SignalState, version: str, k: int,
                         eps: float) -> CacheEntry:
        # close the lookup->submit race: if an identical build finished and
        # was cached after the caller's miss but before this worker ran, the
        # snapshot-version entry is already here — serve it, don't rebuild
        entry, _ = self.cache.lookup(st.name, version, k, eps, record=False)
        if entry is not None:
            return entry
        # the O(Nk) work runs OUTSIDE st.lock (healthz/info/ingest must not
        # stall behind a build); each builder snapshots state under the lock
        # and returns the version its coreset actually corresponds to
        with st.lock:
            streamed = st.streamed
        with obs.span("engine.compress", signal=st.name, k=k,
                      streamed=streamed):
            if streamed:
                cs, eps_eff, version = self._build_streamed(st, k, eps)
            else:
                cs, eps_eff, version = self._build_dense(st, k, eps)
        entry = CacheEntry(
            signal=st.name, version=version, k=k, eps=eps, eps_eff=eps_eff,
            coreset=cs, nbytes=cs.nbytes, fingerprint=cs.fingerprint(),
            build_seconds=float(cs.build_seconds))
        self.cache.put(entry)
        # actual coreset constructions (scheduler's builds_completed counts
        # finished jobs, which include re-lookup short-circuits above)
        self.metrics.inc("coreset_builds")
        return entry

    def _build_dense(self, st: SignalState, k: int, eps: float,
                     ) -> tuple[SignalCoreset, float, str]:
        with st.lock:
            y = st.dense_locked()
            version = st.version
        bands = min(self.num_bands, max(1, y.shape[0] // 32))
        # reuse the delta-patched integral images when a delta write already
        # materialized them (None otherwise, or if the snapshot went stale
        # mid-ingest — then the build derives its own transient stats)
        ps = st.stats_snapshot(version)
        if bands > 1:
            cs = sharded_coreset(y, k, eps, num_bands=bands, _stats=ps)
        else:
            cs = signal_coreset(y, k, eps, _stats=ps)
        return cs, eps, version  # composition of disjoint bands is exact

    @staticmethod
    def _stream_eps_eff(b: StreamingBuilder, eps: float) -> float:
        # each merge level recompresses once: (1+eps)^(L+1) - 1 composed
        return float((1.0 + eps) ** (b.max_level + 1) - 1.0) \
            if b.recompress_levels else eps

    def _build_streamed(self, st: SignalState, k: int, eps: float,
                        ) -> tuple[SignalCoreset, float, str]:
        bk = (k, _eps_key(eps))
        with st.lock:
            slot = st.builders.get(bk)
            if slot is None:
                slot = st.builders[bk] = _BuilderSlot(
                    StreamingBuilder(m=st.m, k=k, eps=eps))
                while len(st.builders) > st.MAX_BUILDERS:
                    st.builders.popitem(last=False)   # LRU slot; replayable
            else:
                st.builders.move_to_end(bk)
        # slot.lock serializes feeders (so bands enter in ingest order) and
        # is taken BEFORE st.lock — never the reverse — so the heavy
        # insert_band cascades run with the signal lock free
        with slot.lock:
            with st.lock:
                missing = list(st.bands[slot.consumed:])
                slot.consumed = len(st.bands)
                version = st.version
            for band in missing:
                slot.builder.insert_band(band)
            cs = slot.builder.result()
            eps_eff = self._stream_eps_eff(slot.builder, eps)
        return cs, eps_eff, version

    # --------------------------------------------------------------- queries
    def tree_loss(self, name: str, seg_rects, seg_labels, *,
                  eps: float = 0.2, k: int | None = None,
                  timeout: float | None = None,
                  deadline: float | None = None,
                  coalesce: bool = True) -> dict:
        """Algorithm-5 loss of a k-segmentation, served from cache.

        ``k`` defaults to the query's leaf count — the smallest coreset
        parameter whose guarantee covers this tree.

        By default the evaluation routes through the :class:`QueryScheduler`
        so concurrent same-signal queries from different connections fuse
        into one ``fitting_loss_batched`` dispatch; ``coalesce=False`` (or
        an engine built with ``coalesce=False``) is the escape hatch that
        scores inline, exactly like the pre-coalescing path.
        """
        seg_rects = np.asarray(seg_rects, np.int64).reshape(-1, 4)
        seg_labels = np.asarray(seg_labels, np.float64).ravel()
        if seg_rects.shape[0] != seg_labels.shape[0]:
            raise ValueError("rects/labels length mismatch")
        k = int(k) if k is not None else int(seg_rects.shape[0])
        with obs.span("engine.tree_loss", signal=name, k=k,
                      coalesce=bool(coalesce and self.coalesce_queries)), \
                self.metrics.timed("query_loss"):
            cs, eps_eff, how = self.get_coreset(name, k, eps, timeout=timeout,
                                                deadline=deadline)
            fp = cs.fingerprint()   # hashes the coreset arrays: once per query
            if coalesce and self.coalesce_queries:
                # fusion key: only queries that score against the SAME
                # cached coreset on the SAME backend may share a dispatch
                # (mixed-k queries resolve different coresets — never fused).
                # The backend is selected at T=1, i.e. what THIS query would
                # run alone, deliberately: fusing must never size-promote a
                # query off the f64 numpy oracle onto an f32 path (the
                # coalesce gate's <=1e-9 parity vs the uncoalesced path
                # depends on it), and on TPU — where the T axis pays — the
                # capability rule selects pallas at any size anyway
                backend = ops.selected_backend(
                    "fitting_loss_batched",
                    ops.fitting_loss_batched_size(cs, seg_rects[None]))
                key = (fp, k, _eps_key(eps), backend)

                def execute(rects3, labels2, _cs=cs, _backend=backend):
                    self.metrics.inc("loss_scoring_calls")  # ONE per fusion
                    self.metrics.inc(f"ops_backend_{_backend}")
                    return ops.fitting_loss_batched(_cs, rects3, labels2,
                                                    backend=_backend)

                fut = self.queries.submit(key, seg_rects, seg_labels, execute,
                                          deadline=deadline)
                loss, fused = fut.result(
                    timeout=self._remaining(deadline, timeout))
            else:
                # resolve once, dispatch with the same choice: the reported
                # backend is by construction the one that served the query
                backend = ops.selected_backend(
                    "fitting_loss", ops.fitting_loss_size(cs, seg_rects))
                loss = ops.fitting_loss(cs, seg_rects, seg_labels,
                                        backend=backend)
                fused = 1
                self.metrics.inc("loss_scoring_calls")
                self.metrics.inc(f"ops_backend_{backend}")
        self.metrics.inc("queries_loss")
        return {"loss": float(loss), "k": k, "eps": eps, "eps_eff": eps_eff,
                "served_from": how, "fingerprint": fp,
                "coreset_size": cs.size, "backend": backend,
                "fused_batch_size": int(fused)}

    def tree_loss_batch(self, name: str, seg_rects, seg_labels, *,
                        eps: float = 0.2, k: int | None = None,
                        timeout: float | None = None,
                        deadline: float | None = None,
                        coalesce: bool = True) -> dict:
        """Fused Algorithm-5 loss for T same-signal segmentations.

        ``seg_rects`` (T, K, 4) / ``seg_labels`` (T, K) score against ONE
        cached coreset through the dispatched batched op
        (``core.sharded.fitting_loss_batched`` — the ``repro.ops`` backend
        rules when no mesh, blocks sharded over ``self.mesh`` when one is
        configured): a single engine scoring call replaces T sequential
        ``tree_loss`` evaluations — the tuning-sweep inner loop served as
        one request.

        With coalescing on (and no mesh), the batch enqueues into the SAME
        QueryScheduler fusion bucket single ``tree_loss`` queries use — a
        tuning sweep's batch and the interactive singles against the same
        hot coreset merge into one dispatch instead of two.
        """
        seg_rects = np.asarray(seg_rects, np.int64)
        seg_labels = np.asarray(seg_labels, np.float64)
        if seg_rects.ndim != 3 or seg_rects.shape[-1] != 4:
            raise ValueError("batch rects must have shape (T, K, 4)")
        if seg_labels.shape != seg_rects.shape[:2]:
            raise ValueError("batch labels must have shape (T, K)")
        if seg_rects.shape[0] < 1:
            raise ValueError("batch must contain at least one segmentation")
        T = int(seg_rects.shape[0])
        k = int(k) if k is not None else int(seg_rects.shape[1])
        with obs.span("engine.tree_loss_batch", signal=name, k=k,
                      batch=T,
                      coalesce=bool(coalesce and self.coalesce_queries
                                    and self.mesh is None)), \
                self.metrics.timed("query_loss_batch"):
            cs, eps_eff, how = self.get_coreset(name, k, eps, timeout=timeout,
                                                deadline=deadline)
            fp = cs.fingerprint()
            fused = T
            if self.mesh is not None:
                # shard_map'd batched Pallas kernel + one psum (core.sharded)
                backend = MESH_BACKEND
                losses = fitting_loss_batched(cs, seg_rects, seg_labels,
                                              mesh=self.mesh)
                self.metrics.inc("loss_scoring_calls")
                self.metrics.inc(f"ops_backend_{backend}")
            elif coalesce and self.coalesce_queries:
                # same fusion key as tree_loss: backend selected at T=1 so
                # a batch never lands in a different bucket than the singles
                # it should fuse with (and never size-promotes co-travelling
                # singles off the f64 oracle — the coalesce parity gate)
                backend = ops.selected_backend(
                    "fitting_loss_batched",
                    ops.fitting_loss_batched_size(cs, seg_rects[:1]))
                key = (fp, k, _eps_key(eps), backend)

                def execute(rects3, labels2, _cs=cs, _backend=backend):
                    self.metrics.inc("loss_scoring_calls")  # ONE per fusion
                    self.metrics.inc(f"ops_backend_{_backend}")
                    return ops.fitting_loss_batched(_cs, rects3, labels2,
                                                    backend=_backend)

                fut = self.queries.submit_batch(key, seg_rects, seg_labels,
                                                execute, deadline=deadline)
                losses, fused = fut.result(
                    timeout=self._remaining(deadline, timeout))
            else:
                # resolve once, dispatch with the same choice (see tree_loss)
                backend = ops.selected_backend(
                    "fitting_loss_batched",
                    ops.fitting_loss_batched_size(cs, seg_rects))
                losses = fitting_loss_batched(cs, seg_rects, seg_labels,
                                              backend=backend)
                self.metrics.inc("loss_scoring_calls")
                self.metrics.inc(f"ops_backend_{backend}")
        self.metrics.inc("queries_loss_batch")
        self.metrics.inc("queries_loss_batch_items", T)
        return {"losses": np.asarray(losses, np.float64),
                "k": k, "eps": eps, "eps_eff": eps_eff, "served_from": how,
                "fingerprint": fp, "coreset_size": cs.size,
                "scoring_calls": 1, "backend": backend,
                "fused_batch_size": int(fused)}

    def fit_forest(self, name: str, *, k: int, eps: float = 0.2,
                   n_estimators: int = 10, max_leaves: int | None = None,
                   predict: np.ndarray | None = None, seed: int = 0,
                   timeout: float | None = None,
                   deadline: float | None = None) -> dict:
        """Train a weighted random forest on the coreset points (§5 solver
        stand-in); optionally evaluate it at ``predict`` (P, 2) grid points."""
        with obs.span("engine.fit_forest", signal=name, k=int(k)), \
                self.metrics.timed("query_fit"):
            cs, eps_eff, how = self.get_coreset(name, k, eps, timeout=timeout,
                                                deadline=deadline)
            fkey = (cs.fingerprint(), int(n_estimators),
                    int(max_leaves or k), int(seed))
            with self._forests_lock:
                cached = self._forests.get(fkey)
                if cached is not None:
                    self._forests.move_to_end(fkey)
            model_cache = "hit"
            if cached is None:
                # materialize the point set only on a miss — a cache hit
                # must not pay the O(|C|) as_points() build
                model_cache = "fit"
                X, y, w = cs.as_points()
                forest = RandomForestRegressor(
                    n_estimators=n_estimators, max_leaves=max_leaves or k,
                    random_state=seed)
                forest.fit(X, y, sample_weight=w)
                cached = (forest, int(len(y)))
                with self._forests_lock:
                    # a racing fit of the same key produced an identical
                    # forest (deterministic given fkey); last writer wins
                    self._forests[fkey] = cached
                    while len(self._forests) > self.MAX_FOREST_CACHE:
                        self._forests.popitem(last=False)
            forest, train_size = cached
            self.metrics.inc(f"forest_cache_{model_cache}")
            out = {"k": k, "eps": eps, "eps_eff": eps_eff, "served_from": how,
                   "train_size": train_size, "n_estimators": n_estimators,
                   "fingerprint": cs.fingerprint(), "model_cache": model_cache}
            if predict is not None:
                pts = np.asarray(predict, np.float64).reshape(-1, 2)
                out["predictions"] = forest.predict(pts).tolist()
        self.metrics.inc("queries_fit")
        return out

    def compress(self, name: str, *, k: int, eps: float | None = None,
                 target_frac: float | None = None, style: str = "mean",
                 max_points: int = 4096, timeout: float | None = None,
                 deadline: float | None = None) -> dict:
        """Compression query: the weighted point set itself (paper Fig 4).

        ``target_frac`` bisects the block tolerance to a size target (dense
        signals only — it re-runs the partition, so it bypasses the cache);
        otherwise the cached (k, eps)-coreset is served.
        """
        with obs.span("engine.compress_query", signal=name, k=int(k)), \
                self.metrics.timed("query_compress"):
            if target_frac is not None:
                st = self.signal(name)
                with st.lock:
                    y = st.dense()
                cs = signal_coreset_to_size(y, k, float(target_frac))
                eps_eff, how = cs.eps, "built"
            else:
                cs, eps_eff, how = self.get_coreset(name, k, eps or 0.2,
                                                    timeout=timeout,
                                                    deadline=deadline)
            X, y, w = cs.as_points(style=style)
            out = {"k": k, "eps_eff": eps_eff, "served_from": how, "size": cs.size,
                   "blocks": cs.num_blocks, "nbytes": cs.nbytes,
                   "compression_ratio": cs.compression_ratio(),
                   "fingerprint": cs.fingerprint(), "truncated": len(y) > max_points}
            keep = slice(0, max_points)
            out["points"] = {"X": X[keep].tolist(), "y": y[keep].tolist(),
                             "w": w[keep].tolist()}
        self.metrics.inc("queries_compress")
        return out

    # ------------------------------------------------------------- lifecycle
    def sync_autotune_metrics(self) -> None:
        """Fold the autotune module's process-global counters into this
        engine's metrics as ``ops_autotune_*`` (delta since last sync), so
        the Prometheus render and /v1/stats expose cache hit/miss, tune
        runs, and promoted-to-compensated-f32 dispatch counts next to the
        ``ops_backend_*`` series."""
        for name, val in autotune.counters_snapshot().items():
            delta = int(val) - self._autotune_synced.get(name, 0)
            # a zero delta still registers the family, so the very first
            # scrape sees every ops_autotune_* series (at 0) rather than
            # the family popping into existence mid-run
            self.metrics.inc(f"ops_autotune_{name}", max(delta, 0))
            self._autotune_synced[name] = int(val)

    def stats(self) -> dict:
        self.sync_autotune_metrics()
        return {"signals": self.list_signals(), "cache": self.cache.stats(),
                "builds_in_flight": self.scheduler.in_flight(),
                "queries_in_flight": self.queries.in_flight(),
                "query_coalescing": {
                    "enabled": self.coalesce_queries,
                    "window_s": self.queries.window,
                    "max_fuse": self.queries.max_fuse},
                "ops_backends": ops.snapshot(),
                "ops_autotune": autotune.snapshot(),
                "tracing": obs.TRACER.stats(),
                "admission": ({**self.admission.snapshot(),
                               "scheduler_load": {
                                   "builds": self.scheduler.load(),
                                   "queries": self.queries.load()}}
                              if self.admission is not None
                              else {"enabled": False}),
                "metrics": self.metrics.snapshot()}

    def close(self) -> None:
        # drain queries first: a queued loss query may still need the cache
        # and ops dispatch, both of which outlive the schedulers
        self.queries.shutdown()
        self.scheduler.shutdown()
        obs.profile.remove_hook(self._profile_hook)
