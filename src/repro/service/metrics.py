"""Service telemetry: counters + log-bucketed latency histograms.

Stdlib-only (the serving layer must run in a bare container), thread-safe,
and renderable both as JSON (``snapshot`` — the /stats endpoint) and as
Prometheus text exposition (``render`` — the /metrics endpoint), so the
engine can sit behind a standard scrape without extra dependencies.
"""
from __future__ import annotations

import re
import threading
import time

__all__ = ["Histogram", "ServiceMetrics"]


# Geometric bucket bounds: 100us .. ~100s, x2 per bucket (21 buckets + inf).
_BOUNDS = tuple(1e-4 * 2.0 ** i for i in range(21))


class Histogram:
    """Histogram over fixed bucket bounds.  Defaults to the geometric
    latency buckets (seconds); pass ``bounds``/``unit`` for other scales —
    e.g. the fused-batch-size histogram uses powers of two and no unit."""

    __slots__ = ("bounds", "unit", "counts", "count", "sum", "max")

    def __init__(self, bounds: tuple = _BOUNDS, unit: str = "seconds") -> None:
        self.bounds = tuple(bounds)
        self.unit = unit
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, value: float) -> None:
        i = 0
        while i < len(self.bounds) and value > self.bounds[i]:
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.sum += value
        if value > self.max:
            self.max = value

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the q-quantile."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return self.bounds[i] if i < len(self.bounds) else self.max
        return self.max

    def snapshot(self) -> dict:
        mean = self.sum / self.count if self.count else 0.0
        # suffix the JSON keys with the unit only for the seconds default,
        # so existing dashboards keep their p50_s fields
        sfx = "_s" if self.unit == "seconds" else ""
        return {"count": self.count, f"mean{sfx}": mean,
                f"p50{sfx}": self.quantile(0.5), f"p90{sfx}": self.quantile(0.9),
                f"p99{sfx}": self.quantile(0.99), f"max{sfx}": self.max}


class ServiceMetrics:
    """Named counters and histograms behind one lock (contention is tiny
    relative to the numpy work per request)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._hists: dict[str, Histogram] = {}
        self.started_at = time.time()

    # --------------------------------------------------------------- writers
    def inc(self, name: str, by: int = 1, **labels) -> None:
        """Bump a counter.  ``labels`` dimensions the metric the Prometheus
        way — ``inc("query_flushes", reason="window")`` is stored (and
        rendered) as ``query_flushes{reason="window"}``."""
        if labels:
            body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
            name = f"{name}{{{body}}}"
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + by

    def observe(self, name: str, value: float, *, bounds: tuple | None = None,
                unit: str | None = None) -> None:
        """Record a histogram sample.  ``bounds``/``unit`` apply on first
        observation of ``name`` (latency seconds by default)."""
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                kw = {}
                if bounds is not None:
                    kw["bounds"] = bounds
                if unit is not None:
                    kw["unit"] = unit
                h = self._hists[name] = Histogram(**kw)
            h.observe(value)

    def timed(self, name: str):
        """Context manager: observe the elapsed wall time under ``name``."""
        return _Timer(self, name)

    def get(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    # --------------------------------------------------------------- readers
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "uptime_s": time.time() - self.started_at,
                "counters": dict(self._counters),
                "latency": {k: h.snapshot() for k, h in self._hists.items()},
            }

    def render(self) -> str:
        """Prometheus text exposition format.  Metric names must match
        [a-zA-Z_:][a-zA-Z0-9_:]* — route-derived names ("http GET /healthz")
        are sanitized here so one bad name can't invalidate the whole scrape
        body; snapshot() keeps the readable originals.  Labeled counters
        (``name{key="value"}``) sanitize only the name part and pass the
        label body through; all series of one labeled family share a single
        # TYPE header, as the exposition format requires."""
        san = lambda n: re.sub(r"[^a-zA-Z0-9_:]", "_", n)  # noqa: E731
        lines = []
        typed: set[str] = set()
        with self._lock:
            for name, v in sorted(self._counters.items()):
                base, brace, labels = name.partition("{")
                base = san(base)
                if base not in typed:
                    typed.add(base)
                    lines.append(f"# TYPE coreset_{base} counter")
                lines.append(f"coreset_{base}{brace}{labels} {v}")
            for name, h in sorted(self._hists.items()):
                sfx = f"_{san(h.unit)}" if h.unit else ""
                base = f"coreset_{san(name)}{sfx}"
                lines.append(f"# TYPE {base} histogram")
                acc = 0
                for bound, c in zip(h.bounds, h.counts):
                    acc += c
                    lines.append(f'{base}_bucket{{le="{bound:g}"}} {acc}')
                lines.append(f'{base}_bucket{{le="+Inf"}} {h.count}')
                lines.append(f"{base}_sum {h.sum:g}")
                lines.append(f"{base}_count {h.count}")
        return "\n".join(lines) + "\n"


class _Timer:
    __slots__ = ("_m", "_name", "_t0")

    def __init__(self, metrics: ServiceMetrics, name: str):
        self._m = metrics
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._m.observe(self._name, time.perf_counter() - self._t0)
        return False
