"""Service telemetry: counters + log-bucketed latency histograms.

Stdlib-only (the serving layer must run in a bare container), thread-safe,
and renderable both as JSON (``snapshot`` — the /stats endpoint) and as
Prometheus text exposition (``render`` — the /metrics endpoint), so the
engine can sit behind a standard scrape without extra dependencies.

Both counters and histograms take Prometheus-style labels
(``inc("query_flushes", reason="window")``,
``observe("ops_dispatch", dt, op="fitting_loss", backend="numpy")``); label
*values* are escaped per the exposition spec (``\\`` -> ``\\\\``, ``"`` ->
``\\"``, newline -> ``\\n``) so a hostile or merely unlucky value cannot
corrupt the whole scrape body.  All series of one labeled family render
under a single ``# TYPE`` header, grouped contiguously.

Histogram buckets may carry an **exemplar**: the most recent trace id that
landed in that bucket, rendered OpenMetrics-style
(``..._bucket{le="0.1"} 5 # {trace_id="<id>"} 0.07``) — a p99 bucket links
to a concrete retrievable trace instead of an anonymous aggregate.

Uptime reads the monotonic clock (an NTP step must not make ``uptime_s``
jump); ``started_at`` remains the wall-clock epoch for display.
"""
from __future__ import annotations

import re
import threading
import time

__all__ = ["Histogram", "ServiceMetrics", "escape_label_value"]


# Geometric bucket bounds: 100us .. ~100s, x2 per bucket (21 buckets + inf).
_BOUNDS = tuple(1e-4 * 2.0 ** i for i in range(21))

_san = lambda n: re.sub(r"[^a-zA-Z0-9_:]", "_", n)  # noqa: E731


def escape_label_value(value) -> str:
    """Escape a label value per the Prometheus exposition format: backslash
    first (an already-escaped quote must not double-escape), then quote and
    newline."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labels_key(labels: dict) -> str:
    """Canonical ``name{...}`` suffix for a label set (sorted, escaped)."""
    body = ",".join(f'{k}="{escape_label_value(v)}"'
                    for k, v in sorted(labels.items()))
    return f"{{{body}}}"


class Histogram:
    """Histogram over fixed bucket bounds.  Defaults to the geometric
    latency buckets (seconds); pass ``bounds``/``unit`` for other scales —
    e.g. the fused-batch-size histogram uses powers of two and no unit.
    Each bucket remembers the last exemplar (trace id, value) observed
    into it."""

    __slots__ = ("bounds", "unit", "counts", "count", "sum", "max",
                 "exemplars")

    def __init__(self, bounds: tuple = _BOUNDS, unit: str = "seconds") -> None:
        self.bounds = tuple(bounds)
        self.unit = unit
        self.counts = [0] * (len(self.bounds) + 1)
        self.exemplars: list[tuple[str, float] | None] = \
            [None] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, value: float, exemplar: str | None = None) -> None:
        i = 0
        while i < len(self.bounds) and value > self.bounds[i]:
            i += 1
        self.counts[i] += 1
        if exemplar is not None:
            self.exemplars[i] = (exemplar, value)
        self.count += 1
        self.sum += value
        if value > self.max:
            self.max = value

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the q-quantile."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return self.bounds[i] if i < len(self.bounds) else self.max
        return self.max

    def snapshot(self) -> dict:
        mean = self.sum / self.count if self.count else 0.0
        # suffix the JSON keys with the unit only for the seconds default,
        # so existing dashboards keep their p50_s fields
        sfx = "_s" if self.unit == "seconds" else ""
        return {"count": self.count, f"mean{sfx}": mean,
                f"p50{sfx}": self.quantile(0.5), f"p90{sfx}": self.quantile(0.9),
                f"p99{sfx}": self.quantile(0.99), f"max{sfx}": self.max}


class ServiceMetrics:
    """Named counters and histograms behind one lock (contention is tiny
    relative to the numpy work per request)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}
        self.started_at = time.time()       # wall clock, display only
        self._started_mono = time.monotonic()  # uptime source (NTP-immune)

    # --------------------------------------------------------------- writers
    def inc(self, name: str, by: int = 1, **labels) -> None:
        """Bump a counter.  ``labels`` dimensions the metric the Prometheus
        way — ``inc("query_flushes", reason="window")`` is stored (and
        rendered) as ``query_flushes{reason="window"}`` with the value
        escaped per the exposition spec."""
        if labels:
            name = f"{name}{_labels_key(labels)}"
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + by

    def set_gauge(self, name: str, value: float, **labels) -> None:
        """Set a gauge to an absolute value (last write wins) — liveness
        flags and level readings that go *down* as well as up, e.g.
        ``set_gauge("cluster_worker_up", 1, worker="w0")``.  Labels
        dimension the family exactly like :meth:`inc`."""
        if labels:
            name = f"{name}{_labels_key(labels)}"
        with self._lock:
            self._gauges[name] = float(value)

    def get_gauge(self, name: str, **labels) -> float | None:
        if labels:
            name = f"{name}{_labels_key(labels)}"
        with self._lock:
            return self._gauges.get(name)

    def observe(self, name: str, value: float, *, bounds: tuple | None = None,
                unit: str | None = None, exemplar: str | None = None,
                **labels) -> None:
        """Record a histogram sample.  ``bounds``/``unit`` apply on first
        observation of ``name`` (latency seconds by default); ``labels``
        dimension the family like :meth:`inc`; ``exemplar`` attaches a
        trace id to the bucket the sample lands in."""
        if labels:
            name = f"{name}{_labels_key(labels)}"
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                kw = {}
                if bounds is not None:
                    kw["bounds"] = bounds
                if unit is not None:
                    kw["unit"] = unit
                h = self._hists[name] = Histogram(**kw)
            h.observe(value, exemplar)

    def timed(self, name: str):
        """Context manager: observe the elapsed wall time under ``name``."""
        return _Timer(self, name)

    def get(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    # --------------------------------------------------------------- readers
    def uptime_s(self) -> float:
        return time.monotonic() - self._started_mono

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                "uptime_s": time.monotonic() - self._started_mono,
                "counters": dict(self._counters),
                "latency": {k: h.snapshot() for k, h in self._hists.items()},
            }
            if self._gauges:
                out["gauges"] = dict(self._gauges)
            return out

    def render(self) -> str:
        """Prometheus text exposition format.  Metric names must match
        [a-zA-Z_:][a-zA-Z0-9_:]* — route-derived names ("http GET /healthz")
        are sanitized here so one bad name can't invalidate the whole scrape
        body; snapshot() keeps the readable originals.  Series are grouped
        per family with exactly one # TYPE header each (sorting alone does
        not guarantee contiguity: "f_total" sorts between "f" and "f{...}"),
        and label bodies pass through verbatim — values were escaped at
        write time."""
        counter_fams: dict[str, list[tuple[str, int]]] = {}
        gauge_fams: dict[str, list[tuple[str, float]]] = {}
        hist_fams: dict[str, list[tuple[str, Histogram]]] = {}
        with self._lock:
            for name, v in sorted(self._counters.items()):
                base, brace, labels = name.partition("{")
                fam = f"coreset_{_san(base)}"
                counter_fams.setdefault(fam, []).append(
                    (brace + labels, v))
            for name, g in sorted(self._gauges.items()):
                base, brace, labels = name.partition("{")
                fam = f"coreset_{_san(base)}"
                gauge_fams.setdefault(fam, []).append((brace + labels, g))
            for name, h in sorted(self._hists.items()):
                base, brace, labels = name.partition("{")
                sfx = f"_{_san(h.unit)}" if h.unit else ""
                fam = f"coreset_{_san(base)}{sfx}"
                hist_fams.setdefault(fam, []).append((labels[:-1], h))
            lines = []
            for fam, series in counter_fams.items():
                lines.append(f"# TYPE {fam} counter")
                for labels, v in series:
                    lines.append(f"{fam}{labels} {v}")
            for fam, series in gauge_fams.items():
                lines.append(f"# TYPE {fam} gauge")
                for labels, g in series:
                    lines.append(f"{fam}{labels} {g:g}")
            for fam, series in hist_fams.items():
                lines.append(f"# TYPE {fam} histogram")
                for labels, h in series:
                    pre = f"{labels}," if labels else ""
                    acc = 0
                    for i, (bound, c) in enumerate(zip(h.bounds, h.counts)):
                        acc += c
                        line = f'{fam}_bucket{{{pre}le="{bound:g}"}} {acc}'
                        ex = h.exemplars[i]
                        if ex is not None:
                            line += (f' # {{trace_id="'
                                     f'{escape_label_value(ex[0])}"}} '
                                     f"{ex[1]:g}")
                        lines.append(line)
                    line = f'{fam}_bucket{{{pre}le="+Inf"}} {h.count}'
                    ex = h.exemplars[-1]
                    if ex is not None:
                        line += (f' # {{trace_id="'
                                 f'{escape_label_value(ex[0])}"}} {ex[1]:g}')
                    lines.append(line)
                    br = f"{{{labels}}}" if labels else ""
                    lines.append(f"{fam}_sum{br} {h.sum:g}")
                    lines.append(f"{fam}_count{br} {h.count}")
        return "\n".join(lines) + "\n"


class _Timer:
    __slots__ = ("_m", "_name", "_t0")

    def __init__(self, metrics: ServiceMetrics, name: str):
        self._m = metrics
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._m.observe(self._name, time.perf_counter() - self._t0)
        return False
