"""Service telemetry: counters + log-bucketed latency histograms.

Stdlib-only (the serving layer must run in a bare container), thread-safe,
and renderable both as JSON (``snapshot`` — the /stats endpoint) and as
Prometheus text exposition (``render`` — the /metrics endpoint), so the
engine can sit behind a standard scrape without extra dependencies.
"""
from __future__ import annotations

import re
import threading
import time

__all__ = ["Histogram", "ServiceMetrics"]


# Geometric bucket bounds: 100us .. ~100s, x2 per bucket (21 buckets + inf).
_BOUNDS = tuple(1e-4 * 2.0 ** i for i in range(21))


class Histogram:
    """Latency histogram over fixed geometric buckets (seconds)."""

    __slots__ = ("counts", "count", "sum", "max")

    def __init__(self) -> None:
        self.counts = [0] * (len(_BOUNDS) + 1)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, seconds: float) -> None:
        i = 0
        while i < len(_BOUNDS) and seconds > _BOUNDS[i]:
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.sum += seconds
        if seconds > self.max:
            self.max = seconds

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the q-quantile."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return _BOUNDS[i] if i < len(_BOUNDS) else self.max
        return self.max

    def snapshot(self) -> dict:
        mean = self.sum / self.count if self.count else 0.0
        return {"count": self.count, "mean_s": mean, "p50_s": self.quantile(0.5),
                "p90_s": self.quantile(0.9), "p99_s": self.quantile(0.99),
                "max_s": self.max}


class ServiceMetrics:
    """Named counters and histograms behind one lock (contention is tiny
    relative to the numpy work per request)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._hists: dict[str, Histogram] = {}
        self.started_at = time.time()

    # --------------------------------------------------------------- writers
    def inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + by

    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.observe(seconds)

    def timed(self, name: str):
        """Context manager: observe the elapsed wall time under ``name``."""
        return _Timer(self, name)

    def get(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    # --------------------------------------------------------------- readers
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "uptime_s": time.time() - self.started_at,
                "counters": dict(self._counters),
                "latency": {k: h.snapshot() for k, h in self._hists.items()},
            }

    def render(self) -> str:
        """Prometheus text exposition format.  Metric names must match
        [a-zA-Z_:][a-zA-Z0-9_:]* — route-derived names ("http GET /healthz")
        are sanitized here so one bad name can't invalidate the whole scrape
        body; snapshot() keeps the readable originals."""
        san = lambda n: re.sub(r"[^a-zA-Z0-9_:]", "_", n)  # noqa: E731
        lines = []
        with self._lock:
            for name, v in sorted(self._counters.items()):
                name = san(name)
                lines.append(f"# TYPE coreset_{name} counter")
                lines.append(f"coreset_{name} {v}")
            for name, h in sorted(self._hists.items()):
                base = f"coreset_{san(name)}_seconds"
                lines.append(f"# TYPE {base} histogram")
                acc = 0
                for bound, c in zip(_BOUNDS, h.counts):
                    acc += c
                    lines.append(f'{base}_bucket{{le="{bound:g}"}} {acc}')
                lines.append(f'{base}_bucket{{le="+Inf"}} {h.count}')
                lines.append(f"{base}_sum {h.sum:g}")
                lines.append(f"{base}_count {h.count}")
        return "\n".join(lines) + "\n"


class _Timer:
    __slots__ = ("_m", "_name", "_t0")

    def __init__(self, metrics: ServiceMetrics, name: str):
        self._m = metrics
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._m.observe(self._name, time.perf_counter() - self._t0)
        return False
