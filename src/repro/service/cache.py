"""Byte-budgeted LRU coreset cache with dominance reuse.

The paper's headline guarantee is *uniform over queries*: one (k, eps)-
coreset answers ell(D, s) for EVERY tree s of at most k leaves within
1 +/- eps.  Turned into a cache rule: a cached coreset built at (k', eps')
with  k' >= k  and  eps'_effective <= eps  is a valid answer source for a
(k, eps) request on the same signal version — no rebuild needed.  This is
what makes a coreset server amortize: the first tuning sweep pays O(Nk),
every later request (smaller trees, looser tolerances) is a cache hit.

``eps_eff`` is the entry's honest guarantee: equal to the requested eps for
one-shot and sharded-compose builds (composition is exact, streaming.py),
and the composed (1+eps)^(levels+1) - 1 bound for merge-reduce streaming
builds — dominance compares against eps_eff, never the nominal eps, so a
recompressed streamed coreset is not claimed tighter than it is.

Entries are keyed by (signal, version, k, eps); ``version`` is a content
hash maintained by the engine (a new ingested band bumps it), so stale
coresets can never serve a mutated signal.

Eviction is cost-aware (GDSF — greedy-dual size-frequency) over a byte
budget: an entry's priority is

    priority = clock + (1 + hits) * max(build_seconds, floor) / nbytes

and overflow evicts the minimum-priority entry.  ``build_seconds / nbytes``
is the rebuild cost per cached byte (an expensive O(Nk) build that
compressed well is the most valuable thing in the cache), ``hits`` folds in
frequency, and the ``clock`` — advanced to each victim's priority — ages
out entries that stop being touched, so a once-hot expensive coreset still
drains away under pressure.  Priorities refresh on every hit and insert.
"""
from __future__ import annotations

import collections
import dataclasses
import threading

from repro.core.coreset import SignalCoreset

from .metrics import ServiceMetrics

__all__ = ["CacheEntry", "DominanceCache"]


def _eps_key(eps: float) -> float:
    return round(float(eps), 6)


@dataclasses.dataclass
class CacheEntry:
    signal: str
    version: str
    k: int
    eps: float            # requested eps (exact-match key component)
    eps_eff: float        # honest guarantee after composition layers
    coreset: SignalCoreset
    nbytes: int
    fingerprint: str
    hits: int = 0
    build_seconds: float = 0.0   # construction cost, recorded at insert;
                                 # weighed against nbytes + recency by the
                                 # GDSF eviction policy
    priority: float = 0.0        # GDSF score, maintained by DominanceCache

    @property
    def key(self) -> tuple:
        return (self.signal, self.version, self.k, _eps_key(self.eps))


class DominanceCache:
    """Byte-budgeted cache; lookup tries exact key, then the dominance rule;
    overflow evicts by GDSF priority (cost-aware, not pure LRU)."""

    # floor for build_seconds in the priority: manually-constructed entries
    # (tests, replicated inserts) with cost 0 still order by size/recency
    MIN_COST = 1e-6

    def __init__(self, byte_budget: int = 256 << 20,
                 metrics: ServiceMetrics | None = None):
        self.byte_budget = int(byte_budget)
        self.metrics = metrics or ServiceMetrics()
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict[tuple, CacheEntry] = collections.OrderedDict()
        # signal -> version -> keys: dominance scans and invalidations touch
        # one signal's entries, not the whole cache (which may span millions
        # of signals)
        self._by_signal: dict[str, dict[str, set[tuple]]] = {}
        self._bytes = 0
        self._clock = 0.0   # GDSF aging clock; advances to victim priority

    def _boost(self, e: CacheEntry) -> None:
        """Refresh an entry's GDSF priority (call under the lock, on every
        insert and hit)."""
        cost = max(float(e.build_seconds), self.MIN_COST)
        e.priority = self._clock + (1.0 + e.hits) * cost / max(e.nbytes, 1)

    # ---------------------------------------------------------------- lookup
    def lookup(self, signal: str, version: str, k: int, eps: float, *,
               record: bool = True) -> tuple[CacheEntry | None, str | None]:
        """Returns (entry, kind) with kind in {"exact", "dominated", None}.

        ``record=False`` skips hit/miss counters (internal re-checks that
        would otherwise double-count the client-facing hit rate).
        """
        key = (signal, version, int(k), _eps_key(eps))
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                self._entries.move_to_end(key)
                e.hits += 1
                self._boost(e)
                if record:
                    self.metrics.inc("cache_hit_exact")
                return e, "exact"
            # dominance scan: any (k', eps'_eff) with k' >= k, eps'_eff <= eps.
            # Among dominating entries prefer the smallest coreset — queries
            # against it are cheapest and the guarantee is already satisfied.
            best = None
            for ek in self._by_signal.get(signal, {}).get(version, ()):
                e = self._entries[ek]
                if e.k >= k and e.eps_eff <= eps + 1e-12:
                    if best is None or e.nbytes < best.nbytes:
                        best = e
            if best is not None:
                self._entries.move_to_end(best.key)
                best.hits += 1
                self._boost(best)
                if record:
                    self.metrics.inc("cache_hit_dominated")
                return best, "dominated"
            if record:
                self.metrics.inc("cache_miss")
            return None, None

    # ------------------------------------------------------------------- put
    def _drop(self, key: tuple) -> CacheEntry | None:
        e = self._entries.pop(key, None)
        if e is not None:
            self._bytes -= e.nbytes
            versions = self._by_signal.get(e.signal)
            if versions is not None:
                keys = versions.get(e.version)
                if keys is not None:
                    keys.discard(key)
                    if not keys:
                        del versions[e.version]
                if not versions:
                    del self._by_signal[e.signal]
        return e

    def put(self, entry: CacheEntry) -> None:
        with self._lock:
            self._drop(entry.key)
            self._entries[entry.key] = entry
            self._by_signal.setdefault(entry.signal, {}).setdefault(
                entry.version, set()).add(entry.key)
            self._bytes += entry.nbytes
            self._boost(entry)
            self.metrics.inc("cache_insertions")
            while self._bytes > self.byte_budget and len(self._entries) > 1:
                # GDSF victim: minimum priority.  O(entries) scan, but only
                # on overflow — lookups stay O(1)+dominance.  The victim may
                # be the entry just inserted (a cheap build must not displace
                # expensive-to-rebuild ones); callers already hold the built
                # coreset, so serving is unaffected.
                victim = min(self._entries.values(), key=lambda e: e.priority)
                self._clock = max(self._clock, victim.priority)
                self._drop(victim.key)
                self.metrics.inc("cache_evictions")

    def specs_for(self, signal: str, version: str) -> list[tuple[int, float]]:
        """(k, eps) of every live entry for one signal version — the delta
        ingest path re-caches exactly these under the successor version."""
        with self._lock:
            keys = self._by_signal.get(signal, {}).get(version, ())
            return sorted({(self._entries[k].k, self._entries[k].eps)
                           for k in keys})

    def invalidate_signal(self, signal: str, keep_version: str | None = None) -> int:
        """Drop entries of stale versions (the version key already prevents
        wrong serving; this just frees the bytes eagerly)."""
        with self._lock:
            dead = [k for ver, keys in self._by_signal.get(signal, {}).items()
                    if ver != keep_version for k in keys]
            for k in dead:
                self._drop(k)
            if dead:
                self.metrics.inc("cache_invalidations", len(dead))
            return len(dead)

    # ----------------------------------------------------------------- stats
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._bytes

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes": self._bytes,
                "byte_budget": self.byte_budget,
                "eviction_policy": "gdsf",
                "clock": self._clock,
                "keys": [{"signal": e.signal, "k": e.k, "eps": e.eps,
                          "eps_eff": e.eps_eff, "blocks": e.coreset.num_blocks,
                          "nbytes": e.nbytes, "hits": e.hits,
                          "build_seconds": e.build_seconds,
                          "priority": e.priority}
                         for e in self._entries.values()],
            }
