"""Byte-budgeted LRU coreset cache with dominance reuse.

The paper's headline guarantee is *uniform over queries*: one (k, eps)-
coreset answers ell(D, s) for EVERY tree s of at most k leaves within
1 +/- eps.  Turned into a cache rule: a cached coreset built at (k', eps')
with  k' >= k  and  eps'_effective <= eps  is a valid answer source for a
(k, eps) request on the same signal version — no rebuild needed.  This is
what makes a coreset server amortize: the first tuning sweep pays O(Nk),
every later request (smaller trees, looser tolerances) is a cache hit.

``eps_eff`` is the entry's honest guarantee: equal to the requested eps for
one-shot and sharded-compose builds (composition is exact, streaming.py),
and the composed (1+eps)^(levels+1) - 1 bound for merge-reduce streaming
builds — dominance compares against eps_eff, never the nominal eps, so a
recompressed streamed coreset is not claimed tighter than it is.

Entries are keyed by (signal, version, k, eps); ``version`` is a content
hash maintained by the engine (a new ingested band bumps it), so stale
coresets can never serve a mutated signal.

Each entry also records ``row_spans`` — the merged half-open row intervals
its coreset's blocks cover (derived from ``coreset.rects`` at insert).
They are the provenance metadata of the delta-ingest **re-anchoring** fast
path: a delta whose row window is disjoint from every span cannot change
any block the entry stores, so the engine may re-key the entry to the
successor version (after splicing in the new rows' leaf blocks) instead of
rebuilding — an O(entries x spans) interval intersection, no coreset math.
``invalidate_signal(keep_version=...)`` returns the entries it dropped so
the engine can inspect exactly those re-anchor candidates, and
``stats()`` exposes ``reanchored`` / ``reanchor_candidates`` counters.

Eviction is cost-aware (GDSF — greedy-dual size-frequency) over a byte
budget: an entry's priority is

    priority = clock + (1 + hits) * max(build_seconds, floor) / nbytes

and overflow evicts the minimum-priority entry.  ``build_seconds / nbytes``
is the rebuild cost per cached byte (an expensive O(Nk) build that
compressed well is the most valuable thing in the cache), ``hits`` folds in
frequency, and the ``clock`` — advanced to each victim's priority — ages
out entries that stop being touched, so a once-hot expensive coreset still
drains away under pressure.  Priorities refresh on every hit and insert.
"""
from __future__ import annotations

import collections
import dataclasses
import threading

import numpy as np

from repro.core.coreset import SignalCoreset

from .metrics import ServiceMetrics

__all__ = ["CacheEntry", "DominanceCache", "block_row_spans",
           "spans_intersect"]


def _eps_key(eps: float) -> float:
    return round(float(eps), 6)


def block_row_spans(rects: np.ndarray) -> np.ndarray:
    """Merged, sorted half-open row intervals covered by coreset blocks.

    ``rects[:, :2]`` are per-block ``[row0, row1)`` windows; adjacent or
    overlapping windows merge, so a composed coreset over bands
    ``[0,32) [32,64)`` collapses to one span ``[0,64)``.  The result is the
    provenance record a :class:`CacheEntry` carries: any delta window
    disjoint from every span provably cannot alter the entry's blocks.
    """
    r = np.asarray(rects).reshape(-1, 4)[:, :2].astype(np.int64)
    if r.shape[0] == 0:
        return np.empty((0, 2), np.int64)
    r = r[np.argsort(r[:, 0], kind="stable")]
    spans = [[int(r[0, 0]), int(r[0, 1])]]
    for row0, row1 in r[1:]:
        if int(row0) <= spans[-1][1]:
            spans[-1][1] = max(spans[-1][1], int(row1))
        else:
            spans.append([int(row0), int(row1)])
    return np.asarray(spans, np.int64)


def spans_intersect(spans: np.ndarray | None, row0: int, row1: int) -> bool:
    """True when ``[row0, row1)`` overlaps any span.  ``None`` (unknown
    provenance — e.g. an entry inserted before span tracking) is treated as
    intersecting: re-anchoring must never be optimistic."""
    if spans is None:
        return True
    spans = np.asarray(spans).reshape(-1, 2)
    if spans.shape[0] == 0 or row1 <= row0:
        return False
    return bool(np.any((spans[:, 0] < row1) & (int(row0) < spans[:, 1])))


@dataclasses.dataclass
class CacheEntry:
    signal: str
    version: str
    k: int
    eps: float            # requested eps (exact-match key component)
    eps_eff: float        # honest guarantee after composition layers
    coreset: SignalCoreset
    nbytes: int
    fingerprint: str
    hits: int = 0
    build_seconds: float = 0.0   # construction cost, recorded at insert;
                                 # weighed against nbytes + recency by the
                                 # GDSF eviction policy
    priority: float = 0.0        # GDSF score, maintained by DominanceCache
    row_spans: np.ndarray | None = None   # merged [row0, row1) block
                                          # coverage; filled from
                                          # coreset.rects at put() if unset

    @property
    def key(self) -> tuple:
        return (self.signal, self.version, self.k, _eps_key(self.eps))


class DominanceCache:
    """Byte-budgeted cache; lookup tries exact key, then the dominance rule;
    overflow evicts by GDSF priority (cost-aware, not pure LRU)."""

    # floor for build_seconds in the priority: manually-constructed entries
    # (tests, replicated inserts) with cost 0 still order by size/recency
    MIN_COST = 1e-6

    def __init__(self, byte_budget: int = 256 << 20,
                 metrics: ServiceMetrics | None = None):
        self.byte_budget = int(byte_budget)
        self.metrics = metrics or ServiceMetrics()
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict[tuple, CacheEntry] = collections.OrderedDict()
        # signal -> version -> keys: dominance scans and invalidations touch
        # one signal's entries, not the whole cache (which may span millions
        # of signals)
        self._by_signal: dict[str, dict[str, set[tuple]]] = {}
        self._bytes = 0
        self._clock = 0.0   # GDSF aging clock; advances to victim priority
        self._reanchored = 0           # entries re-keyed to a new version
        self._reanchor_candidates = 0  # entries dropped by a keep_version
                                       # invalidation (the population the
                                       # re-anchor fast path competes for)

    def _boost(self, e: CacheEntry) -> None:
        """Refresh an entry's GDSF priority (call under the lock, on every
        insert and hit)."""
        cost = max(float(e.build_seconds), self.MIN_COST)
        e.priority = self._clock + (1.0 + e.hits) * cost / max(e.nbytes, 1)

    # ---------------------------------------------------------------- lookup
    def lookup(self, signal: str, version: str, k: int, eps: float, *,
               record: bool = True) -> tuple[CacheEntry | None, str | None]:
        """Returns (entry, kind) with kind in {"exact", "dominated", None}.

        ``record=False`` skips hit/miss counters (internal re-checks that
        would otherwise double-count the client-facing hit rate).
        """
        key = (signal, version, int(k), _eps_key(eps))
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                self._entries.move_to_end(key)
                e.hits += 1
                self._boost(e)
                if record:
                    self.metrics.inc("cache_hit_exact")
                return e, "exact"
            # dominance scan: any (k', eps'_eff) with k' >= k, eps'_eff <= eps.
            # Among dominating entries prefer the smallest coreset — queries
            # against it are cheapest and the guarantee is already satisfied.
            best = None
            for ek in self._by_signal.get(signal, {}).get(version, ()):
                e = self._entries[ek]
                if e.k >= k and e.eps_eff <= eps + 1e-12:
                    if best is None or e.nbytes < best.nbytes:
                        best = e
            if best is not None:
                self._entries.move_to_end(best.key)
                best.hits += 1
                self._boost(best)
                if record:
                    self.metrics.inc("cache_hit_dominated")
                return best, "dominated"
            if record:
                self.metrics.inc("cache_miss")
            return None, None

    # ------------------------------------------------------------------- put
    def _drop(self, key: tuple) -> CacheEntry | None:
        e = self._entries.pop(key, None)
        if e is not None:
            self._bytes -= e.nbytes
            versions = self._by_signal.get(e.signal)
            if versions is not None:
                keys = versions.get(e.version)
                if keys is not None:
                    keys.discard(key)
                    if not keys:
                        del versions[e.version]
                if not versions:
                    del self._by_signal[e.signal]
        return e

    def put(self, entry: CacheEntry) -> None:
        if entry.row_spans is None:
            entry.row_spans = block_row_spans(entry.coreset.rects)
        with self._lock:
            self._drop(entry.key)
            self._entries[entry.key] = entry
            self._by_signal.setdefault(entry.signal, {}).setdefault(
                entry.version, set()).add(entry.key)
            self._bytes += entry.nbytes
            self._boost(entry)
            self.metrics.inc("cache_insertions")
            while self._bytes > self.byte_budget and len(self._entries) > 1:
                # GDSF victim: minimum priority.  O(entries) scan, but only
                # on overflow — lookups stay O(1)+dominance.  The victim may
                # be the entry just inserted (a cheap build must not displace
                # expensive-to-rebuild ones); callers already hold the built
                # coreset, so serving is unaffected.
                victim = min(self._entries.values(), key=lambda e: e.priority)
                self._clock = max(self._clock, victim.priority)
                self._drop(victim.key)
                self.metrics.inc("cache_evictions")

    def specs_for(self, signal: str, version: str) -> list[tuple[int, float]]:
        """(k, eps) of every live entry for one signal version — the delta
        ingest path re-caches exactly these under the successor version."""
        with self._lock:
            keys = self._by_signal.get(signal, {}).get(version, ())
            return sorted({(self._entries[k].k, self._entries[k].eps)
                           for k in keys})

    def take(self, signal: str, version: str, k: int,
             eps: float) -> CacheEntry | None:
        """Pop an entry by exact key WITHOUT touching hit/miss counters —
        the re-anchor path removes the stale-version entry, splices the new
        rows in, and re-puts it under the successor version."""
        with self._lock:
            return self._drop((signal, version, int(k), _eps_key(eps)))

    def mark_reanchored(self, n: int = 1) -> None:
        """Record ``n`` entries re-keyed to a new version in metadata time
        (no rebuild).  Shows up as ``cache_reanchored`` in the metrics
        snapshot and ``stats()["reanchored"]``."""
        with self._lock:
            self._reanchored += n
        self.metrics.inc("cache_reanchored", n)

    def invalidate_signal(self, signal: str,
                          keep_version: str | None = None) -> list[CacheEntry]:
        """Drop entries of stale versions (the version key already prevents
        wrong serving; this just frees the bytes eagerly).

        Returns the dropped entries — with ``keep_version`` given these are
        exactly the re-anchor candidates the fast path did NOT claim (their
        blocks intersected the delta, or the delta shape was ineligible),
        so callers can see what fell back to invalidate+rebuild.  Also
        bumps ``reanchor_candidates`` in that case.
        """
        with self._lock:
            dead = [k for ver, keys in self._by_signal.get(signal, {}).items()
                    if ver != keep_version for k in keys]
            dropped = [e for e in (self._drop(k) for k in dead)
                       if e is not None]
            if dropped and keep_version is not None:
                self._reanchor_candidates += len(dropped)
        if dropped:
            self.metrics.inc("cache_invalidations", len(dropped))
            if keep_version is not None:
                self.metrics.inc("cache_reanchor_candidates", len(dropped))
        return dropped

    # ----------------------------------------------------------------- stats
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._bytes

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes": self._bytes,
                "byte_budget": self.byte_budget,
                "eviction_policy": "gdsf",
                "clock": self._clock,
                "reanchored": self._reanchored,
                "reanchor_candidates": self._reanchor_candidates,
                "keys": [{"signal": e.signal, "k": e.k, "eps": e.eps,
                          "eps_eff": e.eps_eff, "blocks": e.coreset.num_blocks,
                          "nbytes": e.nbytes, "hits": e.hits,
                          "build_seconds": e.build_seconds,
                          "priority": e.priority}
                         for e in self._entries.values()],
            }
