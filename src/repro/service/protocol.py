"""v1 wire protocol — typed request/response messages for the coreset service.

One shared vocabulary for server (``service.api``), SDK (``repro.client``)
and tools (``benchmarks/bench_service.py``, ``serve_coresets --smoke``):
frozen dataclasses with symmetric ``to_wire()`` / ``from_wire()`` so nobody
re-encodes dicts by hand, plus two negotiated encodings

  * ``application/json``            — readable, slow for large arrays;
  * ``application/x-repro-npz-v1``  — a compressed npz frame: magic
    ``RPV1`` + 1 codec byte (``Z`` zstandard / ``z`` zlib, mirroring the
    checkpointer's fallback) + compressed npz whose ``__json__`` member
    holds the scalar fields and whose remaining members are the ndarray
    fields verbatim.  Registration of a 512x512 signal spends its time in
    ``tobytes``/zlib instead of ``tolist``/``json`` — the ROADMAP's "JSON
    array parsing dominates" fix.

Versioning policy (see DESIGN.md "v1 protocol"): the payload carries a
``type`` tag (dispatch) and the frame a protocol magic; adding optional
fields is backward compatible (``from_wire`` ignores unknown keys and fills
defaults), renaming/removing fields requires a new ``/v2`` route family.

Arrays with numpy extension dtypes (bfloat16/fp8 — dtype kind ``V``) are
widened to float32 on encode, exactly like the checkpointer: npz cannot
represent them, and float32 is exact for every sub-32-bit float, so the
widening is lossless (but not round-tripping the dtype — by design).
NaN/inf survive both encodings (Python's json module emits and parses
them; npz stores raw IEEE bytes).
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import struct
import zlib

import numpy as np

try:
    import zstandard
except ModuleNotFoundError:  # bare containers: stdlib zlib fallback
    zstandard = None

__all__ = [
    "PROTOCOL_VERSION", "CONTENT_TYPE_JSON", "CONTENT_TYPE_BINARY",
    "CoresetSpec", "SignalRef", "RegisterRequest", "IngestRequest",
    "IngestDeltaRequest", "BuildRequest", "LossQuery", "BatchLossQuery",
    "FitRequest", "CompressRequest", "SignalInfo", "IngestDeltaResponse",
    "BuildResponse", "LossResponse", "BatchLossResponse", "FitResponse",
    "CompressResponse", "ErrorInfo", "ErrorResponse", "ProtocolError",
    "UnsupportedCodec", "decode", "encode",
    # ---- v2 chunked streaming
    "PROTOCOL_VERSION_STREAM", "CONTENT_TYPE_STREAM", "STREAM_MAGIC",
    "StreamTruncated", "CompressHeader", "CompressChunk", "CompressTrailer",
    "accept_stream", "compress_stream_segments", "read_compress_stream",
]

PROTOCOL_VERSION = "v1"
CONTENT_TYPE_JSON = "application/json"
CONTENT_TYPE_BINARY = "application/x-repro-npz-v1"

_MAGIC = b"RPV1"
# codec byte -> (compress, decompress); level 1: signal payloads are noisy
# floats (near-incompressible), so throughput beats ratio on the wire path
_ENC_ZSTD = (lambda b: zstandard.ZstdCompressor(level=1).compress(b)) \
    if zstandard is not None else None


class ProtocolError(ValueError):
    """Malformed frame / unknown message type / bad field value."""


class UnsupportedCodec(ProtocolError):
    """Frame codec this host cannot decode (zstd frame, no zstandard) —
    the server maps this to HTTP 415 so clients renegotiate, unlike plain
    400s which mean the request itself is bad."""


# decompressed-size ceiling: the HTTP layer caps the *compressed* body, but
# a zlib/zstd bomb (200 MB of compressed zeros -> ~200 GB) must die here,
# before the allocation, not in the OOM killer
_MAX_DECODED = 1 << 30


# --------------------------------------------------------------------- fields
def _arr(dtype, ndim: int | None = None, allow_none: bool = False):
    """Field coercer: JSON lists -> ndarray of ``dtype``; ndarrays from the
    npz path pass through (widened dtypes stay widened).  ``ndim`` enforces
    rank AFTER coercion — a ragged nested list coerces to an object array,
    which both the dtype cast and the rank check reject."""
    def coerce(v):
        if v is None:
            if allow_none:
                return None
            raise ProtocolError("array field must not be null")
        if not isinstance(v, np.ndarray):
            try:
                v = np.asarray(v, dtype)
            except (TypeError, ValueError) as exc:
                raise ProtocolError(f"not a numeric array: {exc}") from None
        if v.dtype.kind not in "iuf":
            raise ProtocolError(f"array has non-numeric dtype {v.dtype}")
        if ndim is not None and v.ndim != ndim:
            raise ProtocolError(f"array must be {ndim}-D, got {v.ndim}-D "
                                f"(ragged input coerces to object arrays)")
        return v
    return coerce


def _widen(a: np.ndarray) -> np.ndarray:
    # npz degrades extension dtypes (kind 'V': bfloat16/fp8) to raw void;
    # float32 is exact for every sub-32-bit float (checkpointer idiom)
    return a.astype(np.float32) if a.dtype.kind == "V" else a


class _Wire:
    """Mixin: generic payload <-> dataclass conversion + frame codecs.

    Subclasses are frozen dataclasses.  Nested messages (``CoresetSpec``,
    ``SignalRef``, ``ErrorInfo``) and ndarray fields are discovered from the
    ``_NESTED`` / ``_COERCE`` class tables, so adding a message is one
    dataclass + one registry line.
    """

    kind: str = ""
    _NESTED: dict = {}
    _COERCE: dict = {}

    # --------------------------------------------------------------- payload
    def to_payload(self) -> dict:
        out = {"type": self.kind}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if dataclasses.is_dataclass(v):
                v = dataclasses.asdict(v)
            out[f.name] = v
        return out

    @classmethod
    def from_payload(cls, d: dict) -> "_Wire":
        kw = {}
        for f in dataclasses.fields(cls):
            if f.name not in d or d[f.name] is None:
                if f.default is dataclasses.MISSING and \
                        f.default_factory is dataclasses.MISSING:
                    raise ProtocolError(f"{cls.kind}: missing field {f.name!r}")
                if f.name not in d:
                    continue
            v = d[f.name]
            if f.name in cls._NESTED and v is not None:
                if not isinstance(v, dict):
                    raise ProtocolError(f"{cls.kind}.{f.name} must be an object")
                # recurse through from_payload: unknown keys are ignored
                # (forward compat) and failures surface as ProtocolError
                v = cls._NESTED[f.name].from_payload(v)
            elif f.name in cls._COERCE:
                v = cls._COERCE[f.name](v)
            kw[f.name] = v
        try:
            return cls(**kw)
        except TypeError as exc:
            raise ProtocolError(f"{cls.kind}: {exc}") from None

    # ---------------------------------------------------------------- frames
    def to_wire(self, encoding: str = "json", *,
                binary_codec: str | None = None) -> tuple[str, bytes]:
        """Serialize to (content_type, body).  ``encoding``: json | binary.

        ``binary_codec`` pins the frame codec: "zlib" (always decodable —
        stdlib), "zstd" (requires zstandard on BOTH ends), or None = the
        best this host can encode.  Servers pass the codec the client
        advertised in ``Accept`` so a zlib-only client never receives a
        zstd frame it cannot decode.
        """
        payload = self.to_payload()
        if encoding == "json":
            body = json.dumps(
                {k: v.tolist() if isinstance(v, np.ndarray) else v
                 for k, v in payload.items()}).encode()
            return CONTENT_TYPE_JSON, body
        if encoding != "binary":
            raise ProtocolError(f"unknown encoding {encoding!r}")
        arrays = {k: _widen(v) for k, v in payload.items()
                  if isinstance(v, np.ndarray)}
        meta = {k: v for k, v in payload.items() if k not in arrays}
        buf = io.BytesIO()
        np.savez(buf, __json__=np.frombuffer(json.dumps(meta).encode(),
                                             np.uint8), **arrays)
        raw = buf.getvalue()
        if binary_codec == "zstd" and _ENC_ZSTD is None:
            raise UnsupportedCodec("zstd requested but zstandard is not "
                                   "installed on this host")
        use_zstd = (_ENC_ZSTD is not None if binary_codec is None
                    else binary_codec == "zstd")
        if use_zstd:
            return CONTENT_TYPE_BINARY, _MAGIC + b"Z" + _ENC_ZSTD(raw)
        return CONTENT_TYPE_BINARY, _MAGIC + b"z" + zlib.compress(raw, 1)

    @staticmethod
    def accept_codec(accept_header: str) -> str:
        """The binary codec a peer's ``Accept`` header permits: "zstd" only
        when explicitly advertised (``;codec=zstd``), else "zlib" — the
        conservative default keeps responses stdlib-decodable for clients
        that predate the codec parameter."""
        return "zstd" if "codec=zstd" in accept_header.replace(" ", "") \
            else "zlib"

    # equality: field-wise with NaN-tolerant array comparison (round-trip
    # tests and client assertions; frozen dataclasses use eq=False)
    def __eq__(self, other) -> bool:
        if type(other) is not type(self):
            return NotImplemented
        for f in dataclasses.fields(self):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
                if not (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                        and a.shape == b.shape
                        and np.array_equal(a, b, equal_nan=True)):
                    return False
            elif a != b:
                return False
        return True

    __hash__ = None


def _payload_from_wire(content_type: str, body: bytes) -> dict:
    ctype = (content_type or "").split(";", 1)[0].strip().lower()
    if ctype in ("", CONTENT_TYPE_JSON):
        try:
            d = json.loads(body or b"{}")
        except json.JSONDecodeError as exc:
            raise ProtocolError(f"bad JSON body: {exc}") from None
        if not isinstance(d, dict):
            raise ProtocolError("JSON body must be an object")
        return d
    if ctype != CONTENT_TYPE_BINARY:
        raise ProtocolError(f"unsupported content type {content_type!r}")
    if len(body) < 5 or body[:4] != _MAGIC:
        raise ProtocolError("bad binary frame: missing RPV1 magic")
    codec, blob = body[4:5], body[5:]
    try:
        if codec == b"Z":
            if zstandard is None:
                raise UnsupportedCodec(
                    "frame is zstd-compressed but the zstandard module is "
                    "not installed on this host")
            params = zstandard.get_frame_parameters(blob)
            if params.content_size > _MAX_DECODED:
                raise ProtocolError(
                    f"decompressed frame exceeds {_MAX_DECODED} bytes")
            raw = zstandard.ZstdDecompressor().decompress(
                blob, max_output_size=_MAX_DECODED)
        elif codec == b"z":
            dec = zlib.decompressobj()
            raw = dec.decompress(blob, _MAX_DECODED)
            if dec.unconsumed_tail:
                raise ProtocolError(
                    f"decompressed frame exceeds {_MAX_DECODED} bytes")
        else:
            raise ProtocolError(f"unknown frame codec {codec!r}")
        npz = np.load(io.BytesIO(raw))
    except ProtocolError:
        raise
    except Exception as exc:  # zlib.error, zstd errors, bad zip
        raise ProtocolError(f"corrupt binary frame: {exc}") from None
    if "__json__" not in npz.files:
        raise ProtocolError("binary frame missing __json__ member")
    d = json.loads(bytes(npz["__json__"]))
    for name in npz.files:
        if name != "__json__":
            d[name] = npz[name]
    return d


_REGISTRY: dict[str, type] = {}


def _message(kind: str):
    """Class decorator: freeze, register under ``kind`` for decode dispatch."""
    def wrap(cls):
        cls = dataclasses.dataclass(frozen=True, eq=False)(cls)
        cls.kind = kind
        _REGISTRY[kind] = cls
        return cls
    return wrap


def decode(content_type: str, body: bytes, expect: type | None = None):
    """Parse a wire frame into its typed message (dispatch on ``type``).

    ``expect`` pins the message class for endpoint handlers: a payload whose
    tag names a different registered message is rejected, and an untagged
    payload (hand-written JSON) is parsed as ``expect`` for compatibility.
    """
    d = _payload_from_wire(content_type, body)
    tag = d.pop("type", None)
    if tag is None:
        if expect is None:
            raise ProtocolError("payload has no 'type' tag")
        cls = expect
    else:
        cls = _REGISTRY.get(tag)
        if cls is None:
            raise ProtocolError(f"unknown message type {tag!r}")
        if expect is not None and cls is not expect:
            raise ProtocolError(f"expected {expect.kind!r}, got {tag!r}")
    return cls.from_payload(d)


def encode(msg: "_Wire", encoding: str = "json") -> tuple[str, bytes]:
    return msg.to_wire(encoding)


# ---------------------------------------------------------------- vocabulary
@_message("coreset_spec")
class CoresetSpec(_Wire):
    """The (k, eps) guarantee a client asks for.  ``fidelity`` selects the
    gamma regime of ``signal_coreset`` ("practical" | "paper")."""
    k: int
    eps: float = 0.2
    fidelity: str = "practical"

    def __post_init__(self):
        object.__setattr__(self, "k", int(self.k))
        object.__setattr__(self, "eps", float(self.eps))
        if self.k < 1:
            raise ProtocolError("spec.k must be >= 1")
        if not (0.0 < self.eps < 1.0):
            raise ProtocolError("spec.eps must be in (0, 1)")
        if self.fidelity not in ("practical", "paper"):
            raise ProtocolError(f"unknown fidelity {self.fidelity!r}")


@_message("signal_ref")
class SignalRef(_Wire):
    """A named signal, optionally pinned to a content version (None = the
    server's current version)."""
    name: str
    version: str | None = None

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise ProtocolError("signal name must be a non-empty string")


# ----------------------------------------------------------------- requests
@_message("register")
class RegisterRequest(_Wire):
    signal: SignalRef
    values: np.ndarray | None = None     # (n, m) dense payload
    synthetic: dict | None = None        # server-side generation spec
    replace: bool = False
    tenant: str | None = None            # QoS accounting identity (PR 10)
    _NESTED = {"signal": SignalRef}
    _COERCE = {"values": _arr(np.float64, ndim=2, allow_none=True)}


@_message("ingest")
class IngestRequest(_Wire):
    signal: SignalRef
    band: np.ndarray | None = None       # (rows, m) appended row band
    synthetic: dict | None = None
    tenant: str | None = None
    _NESTED = {"signal": SignalRef}
    _COERCE = {"band": _arr(np.float64, ndim=2, allow_none=True)}


@_message("ingest_delta")
class IngestDeltaRequest(_Wire):
    """Delta write: only the changed rows cross the wire.  ``row0`` is the
    absolute row offset of the replaced band (must align with an ingested
    band on streamed signals); None appends at the current end.

    **Burst form**: ``row0s``/``rows`` ship MANY deltas in one request —
    ``band`` is then the row-wise concatenation of ``len(row0s)`` bands of
    ``rows[i]`` rows each, placed at ``row0s[i]`` (null entries append).
    The server fans the per-band leaf rebuilds out through one batched
    scheduler submission instead of N sequential builds."""
    signal: SignalRef
    band: np.ndarray                     # (rows, m) changed rows only
    row0: int | None = None
    row0s: list | None = None            # burst: per-band placement
    rows: list | None = None             # burst: per-band row counts
    tenant: str | None = None
    _NESTED = {"signal": SignalRef}
    _COERCE = {"band": _arr(np.float64, ndim=2)}


@_message("build")
class BuildRequest(_Wire):
    signal: SignalRef
    spec: CoresetSpec
    deadline_ms: float | None = None
    tenant: str | None = None
    _NESTED = {"signal": SignalRef, "spec": CoresetSpec}


@_message("loss_query")
class LossQuery(_Wire):
    """Algorithm-5 loss of one k-segmentation.  ``spec`` is optional: k
    defaults to the tree's leaf count, eps to 0.2.

    ``deadline_ms`` bounds the server-side wait (build queue + batching
    window); past it the request fails 504 ``deadline_exceeded``.
    ``coalesce=False`` is the escape hatch that skips the cross-request
    QueryScheduler and scores inline."""
    signal: SignalRef
    rects: np.ndarray                     # (K, 4) half-open block corners
    labels: np.ndarray                    # (K,)
    spec: CoresetSpec | None = None
    deadline_ms: float | None = None
    coalesce: bool = True
    tenant: str | None = None
    _NESTED = {"signal": SignalRef, "spec": CoresetSpec}
    _COERCE = {"rects": _arr(np.int64, ndim=2),
               "labels": _arr(np.float64, ndim=1)}


@_message("batch_loss_query")
class BatchLossQuery(_Wire):
    """T same-signal segmentations scored in ONE fused engine call
    (``core.sharded.fitting_loss_batched``), instead of T sequential
    /query/loss round trips.  ``coalesce=False`` skips the cross-request
    QueryScheduler (the batch then dispatches alone instead of fusing with
    concurrent same-coreset queries)."""
    signal: SignalRef
    rects: np.ndarray                     # (T, K, 4)
    labels: np.ndarray                    # (T, K)
    spec: CoresetSpec | None = None
    deadline_ms: float | None = None
    coalesce: bool = True
    tenant: str | None = None
    _NESTED = {"signal": SignalRef, "spec": CoresetSpec}
    _COERCE = {"rects": _arr(np.int64, ndim=3),
               "labels": _arr(np.float64, ndim=2)}


@_message("fit_request")
class FitRequest(_Wire):
    signal: SignalRef
    spec: CoresetSpec
    n_estimators: int = 10
    max_leaves: int | None = None
    predict: np.ndarray | None = None     # (P, 2) grid points to evaluate
    seed: int = 0
    deadline_ms: float | None = None
    tenant: str | None = None
    _NESTED = {"signal": SignalRef, "spec": CoresetSpec}
    _COERCE = {"predict": _arr(np.float64, ndim=2, allow_none=True)}


@_message("compress_request")
class CompressRequest(_Wire):
    signal: SignalRef
    spec: CoresetSpec
    target_frac: float | None = None
    style: str = "mean"
    max_points: int = 4096
    deadline_ms: float | None = None
    tenant: str | None = None
    _NESTED = {"signal": SignalRef, "spec": CoresetSpec}


# ---------------------------------------------------------------- responses
@_message("signal_info")
class SignalInfo(_Wire):
    name: str
    n: int
    m: int | None
    bands: int
    streamed: bool
    version: str
    builders: list = dataclasses.field(default_factory=list)


@_message("ingest_delta_response")
class IngestDeltaResponse(_Wire):
    """Acknowledgement of a delta write, with the incremental-path telemetry
    (how much merge-reduce state was reused instead of rebuilt)."""
    name: str
    n: int
    m: int
    bands: int
    streamed: bool
    version: str
    mode: str                 # append | replace | burst
    row0: int
    rows: int
    buckets_recompressed: int
    entries_recached: int
    deltas: int = 1           # bands in the burst (1 = single-delta form)
    entries_reanchored: int = 0   # cache entries re-keyed to the new
                                  # version in metadata time (no rebuild)


@_message("build_response")
class BuildResponse(_Wire):
    fingerprint: str
    eps_eff: float
    served_from: str          # exact | dominated | built | coalesced
    size: int
    blocks: int
    nbytes: int
    compression_ratio: float
    certified: bool
    build_seconds: float


@_message("loss_response")
class LossResponse(_Wire):
    loss: float
    k: int
    eps: float
    eps_eff: float
    served_from: str
    fingerprint: str
    coreset_size: int
    fused_batch_size: int = 1 # requests sharing the dispatch that served this
    backend: str = ""         # the repro.ops backend the dispatch ran on


@_message("batch_loss_response")
class BatchLossResponse(_Wire):
    losses: np.ndarray        # (T,)
    k: int
    eps: float
    eps_eff: float
    served_from: str
    fingerprint: str
    coreset_size: int
    scoring_calls: int        # fused engine evaluations consumed (1 per batch)
    fused_batch_size: int = 1 # trees the single dispatch scored
    _COERCE = {"losses": _arr(np.float64, ndim=1)}


@_message("fit_response")
class FitResponse(_Wire):
    k: int
    eps: float
    eps_eff: float
    served_from: str
    fingerprint: str
    train_size: int
    n_estimators: int
    model_cache: str          # hit | fit
    predictions: np.ndarray | None = None
    _COERCE = {"predictions": _arr(np.float64, ndim=1, allow_none=True)}


@_message("compress_response")
class CompressResponse(_Wire):
    k: int
    eps_eff: float
    served_from: str
    fingerprint: str
    size: int
    blocks: int
    nbytes: int
    compression_ratio: float
    truncated: bool
    X: np.ndarray             # (P, 2) weighted point coordinates
    y: np.ndarray             # (P,) labels
    w: np.ndarray             # (P,) weights
    _COERCE = {"X": _arr(np.float64, ndim=2),
               "y": _arr(np.float64, ndim=1),
               "w": _arr(np.float64, ndim=1)}


@_message("error_info")
class ErrorInfo(_Wire):
    code: str                 # bad_request | not_found | overloaded | internal
    message: str
    # admission-rejection extras (PR 10).  All optional with None defaults,
    # so v1 peers that predate them decode the envelope unchanged (unknown
    # keys are ignored on decode, missing keys fill from defaults).
    retry_after: float | None = None    # seconds; mirrors the Retry-After header
    tenant: str | None = None           # tenant the rejection was charged to
    reason: str | None = None           # deadline_unmeetable | tenant_rate | ...


@_message("error")
class ErrorResponse(_Wire):
    """The uniform v1 error envelope: HTTP status >= 400 bodies are always
    ``{"type": "error", "error": {"code", "message"}}``."""
    error: ErrorInfo
    _NESTED = {"error": ErrorInfo}


# ===================================================== v2 chunked streaming
#
# The v1 path buffers a whole ``CompressResponse`` — metadata + every
# (X, y, w) point — into ONE npz frame on both sides, so peak memory during
# a large ``compress`` scales with coreset size.  v2 streams the same
# response as a sequence of independently decodable SEGMENTS over HTTP
# chunked transfer-encoding:
#
#     RPS2 | seg(header) | seg(chunk 0) ... seg(chunk C-1) | seg(trailer)
#
#     seg(msg) := u32 big-endian frame length | v1 binary frame of msg
#
# Each segment's payload is an ordinary v1 binary frame (magic + codec byte
# + compressed npz) of a registered message, so codec negotiation, bomb
# ceilings, and typed decode errors are all inherited from the v1 machinery
# — v2 only adds framing, sequencing, and an end-to-end digest:
#
#   * ``CompressHeader``  — the scalar half of ``CompressResponse`` plus
#     the expected chunk count, sent before any points;
#   * ``CompressChunk``   — ``seq`` (0-based, strictly sequential) and a
#     bounded slice of the point arrays, so the producer's working set is
#     O(chunk) no matter how large the coreset;
#   * ``CompressTrailer`` — chunk/point totals and a blake2b digest over
#     the raw point bytes in order, so truncation at a segment boundary
#     (which plain chunked encoding cannot detect) and reordering both
#     fail closed as ``StreamTruncated`` / ``ProtocolError``.
#
# Version negotiation rides the Accept header — ``Accept:
# application/x-repro-npz-v1;codec=zstd;v=2`` — so a v2 client talking to a
# v1 server degrades silently to the buffered response (the v1 server
# matches on the content-type substring and ignores the parameter), and a
# v1 client never sees a stream it did not ask for.

PROTOCOL_VERSION_STREAM = "v2"
CONTENT_TYPE_STREAM = "application/x-repro-stream-v2"
STREAM_MAGIC = b"RPS2"
STREAM_CHUNK_POINTS = 32768     # default points per chunk (~1 MiB raw)
_MAX_SEGMENT = 1 << 28          # one segment must never be a whole-response
                                # buffer in disguise (nor an alloc bomb)


class StreamTruncated(ProtocolError):
    """v2 stream ended mid-segment or before its trailer — the transfer
    died, not the request.  Clients treat this as transient (retryable)
    where other ProtocolErrors are terminal."""


@_message("compress_header")
class CompressHeader(_Wire):
    """Everything of a ``CompressResponse`` except the point arrays, known
    before the first chunk is encoded."""
    k: int
    eps_eff: float
    served_from: str
    fingerprint: str
    size: int
    blocks: int
    nbytes: int
    compression_ratio: float
    truncated: bool
    points: int               # total points the chunks will carry
    chunks: int               # segments to expect before the trailer


@_message("compress_chunk")
class CompressChunk(_Wire):
    seq: int                  # 0-based, strictly sequential
    X: np.ndarray             # (p, 2) slice of the point coordinates
    y: np.ndarray             # (p,)
    w: np.ndarray             # (p,)
    _COERCE = {"X": _arr(np.float64, ndim=2),
               "y": _arr(np.float64, ndim=1),
               "w": _arr(np.float64, ndim=1)}


@_message("compress_trailer")
class CompressTrailer(_Wire):
    chunks: int
    points: int
    digest: str               # blake2b-16 over the raw chunk bytes in order


def accept_stream(accept_header: str | None) -> bool:
    """True when the client negotiated the v2 stream: the binary content
    type with a ``v=2`` parameter (or the stream type spelled out)."""
    accept = (accept_header or "").replace(" ", "").lower()
    if CONTENT_TYPE_STREAM in accept:
        return True
    return CONTENT_TYPE_BINARY in accept and ";v=2" in (accept + ";")


def _chunk_digest() -> "hashlib._Hash":
    return hashlib.blake2b(digest_size=16)


def _digest_update(h, X: np.ndarray, y: np.ndarray, w: np.ndarray) -> None:
    h.update(np.ascontiguousarray(X, np.float64).tobytes())
    h.update(np.ascontiguousarray(y, np.float64).tobytes())
    h.update(np.ascontiguousarray(w, np.float64).tobytes())


def _segment(msg: "_Wire", binary_codec: str) -> bytes:
    _, frame = msg.to_wire("binary", binary_codec=binary_codec)
    return struct.pack(">I", len(frame)) + frame


def compress_stream_segments(resp: CompressResponse, *,
                             chunk_points: int = STREAM_CHUNK_POINTS,
                             binary_codec: str = "zlib"):
    """Yield the v2 byte segments of ``resp`` (magic first, trailer last).

    Each yielded bytes object is one write: the caller (the HTTP layer)
    flushes it as a transfer-encoding chunk before the next is encoded, so
    encode-side peak memory is O(chunk_points), not O(points).  Chunk
    slices are views into ``resp``'s arrays — nothing is copied until the
    per-segment npz encode.
    """
    chunk_points = max(1, int(chunk_points))
    points = int(resp.y.shape[0])
    chunks = (points + chunk_points - 1) // chunk_points
    header = CompressHeader(
        k=resp.k, eps_eff=resp.eps_eff, served_from=resp.served_from,
        fingerprint=resp.fingerprint, size=resp.size, blocks=resp.blocks,
        nbytes=resp.nbytes, compression_ratio=resp.compression_ratio,
        truncated=resp.truncated, points=points, chunks=chunks)
    yield STREAM_MAGIC + _segment(header, binary_codec)
    h = _chunk_digest()
    for seq in range(chunks):
        lo, hi = seq * chunk_points, min((seq + 1) * chunk_points, points)
        X, y, w = resp.X[lo:hi], resp.y[lo:hi], resp.w[lo:hi]
        _digest_update(h, X, y, w)
        yield _segment(CompressChunk(seq=seq, X=X, y=y, w=w), binary_codec)
    yield _segment(CompressTrailer(chunks=chunks, points=points,
                                   digest=h.hexdigest()), binary_codec)


def _read_exact(read, n: int, what: str) -> bytes:
    """Drain exactly ``n`` bytes from a ``read(size)`` callable (short reads
    are normal at transport boundaries); EOF mid-object is truncation."""
    parts, got = [], 0
    while got < n:
        piece = read(n - got)
        if not piece:
            raise StreamTruncated(
                f"v2 stream truncated reading {what}: wanted {n} bytes, "
                f"got {got}")
        parts.append(piece)
        got += len(piece)
    return b"".join(parts)


def _read_segment(read, expect: type, what: str) -> "_Wire":
    (length,) = struct.unpack(">I", _read_exact(read, 4, f"{what} length"))
    if length == 0 or length > _MAX_SEGMENT:
        raise ProtocolError(f"v2 segment length {length} out of range")
    frame = _read_exact(read, length, what)
    return decode(CONTENT_TYPE_BINARY, frame, expect=expect)


def read_compress_stream(read) -> tuple[CompressResponse, int]:
    """Incrementally decode a v2 stream from a ``read(size)`` callable
    (e.g. ``http.client`` response ``read`` — urllib de-chunks the
    transfer encoding transparently, so this sees the raw segments).

    Returns ``(response, chunks)`` where ``response`` is field-identical
    to the v1 buffered ``CompressResponse`` for the same request.  Raises
    ``StreamTruncated`` on EOF mid-stream (retryable) and ``ProtocolError``
    on sequencing/count/digest violations (corrupt, not transient).
    """
    magic = _read_exact(read, len(STREAM_MAGIC), "stream magic")
    if magic != STREAM_MAGIC:
        raise ProtocolError(f"bad v2 stream magic {magic!r}")
    header = _read_segment(read, CompressHeader, "header segment")
    if header.chunks < 0 or header.points < 0:
        raise ProtocolError("negative chunk/point count in stream header")
    h = _chunk_digest()
    Xs, ys, ws = [], [], []
    got_points = 0
    for seq in range(header.chunks):
        chunk = _read_segment(read, CompressChunk, f"chunk {seq}")
        if chunk.seq != seq:
            raise ProtocolError(
                f"v2 chunk out of order: expected seq {seq}, "
                f"got {chunk.seq}")
        if not (chunk.X.shape[0] == chunk.y.shape[0] == chunk.w.shape[0]):
            raise ProtocolError("v2 chunk arrays disagree on point count")
        _digest_update(h, chunk.X, chunk.y, chunk.w)
        Xs.append(chunk.X)
        ys.append(chunk.y)
        ws.append(chunk.w)
        got_points += int(chunk.y.shape[0])
    trailer = _read_segment(read, CompressTrailer, "trailer segment")
    if trailer.chunks != header.chunks or trailer.points != header.points:
        raise ProtocolError(
            f"v2 trailer disagrees with header: "
            f"{trailer.chunks}/{trailer.points} chunks/points vs "
            f"{header.chunks}/{header.points}")
    if got_points != header.points:
        raise ProtocolError(
            f"v2 stream carried {got_points} points, header promised "
            f"{header.points}")
    if trailer.digest != h.hexdigest():
        raise ProtocolError("v2 stream digest mismatch (corrupt chunk)")
    resp = CompressResponse(
        k=header.k, eps_eff=header.eps_eff, served_from=header.served_from,
        fingerprint=header.fingerprint, size=header.size,
        blocks=header.blocks, nbytes=header.nbytes,
        compression_ratio=header.compression_ratio,
        truncated=header.truncated,
        X=(np.concatenate(Xs, axis=0) if Xs else np.empty((0, 2))),
        y=(np.concatenate(ys) if ys else np.empty(0)),
        w=(np.concatenate(ws) if ws else np.empty(0)))
    return resp, int(header.chunks)
