# Coreset-as-a-service layer: the paper's reuse guarantee (one (k, eps)-
# coreset answers EVERY <=k-leaf tree query) turned into a serving system —
# dominance-aware cache, continuous-batching build scheduler, streamed
# ingest via merge-reduce, and a stdlib HTTP/JSON front.  See DESIGN.md.
from .cache import CacheEntry, DominanceCache
from .engine import CoresetEngine, SignalState
from .metrics import Histogram, ServiceMetrics
from .scheduler import BuildScheduler
from .api import make_server, serve_forever_in_thread

__all__ = [
    "CacheEntry", "DominanceCache", "CoresetEngine", "SignalState",
    "Histogram", "ServiceMetrics", "BuildScheduler", "make_server",
    "serve_forever_in_thread",
]
