# Coreset-as-a-service layer: the paper's reuse guarantee (one (k, eps)-
# coreset answers EVERY <=k-leaf tree query) turned into a serving system —
# dominance-aware cache, continuous-batching build scheduler, streamed
# ingest via merge-reduce, a typed v1 wire protocol (JSON + binary npz
# frames) and a stdlib HTTP front.  See DESIGN.md.
from .admission import (AdmissionConfig, AdmissionController,
                        AdmissionRejected)
from .cache import CacheEntry, DominanceCache
from .engine import CoresetEngine, SignalState, UnknownSignalError
from .metrics import Histogram, ServiceMetrics
from .query_scheduler import DeadlineExceeded, QueryScheduler
from .scheduler import BuildScheduler
from . import protocol
from .api import ApiError, make_server, serve_forever_in_thread

__all__ = [
    "AdmissionConfig", "AdmissionController", "AdmissionRejected",
    "CacheEntry", "DominanceCache", "CoresetEngine", "SignalState",
    "UnknownSignalError", "Histogram", "ServiceMetrics", "BuildScheduler",
    "QueryScheduler", "DeadlineExceeded",
    "protocol", "ApiError", "make_server", "serve_forever_in_thread",
]
