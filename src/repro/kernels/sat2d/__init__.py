from . import ops, ref
from .kernel import sat2d, scan_rows

__all__ = ["ops", "ref", "sat2d", "scan_rows"]
