"""Jit'd public wrappers for the sat2d kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import sat2d, scan_rows

__all__ = ["sat", "sat_moments", "delta_sat_moments", "sat_stack"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sat(x: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Summed-area table of a 2D array."""
    return sat2d(x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sat_moments(y: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """(3, n, m) integral images of (1, y, y^2): the coreset prefix stats.

    The three channels are folded into the row axis so both scan passes run
    as single kernel launches ((3n, m) row scan; (m, 3n) per-channel column
    scan via a channel-blocked layout)."""
    n, m = y.shape
    stk = jnp.stack([jnp.ones_like(y), y, y * y], axis=0)   # (3, n, m)
    r = scan_rows(stk.reshape(3 * n, m), interpret=interpret).reshape(3, n, m)
    # column pass: transpose each channel, fold channels into rows again
    rt = r.transpose(0, 2, 1).reshape(3 * m, n)
    c = scan_rows(rt, interpret=interpret).reshape(3, m, n).transpose(0, 2, 1)
    return c


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_sat_moments(carry: jnp.ndarray, tail: jnp.ndarray,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Patched integral-image rows (see ``ref.delta_sat_ref``): within-row
    prefix of the (1, y, y^2) stack of the changed rows, then a row-direction
    scan seeded from ``carry`` — two kernel launches regardless of how many
    rows changed."""
    b, m = tail.shape
    stk = jnp.stack([jnp.ones_like(tail), tail, tail * tail], axis=0)
    inner = scan_rows(stk.reshape(3 * b, m),
                      interpret=interpret).reshape(3, b, m)
    # row-direction scan: fold channels x columns into the scan rows and
    # seed the carry with the stored integral-image row above the patch
    rt = inner.transpose(0, 2, 1).reshape(3 * m, b)
    init = carry.astype(tail.dtype).reshape(3 * m, 1)
    out = scan_rows(rt, interpret=interpret, init=init).reshape(3, m, b)
    return out.transpose(0, 2, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sat_stack(stk: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Integral images over the last two axes of a batched stack — the
    Pallas body of the batched ``streaming_compress`` backend: the moment
    rasters of all dirty merge-reduce buckets fold into one (L*3*n, m) row
    scan + one (L*3*m, n) column scan."""
    *lead, n, m = stk.shape
    flat = 1
    for d in lead:
        flat *= int(d)
    x = stk.reshape(flat * n, m)
    r = scan_rows(x, interpret=interpret).reshape(flat, n, m)
    rt = r.transpose(0, 2, 1).reshape(flat * m, n)
    c = scan_rows(rt, interpret=interpret).reshape(flat, m, n)
    return c.transpose(0, 2, 1).reshape(*lead, n, m)
