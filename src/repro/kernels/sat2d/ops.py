"""Jit'd public wrappers for the sat2d kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import sat2d, scan_rows

__all__ = ["sat", "sat_moments"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sat(x: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Summed-area table of a 2D array."""
    return sat2d(x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sat_moments(y: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """(3, n, m) integral images of (1, y, y^2): the coreset prefix stats.

    The three channels are folded into the row axis so both scan passes run
    as single kernel launches ((3n, m) row scan; (m, 3n) per-channel column
    scan via a channel-blocked layout)."""
    n, m = y.shape
    stk = jnp.stack([jnp.ones_like(y), y, y * y], axis=0)   # (3, n, m)
    r = scan_rows(stk.reshape(3 * n, m), interpret=interpret).reshape(3, n, m)
    # column pass: transpose each channel, fold channels into rows again
    rt = r.transpose(0, 2, 1).reshape(3 * m, n)
    c = scan_rows(rt, interpret=interpret).reshape(3, m, n).transpose(0, 2, 1)
    return c
