"""Jit'd public wrappers for the sat2d kernel.

Each wrapper exists in two jitted flavours: the plain one, and — on
accelerator platforms only — one with **buffer donation** on the scan
inputs.  The ``repro.ops`` backends ship fresh host arrays to the device on
every call and never touch them again, so the carry/stack buffers can be
donated to XLA and their HBM reused for the outputs (free on CPU, where
donation is unimplemented and would only warn).  Callers that keep their
arrays (tests, the mesh scorer) use the default non-donating path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import sat2d, scan_rows

__all__ = ["sat", "sat_moments", "delta_sat_moments", "sat_stack"]

_DEFAULT_TILE = 256


@functools.cache
def _donation_supported() -> bool:
    return jax.default_backend() in ("tpu", "gpu")


@functools.partial(jax.jit, static_argnames=("interpret",))
def sat(x: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Summed-area table of a 2D array."""
    return sat2d(x, interpret=interpret)


def _sat_moments(y, tile, interpret):
    n, m = y.shape
    stk = jnp.stack([jnp.ones_like(y), y, y * y], axis=0)   # (3, n, m)
    r = scan_rows(stk.reshape(3 * n, m), tile, tile,
                  interpret=interpret).reshape(3, n, m)
    # column pass: transpose each channel, fold channels into rows again
    rt = r.transpose(0, 2, 1).reshape(3 * m, n)
    c = scan_rows(rt, tile, tile,
                  interpret=interpret).reshape(3, m, n).transpose(0, 2, 1)
    return c


_sat_moments_jit = functools.partial(jax.jit,
                                     static_argnames=("tile", "interpret"))
_sat_moments_plain = _sat_moments_jit(_sat_moments)
_sat_moments_donate = _sat_moments_jit(_sat_moments, donate_argnums=(0,))


def sat_moments(y: jnp.ndarray, tile: int = _DEFAULT_TILE,
                interpret: bool | None = None,
                donate: bool = False) -> jnp.ndarray:
    """(3, n, m) integral images of (1, y, y^2): the coreset prefix stats.

    The three channels are folded into the row axis so both scan passes run
    as single kernel launches ((3n, m) row scan; (m, 3n) per-channel column
    scan via a channel-blocked layout).  ``tile`` is the Pallas block edge
    the autotuner searches over; ``donate=True`` releases ``y``'s device
    buffer to XLA (accelerator platforms only — the caller must not reuse
    it)."""
    fn = (_sat_moments_donate if donate and _donation_supported()
          else _sat_moments_plain)
    return fn(y, tile=tile, interpret=interpret)


def _delta_sat_moments(carry, tail, tile, interpret):
    b, m = tail.shape
    stk = jnp.stack([jnp.ones_like(tail), tail, tail * tail], axis=0)
    inner = scan_rows(stk.reshape(3 * b, m), tile, tile,
                      interpret=interpret).reshape(3, b, m)
    # row-direction scan: fold channels x columns into the scan rows and
    # seed the carry with the stored integral-image row above the patch
    rt = inner.transpose(0, 2, 1).reshape(3 * m, b)
    init = carry.astype(tail.dtype).reshape(3 * m, 1)
    out = scan_rows(rt, tile, tile, interpret=interpret,
                    init=init).reshape(3, m, b)
    return out.transpose(0, 2, 1)


_delta_jit = functools.partial(jax.jit, static_argnames=("tile", "interpret"))
_delta_plain = _delta_jit(_delta_sat_moments)
_delta_donate = _delta_jit(_delta_sat_moments, donate_argnums=(0, 1))


def delta_sat_moments(carry: jnp.ndarray, tail: jnp.ndarray,
                      tile: int = _DEFAULT_TILE,
                      interpret: bool | None = None,
                      donate: bool = False) -> jnp.ndarray:
    """Patched integral-image rows (see ``ref.delta_sat_ref``): within-row
    prefix of the (1, y, y^2) stack of the changed rows, then a row-direction
    scan seeded from ``carry`` — two kernel launches regardless of how many
    rows changed.  ``donate=True`` hands the carry/tail buffers to XLA."""
    fn = (_delta_donate if donate and _donation_supported()
          else _delta_plain)
    return fn(carry, tail, tile=tile, interpret=interpret)


def _sat_stack(stk, tile, interpret):
    *lead, n, m = stk.shape
    flat = 1
    for d in lead:
        flat *= int(d)
    x = stk.reshape(flat * n, m)
    r = scan_rows(x, tile, tile, interpret=interpret).reshape(flat, n, m)
    rt = r.transpose(0, 2, 1).reshape(flat * m, n)
    c = scan_rows(rt, tile, tile, interpret=interpret).reshape(flat, m, n)
    return c.transpose(0, 2, 1).reshape(*lead, n, m)


_stack_jit = functools.partial(jax.jit, static_argnames=("tile", "interpret"))
_stack_plain = _stack_jit(_sat_stack)
_stack_donate = _stack_jit(_sat_stack, donate_argnums=(0,))


def sat_stack(stk: jnp.ndarray, tile: int = _DEFAULT_TILE,
              interpret: bool | None = None,
              donate: bool = False) -> jnp.ndarray:
    """Integral images over the last two axes of a batched stack — the
    Pallas body of the batched ``streaming_compress`` backend: the moment
    rasters of all dirty merge-reduce buckets fold into one (L*3*n, m) row
    scan + one (L*3*m, n) column scan.  ``donate=True`` hands the padded
    raster stack to XLA (it is rebuilt per call by the backend)."""
    fn = (_stack_donate if donate and _donation_supported()
          else _stack_plain)
    return fn(stk, tile=tile, interpret=interpret)
