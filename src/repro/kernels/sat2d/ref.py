"""Pure-jnp oracle for the 2D summed-area table (integral image), plus the
compensated-summation (two-float) f32 variants.

The compensated variants carry every partial sum as an unevaluated pair
``hi + lo`` of float32s (a "double-float"): prefix sums combine pairs with
Knuth's error-free TwoSum, so the rounding error of each addition lands in
the ``lo`` channel instead of being discarded.  The inputs are split the
same way (``hi = f32(x)``, ``lo = f32(x - f64(hi))``), which also captures
the f64 -> f32 cast error of the raw signal.  Recombining ``hi + lo`` in
f64 on the host yields integral images within ~1e-10 scaled relative error
of the f64 oracle — comfortably inside the 1e-6 certificate the autotuner
requires before it lifts a precision pin — at roughly 3-4x the flops of the
plain f32 scan, all of them accelerator-resident.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "sat2d_ref", "sat_moments_ref", "delta_sat_ref", "sat_stack_ref",
    "split_hi_lo", "comp_cumsum", "sat_moments_comp_ref",
    "delta_sat_comp_ref", "sat_stack_comp_ref",
]


def sat2d_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive 2D prefix sum of a (n, m) array."""
    return jnp.cumsum(jnp.cumsum(x, axis=0), axis=1)


def sat_moments_ref(y: jnp.ndarray) -> jnp.ndarray:
    """(3, n, m) integral images of (1, y, y^2) — the coreset's prefix stats.

    Canonical summation order is columns-within-row first, then down the
    rows: row i of the result is ``row i-1 + rowprefix(stk[i])``, which is
    exactly the recurrence ``delta_sat`` continues from a stored carry row.
    """
    stk = jnp.stack([jnp.ones_like(y), y, y * y], axis=0)
    return jnp.cumsum(jnp.cumsum(stk, axis=2), axis=1)


def delta_sat_ref(carry: jnp.ndarray, tail: jnp.ndarray) -> jnp.ndarray:
    """Patched integral-image rows for replaced/appended suffix rows.

    ``carry`` (3, m) is the integral-image row just above the patch (zeros
    when the patch starts at row 0); ``tail`` (b, m) holds the raw signal
    rows from the first changed row to the (new) end.  Returns (3, b, m):
    the rows of ``sat_moments_ref`` that change.
    """
    stk = jnp.stack([jnp.ones_like(tail), tail, tail * tail], axis=0)
    inner = jnp.cumsum(stk, axis=2)
    return carry[:, None, :] + jnp.cumsum(inner, axis=1)


def sat_stack_ref(stk: jnp.ndarray) -> jnp.ndarray:
    """Integral images over the last two axes of an arbitrarily-batched
    stack (columns-within-row first — same order as sat_moments_ref).  Used
    by the batched ``streaming_compress`` backends: one call integrates the
    moment rasters of every dirty merge-reduce bucket at once."""
    return jnp.cumsum(jnp.cumsum(stk, axis=-1), axis=-2)


# -------------------------------------------------- compensated (two-float)
def split_hi_lo(x) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split a float64 host array into an (hi, lo) float32 pair with
    ``hi + lo == x`` to f32-pair precision (~2^-48 relative)."""
    import numpy as np
    x = np.asarray(x, np.float64)
    hi = np.asarray(x, np.float32)
    lo = np.asarray(x - np.asarray(hi, np.float64), np.float32)
    return jnp.asarray(hi), jnp.asarray(lo)


def _two_sum(a, b):
    """Knuth TwoSum on (hi, lo) pairs: the rounding error of ``hi`` adds is
    recovered exactly and folded into ``lo``."""
    a_hi, a_lo = a
    b_hi, b_lo = b
    s = a_hi + b_hi
    z = s - a_hi
    err = (a_hi - (s - z)) + (b_hi - z)
    return s, a_lo + b_lo + err


def comp_cumsum(hi: jnp.ndarray, lo: jnp.ndarray,
                axis: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compensated inclusive prefix sum along ``axis`` over (hi, lo) pairs."""
    return jax.lax.associative_scan(_two_sum, (hi, lo), axis=axis)


def sat_moments_comp_ref(y_hi: jnp.ndarray, y_lo: jnp.ndarray
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(hi, lo) pairs of the (3, n, m) moment integral images.

    The ones channel is analytic — ``S0[i, j] = (i+1)(j+1)`` exactly, and
    f32 holds integers up to 2^24 — so only the S1/S2 channels pay for the
    compensated scans.  ``y^2`` enters as the pair
    ``(hi*hi, 2*hi*lo)``: the dropped ``lo^2`` term is ~2^-96 relative.
    """
    n, m = y_hi.shape
    hi2, lo2 = y_hi * y_hi, 2.0 * y_hi * y_lo
    stk_hi = jnp.stack([y_hi, hi2], 0)
    stk_lo = jnp.stack([y_lo, lo2], 0)
    h, l = comp_cumsum(stk_hi, stk_lo, axis=2)
    h, l = comp_cumsum(h, l, axis=1)
    counts = ((jnp.arange(1, n + 1, dtype=jnp.float32)[:, None]
               * jnp.arange(1, m + 1, dtype=jnp.float32)[None, :])[None])
    return (jnp.concatenate([counts, h], axis=0),
            jnp.concatenate([jnp.zeros_like(counts), l], axis=0))


def delta_sat_comp_ref(carry_hi, carry_lo, tail_hi, tail_lo
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compensated twin of ``delta_sat_ref``: (3, b, m) patched rows as
    (hi, lo) pairs, the stored carry row entering as its own pair so chained
    patches keep full two-float precision."""
    ones = jnp.ones_like(tail_hi)
    hi2, lo2 = tail_hi * tail_hi, 2.0 * tail_hi * tail_lo
    stk_hi = jnp.stack([ones, tail_hi, hi2], 0)
    stk_lo = jnp.stack([jnp.zeros_like(tail_hi), tail_lo, lo2], 0)
    h, l = comp_cumsum(stk_hi, stk_lo, axis=2)
    # continue the row recurrence from the carry pair: prepend, scan, drop
    h = jnp.concatenate([carry_hi[:, None, :], h], axis=1)
    l = jnp.concatenate([carry_lo[:, None, :], l], axis=1)
    h, l = comp_cumsum(h, l, axis=1)
    return h[:, 1:, :], l[:, 1:, :]


def sat_stack_comp_ref(stk_hi: jnp.ndarray, stk_lo: jnp.ndarray
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compensated twin of ``sat_stack_ref`` over (hi, lo) pairs."""
    h, l = comp_cumsum(stk_hi, stk_lo, axis=-1)
    return comp_cumsum(h, l, axis=-2)
