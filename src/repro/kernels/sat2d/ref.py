"""Pure-jnp oracle for the 2D summed-area table (integral image)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["sat2d_ref", "sat_moments_ref", "delta_sat_ref", "sat_stack_ref"]


def sat2d_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive 2D prefix sum of a (n, m) array."""
    return jnp.cumsum(jnp.cumsum(x, axis=0), axis=1)


def sat_moments_ref(y: jnp.ndarray) -> jnp.ndarray:
    """(3, n, m) integral images of (1, y, y^2) — the coreset's prefix stats.

    Canonical summation order is columns-within-row first, then down the
    rows: row i of the result is ``row i-1 + rowprefix(stk[i])``, which is
    exactly the recurrence ``delta_sat`` continues from a stored carry row.
    """
    stk = jnp.stack([jnp.ones_like(y), y, y * y], axis=0)
    return jnp.cumsum(jnp.cumsum(stk, axis=2), axis=1)


def delta_sat_ref(carry: jnp.ndarray, tail: jnp.ndarray) -> jnp.ndarray:
    """Patched integral-image rows for replaced/appended suffix rows.

    ``carry`` (3, m) is the integral-image row just above the patch (zeros
    when the patch starts at row 0); ``tail`` (b, m) holds the raw signal
    rows from the first changed row to the (new) end.  Returns (3, b, m):
    the rows of ``sat_moments_ref`` that change.
    """
    stk = jnp.stack([jnp.ones_like(tail), tail, tail * tail], axis=0)
    inner = jnp.cumsum(stk, axis=2)
    return carry[:, None, :] + jnp.cumsum(inner, axis=1)


def sat_stack_ref(stk: jnp.ndarray) -> jnp.ndarray:
    """Integral images over the last two axes of an arbitrarily-batched
    stack (columns-within-row first — same order as sat_moments_ref).  Used
    by the batched ``streaming_compress`` backends: one call integrates the
    moment rasters of every dirty merge-reduce bucket at once."""
    return jnp.cumsum(jnp.cumsum(stk, axis=-1), axis=-2)
