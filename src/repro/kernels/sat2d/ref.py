"""Pure-jnp oracle for the 2D summed-area table (integral image)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["sat2d_ref", "sat_moments_ref"]


def sat2d_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive 2D prefix sum of a (n, m) array."""
    return jnp.cumsum(jnp.cumsum(x, axis=0), axis=1)


def sat_moments_ref(y: jnp.ndarray) -> jnp.ndarray:
    """(3, n, m) integral images of (1, y, y^2) — the coreset's prefix stats."""
    stk = jnp.stack([jnp.ones_like(y), y, y * y], axis=0)
    return jnp.cumsum(jnp.cumsum(stk, axis=1), axis=2)
