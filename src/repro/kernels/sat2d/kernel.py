"""Pallas TPU kernel: blocked 2D prefix sum (summed-area table).

Two sequential-grid passes, each a 1D scan with a VMEM carry:

  pass 1 (rows):    grid = (R/TR, C/TC); within a (TR, TC) tile compute the
                    row-wise cumsum on the VPU and add the running carry
                    (TR, 1) kept in VMEM scratch.  TPU grids execute
                    sequentially with the last axis innermost, so the carry
                    is valid across the column tiles of one row band and is
                    reset when a new band starts (program_id(1) == 0).
  pass 2 (columns): the same kernel on the transposed layout.

Tile sizes default to (256, 256) f32 — 256 KiB per buffer, well inside the
~16 MiB/core VMEM budget including double buffering.  HBM traffic is one
read + one write per pass; the win over the XLA lowering is fusing the
(1, y, y^2) channel stack of the coreset's prefix-statistics stage into one
pass (see ops.sat_moments).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

__all__ = ["scan_rows", "sat2d"]


def _row_scan_kernel(x_ref, o_ref, carry_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    cs = jnp.cumsum(x_ref[...], axis=1) + carry_ref[...]
    o_ref[...] = cs
    carry_ref[...] = cs[:, -1:]


def _row_scan_seeded_kernel(x_ref, init_ref, o_ref, carry_ref):
    # identical to _row_scan_kernel except the running carry starts from a
    # caller-provided (TR, 1) column instead of zeros — the delta-SAT patch
    # continues a prefix sum from the integral-image row above the patch
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = init_ref[...]

    cs = jnp.cumsum(x_ref[...], axis=1) + carry_ref[...]
    o_ref[...] = cs
    carry_ref[...] = cs[:, -1:]


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_c", "interpret"))
def scan_rows(x: jnp.ndarray, tile_r: int = 256, tile_c: int = 256,
              interpret: bool | None = None,
              init: jnp.ndarray | None = None) -> jnp.ndarray:
    """Row-wise inclusive cumsum of a 2D array via the blocked kernel.

    ``init`` (optional, shape (n, 1)) seeds the running carry of each row:
    row i scans as ``init[i] + cumsum(x[i])`` — the continuation used by the
    ``delta_sat`` patch op, where ``init`` is the last unchanged prefix row.
    """
    if interpret is None:
        interpret = default_interpret()
    n, m = x.shape
    tr, tc = min(tile_r, n), min(tile_c, m)
    pad_r, pad_c = (-n) % tr, (-m) % tc
    xp = jnp.pad(x, ((0, pad_r), (0, pad_c)))
    np_, mp = xp.shape
    if init is None:
        out = pl.pallas_call(
            _row_scan_kernel,
            grid=(np_ // tr, mp // tc),
            in_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((np_, mp), x.dtype),
            scratch_shapes=[pltpu.VMEM((tr, 1), x.dtype)],
            interpret=interpret,
        )(xp)
    else:
        ip = jnp.pad(init.astype(x.dtype), ((0, pad_r), (0, 0)))
        out = pl.pallas_call(
            _row_scan_seeded_kernel,
            grid=(np_ // tr, mp // tc),
            in_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
                      pl.BlockSpec((tr, 1), lambda i, j: (i, 0))],
            out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((np_, mp), x.dtype),
            scratch_shapes=[pltpu.VMEM((tr, 1), x.dtype)],
            interpret=interpret,
        )(xp, ip)
    return out[:n, :m]


def sat2d(x: jnp.ndarray, tile: int = 256, interpret: bool | None = None) -> jnp.ndarray:
    """Inclusive 2D prefix sum: row scan, then column scan (transposed)."""
    r = scan_rows(x, tile, tile, interpret=interpret)
    return scan_rows(r.T, tile, tile, interpret=interpret).T
