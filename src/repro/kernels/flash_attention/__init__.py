from . import ops, ref
from .kernel import flash_attention_call

__all__ = ["ops", "ref", "flash_attention_call"]
