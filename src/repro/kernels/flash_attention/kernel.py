"""Pallas TPU kernel: causal FlashAttention with online softmax.

Blocked over (batch*heads, Lq/TQ, Lk/TK) with the key axis innermost and
sequential; running (max, sum, acc) live in VMEM scratch across key tiles —
the classic memory-hierarchy adaptation: HBM traffic O(L*D) instead of the
O(L^2) score matrix, with (TQ x D) @ (D x TK) and (TQ x TK) @ (TK x D)
contractions on the MXU.  Tiles default to TQ = TK = 256, D <= 256:
~0.8 MiB of f32 scratch + double-buffered operands in VMEM.

GQA is handled in the index maps (query head h reads KV head h // group) —
no materialized K/V repeat in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

__all__ = ["flash_attention_call"]

_NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, q_offset: int, lk_real: int):
    kt = pl.program_id(2)
    qt = pl.program_id(1)

    @pl.when(kt == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # (TQ, D)
    k = k_ref[0]                                  # (TK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    tq, tk = s.shape
    ki = kt * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    if causal:
        qi = qt * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0) + q_offset
        s = jnp.where((ki <= qi) & (ki < lk_real), s, _NEG)
    else:
        s = jnp.where(ki < lk_real, s, _NEG)      # mask padded keys

    m_prev = m_ref[...]                            # (TQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (TQ, TK) f32
    corr = jnp.exp(m_prev - m_new)                 # (TQ, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kt == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "tile_q", "tile_k",
                                             "interpret"))
def flash_attention_call(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         causal: bool = True, tile_q: int = 256,
                         tile_k: int = 256,
                         interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D). Returns (B, Hq, Lq, D)."""
    if interpret is None:
        interpret = default_interpret()
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = 1.0 / float(D) ** 0.5
    tq, tk = min(tile_q, Lq), min(tile_k, Lk)
    pad_q, pad_k = (-Lq) % tq, (-Lk) % tk
    q_offset = Lk - Lq  # decode-style causal alignment
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # fold batch and heads
    qf = qp.reshape(B * Hq, qp.shape[2], D)
    kf = kp.reshape(B * Hkv, kp.shape[2], D)
    vf = vp.reshape(B * Hkv, vp.shape[2], D)

    grid = (B * Hq, qp.shape[2] // tq, kp.shape[2] // tk)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, lk_real=Lk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, tk, D), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, tk, D), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, qp.shape[2], D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, D), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, qp.shape[2], D)[:, :, :Lq, :]
