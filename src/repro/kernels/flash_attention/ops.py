"""Jit'd public wrapper for flash attention."""
from __future__ import annotations

from .kernel import flash_attention_call

__all__ = ["flash_attention"]


def flash_attention(q, k, v, causal: bool = True, interpret: bool | None = None):
    """Causal GQA flash attention; q (B,Hq,L,D), k/v (B,Hkv,L,D)."""
    return flash_attention_call(q, k, v, causal=causal, interpret=interpret)
