"""Pure-jnp oracle: causal (optionally GQA) attention."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D) with Hq % Hkv == 0."""
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        # decode-style alignment: query i attends to keys <= i + (Lk - Lq)
        qi = jnp.arange(Lq)[:, None] + (Lk - Lq)
        ki = jnp.arange(Lk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)
