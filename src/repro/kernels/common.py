"""Shared kernel plumbing: interpret-mode default and tiling helpers.

All kernels are written against the TPU backend (pl.pallas_call + BlockSpec
VMEM tiling, MXU-aligned shapes); on CPU they run the kernel body under
``interpret=True`` (the correctness path used by the test suite — this
container has no TPU).
"""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "ceil_div", "pad_to"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x, multiple: int, axis: int):
    import jax.numpy as jnp
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size
