from . import ops, ref
from .kernel import fitting_loss_batched_call, fitting_loss_call

__all__ = ["ops", "ref", "fitting_loss_call", "fitting_loss_batched_call"]
