"""Pallas TPU kernel: Algorithm 5 (FITTING-LOSS) evaluation, fused.

The tree-tuning inner loop evaluates many candidate k-trees against the
coreset.  Per (block-tile, all K leaves): rectangle-overlap counts, the
cumulative-mass interval overlap (the closed form of the paper's while-loop,
see core/fitting_loss.py), and the weighted squared-difference reduction —
all fused in VMEM, so HBM traffic is one read of the coreset tile and the
(K, 5) segmentation instead of a (B, K, 4) intermediate.

Grid: (B / TB,).  Blocks: coreset tile (TB, 16) (rects|labels|weights packed
and padded to the lane quantum), segmentation (K, 8).  Output: per-tile
partial sums (grid, 8) reduced by the wrapper (keeps the kernel free of
cross-tile accumulation ordering concerns).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import default_interpret

__all__ = ["fitting_loss_call"]


def _fl_kernel(blk_ref, seg_ref, o_ref):
    blk = blk_ref[...]                         # (TB, 16)
    rects = blk[:, 0:4]
    labels4 = blk[:, 4:8]
    weights4 = blk[:, 8:12]
    seg = seg_ref[...]                         # (K, 8)
    seg_rects = seg[:, 0:4]
    seg_labels = seg[:, 4]

    z_r = jnp.clip(jnp.minimum(rects[:, None, 1], seg_rects[None, :, 1])
                   - jnp.maximum(rects[:, None, 0], seg_rects[None, :, 0]), 0, None)
    z_c = jnp.clip(jnp.minimum(rects[:, None, 3], seg_rects[None, :, 3])
                   - jnp.maximum(rects[:, None, 2], seg_rects[None, :, 2]), 0, None)
    z = z_r * z_c                              # (TB, K)
    Z = jnp.cumsum(z, axis=1)
    Zp = Z - z
    U = jnp.cumsum(weights4, axis=1)
    Up = U - weights4
    lo = jnp.maximum(Zp[:, :, None], Up[:, None, :])
    hi = jnp.minimum(Z[:, :, None], U[:, None, :])
    consumed = jnp.clip(hi - lo, 0.0, None)    # (TB, K, 4)
    diff = seg_labels[None, :, None] - labels4[:, None, :]
    part = (consumed * diff * diff).sum()
    o_ref[...] = jnp.full_like(o_ref, part)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def fitting_loss_call(rects, labels4, weights4, seg_rects, seg_labels,
                      tile_b: int = 1024, interpret: bool | None = None):
    """Scalar Algorithm-5 loss. rects/labels4/weights4: (B, 4) f32;
    seg_rects: (K, 4) f32; seg_labels: (K,) f32."""
    if interpret is None:
        interpret = default_interpret()
    B = rects.shape[0]
    K = seg_rects.shape[0]
    tb = min(tile_b, max(B, 1))
    pad = (-B) % tb
    blk = jnp.concatenate([rects, labels4, weights4,
                           jnp.zeros((B, 4), rects.dtype)], axis=1)  # (B,16)
    if pad:
        blk = jnp.pad(blk, ((0, pad), (0, 0)))   # zero-weight blocks: no loss
    seg = jnp.concatenate([seg_rects, seg_labels[:, None],
                           jnp.zeros((K, 3), seg_rects.dtype)], axis=1)  # (K,8)
    grid = (blk.shape[0] // tb,)
    partials = pl.pallas_call(
        _fl_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, 16), lambda i: (i, 0)),
            pl.BlockSpec((K, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 8), jnp.float32),
        interpret=interpret,
    )(blk.astype(jnp.float32), seg.astype(jnp.float32))
    return partials[:, 0].sum()
