"""Pallas TPU kernels: Algorithm 5 (FITTING-LOSS) evaluation, fused.

The tree-tuning inner loop evaluates many candidate k-trees against the
coreset.  Per (block-tile, all K leaves): rectangle-overlap counts, the
cumulative-mass interval overlap (the closed form of the paper's while-loop,
see core/fitting_loss.py), and the weighted squared-difference reduction —
all fused in VMEM, so HBM traffic is one read of the coreset tile and the
(K, 5) segmentation instead of a (B, K, 4) intermediate.

Two entry points:

``fitting_loss_call``        one segmentation.  Grid (B/TB,); blocks:
                             coreset tile (TB, 16) (rects|labels|weights
                             packed and padded to the lane quantum),
                             segmentation (K, 8); output per-tile partial
                             sums (grid, 8) reduced by the wrapper.

``fitting_loss_batched_call``  T segmentations in ONE pallas_call (the
                             serving /v1/query/loss:batch and tuning-sweep
                             hot path — previously a per-segmentation
                             Python loop).  Grid (T/TT, B/TB) with the
                             B axis innermost: TPU grids execute
                             sequentially with the last axis fastest, so
                             each (TT, 8) output tile accumulates its
                             B-tile partial losses in place (initialized
                             at b == 0 — the histsplit accumulation
                             pattern).  The coreset tile is read once per
                             (t, b) cell and scored against TT candidate
                             trees while resident in VMEM, amortizing the
                             HBM read T/TT-fold versus the looped kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import default_interpret

__all__ = ["fitting_loss_call", "fitting_loss_batched_call"]


def _smoothed_loss_terms(rects, labels4, weights4, seg_rects, seg_labels):
    """Smoothed-assignment loss contributions, batched over leading axes.

    rects/labels4/weights4: (TB, 4); seg_rects: (..., K, 4);
    seg_labels: (..., K).  Returns the consumed-mass weighted squared
    differences with shape (TB, ..., K, 4); callers reduce.
    """
    extra = seg_rects.ndim - 1               # broadcast axes: (TT,) K or K
    rshape = (rects.shape[0],) + (1,) * extra
    z_r = jnp.clip(jnp.minimum(rects[:, 1].reshape(rshape), seg_rects[None, ..., 1])
                   - jnp.maximum(rects[:, 0].reshape(rshape), seg_rects[None, ..., 0]),
                   0, None)
    z_c = jnp.clip(jnp.minimum(rects[:, 3].reshape(rshape), seg_rects[None, ..., 3])
                   - jnp.maximum(rects[:, 2].reshape(rshape), seg_rects[None, ..., 2]),
                   0, None)
    z = z_r * z_c                                  # (TB, ..., K)
    Z = jnp.cumsum(z, axis=-1)
    Zp = Z - z
    U = jnp.cumsum(weights4, axis=1)               # (TB, 4)
    Up = U - weights4
    # broadcast U/Up (TB, 4) against Z (TB, ..., K) -> (TB, ..., K, 4)
    shape = (U.shape[0],) + (1,) * extra + (4,)
    lo = jnp.maximum(Zp[..., None], Up.reshape(shape))
    hi = jnp.minimum(Z[..., None], U.reshape(shape))
    consumed = jnp.clip(hi - lo, 0.0, None)        # (TB, ..., K, 4)
    diff = seg_labels[None, ..., None] - labels4.reshape(shape)
    return consumed * diff * diff


def _fl_kernel(blk_ref, seg_ref, o_ref):
    blk = blk_ref[...]                         # (TB, 16)
    seg = seg_ref[...]                         # (K, 8)
    part = _smoothed_loss_terms(blk[:, 0:4], blk[:, 4:8], blk[:, 8:12],
                                seg[:, 0:4], seg[:, 4]).sum()
    o_ref[...] = jnp.full_like(o_ref, part)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def fitting_loss_call(rects, labels4, weights4, seg_rects, seg_labels,
                      tile_b: int = 1024, interpret: bool | None = None):
    """Scalar Algorithm-5 loss. rects/labels4/weights4: (B, 4) f32;
    seg_rects: (K, 4) f32; seg_labels: (K,) f32."""
    if interpret is None:
        interpret = default_interpret()
    B = rects.shape[0]
    K = seg_rects.shape[0]
    tb = min(tile_b, max(B, 1))
    pad = (-B) % tb
    blk = jnp.concatenate([rects, labels4, weights4,
                           jnp.zeros((B, 4), rects.dtype)], axis=1)  # (B,16)
    if pad:
        blk = jnp.pad(blk, ((0, pad), (0, 0)))   # zero-weight blocks: no loss
    seg = jnp.concatenate([seg_rects, seg_labels[:, None],
                           jnp.zeros((K, 3), seg_rects.dtype)], axis=1)  # (K,8)
    grid = (blk.shape[0] // tb,)
    partials = pl.pallas_call(
        _fl_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, 16), lambda i: (i, 0)),
            pl.BlockSpec((K, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 8), jnp.float32),
        interpret=interpret,
    )(blk.astype(jnp.float32), seg.astype(jnp.float32))
    return partials[:, 0].sum()


def _fl_batched_kernel(seg_ref, blk_ref, o_ref):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = blk_ref[...]                         # (TB, 16)
    seg = seg_ref[...]                         # (TT, K, 8)
    terms = _smoothed_loss_terms(blk[:, 0:4], blk[:, 4:8], blk[:, 8:12],
                                 seg[:, :, 0:4], seg[:, :, 4])
    part = terms.sum(axis=(0, 2, 3))           # (TT,)
    o_ref[...] += part[:, None]


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "tile_t", "interpret"))
def fitting_loss_batched_call(rects, labels4, weights4, seg_rects, seg_labels,
                              tile_b: int = 512, tile_t: int = 8,
                              interpret: bool | None = None):
    """(T,) Algorithm-5 losses, one pallas_call for the whole candidate set.

    rects/labels4/weights4: (B, 4) f32; seg_rects: (T, K, 4) f32;
    seg_labels: (T, K) f32.  B pads with zero-weight blocks (no loss),
    T pads with zero segmentations (rows sliced off).  ``tile_b`` is capped
    so the fused (TB, TT, K, 4) intermediate stays inside the VMEM budget.
    """
    if interpret is None:
        interpret = default_interpret()
    B = rects.shape[0]
    T, K = seg_rects.shape[0], seg_rects.shape[1]
    tt = min(tile_t, max(T, 1))
    # (TB, TT, K, 4) f32 working set <= ~4 MiB alongside double buffering
    vmem_cap = max(8, (1 << 20) // max(tt * K * 4, 1))
    tb = min(tile_b, max(B, 1), vmem_cap)
    pad_b = (-B) % tb
    pad_t = (-T) % tt

    blk = jnp.concatenate([rects, labels4, weights4,
                           jnp.zeros((B, 4), rects.dtype)], axis=1)  # (B,16)
    if pad_b:
        blk = jnp.pad(blk, ((0, pad_b), (0, 0)))
    seg = jnp.concatenate([seg_rects, seg_labels[..., None],
                           jnp.zeros((T, K, 3), seg_rects.dtype)],
                          axis=-1)                                   # (T,K,8)
    if pad_t:
        seg = jnp.pad(seg, ((0, pad_t), (0, 0), (0, 0)))
    Tp = seg.shape[0]
    grid = (Tp // tt, blk.shape[0] // tb)      # B innermost: accumulation
    out = pl.pallas_call(
        _fl_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, K, 8), lambda t, b: (t, 0, 0)),
            pl.BlockSpec((tb, 16), lambda t, b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((tt, 8), lambda t, b: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, 8), jnp.float32),
        interpret=interpret,
    )(seg.astype(jnp.float32), blk.astype(jnp.float32))
    return out[:T, 0]
