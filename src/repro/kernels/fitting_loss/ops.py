"""Jit'd public wrapper: evaluate a segmentation (or many) on a coreset."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import fitting_loss_call

__all__ = ["coreset_loss", "coreset_loss_many"]


def coreset_loss(cs, seg_rects, seg_labels, interpret: bool | None = None):
    """Algorithm-5 loss of one segmentation against a SignalCoreset."""
    return fitting_loss_call(
        jnp.asarray(cs.rects, jnp.float32), jnp.asarray(cs.labels, jnp.float32),
        jnp.asarray(cs.weights, jnp.float32),
        jnp.asarray(seg_rects, jnp.float32), jnp.asarray(seg_labels, jnp.float32),
        interpret=interpret)


def coreset_loss_many(cs, seg_rects_batch, seg_labels_batch,
                      interpret: bool | None = None):
    """(T,) losses for T segmentations (the tuning inner loop)."""
    rects = jnp.asarray(cs.rects, jnp.float32)
    lab = jnp.asarray(cs.labels, jnp.float32)
    wgt = jnp.asarray(cs.weights, jnp.float32)
    out = [fitting_loss_call(rects, lab, wgt,
                             jnp.asarray(sr, jnp.float32),
                             jnp.asarray(sl, jnp.float32), interpret=interpret)
           for sr, sl in zip(seg_rects_batch, seg_labels_batch)]
    return jnp.stack(out)
