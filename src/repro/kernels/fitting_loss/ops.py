"""Jit'd public wrapper: evaluate a segmentation (or many) on a coreset.

``coreset_loss`` remains the thin coreset-to-arrays adapter the pallas
backend of ``repro.ops`` registers.  ``coreset_loss_many`` is a deprecated
shim: the per-segmentation Python loop it used to run is gone — it now
delegates to the dispatched batched op (one fused evaluation for all T
candidates).  New code should call ``repro.ops.fitting_loss_batched``.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp
import numpy as np

from .kernel import fitting_loss_batched_call, fitting_loss_call

__all__ = ["coreset_loss", "coreset_loss_batched", "coreset_loss_many"]

_MANY_DEPRECATION_WARNED = False


def coreset_loss(cs, seg_rects, seg_labels, interpret: bool | None = None,
                 tile_b: int = 1024):
    """Algorithm-5 loss of one segmentation against a SignalCoreset.
    ``tile_b`` is the coreset-block tile edge the autotuner searches over."""
    return fitting_loss_call(
        jnp.asarray(cs.rects, jnp.float32), jnp.asarray(cs.labels, jnp.float32),
        jnp.asarray(cs.weights, jnp.float32),
        jnp.asarray(seg_rects, jnp.float32), jnp.asarray(seg_labels, jnp.float32),
        tile_b=tile_b, interpret=interpret)


def coreset_loss_batched(cs, seg_rects, seg_labels,
                         interpret: bool | None = None,
                         tile_b: int = 512, tile_t: int = 8):
    """(T,) losses via the batched kernel: seg_rects (T, K, 4),
    seg_labels (T, K) scored in one pallas_call.  ``tile_b``/``tile_t``
    are the block/tree tile edges the autotuner searches over."""
    return fitting_loss_batched_call(
        jnp.asarray(cs.rects, jnp.float32), jnp.asarray(cs.labels, jnp.float32),
        jnp.asarray(cs.weights, jnp.float32),
        jnp.asarray(seg_rects, jnp.float32), jnp.asarray(seg_labels, jnp.float32),
        tile_b=tile_b, tile_t=tile_t, interpret=interpret)


def coreset_loss_many(cs, seg_rects_batch, seg_labels_batch,
                      interpret: bool | None = None):
    """Deprecated: use ``repro.ops.fitting_loss_batched``.

    Kept so existing callers and examples keep working; delegates to the
    backend dispatcher (or straight to the batched kernel when ``interpret``
    is pinned), so the old per-segmentation loop no longer exists.
    """
    global _MANY_DEPRECATION_WARNED
    if not _MANY_DEPRECATION_WARNED:
        _MANY_DEPRECATION_WARNED = True
        warnings.warn(
            "coreset_loss_many is deprecated; use repro.ops.fitting_loss_batched",
            DeprecationWarning, stacklevel=2)
    rs = [np.asarray(r, np.float64) for r in seg_rects_batch]
    ls = [np.asarray(l, np.float64) for l in seg_labels_batch]
    if len({r.shape for r in rs}) > 1:
        # ragged candidate set (differing leaf counts) — the old loop
        # accepted it, so score per segmentation; uniform K stays fused
        if interpret is not None:
            return jnp.stack([coreset_loss(cs, r, l, interpret=interpret)
                              for r, l in zip(rs, ls)])
        from repro import ops
        return jnp.asarray([ops.fitting_loss(cs, r, l)
                            for r, l in zip(rs, ls)])
    sr, sl = np.stack(rs), np.stack(ls)
    if interpret is not None:
        return coreset_loss_batched(cs, sr, sl, interpret=interpret)
    from repro import ops
    return jnp.asarray(ops.fitting_loss_batched(cs, sr, sl))
