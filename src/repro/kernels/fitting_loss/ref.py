"""Pure-jnp oracle for the Algorithm-5 smoothed-assignment loss.

``fitting_loss_ref`` is also the single source of the dense math: the
``repro.ops`` xla backend jits it, and ``core.sharded`` shards the vmapped
``fitting_loss_batched_ref`` over the device mesh — there is no second
hand-written dense implementation anywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fitting_loss_ref", "fitting_loss_batched_ref"]


def fitting_loss_ref(rects, labels4, weights4, seg_rects, seg_labels):
    """Dense Algorithm 5 over all (block, leaf, point) triples.

    rects (B,4) f32 half-open block corners; labels4/weights4 (B,4);
    seg_rects (K,4); seg_labels (K,).  Returns the scalar loss.
    (The smoothed path reduces to the exact moment formula when one leaf
    covers a block, so no separate exact branch is needed.)
    """
    z_r = jnp.clip(jnp.minimum(rects[:, None, 1], seg_rects[None, :, 1])
                   - jnp.maximum(rects[:, None, 0], seg_rects[None, :, 0]), 0, None)
    z_c = jnp.clip(jnp.minimum(rects[:, None, 3], seg_rects[None, :, 3])
                   - jnp.maximum(rects[:, None, 2], seg_rects[None, :, 2]), 0, None)
    z = (z_r * z_c).astype(jnp.float32)              # (B, K)
    Z = jnp.cumsum(z, axis=1)
    Zp = Z - z
    U = jnp.cumsum(weights4, axis=1)                  # (B, 4)
    Up = U - weights4
    lo = jnp.maximum(Zp[:, :, None], Up[:, None, :])
    hi = jnp.minimum(Z[:, :, None], U[:, None, :])
    consumed = jnp.clip(hi - lo, 0.0, None)           # (B, K, 4)
    diff = seg_labels[None, :, None] - labels4[:, None, :]
    return (consumed * diff * diff).sum()


def fitting_loss_batched_ref(rects, labels4, weights4, seg_rects, seg_labels):
    """(T,) dense losses for T segmentations: seg_rects (T, K, 4),
    seg_labels (T, K).  vmap of :func:`fitting_loss_ref` over candidates —
    every device in the sharded path scores its block shard against all T
    trees at once."""
    return jax.vmap(
        lambda r, l: fitting_loss_ref(rects, labels4, weights4, r, l)
    )(seg_rects, seg_labels)
