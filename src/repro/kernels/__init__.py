# Pallas TPU kernels for the compute hot spots (validated with
# interpret=True on CPU; see DESIGN.md §4 for the GPU->TPU adaptations):
#   sat2d           - blocked 2D prefix sums (coreset prefix statistics)
#   histsplit       - split histograms as one-hot MXU matmuls (CART/GBDT)
#   flash_attention - causal GQA flash attention (LM substrate)
#   fitting_loss    - Algorithm-5 coreset queries, fused
from . import fitting_loss, flash_attention, histsplit, sat2d  # noqa: F401
