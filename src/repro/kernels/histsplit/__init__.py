from . import ops, ref
from .kernel import histograms_kernel_call

__all__ = ["ops", "ref", "histograms_kernel_call"]
