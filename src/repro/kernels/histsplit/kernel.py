"""Pallas TPU kernel: CART/GBDT split histograms as one-hot MXU matmuls.

GPU gradient-boosting libraries (LightGBM/XGBoost CUDA) build per-node split
histograms with shared-memory **atomic scatter-adds**.  TPUs have no atomics
and no efficient scatter — the TPU-native reformulation (DESIGN.md §4) is

    hist[f] = onehot(codes[:, f])^T  @  [w | wy | wy2]      (B x P)(P x S)

i.e. a dense one-hot contraction that runs on the **MXU systolic array**.
The one-hot tile is materialized in VMEM from an iota comparison (never in
HBM), so HBM traffic is just codes + values + the (F, B, S) output.

Grid: (F, P/TP).  The P axis is innermost and sequential on TPU, so the
output block (B, S) for feature f accumulates across P tiles in place.
Tiles: TP = 512 rows; B = 256 bins (lane-aligned); S = 8 value lanes
(w, wy, wy2 + padding to the f32 sublane quantum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import default_interpret

__all__ = ["histograms_kernel_call"]

_S_PAD = 8  # value lanes (3 used), padded for layout friendliness


def _hist_kernel(codes_ref, vals_ref, o_ref):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[0, :]                                   # (TP,) int32
    n_bins = o_ref.shape[1]
    onehot = (codes[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (codes.shape[0], n_bins), 1)).astype(vals_ref.dtype)
    # (B, TP) @ (TP, S) on the MXU
    o_ref[0] += jnp.dot(onehot.T, vals_ref[...],
                        preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_bins", "tile_p", "interpret"))
def histograms_kernel_call(codes_fp: jnp.ndarray, vals: jnp.ndarray,
                           n_bins: int, tile_p: int = 512,
                           interpret: bool | None = None) -> jnp.ndarray:
    """codes_fp: (F, P) int32; vals: (P, S<=8) f32. Returns (F, n_bins, S)."""
    if interpret is None:
        interpret = default_interpret()
    F, P = codes_fp.shape
    S = vals.shape[1]
    tp = min(tile_p, P)
    pad = (-P) % tp
    if pad:
        codes_fp = jnp.pad(codes_fp, ((0, 0), (0, pad)), constant_values=n_bins - 1)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))  # zero weights: no effect
    Pp = codes_fp.shape[1]
    vals_p = jnp.pad(vals, ((0, 0), (0, _S_PAD - S))) if S < _S_PAD else vals
    out = pl.pallas_call(
        _hist_kernel,
        grid=(F, Pp // tp),
        in_specs=[
            pl.BlockSpec((1, tp), lambda f, p: (f, p)),
            pl.BlockSpec((tp, _S_PAD), lambda f, p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_bins, _S_PAD), lambda f, p: (f, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, n_bins, _S_PAD), vals.dtype),
        interpret=interpret,
    )(codes_fp, vals_p)
    return out[:, :, :S]
