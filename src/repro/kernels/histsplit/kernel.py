"""Pallas TPU kernel: CART/GBDT split histograms as one-hot MXU matmuls.

GPU gradient-boosting libraries (LightGBM/XGBoost CUDA) build per-node split
histograms with shared-memory **atomic scatter-adds**.  TPUs have no atomics
and no efficient scatter — the TPU-native reformulation (DESIGN.md §4) is a
dense one-hot contraction on the **MXU systolic array**:

    hist[f] = [w | wy | wy2]^T  @  onehot(codes[:, f])       (S x P)(P x B)

The one-hot tile is materialized in VMEM from an iota comparison (never in
HBM), so HBM traffic is just codes + values + the (F, S, B) output.

Grid: (P/TP,) — **one** grid axis.  Each step loads one (F, TP) codes tile
and one (TP, S) values tile and accumulates all F per-feature histograms in
place (the P axis is sequential on TPU, so in-place accumulation across
steps is sound).  Folding the feature loop into the kernel body instead of
a second grid axis divides the launch/step count by F and loads the values
tile once per P tile instead of once per (feature, P) tile.

The matmul is laid out as (S, TP) @ (TP, B): the B bins ride the 128-wide
lane axis (fully utilized for B >= 128) and the S value channels ride the
sublane axis.  The transposed layout this kernel replaced — (B, TP) @
(TP, S) with S = 8 output lanes — wasted 15/16 of every MXU output tile and
ran F x P/TP grid steps; it survives as ``variant="legacy"`` so the
autotuner can measure the difference on real hardware (and so the bench can
record the before/after), but is never picked.

``accumulate=False`` ("partials" variant) skips the cross-tile accumulation
and emits per-P-tile partial histograms (P/TP, F, S, B) instead: the host
combines them in f64, turning the f32 scatter-order error of a long P axis
into a handful of f64 adds — the compensated path the autotuner certifies
for precision-pinned dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import default_interpret

__all__ = ["histograms_kernel_call"]

_S_PAD = 8  # value lanes (3 used), padded to the f32 sublane quantum


def _hist_kernel(codes_ref, vals_ref, o_ref):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    F = codes_ref.shape[0]
    n_bins = o_ref.shape[2]
    vals_t = vals_ref[...].T                                  # (S, TP)
    iota = jax.lax.broadcasted_iota(
        jnp.int32, (codes_ref.shape[1], n_bins), 1)
    for f in range(F):                                        # static unroll
        onehot = (codes_ref[f, :][:, None] == iota).astype(vals_ref.dtype)
        # (S, TP) @ (TP, B): bins on the lane axis, channels on sublanes
        o_ref[f] += jnp.dot(vals_t, onehot,
                            preferred_element_type=o_ref.dtype)


def _hist_kernel_partials(codes_ref, vals_ref, o_ref):
    # the compensated variant: no cross-tile accumulation — each grid step
    # owns its own output block, the host reduces the P/TP partials in f64
    F = codes_ref.shape[0]
    n_bins = o_ref.shape[3]
    vals_t = vals_ref[...].T
    iota = jax.lax.broadcasted_iota(
        jnp.int32, (codes_ref.shape[1], n_bins), 1)
    for f in range(F):
        onehot = (codes_ref[f, :][:, None] == iota).astype(vals_ref.dtype)
        o_ref[0, f] = jnp.dot(vals_t, onehot,
                              preferred_element_type=o_ref.dtype)


def _hist_kernel_legacy(codes_ref, vals_ref, o_ref):
    # pre-fix kernel, kept for the autotuner/bench as variant="legacy":
    # grid (F, P/TP), one feature per step, (B, TP) @ (TP, S) layout
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[0, :]                                   # (TP,) int32
    n_bins = o_ref.shape[1]
    onehot = (codes[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (codes.shape[0], n_bins), 1)).astype(vals_ref.dtype)
    o_ref[0] += jnp.dot(onehot.T, vals_ref[...],
                        preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_bins", "tile_p", "variant",
                                             "interpret"))
def histograms_kernel_call(codes_fp: jnp.ndarray, vals: jnp.ndarray,
                           n_bins: int, tile_p: int = 2048,
                           variant: str = "fused",
                           interpret: bool | None = None) -> jnp.ndarray:
    """codes_fp: (F, P) int32; vals: (P, S<=8) f32.

    Returns (F, n_bins, S) for ``variant`` in {"fused", "legacy"}; the
    "partials" variant returns (P/TP, F, n_bins, S) per-tile partials for
    the host to combine in f64 (the compensated path).
    """
    if interpret is None:
        interpret = default_interpret()
    F, P = codes_fp.shape
    S = vals.shape[1]
    tp = min(tile_p, P)
    pad = (-P) % tp
    if pad:
        codes_fp = jnp.pad(codes_fp, ((0, 0), (0, pad)),
                           constant_values=n_bins - 1)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))  # zero weights: no effect
    Pp = codes_fp.shape[1]
    vals_p = jnp.pad(vals, ((0, 0), (0, _S_PAD - S))) if S < _S_PAD else vals
    if variant == "legacy":
        out = pl.pallas_call(
            _hist_kernel_legacy,
            grid=(F, Pp // tp),
            in_specs=[
                pl.BlockSpec((1, tp), lambda f, p: (f, p)),
                pl.BlockSpec((tp, _S_PAD), lambda f, p: (p, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_bins, _S_PAD), lambda f, p: (f, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((F, n_bins, _S_PAD), vals.dtype),
            interpret=interpret,
        )(codes_fp, vals_p)
        return out[:, :, :S]
    if variant == "partials":
        out = pl.pallas_call(
            _hist_kernel_partials,
            grid=(Pp // tp,),
            in_specs=[pl.BlockSpec((F, tp), lambda p: (0, p)),
                      pl.BlockSpec((tp, _S_PAD), lambda p: (p, 0))],
            out_specs=pl.BlockSpec((1, F, _S_PAD, n_bins),
                                   lambda p: (p, 0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((Pp // tp, F, _S_PAD, n_bins),
                                           vals.dtype),
            interpret=interpret,
        )(codes_fp, vals_p)
        return out[:, :, :S, :].transpose(0, 1, 3, 2)  # (C, F, n_bins, S)
    if variant != "fused":
        raise ValueError(f"unknown histsplit variant {variant!r}")
    out = pl.pallas_call(
        _hist_kernel,
        grid=(Pp // tp,),
        in_specs=[pl.BlockSpec((F, tp), lambda p: (0, p)),
                  pl.BlockSpec((tp, _S_PAD), lambda p: (p, 0))],
        out_specs=pl.BlockSpec((F, _S_PAD, n_bins), lambda p: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((F, _S_PAD, n_bins), vals.dtype),
        interpret=interpret,
    )(codes_fp, vals_p)
    return out[:, :S, :].transpose(0, 2, 1)            # (F, n_bins, S)
