"""Pure-jnp oracle for split-histogram building."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["histograms_ref"]


def histograms_ref(codes: jnp.ndarray, w: jnp.ndarray, wy: jnp.ndarray,
                   wy2: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """(F, n_bins, 3) sums of (w, wy, wy2) per feature x bin.

    codes: (P, F) integer bin ids; w/wy/wy2: (P,).
    """
    onehot = (codes[..., None] == jnp.arange(n_bins)[None, None, :]).astype(w.dtype)
    vals = jnp.stack([w, wy, wy2], axis=1)                   # (P, 3)
    return jnp.einsum("pfb,ps->fbs", onehot, vals)
