"""Jit'd public wrapper for the histsplit kernel (matches the numpy-side
signature used by ``repro.trees.cart``)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..sat2d.ref import split_hi_lo
from .kernel import histograms_kernel_call

__all__ = ["histograms"]


def histograms(codes, w, wy, wy2, n_bins: int, *, tile_p: int = 2048,
               variant: str = "fused", interpret: bool | None = None):
    """codes: (P, F) uint8/int; w/wy/wy2: (P,). Returns (F, n_bins, 3).

    ``variant="partials"`` is the compensated path: each value column is
    split into an (hi, lo) f32 pair (capturing the f64 -> f32 cast error),
    the kernel bins all six channels and emits per-P-tile partial
    histograms, and the cross-tile + hi/lo reduction happens here in f64 —
    so neither the input cast nor the scatter order of a long P axis leaves
    f32-level error in the bin sums.  Tile size and variant are what the
    autotuner searches over.
    """
    codes_fp = jnp.asarray(np.asarray(codes).T, jnp.int32)       # (F, P)
    if variant == "partials":
        pairs = [split_hi_lo(a) for a in (w, wy, wy2)]
        vals = jnp.stack([p[0] for p in pairs]
                         + [p[1] for p in pairs], axis=1)        # (P, 6)
        out = histograms_kernel_call(codes_fp, vals, n_bins, tile_p=tile_p,
                                     variant=variant, interpret=interpret)
        out = np.asarray(out, np.float64)          # (C, F, n_bins, 6)
        return out[..., :3].sum(axis=0) + out[..., 3:].sum(axis=0)
    vals = jnp.stack([jnp.asarray(w, jnp.float32),
                      jnp.asarray(wy, jnp.float32),
                      jnp.asarray(wy2, jnp.float32)], axis=1)    # (P, 3)
    return histograms_kernel_call(codes_fp, vals, n_bins, tile_p=tile_p,
                                  variant=variant, interpret=interpret)
