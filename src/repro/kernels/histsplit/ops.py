"""Jit'd public wrapper for the histsplit kernel (matches the numpy-side
signature used by ``repro.trees.cart``)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernel import histograms_kernel_call

__all__ = ["histograms"]


def histograms(codes, w, wy, wy2, n_bins: int):
    """codes: (P, F) uint8/int; w/wy/wy2: (P,). Returns (F, n_bins, 3) f32."""
    codes_fp = jnp.asarray(np.asarray(codes).T, jnp.int32)       # (F, P)
    vals = jnp.stack([jnp.asarray(w, jnp.float32),
                      jnp.asarray(wy, jnp.float32),
                      jnp.asarray(wy2, jnp.float32)], axis=1)    # (P, 3)
    return histograms_kernel_call(codes_fp, vals, n_bins)
