"""repro.obs — end-to-end request tracing + op-level profiling (stdlib-only).

One process-global :class:`Tracer` (``obs.TRACER``) that every layer of the
serving hot path records spans into:

    HTTP handler  ->  QueryScheduler wait / fused dispatch (linked)
                  ->  BuildScheduler build
                  ->  engine cache lookup / compress
                  ->  repro.ops dispatch (op, backend, shape bucket)

plus the :mod:`repro.obs.profile` hook point the dispatcher feeds, so the
engine can turn per-dispatch wall time into Prometheus families.  See
DESIGN.md "Observability" for the span taxonomy and linking semantics.

The module-level helpers below delegate to ``TRACER`` — call sites read as
``obs.span("cache.lookup")`` without threading a tracer through every
constructor.  Tests that need isolation build their own ``Tracer``.
"""
from __future__ import annotations

from . import profile
from .trace import (NOOP, TRACER, Span, SpanContext, Tracer, current_span,
                    format_traceparent, mint_span_id, mint_trace_id,
                    parse_traceparent)

__all__ = [
    "NOOP", "TRACER", "Span", "SpanContext", "Tracer", "profile",
    "current_span", "parse_traceparent", "format_traceparent",
    "mint_trace_id", "mint_span_id",
    "span", "child_span", "start_trace", "attach", "set_enabled",
]


def span(name: str, **attrs):
    """Context manager: child span of the current one (NOOP outside)."""
    return TRACER.span(name, **attrs)


def child_span(name: str, *, parent=None, attrs: dict | None = None):
    return TRACER.child_span(name, parent=parent, attrs=attrs)


def start_trace(name: str, *, traceparent: str | None = None, links=None,
                attrs: dict | None = None):
    return TRACER.start_trace(name, traceparent=traceparent, links=links,
                              attrs=attrs)


def attach(span_obj):
    return TRACER.attach(span_obj)


def set_enabled(on: bool) -> None:
    TRACER.set_enabled(on)
