"""Op-level profiling hooks for ``repro.ops.dispatch``.

The dispatcher is the one chokepoint every backend call crosses, so this is
where per-(op, backend, shape-bucket) wall time becomes observable.  The
registry stays dependency-free: it calls :func:`record` after each dispatch
and whoever wants the numbers (the serving engine, a bench) registers a
hook.  With no hooks installed the cost is one ``if not _HOOKS`` check.

Shape buckets: problem "size" (op-specific, see ``repro.ops``) collapses to
its power-of-two ceiling — ``le_2^12`` means ``2^11 < size <= 2^12`` — so
the Prometheus label space stays bounded (~20 buckets) while still
separating the tiny dispatches the numpy oracle should win from the large
ones that should have promoted to an accelerator backend.
"""
from __future__ import annotations

import threading
from typing import Callable

__all__ = ["add_hook", "remove_hook", "record", "shape_bucket", "hooks"]

# fn(op: str, backend: str, size: int | None, seconds: float)
_HOOKS: list[Callable] = []
_LOCK = threading.Lock()


def add_hook(fn: Callable) -> Callable:
    """Register a dispatch observer; returns ``fn`` for symmetry."""
    with _LOCK:
        if fn not in _HOOKS:
            _HOOKS.append(fn)
    return fn


def remove_hook(fn: Callable) -> None:
    with _LOCK:
        try:
            _HOOKS.remove(fn)
        except ValueError:
            pass


def hooks() -> tuple:
    return tuple(_HOOKS)


def record(op: str, backend: str, size: int | None, seconds: float) -> None:
    """Fan one dispatch observation out to every hook.  Hook exceptions are
    swallowed: telemetry must never fail the computation it observes."""
    for fn in tuple(_HOOKS):
        try:
            fn(op, backend, size, seconds)
        except Exception:  # noqa: BLE001 - observability is best-effort
            pass


def shape_bucket(size: int | None) -> str:
    """Power-of-two ceiling label for a problem size (``le_2^b``)."""
    if size is None:
        return "none"
    size = int(size)
    if size <= 1:
        return "le_2^0"
    return f"le_2^{(size - 1).bit_length()}"
