"""Spans, traces, and the completed-trace ring buffer.

Stdlib-only (the serving layer runs in a bare container, same constraint as
``service/metrics.py``).  The model is a deliberately small slice of
OpenTelemetry:

  * a **trace** is a tree of spans sharing one 128-bit ``trace_id``; the
    HTTP layer mints one per request (or *continues* the caller's via the
    W3C ``traceparent`` header, so an SDK-side id and the server-side trace
    are the same trace);
  * a **span** is one timed hop (http handler, scheduler wait, coreset
    build, ops dispatch) with attributes and optional **links** to spans in
    OTHER traces — the coalescing escape hatch: one fused dispatch span is
    linked from every request trace that rode in it, because a span cannot
    have N parents;
  * finished traces land in a bounded thread-safe ring buffer on the
    :class:`Tracer`, served by ``GET /v1/traces:recent`` and
    ``GET /v1/trace/{id}``, with a Chrome trace-event export
    (``?format=chrome``) that Perfetto loads directly.

Propagation is contextvar-based *within* a thread (``tracer.span(...)``
nests under the current span automatically) and explicit *across* threads:
a scheduler captures ``current_span()`` at submit and re-enters it on the
worker with :func:`Tracer.attach` — thread pools do not inherit context.

Overhead discipline: when no trace is active (pure-library callers, or
tracing disabled) every entry point returns the singleton :data:`NOOP`
span, whose methods do nothing — the hot ``ops.dispatch`` path pays one
contextvar read, nothing else.  The <5% serving-overhead budget is gated in
CI (``scripts/check_bench_regression.py``, ``tracing`` row).
"""
from __future__ import annotations

import contextvars
import json
import os
import random
import re
import threading
import time
from collections import OrderedDict

__all__ = [
    "Span", "SpanContext", "Tracer", "NOOP", "TRACER",
    "parse_traceparent", "format_traceparent", "mint_trace_id",
    "mint_span_id", "current_span",
]

_TRACEPARENT_RE = re.compile(
    r"^([0-9a-f]{2})-([0-9a-f]{32})-([0-9a-f]{16})-([0-9a-f]{2})$")

# the current span of THIS thread of execution (contextvars, not
# threading.local: generators/ctx managers compose correctly, and worker
# threads get a clean slate instead of a stale inherited value)
_CURRENT: contextvars.ContextVar["Span | None"] = \
    contextvars.ContextVar("repro_obs_current_span", default=None)


# id minting sits on the per-span hot path (the <5% overhead budget), so
# ids come from a process-local PRNG seeded once from the OS — ~4x cheaper
# than os.urandom per call, and uniqueness (not secrecy) is all ids need.
# Single getrandbits calls are atomic under the GIL, so no lock.
_ID_RNG = random.Random(int.from_bytes(os.urandom(16), "big"))


def mint_trace_id() -> str:
    """128-bit lowercase-hex trace id (W3C trace-context format)."""
    return "%032x" % _ID_RNG.getrandbits(128)


def mint_span_id() -> str:
    """64-bit lowercase-hex span id."""
    return "%016x" % _ID_RNG.getrandbits(64)


# thread names are stable per thread; current_thread() costs ~0.5us per
# call, so cache the name in a threading.local for the span hot path
_TLS = threading.local()


def _thread_name() -> str:
    try:
        return _TLS.name
    except AttributeError:
        name = _TLS.name = threading.current_thread().name
        return name


def parse_traceparent(header: str | None) -> tuple[str, str] | None:
    """(trace_id, parent span_id) from a W3C ``traceparent`` header, or
    None when absent/malformed/all-zero (the spec says ignore, not fail)."""
    if not header:
        return None
    m = _TRACEPARENT_RE.match(header.strip().lower())
    if m is None:
        return None
    version, trace_id, span_id = m.group(1), m.group(2), m.group(3)
    if version == "ff" or trace_id == "0" * 32 or span_id == "0" * 16:
        return None
    return trace_id, span_id


def format_traceparent(trace_id: str, span_id: str) -> str:
    """W3C header for an outgoing hop (always sampled: 01)."""
    return f"00-{trace_id}-{span_id}-01"


class SpanContext:
    """The addressable identity of a span — what links and traceparent
    headers carry across trace boundaries."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id: str, span_id: str):
        self.trace_id = trace_id
        self.span_id = span_id

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "span_id": self.span_id}


class Span:
    """One timed operation.  Create through the :class:`Tracer`; ``end()``
    records it (idempotent — double-end keeps the first duration)."""

    __slots__ = ("_tracer", "name", "trace_id", "span_id", "parent_id",
                 "start_pc", "end_pc", "attrs", "links", "thread", "_token")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 span_id: str, parent_id: str | None, attrs: dict | None):
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.end_pc: float | None = None
        # lazily materialized: most spans carry no attrs and no links, and
        # allocations per span add up on the hot path.  attrs is stored by
        # REFERENCE — every call site passes a fresh kwargs/literal dict,
        # and readers copy (_span_dict) before handing records out
        self.attrs: dict | None = attrs if attrs else None
        self.links: list[dict] | None = None
        self.thread = _thread_name()
        self._token = None
        self.start_pc = time.perf_counter()

    # ------------------------------------------------------------ recording
    @property
    def context(self) -> SpanContext:
        return SpanContext(self.trace_id, self.span_id)

    def set_attr(self, key: str, value) -> None:
        a = self.attrs
        if a is None:
            a = self.attrs = {}
        a[key] = value

    def add_link(self, ctx: "SpanContext | Span", **attrs) -> None:
        """Link to a span in another trace (the coalesced-dispatch edge)."""
        link = {"trace_id": ctx.trace_id, "span_id": ctx.span_id}
        if attrs:
            link["attrs"] = attrs
        if self.links is None:
            self.links = []
        self.links.append(link)

    def end(self) -> None:
        if self.end_pc is not None:
            return
        self.end_pc = time.perf_counter()
        self._tracer._record(self)

    # span objects are truthy; NOOP overrides to False so callers can
    # cheaply skip optional work (attribute formatting) when not tracing
    def __bool__(self) -> bool:
        return True


class _NoopSpan(Span):
    """Do-nothing span: returned whenever tracing is off or no trace is
    active, so call sites never branch."""

    __slots__ = ()

    def __init__(self):  # noqa: super().__init__ deliberately skipped
        pass

    name = "noop"
    trace_id = ""
    span_id = ""
    parent_id = None
    attrs: dict = {}
    links: list = []

    @property
    def context(self):
        return None

    def set_attr(self, key, value):
        pass

    def add_link(self, ctx, **attrs):
        pass

    def end(self):
        pass

    def __bool__(self):
        return False


NOOP = _NoopSpan()


class _SpanCM:
    """``with tracer.span(...)``: opens a child span on enter, makes it
    current, ends it on exit.  NOOP pass-through outside a trace."""

    __slots__ = ("_tracer", "_name", "_attrs", "_span", "_token")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict | None):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> Span:
        sp = self._tracer.child_span(self._name, attrs=self._attrs)
        self._span = sp
        self._token = _CURRENT.set(sp) if sp else None
        return sp

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._token is not None:
            _CURRENT.reset(self._token)
            self._span.end()
        return False


class _AttachCM:
    """``with tracer.attach(span)``: make a captured span current on this
    thread for the duration.  No-op for None/NOOP spans."""

    __slots__ = ("_span", "_token")

    def __init__(self, span: Span | None):
        self._span = span
        self._token = None

    def __enter__(self) -> None:
        if self._span:
            self._token = _CURRENT.set(self._span)
        return None

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._token is not None:
            _CURRENT.reset(self._token)
            self._token = None
        return False


class _ActiveTrace:
    __slots__ = ("spans", "root_span_id")

    def __init__(self, root_span_id: str):
        self.spans: list[dict] = []
        self.root_span_id = root_span_id


class Tracer:
    """Span factory + bounded ring buffer of completed traces.

    A trace is *finalized* (moved to the ring) when its **root** span —
    the span the tracer created with no in-trace parent — ends.  In this
    codebase every child span ends before its root does (handlers block on
    the futures their spans wrap), but a straggler that ends after
    finalization is appended to the finished trace if it is still in the
    ring, and dropped otherwise — never lost silently: ``spans_dropped``
    counts them.
    """

    def __init__(self, capacity: int = 512, enabled: bool = True,
                 max_spans_per_trace: int = 256):
        self.capacity = int(capacity)
        self.max_spans_per_trace = int(max_spans_per_trace)
        self._enabled = bool(enabled)
        self._lock = threading.Lock()
        # readers waiting for an in-flight trace to finalize (see get()):
        # shares _lock, so notify happens under the same mutual exclusion
        self._cond = threading.Condition(self._lock)
        self._active: dict[str, _ActiveTrace] = {}
        self._finished: "OrderedDict[str, dict]" = OrderedDict()
        self.completed_total = 0
        self.spans_dropped = 0
        # export anchor: spans time with perf_counter (monotonic); exports
        # shift onto the wall clock through one (wall, pc) pair
        self._anchor_wall = time.time()
        self._anchor_pc = time.perf_counter()

    # -------------------------------------------------------------- control
    @property
    def enabled(self) -> bool:
        return self._enabled

    def set_enabled(self, on: bool) -> None:
        self._enabled = bool(on)

    # -------------------------------------------------------------- creation
    def start_trace(self, name: str, *, traceparent: str | None = None,
                    links=None, attrs: dict | None = None) -> Span:
        """Open a new trace (or continue the caller's, when a valid
        ``traceparent`` is given) and return its root span.  The caller
        must ``attach()`` it to make it current, and ``end()`` it to
        finalize the trace."""
        if not self._enabled:
            return NOOP
        parent = parse_traceparent(traceparent)
        if parent is not None:
            trace_id, parent_id = parent
        else:
            trace_id, parent_id = mint_trace_id(), None
        span = Span(self, name, trace_id, mint_span_id(), parent_id, attrs)
        if links:
            for ctx in links:
                if ctx is not None:
                    span.add_link(ctx)
        with self._lock:
            self._active[trace_id] = _ActiveTrace(span.span_id)
        return span

    def child_span(self, name: str, *, parent: Span | SpanContext | None = None,
                   attrs: dict | None = None) -> Span:
        """A span under ``parent`` (default: this thread's current span).
        With no parent and no current span this is a NOOP — library callers
        outside a request pay one contextvar read and nothing else."""
        if not self._enabled:
            return NOOP
        if parent is None:
            parent = _CURRENT.get()
        if parent is None or not parent:
            return NOOP
        return Span(self, name, parent.trace_id, mint_span_id(),
                    parent.span_id, attrs)

    def span(self, name: str, **attrs) -> "_SpanCM":
        """Context manager: child of the current span, made current for the
        duration.  Yields the span (NOOP outside a trace).  Class-based
        rather than @contextmanager: the generator machinery costs ~1us per
        use, which matters at several spans per request."""
        return _SpanCM(self, name, attrs or None)

    def attach(self, span: Span | None) -> "_AttachCM":
        """Make ``span`` current on THIS thread (cross-thread re-entry: a
        scheduler captured it at submit, the worker attaches it)."""
        return _AttachCM(span)

    # ------------------------------------------------------------- recording
    # Spans are stored as tuples and turned into dicts only when read:
    # recording is per-span-end on the serving hot path, reading is a human
    # hitting /v1/trace — so the dict building belongs on the read side.
    # The hot branch is lock-free: dict.get and list.append are GIL-atomic,
    # and only finalize/straggler handling (rare) takes the lock.
    def _record(self, span: Span) -> None:
        dur = (span.end_pc - span.start_pc) * 1e6
        rec = (span.name, span.trace_id, span.span_id, span.parent_id,
               (self._anchor_wall + (span.start_pc - self._anchor_pc)) * 1e6,
               dur if dur > 0.0 else 0.0, span.thread, span.attrs, span.links)
        active = self._active.get(span.trace_id)
        if active is not None:
            if len(active.spans) < self.max_spans_per_trace:
                active.spans.append(rec)
            else:
                with self._lock:
                    self.spans_dropped += 1
            if span.span_id == active.root_span_id:
                with self._lock:
                    self._finalize_locked(span.trace_id, rec)
            return
        with self._lock:
            done = self._finished.get(span.trace_id)
            if done is not None and \
                    len(done["spans"]) < self.max_spans_per_trace:
                done["spans"].append(rec)   # straggler after finalize
            else:
                self.spans_dropped += 1

    def _finalize_locked(self, trace_id: str, root_rec: tuple) -> None:
        active = self._active.pop(trace_id, None)
        if active is None:      # already finalized by a racing end()
            return
        self._finished[trace_id] = {
            "trace_id": trace_id,
            "root": root_rec[0],
            "start_us": root_rec[4],
            "duration_us": root_rec[5],
            "spans": active.spans,
        }
        self.completed_total += 1
        while len(self._finished) > self.capacity:
            self._finished.popitem(last=False)
        self._cond.notify_all()

    @staticmethod
    def _span_dict(rec: tuple) -> dict:
        d = {"name": rec[0], "trace_id": rec[1], "span_id": rec[2],
             "parent_id": rec[3], "start_us": rec[4], "duration_us": rec[5],
             "thread": rec[6]}
        if rec[7]:
            d["attrs"] = dict(rec[7])
        if rec[8]:
            d["links"] = [dict(li) for li in rec[8]]
        return d

    # --------------------------------------------------------------- reading
    def recent(self, limit: int = 50) -> list[dict]:
        """Newest-first summaries of completed traces."""
        with self._lock:
            items = list(self._finished.values())
        out = []
        for t in reversed(items[-max(int(limit), 0):] if limit else items):
            out.append({"trace_id": t["trace_id"], "root": t["root"],
                        "start_us": t["start_us"],
                        "duration_us": t["duration_us"],
                        "spans": len(t["spans"])})
        return out

    def get(self, trace_id: str, *, resolve_links: bool = True,
            wait_s: float = 0.0) -> dict | None:
        """One completed trace, plus (one hop of) the traces its spans link
        to — so a request trace arrives together with the fused-dispatch
        trace it rode in.

        ``wait_s`` bounds a wait for a trace that is still ACTIVE: the HTTP
        layer writes the response body *before* the request's root span ends
        (the observation must not gate the reply), so a client that turns
        around and fetches its own trace can arrive in the microseconds
        between reply and finalize.  Waiting only applies to known in-flight
        trace ids — an id the tracer has never seen returns None immediately,
        so a bad id cannot stall the trace route."""
        with self._cond:
            if wait_s > 0.0:
                deadline = time.perf_counter() + wait_s
                while (trace_id not in self._finished
                       and trace_id in self._active):
                    left = deadline - time.perf_counter()
                    if left <= 0.0:
                        break
                    self._cond.wait(left)
            t = self._finished.get(trace_id)
            if t is None:
                return None
            spans = [self._span_dict(s) for s in t["spans"]]
            out = {"trace_id": t["trace_id"], "root": t["root"],
                   "start_us": t["start_us"], "duration_us": t["duration_us"],
                   "spans": spans}
            if resolve_links:
                linked_ids = []
                for s in spans:
                    for link in s.get("links", ()):
                        lid = link["trace_id"]
                        if lid != trace_id and lid not in linked_ids:
                            linked_ids.append(lid)
                linked = []
                for lid in linked_ids:
                    lt = self._finished.get(lid)
                    if lt is not None:
                        linked.append(
                            {"trace_id": lid, "root": lt["root"],
                             "spans": [self._span_dict(s)
                                       for s in lt["spans"]]})
                out["linked_traces"] = linked
        return out

    def chrome(self, trace_id: str, *, wait_s: float = 0.0) -> dict | None:
        """Chrome trace-event JSON (Perfetto loads it as-is): the trace's
        spans as complete ("X") events, linked traces as separate process
        groups, and flow arrows ("s"/"f") along every link."""
        t = self.get(trace_id, resolve_links=True, wait_s=wait_s)
        if t is None:
            return None
        events: list[dict] = []
        flow_id = 0

        def emit(spans, pid, label):
            nonlocal flow_id
            events.append({"name": "process_name", "ph": "M", "pid": pid,
                           "tid": 0, "args": {"name": label}})
            for s in spans:
                args = dict(s.get("attrs", {}))
                args["span_id"] = s["span_id"]
                if s.get("parent_id"):
                    args["parent_id"] = s["parent_id"]
                events.append({
                    "name": s["name"], "cat": "coreset", "ph": "X",
                    "ts": s["start_us"], "dur": s["duration_us"],
                    "pid": pid, "tid": s.get("thread", "?"),
                    "args": args})
                for link in s.get("links", ()):
                    flow_id += 1
                    events.append({"name": "link", "cat": "link", "ph": "s",
                                   "id": flow_id, "pid": pid,
                                   "tid": s.get("thread", "?"),
                                   "ts": s["start_us"] + s["duration_us"] / 2,
                                   "args": link})

        emit(t["spans"], 1, f"trace {t['trace_id'][:8]} ({t['root']})")
        for i, lt in enumerate(t.get("linked_traces", ()), start=2):
            emit(lt["spans"], i, f"linked {lt['trace_id'][:8]} ({lt['root']})")
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def chrome_json(self, trace_id: str, *, wait_s: float = 0.0) -> bytes | None:
        doc = self.chrome(trace_id, wait_s=wait_s)
        return None if doc is None else json.dumps(doc).encode()

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": self._enabled, "capacity": self.capacity,
                    "buffered": len(self._finished),
                    "active": len(self._active),
                    "completed_total": self.completed_total,
                    "spans_dropped": self.spans_dropped}

    def clear(self) -> None:
        with self._lock:
            self._active.clear()
            self._finished.clear()


def current_span() -> Span | None:
    """This thread-of-execution's current span (None outside a trace)."""
    return _CURRENT.get()


# the process-global tracer every layer records into by default; tests
# build private Tracer instances instead of mutating this one
TRACER = Tracer()
