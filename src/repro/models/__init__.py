from .model import Model, decode_step, forward, init_cache, init_params, prefill

__all__ = ["Model", "decode_step", "forward", "init_cache", "init_params",
           "prefill"]
