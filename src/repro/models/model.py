"""Model assembly: init / forward / prefill / decode for every arch family.

One decoder skeleton covers the pool:

  dense / vlm / audio:  [norm -> attention -> norm -> SwiGLU] x L
  moe (incl. MLA):      [norm -> attention|MLA -> norm -> MoE] x L
  ssm:                  [norm -> Mamba1] x L                  (no MLP, falcon)
  hybrid (zamba2):      [norm -> Mamba2] x L, with one *shared* GQA block
                        applied every cfg.attn_every layers

Layers are stacked along a leading axis and executed with ``lax.scan``
(+ ``jax.checkpoint`` on the body when cfg.remat): the HLO stays one
layer-body + loop, which is what keeps 94-layer/512-device dry-run compiles
tractable, and remat bounds live activation memory.

Modality frontends are stubs per the brief: pixtral consumes precomputed
patch embeddings concatenated before the text tokens; musicgen sums
``n_codebooks`` embedding tables and emits per-codebook heads.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (embed, init_embed, init_linear, init_rmsnorm, init_swiglu,
                     linear, rms_norm, swiglu)

__all__ = ["init_params", "forward", "prefill", "decode_step", "Model"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ================================================================== layer init
def _init_layer(key, cfg) -> dict:
    """One decoder layer's params (unstacked)."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": init_rmsnorm(cfg.d_model, dt)}
    if cfg.family == "ssm":
        p["mixer"] = ssm_mod.init_mamba1(ks[0], cfg) if cfg.mamba_version == 1 \
            else ssm_mod.init_mamba2(ks[0], cfg)
        return p
    if cfg.family == "hybrid":
        p["mixer"] = ssm_mod.init_mamba2(ks[0], cfg) if cfg.mamba_version == 2 \
            else ssm_mod.init_mamba1(ks[0], cfg)
        return p
    # attention families
    p["attn"] = attn.init_mla(ks[0], cfg) if cfg.is_mla else attn.init_gqa(ks[0], cfg)
    p["ln2"] = init_rmsnorm(cfg.d_model, dt)
    if cfg.is_moe:
        p["mlp"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(cfg, rng) -> dict:
    dt = _dtype(cfg)
    k_emb, k_layers, k_head, k_shared = jax.random.split(rng, 4)
    p: dict = {}
    if cfg.frontend == "audio_codebooks":
        p["embed"] = {"table": jax.vmap(
            lambda k: init_embed(k, cfg.vocab, cfg.d_model, dt)["table"])(
            jax.random.split(k_emb, cfg.n_codebooks))}
        p["head"] = init_linear(k_head, cfg.d_model, (cfg.n_codebooks, cfg.vocab),
                                dt, scale=cfg.d_model ** -0.5)
    else:
        p["embed"] = init_embed(k_emb, cfg.vocab, cfg.d_model, dt)
        p["head"] = init_linear(k_head, cfg.d_model, cfg.vocab, dt,
                                scale=cfg.d_model ** -0.5)
    # stacked layers (vmapped init -> leading L axis on every leaf)
    p["layers"] = jax.vmap(lambda k: _init_layer(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers))
    if cfg.family == "hybrid" and cfg.attn_every:
        p["shared_attn"] = attn.init_gqa(k_shared, cfg)
        p["shared_ln"] = init_rmsnorm(cfg.d_model, dt)
    p["final_ln"] = init_rmsnorm(cfg.d_model, dt)
    return p


# ================================================================ embeddings
def embed_inputs(cfg, params, batch: dict) -> jnp.ndarray:
    """batch -> (B, L, d) hidden states (modality stubs resolved here)."""
    if cfg.frontend == "audio_codebooks":
        # tokens: (B, L, n_codebooks) -> summed codebook embeddings
        return _codebook_embed(params["embed"]["table"], batch["tokens"])
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        # decode steps carry no patch embeddings (image is in the cache)
        txt = embed(params["embed"], batch["tokens"])            # (B, Lt, d)
        return jnp.concatenate(
            [batch["patch_embeds"].astype(txt.dtype), txt], axis=1)
    return embed(params["embed"], batch["tokens"])


def _codebook_embed(table: jnp.ndarray, toks: jnp.ndarray) -> jnp.ndarray:
    """table: (C, V, d); toks: (B, L, C) -> sum_c table[c, toks[..., c]]."""
    C = table.shape[0]
    parts = [jnp.take(table[c], toks[..., c], axis=0) for c in range(C)]
    return sum(parts)


# ==================================================================== forward
def _layer_apply(cfg, lp, x, positions, attn_impl, unroll=False):
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        mix = ssm_mod.mamba1_forward if cfg.mamba_version == 1 else ssm_mod.mamba2_forward
        x = x + mix(lp["mixer"], cfg, rms_norm(lp["ln1"], x, cfg.norm_eps),
                    unroll=unroll)
        return x, aux
    h = rms_norm(lp["ln1"], x, cfg.norm_eps)
    if cfg.is_mla:
        x = x + attn.mla_forward(lp["attn"], cfg, h, positions, attn_impl,
                                 unroll=unroll)
    else:
        x = x + attn.gqa_forward(lp["attn"], cfg, h, positions, attn_impl,
                                 unroll=unroll)
    h2 = rms_norm(lp["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_mod.moe_forward(lp["mlp"], cfg, h2)
        x = x + y
    else:
        x = x + swiglu(lp["mlp"], h2)
    return x, aux


def forward(cfg, params, batch: dict, attn_impl: str = "xla",
            unroll: bool = False, return_hidden: bool = False) -> tuple:
    """-> (logits, aux_loss), or (hidden, aux_loss) with return_hidden=True
    (training uses the hidden states + a chunked fused CE so the full
    (B, L, V) logits are never materialized).  batch: {"tokens": ...}
    (+ frontend inputs).

    ``unroll=True`` replaces every scan (layers + sequence chunks) with
    python loops — dry-run costing only (see launch/dryrun.py).
    """
    x = embed_inputs(cfg, params, batch)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    shared = params.get("shared_attn")

    def shared_apply(x):
        h = rms_norm(params["shared_ln"], x, cfg.norm_eps)
        return x + attn.gqa_forward(shared, cfg, h, positions, attn_impl,
                                    unroll=unroll)

    if unroll:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = _layer_apply(cfg, lp, x, positions, attn_impl, unroll=True)
            aux = aux + a
            if shared is not None and cfg.attn_every and (i + 1) % cfg.attn_every == 0:
                x = shared_apply(x)
    else:
        def body(carry, scanned):
            x, aux, idx = carry
            lp = scanned
            x, a = _layer_apply(cfg, lp, x, positions, attn_impl)
            if shared is not None and cfg.attn_every:
                x = jax.lax.cond((idx + 1) % cfg.attn_every == 0, shared_apply,
                                 lambda x: x, x)
            return (x, aux + a, idx + 1), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux, _), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32),
                                                jnp.zeros((), jnp.int32)),
                                      params["layers"])
    x = rms_norm(params["final_ln"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux
    logits = linear(params["head"], x)
    return logits, aux


# ===================================================================== decode
def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    """Per-layer stacked cache pytree (leading L axis, scanned in decode)."""
    dt = _dtype(cfg)
    L = cfg.n_layers
    if cfg.family == "ssm" or cfg.family == "hybrid":
        di = cfg.d_inner
        if cfg.mamba_version == 1:
            layer = {"conv": jnp.zeros((L, batch_size, cfg.ssm_conv - 1, di), dt),
                     "h": jnp.zeros((L, batch_size, di, cfg.ssm_state), jnp.float32)}
        else:
            layer = {"conv": jnp.zeros((L, batch_size, cfg.ssm_conv - 1, di), dt),
                     "S": jnp.zeros((L, batch_size, cfg.ssm_heads, cfg.ssm_state,
                                     cfg.mamba_headdim), jnp.float32)}
        cache = {"layers": layer, "pos": jnp.zeros((), jnp.int32)}
        if cfg.family == "hybrid" and cfg.attn_every:
            n_shared = cfg.n_layers // cfg.attn_every
            cache["shared"] = {
                "k": jnp.zeros((n_shared, batch_size, cfg.n_kv_heads, max_len, cfg.hd), dt),
                "v": jnp.zeros((n_shared, batch_size, cfg.n_kv_heads, max_len, cfg.hd), dt)}
        return cache
    if cfg.is_mla:
        layer = {"c_kv": jnp.zeros((L, batch_size, max_len, cfg.kv_lora_rank), dt),
                 "k_rope": jnp.zeros((L, batch_size, max_len, cfg.qk_rope_dim), dt)}
    else:
        layer = {"k": jnp.zeros((L, batch_size, cfg.n_kv_heads, max_len, cfg.hd), dt),
                 "v": jnp.zeros((L, batch_size, cfg.n_kv_heads, max_len, cfg.hd), dt)}
    return {"layers": layer, "pos": jnp.zeros((), jnp.int32)}


def decode_step(cfg, params, cache: dict, batch: dict,
                unroll: bool = False) -> tuple:
    """One new token for every sequence. batch["tokens"]: (B, 1) (or
    (B, 1, C) for audio). Returns (logits, new_cache)."""
    x = embed_inputs(cfg, params, batch)
    B = x.shape[0]
    pos = cache["pos"]

    shared = params.get("shared_attn")
    shared_cache = cache.get("shared")

    if cfg.family in ("ssm", "hybrid"):
        def ssm_layer(x, sc, lp, lc, idx_static=None, idx_dyn=None):
            mix = ssm_mod.mamba1_decode if cfg.mamba_version == 1 else ssm_mod.mamba2_decode
            y, lc_new = mix(lp["mixer"], cfg, rms_norm(lp["ln1"], x, cfg.norm_eps), lc)
            x = x + y

            def with_attn(op):
                x, sc = op
                idx = idx_static if idx_static is not None else idx_dyn
                si = (idx + 1) // cfg.attn_every - 1
                h = rms_norm(params["shared_ln"], x, cfg.norm_eps)
                layer_sc = jax.tree.map(lambda a: a[si], sc)
                y, new_sc = attn.gqa_decode(shared, cfg, h, layer_sc, pos)
                sc = jax.tree.map(lambda a, b: a.at[si].set(b), sc, new_sc)
                return (x + y, sc)

            if shared is not None and cfg.attn_every:
                if idx_static is not None:
                    if (idx_static + 1) % cfg.attn_every == 0:
                        x, sc = with_attn((x, sc))
                else:
                    x, sc = jax.lax.cond((idx_dyn + 1) % cfg.attn_every == 0,
                                         with_attn, lambda op: op, (x, sc))
            return x, sc, lc_new

        if unroll:
            new_lc = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                lc = jax.tree.map(lambda a: a[i], cache["layers"])
                x, shared_cache, lc_new = ssm_layer(x, shared_cache, lp, lc,
                                                    idx_static=i)
                new_lc.append(lc_new)
            new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *new_lc)
        else:
            def body(carry, scanned):
                x, sc, idx = carry
                lp, lc = scanned
                x, sc, lc_new = ssm_layer(x, sc, lp, lc, idx_dyn=idx)
                return (x, sc, idx + 1), lc_new

            (x, shared_cache, _), new_layers = jax.lax.scan(
                body, (x, shared_cache, jnp.zeros((), jnp.int32)),
                (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers, "pos": pos + 1}
        if shared_cache is not None:
            new_cache["shared"] = shared_cache
    else:
        def attn_layer(x, lp, lc):
            h = rms_norm(lp["ln1"], x, cfg.norm_eps)
            if cfg.is_mla:
                y, lc_new = attn.mla_decode(lp["attn"], cfg, h, lc, pos)
            else:
                y, lc_new = attn.gqa_decode(lp["attn"], cfg, h, lc, pos)
            x = x + y
            h2 = rms_norm(lp["ln2"], x, cfg.norm_eps)
            if cfg.is_moe:
                y2, _ = moe_mod.moe_forward(lp["mlp"], cfg, h2)
            else:
                y2 = swiglu(lp["mlp"], h2)
            return x + y2, lc_new

        if unroll:
            new_lc = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                lc = jax.tree.map(lambda a: a[i], cache["layers"])
                x, lc_new = attn_layer(x, lp, lc)
                new_lc.append(lc_new)
            new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *new_lc)
        else:
            def body(carry, scanned):
                lp, lc = scanned
                return attn_layer(carry, lp, lc)

            x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers, "pos": pos + 1}

    x = rms_norm(params["final_ln"], x, cfg.norm_eps)
    logits = linear(params["head"], x)
    return logits, new_cache


def prefill(cfg, params, batch: dict, attn_impl: str = "xla",
            unroll: bool = False):
    """Prefill = forward pass producing logits (cache omitted: the dry-run
    measures prefill compute; decode shapes carry the cache)."""
    return forward(cfg, params, batch, attn_impl, unroll=unroll)


class Model:
    """Convenience OO wrapper over the functional API."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, rng):
        return init_params(self.cfg, rng)

    def apply(self, params, batch, attn_impl: str = "xla"):
        return forward(self.cfg, params, batch, attn_impl)

    def decode(self, params, cache, batch):
        return decode_step(self.cfg, params, cache, batch)

    def init_cache(self, batch_size: int, max_len: int):
        return init_cache(self.cfg, batch_size, max_len)
