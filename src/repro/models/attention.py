"""Attention: GQA (+bias), MLA (DeepSeek-V2), chunked-flash XLA path, decode.

Three execution paths:
  * ``chunked_attention``: pure-JAX flash attention — lax.scan over KV chunks
    with an online softmax.  O(L * chunk) live memory, compact HLO (the path
    the 512-device dry-run compiles; 32k prefill would need the O(L^2) score
    matrix otherwise).
  * ``repro.kernels.flash_attention``: the Pallas TPU kernel (real-hardware
    path; numerically identical — validated in tests).
  * decode: single-query attention against a KV cache (memory-bound einsum).

MLA implements the *absorbed* decode of the DeepSeek-V2 paper: the per-head
K/V up-projections fold into the query/output projections so decode attends
directly over the (kv_lora + rope) compressed cache — the whole point of MLA
serving.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import init_linear, linear, rope

__all__ = ["init_gqa", "gqa_forward", "gqa_decode", "init_mla", "mla_forward",
           "mla_decode", "chunked_attention"]

_NEG = -1e30


# ------------------------------------------------------ chunked flash (XLA)
def chunked_attention(q, k, v, *, causal: bool = True, q_chunk: int = 1024,
                      k_chunk: int = 1024, impl: str = "xla",
                      unroll: bool = False):
    """q: (B, Hq, Lq, D); k/v: (B, Hkv, Lk, D).  Online-softmax scan over KV
    chunks, vmapped-free (einsum keeps GQA head groups implicit via repeat on
    the fly).  Returns (B, Hq, Lq, D).

    ``unroll=True`` replaces the chunk scans with python loops — used only by
    the dry-run costing lowers, because XLA's cost analysis counts a while
    body once (see launch/dryrun.py)."""
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal)
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                      # MLA: v head dim != qk head dim
    group = Hq // Hkv
    scale = 1.0 / D ** 0.5
    q_offset = Lk - Lq

    qc = min(q_chunk, Lq)
    kc = min(k_chunk, Lk)
    pad_q = (-Lq) % qc
    pad_k = (-Lk) % kc
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = qp.shape[2] // qc, kp.shape[2] // kc
    # (nk, B, Hkv, kc, D)
    ks = jnp.moveaxis(kp.reshape(B, Hkv, nk, kc, D), 2, 0)
    vs = jnp.moveaxis(vp.reshape(B, Hkv, nk, kc, Dv), 2, 0)

    def q_block(qi, q_blk):
        # q_blk: (B, Hq, qc, D)
        def kv_step(carry, inp):
            m, l, acc, kj = carry[0], carry[1], carry[2], carry[3]
            k_blk, v_blk = inp
            if group > 1:
                k_blk = jnp.repeat(k_blk, group, axis=1)
                v_blk = jnp.repeat(v_blk, group, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            ki = kj * kc + jnp.arange(kc)[None, :]
            if causal:
                qi_abs = qi * qc + jnp.arange(qc)[:, None] + q_offset
                mask = (ki <= qi_abs) & (ki < Lk)
            else:
                mask = jnp.broadcast_to(ki < Lk, (qc, kc))
            s = jnp.where(mask[None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
            return (m_new, l_new, acc_new, kj + 1), None

        init = (jnp.full((B, Hq, qc), _NEG, jnp.float32),
                jnp.zeros((B, Hq, qc), jnp.float32),
                jnp.zeros((B, Hq, qc, Dv), jnp.float32),
                jnp.zeros((), jnp.int32))
        if unroll:
            carry = init
            for j in range(nk):
                carry, _ = kv_step(carry, (ks[j], vs[j]))
            m, l, acc = carry[0], carry[1], carry[2]
        else:
            # checkpoint each KV step: backward recomputes the (qc, kc)
            # score tile instead of saving it (flash-attention backward) —
            # peak live memory drops from O(L^2) to O(qc * kc) per layer.
            (m, l, acc, _), _ = jax.lax.scan(jax.checkpoint(kv_step), init,
                                             (ks, vs))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    qs = jnp.moveaxis(qp.reshape(B, Hq, nq, qc, D), 2, 0)
    if unroll:
        out = jnp.stack([q_block(jnp.asarray(i), qs[i]) for i in range(nq)])
    else:
        out = jax.lax.map(jax.checkpoint(lambda t: q_block(t[0], t[1])),
                          (jnp.arange(nq), qs))        # (nq, B, Hq, qc, Dv)
    out = jnp.moveaxis(out, 0, 2).reshape(B, Hq, nq * qc, Dv)
    return out[:, :, :Lq]


# ---------------------------------------------------------------------- GQA
def init_gqa(key, cfg) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, d, (H, hd), dt, bias=cfg.qkv_bias),
        "wk": init_linear(k2, d, (Hkv, hd), dt, bias=cfg.qkv_bias),
        "wv": init_linear(k3, d, (Hkv, hd), dt, bias=cfg.qkv_bias),
        "wo": init_linear(k4, H * hd, d, dt, scale=(H * hd) ** -0.5),
    }


def gqa_forward(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                attn_impl: str = "xla", return_kv: bool = False,
                unroll: bool = False):
    """x: (B, L, d). Returns (B, L, d) (+ optional (k, v) for prefill)."""
    B, L, _ = x.shape
    q = linear(p["wq"], x)                        # (B, L, H, hd)
    k = linear(p["wk"], x)
    v = linear(p["wv"], x)
    q = rope(q.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    k = rope(k.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    o = chunked_attention(q, k, v, causal=True, impl=attn_impl, unroll=unroll,
                          q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, -1)
    out = linear(p["wo"], o)
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(p: dict, cfg, x: jnp.ndarray, cache: dict, pos: jnp.ndarray):
    """One-token decode. x: (B, 1, d); cache: {"k","v"}: (B, Hkv, S, hd),
    pos: () int32 current position. Returns (out, cache)."""
    B = x.shape[0]
    q = linear(p["wq"], x).transpose(0, 2, 1, 3)          # (B, H, 1, hd)
    k1 = linear(p["wk"], x).transpose(0, 2, 1, 3)         # (B, Hkv, 1, hd)
    v1 = linear(p["wv"], x).transpose(0, 2, 1, 3)
    posv = jnp.full((B, 1, 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k1 = rope(k1, posv, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(cache["k"], k1.astype(cache["k"].dtype),
                                      (0, 0, pos, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v1.astype(cache["v"].dtype),
                                      (0, 0, pos, 0))
    S = ck.shape[2]
    group = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(ck, group, axis=1) if group > 1 else ck
    vv = jnp.repeat(cv, group, axis=1) if group > 1 else cv
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) / cfg.hd ** 0.5
    mask = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(mask, s, _NEG)
    w = jax.nn.softmax(s, axis=-1).astype(vv.dtype)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vv)
    out = linear(p["wo"], o.transpose(0, 2, 1, 3).reshape(B, 1, -1))
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------- MLA
def init_mla(key, cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": init_linear(ks[0], d, r_kv + dr, dt),
        "kv_norm": {"scale": jnp.ones((r_kv,), dt)},
        "wk_b": init_linear(ks[1], r_kv, (H, dn), dt),
        "wv_b": init_linear(ks[2], r_kv, (H, dv), dt),
        "wo": init_linear(ks[3], H * dv, d, dt, scale=(H * dv) ** -0.5),
    }
    if r_q:
        p["wq_a"] = init_linear(ks[4], d, r_q, dt)
        p["q_norm"] = {"scale": jnp.ones((r_q,), dt)}
        p["wq_b"] = init_linear(ks[5], r_q, (H, dn + dr), dt)
    else:
        p["wq"] = init_linear(ks[4], d, (H, dn + dr), dt)
    return p


def _mla_q(p, cfg, x, positions):
    from .layers import rms_norm
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if "wq_a" in p:
        q = linear(p["wq_b"], rms_norm(p["q_norm"], linear(p["wq_a"], x), cfg.norm_eps))
    else:
        q = linear(p["wq"], x)
    q = q.transpose(0, 2, 1, 3)                            # (B, H, L, dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions[:, None, :], cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                attn_impl: str = "xla", return_kv: bool = False,
                unroll: bool = False):
    from .layers import rms_norm
    B, L, _ = x.shape
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    kv = linear(p["wkv_a"], x)                              # (B, L, r_kv + dr)
    c_kv = rms_norm(p["kv_norm"], kv[..., :cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(kv[..., None, cfg.kv_lora_rank:].transpose(0, 2, 1, 3),
                  positions[:, None, :], cfg.rope_theta)    # (B, 1, L, dr)
    k_nope = linear(p["wk_b"], c_kv).transpose(0, 2, 1, 3)  # (B, H, L, dn)
    v = linear(p["wv_b"], c_kv).transpose(0, 2, 1, 3)       # (B, H, L, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, k_nope.shape[:3] + (dr,))],
                        axis=-1)
    o = chunked_attention(q, k, v, causal=True, impl=attn_impl, unroll=unroll,
                          q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    out = linear(p["wo"], o.transpose(0, 2, 1, 3).reshape(B, L, -1))
    if return_kv:
        # the compressed latent IS the cache (MLA's point)
        return out, (c_kv, k_rope[:, 0])
    return out


def mla_decode(p: dict, cfg, x: jnp.ndarray, cache: dict, pos: jnp.ndarray):
    """Absorbed-matmul decode over the compressed cache.
    cache: {"c_kv": (B, S, r_kv), "k_rope": (B, S, dr)}."""
    from .layers import rms_norm
    B = x.shape[0]
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    H, r_kv = cfg.n_heads, cfg.kv_lora_rank
    posv = jnp.full((B, 1, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, jnp.full((B, 1), pos, jnp.int32))
    kv = linear(p["wkv_a"], x)                              # (B, 1, r_kv+dr)
    c_new = rms_norm(p["kv_norm"], kv[..., :r_kv], cfg.norm_eps)
    kr_new = rope(kv[..., None, r_kv:].transpose(0, 2, 1, 3), posv,
                  cfg.rope_theta)[:, 0]                     # (B, 1, dr)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype),
                                        (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new.astype(cache["k_rope"].dtype),
                                          (0, pos, 0))
    # absorb wk_b into q: q' (B, H, 1, r_kv)
    q_abs = jnp.einsum("bhqn,rhn->bhqr", q_nope, p["wk_b"]["w"].reshape(r_kv, H, dn))
    s = (jnp.einsum("bhqr,bsr->bhqs", q_abs, c_kv)
         + jnp.einsum("bhqr,bsr->bhqs", q_rope, k_rope)).astype(jnp.float32)
    s = s / (dn + dr) ** 0.5
    S = c_kv.shape[1]
    mask = jnp.arange(S)[None, None, None, :] <= pos
    w = jax.nn.softmax(jnp.where(mask, s, _NEG), axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bhqr", w.astype(c_kv.dtype), c_kv)  # (B,H,1,r)
    # absorb wv_b into the output projection
    o = jnp.einsum("bhqr,rhv->bhqv", ctx, p["wv_b"]["w"].reshape(r_kv, H, dv))
    out = linear(p["wo"], o.transpose(0, 2, 1, 3).reshape(B, 1, -1))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
