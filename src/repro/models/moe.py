"""Mixture-of-Experts: top-k routing with capacity, sort-based dispatch.

Dispatch is **scatter/gather based** (sort tokens by expert, place into an
(E, C, d) buffer, batched expert matmul, weighted gather back) rather than
the GShard one-hot-einsum formulation: the one-hot dispatch contraction
costs O(T^2 d) *real* MXU FLOPs (it corrupts both the roofline and actual
hardware utilization), while scatter/gather is memory-bound data movement
XLA lowers to dynamic-slice/scatter + the EP all-to-alls.

Experts shard over the "model" mesh axis (EP); tokens stay batch-sharded —
the cross-shard movement materializes as all-to-all/all-gather collectives
in the compiled dry-run, which §Roofline accounts explicitly.

Aux load-balancing loss follows Switch/GShard: E * sum_e f_e * p_e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import truncated_normal

__all__ = ["init_moe", "moe_forward"]


def init_moe(key, cfg) -> dict:
    d, E, dff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": truncated_normal(ks[0], (d, E), d ** -0.5, jnp.float32)},
        "wi": truncated_normal(ks[1], (E, d, dff), d ** -0.5, dt),
        "wg": truncated_normal(ks[2], (E, d, dff), d ** -0.5, dt),
        "wo": truncated_normal(ks[3], (E, dff, d), dff ** -0.5, dt),
    }
    if cfg.n_shared_experts:
        from .layers import init_swiglu
        p["shared"] = init_swiglu(ks[4], d, cfg.n_shared_experts * dff, dt)
    return p


def _dispatch_groups(cfg) -> int:
    """GShard-style dispatch group count = DP shard count: every group's
    sort/cumsum/scatter stays local to its shard (no cross-device gathers),
    and the only cross-shard movement is the expert einsum's TP collectives."""
    from repro.sharding import compat_get_abstract_mesh
    sizes = dict(compat_get_abstract_mesh().shape)
    return max(sizes.get("pod", 1) * sizes.get("data", 1), 1)


def moe_forward(p: dict, cfg, x: jnp.ndarray):
    """x: (B, L, d) -> (y, aux_loss)."""
    from .layers import maybe_constrain
    B, L, d = x.shape
    E, topk = cfg.n_experts, cfg.moe_top_k
    T = B * L
    G = _dispatch_groups(cfg)
    while T % G:
        G //= 2
    G = max(G, 1)
    Tg = T // G
    xt = maybe_constrain(x.reshape(G, Tg, d), "data", None, None)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, topk)                  # (G, Tg, topk)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss (global statistics)
    f = jnp.zeros((E,), jnp.float32).at[experts.reshape(-1)].add(1.0) / (T * topk)
    pbar = probs.mean(axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(f * pbar)

    C = max(int(Tg * topk * cfg.capacity_factor / E), 4)

    def dispatch_one(xg, eg, gg):
        """One group: local sort-by-expert + capacity scatter."""
        flat_e = eg.reshape(-1)                                  # (Tg*topk,)
        flat_g = gg.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tg), topk)
        order = jnp.argsort(flat_e)
        e_s, g_s, t_s = flat_e[order], flat_g[order], flat_t[order]
        counts = jnp.zeros((E,), jnp.int32).at[e_s].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(Tg * topk) - starts[e_s]
        keep = pos < C
        slot = e_s * C + jnp.where(keep, pos, 0)
        buf = jnp.zeros((E * C, d), xg.dtype)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xg[t_s], 0))
        return buf.reshape(E, C, d), (slot, t_s, g_s, keep)

    h, meta = jax.vmap(dispatch_one)(xt, experts, gates)         # (G, E, C, d)
    h = maybe_constrain(h, "data", "model", None, None)
    # batched expert SwiGLU: real FLOPs 2*G*E*C*d*dff per matmul; the ff/d
    # contraction dims carry the "model" sharding of the expert weights (TP
    # inside each expert), so compute splits over data x model.
    y = jnp.einsum("gecd,edf->gecf", h, p["wi"]) * jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", h, p["wg"]))
    y = jnp.einsum("gecf,efd->gecd", y, p["wo"])
    y = maybe_constrain(y, "data", "model", None, None)

    def combine_one(yg, m):
        slot, t_s, g_s, keep = m
        contrib = jnp.where(keep[:, None],
                            yg.reshape(E * C, d)[slot] * g_s[:, None].astype(yg.dtype), 0)
        return jnp.zeros((Tg, d), yg.dtype).at[t_s].add(contrib)

    out = jax.vmap(combine_one)(y, meta).astype(x.dtype)         # (G, Tg, d)
    out = maybe_constrain(out, "data", None, None)

    if "shared" in p:
        from .layers import swiglu
        out = out + swiglu(p["shared"], xt)
    return out.reshape(B, L, d), aux
