"""State-space blocks: Mamba1 (selective scan) and Mamba2 (SSD), + decode.

Both use the chunked formulation that TPU likes (DESIGN.md §4): the
sequence is cut into chunks of Q steps; within a chunk the recurrence is
evaluated in parallel (associative scan for Mamba1's diagonal dynamics,
matmul-form SSD for Mamba2's scalar-per-head dynamics — the latter runs on
the MXU), and a single (state)-sized carry crosses chunk boundaries via
lax.scan.  Live memory is O(Q * d_inner * state / TP-shards) instead of
O(L * ...), and the HLO stays compact for the 512-device dry-run.

Decode is the O(1) recurrent update (conv window + state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_linear, linear, truncated_normal

__all__ = ["init_mamba1", "mamba1_forward", "mamba1_decode",
           "init_mamba2", "mamba2_forward", "mamba2_decode"]


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ===================================================================== Mamba1
def init_mamba1(key, cfg) -> dict:
    d, di, s = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(d // 16, 1)
    dt = _dt(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, dt),
        "conv": truncated_normal(ks[1], (di, cfg.ssm_conv), cfg.ssm_conv ** -0.5, dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": init_linear(ks[2], di, dt_rank + 2 * s, dt),
        "dt_proj": init_linear(ks[3], dt_rank, di, dt, bias=True),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, s + 1, dtype=jnp.float32), (di, s))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ks[4], di, d, dt, scale=di ** -0.5),
    }


def _causal_conv(x, w, b, window: int):
    """x: (B, L, di); depthwise causal conv along L (shift-and-scale form:
    window is tiny, so W shifted adds beat a conv op for layout)."""
    xp = jnp.pad(x, ((0, 0), (window - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[:, i][None, None, :]
              for i in range(window))
    return out + b[None, None, :]


def _mamba1_ssm_chunked(dA, dBx, C, chunk: int, unroll: bool = False):
    """Diagonal linear recurrence h_t = dA_t * h_{t-1} + dBx_t, y_t = <C_t, h_t>.

    dA, dBx: (B, L, di, s); C: (B, L, s).  Chunked associative scan.
    """
    B, L, di, s = dA.shape
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nq = dA.shape[1] // Q
    dA_c = jnp.moveaxis(dA.reshape(B, nq, Q, di, s), 1, 0)
    dBx_c = jnp.moveaxis(dBx.reshape(B, nq, Q, di, s), 1, 0)
    C_c = jnp.moveaxis(C.reshape(B, nq, Q, s), 1, 0)

    def chunk_step(h0, inp):
        a, bx, c = inp                                  # (B,Q,di,s),(B,Q,s)
        # within-chunk associative scan of (a, b) pairs
        def combine(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])
        aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h = hh + aa * h0[:, None]                        # inject carry
        y = jnp.einsum("bqds,bqs->bqd", h, c)
        return h[:, -1], y

    h0 = jnp.zeros((B, di, s), dA.dtype)
    if unroll:
        ys_list, h = [], h0
        for i in range(nq):
            h, y_i = chunk_step(h, (dA_c[i], dBx_c[i], C_c[i]))
            ys_list.append(y_i)
        ys = jnp.stack(ys_list)
    else:
        # checkpoint per chunk: backward recomputes the (Q, di, s) intra-
        # chunk states instead of saving them for every chunk.
        _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, (dA_c, dBx_c, C_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nq * Q, di)
    return y[:, :L] if pad else y


def mamba1_forward(p: dict, cfg, x: jnp.ndarray, unroll: bool = False):
    """x: (B, L, d) -> (B, L, d)."""
    B, L, d = x.shape
    di, s = cfg.d_inner, cfg.ssm_state
    xz = linear(p["in_proj"], x)
    xin, z = xz[..., :di], xz[..., di:]
    xin = jax.nn.silu(_causal_conv(xin, p["conv"], p["conv_b"], cfg.ssm_conv))
    proj = linear(p["x_proj"], xin)
    dt_rank = max(d // 16, 1)
    dt_raw = linear(p["dt_proj"], proj[..., :dt_rank])
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32))          # (B, L, di)
    Bmat = proj[..., dt_rank:dt_rank + s].astype(jnp.float32)    # (B, L, s)
    Cmat = proj[..., dt_rank + s:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                     # (di, s)
    dA = jnp.exp(delta[..., None] * A[None, None])               # (B, L, di, s)
    dBx = (delta * xin.astype(jnp.float32))[..., None] * Bmat[:, :, None, :]
    y = _mamba1_ssm_chunked(dA, dBx, Cmat, cfg.ssm_chunk, unroll=unroll)
    y = y + p["D"][None, None] * xin.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return linear(p["out_proj"], y)


def mamba1_decode(p: dict, cfg, x: jnp.ndarray, cache: dict):
    """One-step recurrence. x: (B, 1, d); cache: {"conv": (B, W-1, di),
    "h": (B, di, s)}. Returns (y, cache)."""
    B = x.shape[0]
    d, di, s = cfg.d_model, cfg.d_inner, cfg.ssm_state
    W = cfg.ssm_conv
    xz = linear(p["in_proj"], x)
    xin, z = xz[..., :di], xz[..., di:]
    win = jnp.concatenate([cache["conv"], xin], axis=1)          # (B, W, di)
    conv_out = jnp.einsum("bwd,dw->bd", win, p["conv"]) + p["conv_b"]
    xc = jax.nn.silu(conv_out)[:, None, :]                        # (B, 1, di)
    proj = linear(p["x_proj"], xc)
    dt_rank = max(d // 16, 1)
    delta = jax.nn.softplus(linear(p["dt_proj"], proj[..., :dt_rank]).astype(jnp.float32))
    Bmat = proj[..., dt_rank:dt_rank + s].astype(jnp.float32)
    Cmat = proj[..., dt_rank + s:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(delta[:, 0, :, None] * A[None])                  # (B, di, s)
    h = cache["h"] * dA + (delta[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
        * Bmat[:, 0, None, :]
    y = jnp.einsum("bds,bs->bd", h, Cmat[:, 0]) + p["D"][None] * xc[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return linear(p["out_proj"], y), {"conv": win[:, 1:], "h": h}


# ===================================================================== Mamba2
def init_mamba2(key, cfg) -> dict:
    d, di, s = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    dt = _dt(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, dt),            # x and z
        "bc_proj": init_linear(ks[1], d, 2 * s + H, dt),         # B, C, dt
        "conv": truncated_normal(ks[2], (di, cfg.ssm_conv), cfg.ssm_conv ** -0.5, dt),
        "conv_b": jnp.zeros((di,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": init_linear(ks[3], di, d, dt, scale=di ** -0.5),
        "dt_bias": jnp.zeros((H,), jnp.float32),
    }


def mamba2_forward(p: dict, cfg, x: jnp.ndarray, unroll: bool = False):
    """SSD (chunked matmul) forward. x: (B, L, d) -> (B, L, d)."""
    B, L, d = x.shape
    di, s, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.mamba_headdim
    xz = linear(p["in_proj"], x)
    xin, z = xz[..., :di], xz[..., di:]
    xin = jax.nn.silu(_causal_conv(xin, p["conv"], p["conv_b"], cfg.ssm_conv))
    bc = linear(p["bc_proj"], x)
    Bm = bc[..., :s].astype(jnp.float32)                          # (B, L, s)
    Cm = bc[..., s:2 * s].astype(jnp.float32)
    dt_raw = bc[..., 2 * s:].astype(jnp.float32) + p["dt_bias"]
    delta = jax.nn.softplus(dt_raw)                               # (B, L, H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    a = jnp.exp(delta * A[None, None])                            # (B, L, H) decay
    xh = xin.reshape(B, L, H, P).astype(jnp.float32)
    xd = xh * delta[..., None]                                    # Δ-scaled input

    Q = min(cfg.ssm_chunk, L)
    pad = (-L) % Q
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nq = a.shape[1] // Q

    a_c = jnp.moveaxis(a.reshape(B, nq, Q, H), 1, 0)
    x_c = jnp.moveaxis(xd.reshape(B, nq, Q, H, P), 1, 0)
    B_c = jnp.moveaxis(Bm.reshape(B, nq, Q, s), 1, 0)
    C_c = jnp.moveaxis(Cm.reshape(B, nq, Q, s), 1, 0)

    def chunk_step(S0, inp):
        av, xv, bv, cv = inp          # (B,Q,H) (B,Q,H,P) (B,Q,s) (B,Q,s)
        la = jnp.log(jnp.maximum(av, 1e-30))
        cum = jnp.cumsum(la, axis=1)                              # (B,Q,H)
        # intra-chunk: Gamma[i,j] = prod_{r=j+1..i} a_r  (i >= j)
        gam = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])    # (B,Qi,Qj,H)
        mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        gam = jnp.where(mask[None, :, :, None], gam, 0.0)
        cb = jnp.einsum("bis,bjs->bij", cv, bv)                   # (B,Qi,Qj)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb, gam, xv)
        # carry-in contribution: C_i (prod_{r<=i} a) S0
        dec = jnp.exp(cum)                                        # (B,Q,H)
        y_carry = jnp.einsum("bis,bih,bhsp->bihp", cv, dec, S0)
        # next state: S = a_total * S0 + sum_j (prod_{r>j} a) B_j x_j^T
        rev = jnp.exp(cum[:, -1:, :] - cum)                       # (B,Q,H)
        S_new = dec[:, -1][:, :, None, None] * S0 + jnp.einsum(
            "bjs,bjh,bjhp->bhsp", bv, rev, xv)
        return S_new, y_intra + y_carry

    S0 = jnp.zeros((B, H, s, P), jnp.float32)
    if unroll:
        ys_list, S = [], S0
        for i in range(nq):
            S, y_i = chunk_step(S, (a_c[i], x_c[i], B_c[i], C_c[i]))
            ys_list.append(y_i)
        ys = jnp.stack(ys_list)
    else:
        # checkpoint per chunk (see mamba1): the (Q, Q, H) decay tensor is
        # recomputed in backward, not saved per chunk.
        _, ys = jax.lax.scan(jax.checkpoint(chunk_step), S0, (a_c, x_c, B_c, C_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nq * Q, H, P)[:, :L]
    y = y + p["D"][None, None, :, None] * xh
    y = (y.reshape(B, L, di).astype(x.dtype)) * jax.nn.silu(z)
    return linear(p["out_proj"], y)


def mamba2_decode(p: dict, cfg, x: jnp.ndarray, cache: dict):
    """cache: {"conv": (B, W-1, di), "S": (B, H, s, P)}."""
    B = x.shape[0]
    di, s, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.mamba_headdim
    xz = linear(p["in_proj"], x)
    xin, z = xz[..., :di], xz[..., di:]
    win = jnp.concatenate([cache["conv"], xin], axis=1)
    xc = jax.nn.silu(jnp.einsum("bwd,dw->bd", win, p["conv"]) + p["conv_b"])
    bc = linear(p["bc_proj"], x)[:, 0]
    Bm = bc[:, :s].astype(jnp.float32)
    Cm = bc[:, s:2 * s].astype(jnp.float32)
    delta = jax.nn.softplus(bc[:, 2 * s:].astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(delta * (-jnp.exp(p["A_log"]))[None])             # (B, H)
    xh = xc.reshape(B, H, P).astype(jnp.float32) * delta[..., None]
    S = cache["S"] * a[:, :, None, None] + jnp.einsum("bs,bhp->bhsp", Bm, xh)
    y = jnp.einsum("bhsp,bs->bhp", S, Cm) + p["D"][None, :, None] \
        * xc.reshape(B, H, P).astype(jnp.float32)
    y = (y.reshape(B, 1, di).astype(x.dtype)) * jax.nn.silu(z)
    return linear(p["out_proj"], y), {"conv": win[:, 1:], "S": S}
