"""Foundational layers: norms, RoPE, MLPs, embeddings, logits.

All layers are pure functions over parameter dicts (pytree leaves are
jnp arrays; stacked along a leading L axis when scanned over layers).
Initializers return the same tree structure, so ``jax.eval_shape`` yields
allocation-free ShapeDtypeStruct trees for the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "swiglu", "init_linear", "init_rmsnorm",
           "linear", "embed", "unembed", "init_embed", "truncated_normal",
           "maybe_constrain"]


def maybe_constrain(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """with_sharding_constraint that no-ops when the named axes are absent
    (CPU smoke tests run mesh-less; the dry-run/train run under set_mesh)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import compat_get_abstract_mesh
    mesh_axes = set(compat_get_abstract_mesh().axis_names)
    spec = tuple(a if (a in mesh_axes) else None for a in axes)
    if not any(spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def truncated_normal(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """x: (..., L, D) with D even; positions: (..., L) int."""
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- linears
def init_linear(key, d_in: int, d_out, dtype, bias: bool = False,
                scale: float | None = None) -> dict:
    shape = (d_in,) + (tuple(d_out) if isinstance(d_out, (tuple, list)) else (d_out,))
    p = {"w": truncated_normal(key, shape, scale or (d_in ** -0.5), dtype)}
    if bias:
        p["b"] = jnp.zeros(shape[1:], dtype)
    return p


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    w = p["w"]
    y = jax.lax.dot_general(x, w.reshape(w.shape[0], -1),
                            (((x.ndim - 1,), (0,)), ((), ())))
    y = y.reshape(x.shape[:-1] + w.shape[1:])
    if "b" in p:
        y = y + p["b"]
    return y


# ------------------------------------------------------------------- MLPs
def init_swiglu(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": init_linear(k1, d, d_ff, dtype),
            "wg": init_linear(k2, d, d_ff, dtype),
            "wo": init_linear(k3, d_ff, d, dtype, scale=d_ff ** -0.5)}


def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))


# ------------------------------------------------------- embedding / logits
def init_embed(key, vocab: int, d: int, dtype) -> dict:
    return {"table": truncated_normal(key, (vocab, d), 1.0, dtype)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Logits head; kept separate from the embedding (no tying by default)."""
    return linear(p, x)
