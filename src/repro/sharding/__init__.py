import jax

from .rules import (batch_specs, cache_specs, data_axes, named, opt_specs,
                    param_specs)

__all__ = ["batch_specs", "cache_specs", "data_axes", "named", "opt_specs",
           "param_specs", "compat_set_mesh", "compat_abstract_mesh",
           "compat_get_abstract_mesh", "compat_shard_map"]


def compat_get_abstract_mesh():
    """The mesh currently in scope (jax.sharding.get_abstract_mesh on newer
    jax; the thread-resources physical mesh set by ``with mesh:`` on older).
    Outside any mesh context both return an empty mesh (no axis names)."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as _mesh_lib
    return _mesh_lib.thread_resources.env.physical_mesh


def compat_abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh across jax versions: newer jax takes (sizes, names),
    older takes a single ((name, size), ...) shape tuple."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def compat_shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions.

    Newer jax exports ``jax.shard_map`` (replication check keyword
    ``check_vma``); older versions keep it in ``jax.experimental.shard_map``
    (keyword ``check_rep``).  The replication check is disabled either way:
    the bodies this repo maps contain a Pallas call, whose replication rule
    the checker cannot see through.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)


def compat_set_mesh(mesh):
    """``with compat_set_mesh(mesh):`` across jax versions — newer jax has
    jax.set_mesh; older versions use the Mesh object's own context manager
    (same effect for the Auto axis semantics this repo runs under)."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh
