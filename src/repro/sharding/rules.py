"""Logical sharding rules: param/optimizer/cache/batch PartitionSpecs.

Axis semantics of the production mesh (launch/mesh.py):
  "pod"   — data parallel across pods (slow DCN links; grad sync crosses it)
  "data"  — data parallel within a pod
  "model" — tensor/expert parallel (attention heads, ffn hidden, experts,
            mamba inner channels, vocab)

Rules are path-based with divisibility guards: a dim is sharded only when
divisible by the mesh axis size (e.g. granite's kv=1 head stays replicated —
the realistic MQA serving layout).  ZeRO-1: optimizer-state leaves get their
first still-replicated divisible dim sharded over "data" on top of the param
layout.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "opt_specs", "batch_specs", "cache_specs",
           "named", "data_axes"]


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, name) -> int:
    # mesh.shape is an axis-name -> size mapping for both Mesh and
    # AbstractMesh (the latter lets spec logic run without real devices)
    return dict(mesh.shape).get(name, 1)


def named(mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _leaf_spec(path: tuple[str, ...], shape: tuple[int, ...], msize: int) -> P:
    """Param sharding for one leaf, identified by its dict path."""
    p = list(path)
    stacked = p and p[0] == "layers"
    off = 1 if stacked else 0           # leading L axis of scanned stacks

    def spec(*axes):
        return P(*([None] * off + list(axes)))

    name = p[-1]
    parent = p[-2] if len(p) >= 2 else ""
    gparent = p[-3] if len(p) >= 3 else ""
    dims = shape[off:]

    def model_if(idx: int):
        axes = [None] * len(dims)
        if _div(dims[idx], msize):
            axes[idx] = "model"
        return spec(*axes)

    # ---- embeddings / head ------------------------------------------------
    if parent == "embed" and name == "table":
        return model_if(len(dims) - 2)            # vocab dim (C, V, d) or (V, d)
    if parent == "head" and name == "w":
        return model_if(len(dims) - 1)            # (d, V) or (d, C, V)
    if parent == "head" and name == "b":
        return model_if(len(dims) - 1)

    # ---- norms / scalars ---------------------------------------------------
    if name in ("scale",) or parent in ("ln1", "ln2", "final_ln", "kv_norm",
                                        "q_norm", "shared_ln"):
        return spec(*([None] * len(dims)))

    # ---- attention ----------------------------------------------------------
    if gparent in ("attn", "shared_attn") or parent in ("attn", "shared_attn") \
            or (stacked and len(p) >= 2 and p[1] == "attn") \
            or path[0] == "shared_attn":
        if parent in ("wq", "wk", "wv", "wq_b", "wk_b", "wv_b"):
            if name == "w":                       # (d|r, H, hd)
                sp = model_if(1)
                if sp == spec(None, None, None) and len(dims) == 3:
                    return model_if(2)            # odd head counts: shard hd
                return sp
            sp = model_if(0)                      # bias (H, hd)
            if sp == spec(None, None) and len(dims) == 2:
                return model_if(1)
            return sp
        if parent == "wo" and name == "w":        # (H*hd, d)
            return model_if(0)
        if parent in ("wq_a", "wkv_a"):
            return spec(*([None] * len(dims)))    # low-rank stems replicated
        return spec(*([None] * len(dims)))

    # ---- MoE ------------------------------------------------------------------
    if parent == "router":
        return spec(*([None] * len(dims)))
    if name in ("wi", "wg", "wo") and len(dims) == 3 and parent == "mlp":
        return model_if(0)                        # (E, d, ff) expert dim -> EP
    if gparent == "shared" or parent == "shared":
        # shared experts: dense SwiGLU layout
        if parent in ("wi", "wg") and name == "w":
            return model_if(1)
        if parent == "wo" and name == "w":
            return model_if(0)
        return spec(*([None] * len(dims)))

    # ---- dense MLP ---------------------------------------------------------------
    if gparent == "mlp" or parent == "mlp":
        if parent in ("wi", "wg") and name == "w":    # (d, ff)
            return model_if(1)
        if parent == "wo" and name == "w":            # (ff, d)
            return model_if(0)
        return spec(*([None] * len(dims)))

    # ---- mamba ------------------------------------------------------------------
    if parent == "mixer" or gparent == "mixer":
        if parent == "in_proj" and name == "w":       # (d, 2*di)
            return model_if(1)
        if parent == "out_proj" and name == "w":      # (di, d)
            return model_if(0)
        if parent == "x_proj" and name == "w":        # (di, k)
            return model_if(0)
        if parent == "dt_proj":
            if name == "w":                            # (dt_rank, di)
                return model_if(1)
            return model_if(0)                         # bias (di,)
        if name == "conv":                             # (di, W)
            return model_if(0)
        if name in ("conv_b", "D") and len(dims) == 1:
            return model_if(0)
        if name == "A_log":                            # (di, s) or (H,)
            return model_if(0)
        if name == "dt_bias":
            return model_if(0)
        if parent == "bc_proj":
            return spec(*([None] * len(dims)))         # small (d, 2s+H)
        return spec(*([None] * len(dims)))

    return spec(*([None] * len(dims)))


def _paths_and_shapes(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = tuple(getattr(k, "key", getattr(k, "idx", str(k))) for k in kp)
        out.append((path, tuple(leaf.shape)))
    return out, treedef


def param_specs(params_shapes, mesh, serve: bool = False,
                expert_2d: bool = False, layout: str = "tp"):
    """PartitionSpec tree matching a params (shapes) tree.

    ``serve=True`` / ``expert_2d=True``: expert tensors additionally shard
    their d_model axis over the data axis (2D weight sharding; the MoE
    einsum re-gathers per use) — what fits a 236B MoE on 256 x 16 GiB chips
    (serving always; training as the FSDP-style §Perf lever).

    ``layout="dp"``: replicate all weights; the model axis is given to the
    batch instead (see batch_specs(include_model=True)) — the right layout
    for small models where TP activation psums dominate (§Perf, qwen2).
    """
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    flat, treedef = _paths_and_shapes(params_shapes)

    def leaf(path, shape):
        if layout == "dp":
            return P(*([None] * len(shape)))
        spec = _leaf_spec(path, shape, msize)
        if (serve or expert_2d) and path[-1] in ("wi", "wg", "wo") \
                and len(shape) == 4 and path[-2] == "mlp" \
                and spec == P(None, "model", None, None):
            # stacked expert weights (L, E, d, ff)/(L, E, ff, d): shard the
            # wider inner axis over data
            inner = 2 if shape[2] >= shape[3] else 3
            if _div(shape[inner], dsize):
                axes = [None, "model", None, None]
                axes[inner] = "data"
                return P(*axes)
        if layout == "fsdp":
            # ZeRO-3: every big param also shards a replicated dim over
            # "data" (XLA re-gathers per use; grads reduce-scatter back)
            n = 1
            for s in shape:
                n *= s
            axes = list(spec) + [None] * (len(shape) - len(spec))
            if n >= 1 << 20 and "data" not in axes:
                for i in range(len(shape) - 1, -1, -1):
                    if axes[i] is None and _div(shape[i], dsize) \
                            and shape[i] >= dsize:
                        axes[i] = "data"
                        return P(*axes)
        return spec

    return jax.tree_util.tree_unflatten(
        treedef, [leaf(p, s) for p, s in flat])


def opt_specs(params_shapes, mesh, zero1: bool = True,
              expert_2d: bool = False, layout: str = "tp"):
    """Optimizer-state specs: master/m/v mirror the param layout; under
    ZeRO-1 the first still-replicated divisible dim also shards over "data"
    (and over "model" too in the pure-DP layout, where weights are
    replicated and the optimizer is the only sharded copy)."""
    dsize = _axis_size(mesh, "data")
    msize = _axis_size(mesh, "model")
    pspecs = param_specs(params_shapes, mesh, expert_2d=expert_2d,
                         layout=layout)

    def zero1_spec(spec: P, shape: tuple[int, ...]) -> P:
        if not zero1:
            return spec
        axes = list(spec) + [None] * (len(shape) - len(spec))
        pending = [a for a in (["data"] + (["model"] if layout == "dp" else []))
                   if a not in axes]    # an axis may appear only once
        sizes = {"data": dsize, "model": msize}
        for i in range(len(shape)):
            if not pending:
                break
            ax = pending[0]
            if axes[i] is None and _div(shape[i], sizes[ax]) and shape[i] >= sizes[ax]:
                axes[i] = ax       # ZeRO-1: slice replicated dims over DP
                pending.pop(0)
        return P(*axes)

    flat, treedef = _paths_and_shapes(params_shapes)
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    state_leaf_specs = jax.tree_util.tree_unflatten(
        treedef, [zero1_spec(sp, sh) for (path, sh), sp in zip(flat, flat_p)])
    return {
        "master": state_leaf_specs,
        "m": state_leaf_specs,
        "v": state_leaf_specs,
        "step": P(),
    }


def batch_specs(batch_shapes, mesh, include_model: bool = False):
    """Batch dims shard over the DP axes when divisible (long_500k's B=1
    stays replicated).  ``include_model=True``: pure-DP layout — the model
    axis joins the batch sharding (weights replicated)."""
    dp = data_axes(mesh)
    if include_model and "model" in mesh.axis_names:
        dp = dp + ("model",)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp])) if dp else 1

    def one(leaf):
        if not leaf.shape:
            return P()
        if _div(leaf.shape[0], dp_size):
            return P(dp, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(one, batch_shapes)


def cache_specs(cache_shapes, mesh):
    """KV/SSM cache: batch dim -> DP axes; head/channel dims -> model when
    divisible.  Cache layouts (leading L stack axis):
      k/v    (L, B, Hkv, S, hd)   model on Hkv
      c_kv   (L, B, S, r)          replicated feature dim (MLA latent)
      conv   (L, B, W-1, di)       model on di
      h      (L, B, di, s)         model on di
      S      (L, B, H, s, P)       model on H
      shared k/v (Ns, B, Hkv, S, hd)
    """
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp])) if dp else 1
    msize = _axis_size(mesh, "model")
    flat, treedef = _paths_and_shapes(cache_shapes)

    def one(path, shape):
        name = path[-1]
        if name == "pos" or not shape:
            return P()
        axes: list = [None] * len(shape)
        # batch axis is dim 1 for stacked entries
        bdim = 1 if len(shape) >= 2 else 0
        if _div(shape[bdim], dp_size):
            axes[bdim] = dp
        if name in ("k", "v") and len(shape) == 5:
            if _div(shape[2], msize):
                axes[2] = "model"          # KV heads
            elif _div(shape[4], msize):
                axes[4] = "model"          # MQA/odd-head serving: shard hd
        elif name == "c_kv" and _div(shape[-1], msize):
            axes[-1] = "model"             # MLA latent dim (512/16 = 32)
        elif name == "conv" and _div(shape[-1], msize):
            axes[-1] = "model"
        elif name == "h" and _div(shape[2], msize):
            axes[2] = "model"
        elif name == "S" and _div(shape[2], msize):
            axes[2] = "model"
        return P(*axes)

    return jax.tree_util.tree_unflatten(
        treedef, [one(p, s) for p, s in flat])
