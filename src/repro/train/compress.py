"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 quantization with per-tensor scales and **error feedback** (the
quantization residual is carried to the next step, so compression bias
vanishes in expectation — Seide et al. / EF-SGD).  Intended use: the "pod"
axis of the production mesh is the slow DCN dimension; compressing the
gradient sync there cuts cross-pod bytes 4x (bf16 -> int8 + scale).

The pure-array API here (quantize / dequantize / ef_update) is used by
train_step's ``compress_pod_grads`` hook and unit-tested directly; on a real
multi-pod run the psum over "pod" happens inside a shard_map with these
transforms around it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_init", "compress_with_feedback",
           "compressed_pod_psum"]


def quantize_int8(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_with_feedback(grads, error_state):
    """Returns (quantized tree of (q, scale) pairs, new_error_state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    quant, err = [], []
    for g, e in zip(flat_g, flat_e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        quant.append((q, s))
        err.append(target - dequantize_int8(q, s))
    return tdef.unflatten(quant), tdef.unflatten(err)


def compressed_pod_psum(grads, error_state, axis_name: str = "pod"):
    """Inside shard_map over the pod axis: int8+EF all-reduce of grads.
    Returns (synced_grads_f32_mean, new_error_state)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        new_e = target - deq
        summed = jax.lax.psum(deq, axis_name)
        return summed / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
