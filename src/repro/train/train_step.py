"""Training step: CE (+z-loss, +MoE aux), remat'd scan backward, AdamW,
optional microbatch gradient accumulation.

The step is a single pjit program: the data-parallel gradient all-reduce is
inserted by SPMD partitioning (and overlapped by XLA's latency-hiding
scheduler); microbatching amortizes it via a lax.scan accumulation.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import forward
from .optimizer import AdamWConfig, adamw_apply

__all__ = ["cross_entropy", "loss_fn", "make_train_step"]


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  z_coef: float = 0.0):
    """logits: (..., V) (extra codebook dims fold into ...); targets ints.

    The true-class logit is extracted with an iota-compare masked sum (not a
    gather): under vocab sharding each shard reduces its local slice and the
    cross-shard psum is a scalar tree — no logits all-gather.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    V = lf.shape[-1]
    onehot = targets[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, targets.shape + (V,), targets.ndim)
    true = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = (lse - true).mean()
    if z_coef:
        nll = nll + z_coef * jnp.square(lse).mean()
    return nll


def chunked_xent(cfg, head_p, hidden: jnp.ndarray, targets: jnp.ndarray,
                 n_chunks: int = 8, unroll: bool = False):
    """Fused CE: the unembedding matmul runs per sequence-chunk inside the
    loop, so only (B, L/n_chunks, V_shard) logits are ever live."""
    from repro.models.layers import linear
    B, L = hidden.shape[0], hidden.shape[1]
    while L % n_chunks:
        n_chunks //= 2
    n_chunks = max(n_chunks, 1)
    hs = jnp.moveaxis(hidden.reshape(B, n_chunks, L // n_chunks, *hidden.shape[2:]), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n_chunks, L // n_chunks, *targets.shape[2:]), 1, 0)

    def one(h, t):
        return cross_entropy(linear(head_p, h), t, cfg.z_loss_coef)

    if unroll:
        losses = jnp.stack([one(hs[i], ts[i]) for i in range(n_chunks)])
    else:
        losses = jax.lax.map(lambda ht: one(*ht), (hs, ts))
    return losses.mean()


def loss_fn(cfg, params, batch: dict, attn_impl: str = "xla",
            unroll: bool = False):
    hidden, aux = forward(cfg, params, batch, attn_impl=attn_impl,
                          unroll=unroll, return_hidden=True)
    loss = chunked_xent(cfg, params["head"], hidden, batch["targets"],
                        unroll=unroll) + aux
    return loss, {"ce": loss, "aux": aux}


def make_train_step(cfg, ocfg: AdamWConfig, attn_impl: str = "xla",
                    num_microbatches: int = 1, unroll: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, attn_impl, unroll), has_aux=True)

    def compute_grads(params, batch):
        if num_microbatches <= 1:
            (loss, met), grads = grad_fn(params, batch)
            return loss, grads, met
        # split batch leading dim into microbatches and accumulate
        def split(x):
            B = x.shape[0]
            return x.reshape(num_microbatches, B // num_microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def acc_step(carry, mbatch):
            loss_acc, grads_acc = carry
            (loss, met), grads = grad_fn(params, mbatch)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, grads_acc, grads)), met

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads_sum), mets = jax.lax.scan(
            acc_step, (jnp.zeros(()), zeros), mb)
        inv = 1.0 / num_microbatches
        return (loss_sum * inv,
                jax.tree.map(lambda g: g * inv, grads_sum),
                jax.tree.map(lambda m: m[-1], mets))

    def train_step(params, opt_state, batch):
        loss, grads, met = compute_grads(params, batch)
        params, opt_state, opt_met = adamw_apply(ocfg, grads, opt_state, params)
        metrics = {"loss": loss, **met, **opt_met}
        return params, opt_state, metrics

    return train_step
