"""AdamW (hand-rolled, pytree-native) with fp32 master weights.

State layout per parameter: {master fp32, m fp32, v fp32} — 12 bytes/param
on top of the bf16 params.  Under ZeRO-1 (sharding/rules.py) the state tree
is additionally sharded over the data axis, dividing that cost by |data|.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_apply", "global_norm",
           "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def adamw_init(params) -> dict:
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        # copy=True: f32 params would otherwise alias the master buffer and
        # break double donation in train_step
        "master": jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                               params),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def adamw_apply(ocfg: AdamWConfig, grads, opt_state, params):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(ocfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m_new = ocfg.beta1 * m + (1 - ocfg.beta1) * g
        v_new = ocfg.beta2 * v + (1 - ocfg.beta2) * g * g
        mhat = m_new / (1 - ocfg.beta1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - ocfg.beta2 ** step.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + ocfg.eps) + ocfg.weight_decay * master
        master_new = master - lr * upd
        return m_new, v_new, master_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    flat_p = treedef.flatten_up_to(params)
    new_params = treedef.unflatten(
        [w.astype(p.dtype) for w, p in zip([o[2] for o in out], flat_p)])
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
