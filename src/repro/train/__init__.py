from .optimizer import AdamWConfig, adamw_apply, adamw_init, cosine_lr, global_norm
from .train_step import cross_entropy, loss_fn, make_train_step
from .compress import (compress_with_feedback, compressed_pod_psum,
                       dequantize_int8, ef_init, quantize_int8)

__all__ = ["AdamWConfig", "adamw_apply", "adamw_init", "cosine_lr",
           "global_norm", "cross_entropy", "loss_fn", "make_train_step",
           "compress_with_feedback", "compressed_pod_psum", "dequantize_int8",
           "ef_init", "quantize_int8"]
