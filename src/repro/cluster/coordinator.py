"""ClusterEngine — the coordinator of the distributed serving plane.

A :class:`~repro.service.engine.CoresetEngine` whose **dense build path**
scatters row-band builds to :class:`~repro.cluster.worker.ShardWorker`
peers instead of the in-process thread pool, and gathers only the tiny
band coresets back (the merge-reduce wire pattern of paper challenge iv —
data stays put, coresets travel).  Everything else — cache, dominance
rule, schedulers, streamed signals, queries — is inherited unchanged, so
a coordinator speaks the exact public v1 API.

Parity is the design invariant: the composed coreset must be **bitwise
fingerprint-equal** to the single-host ``sharded_coreset`` thread-pool
path.  Three shared pieces guarantee it:

  * ``shared_tolerance`` — the coordinator computes the global per-block
    cap from its own full-signal stats, identical float op order;
  * ``band_bounds``       — the same linspace band layout; worker i owns
    band i (round-robin when bands > peers);
  * workers build ``signal_coreset(slab, k, eps, tolerance_override=tol)``
    on the same bytes, and both wire codecs round-trip f64 exactly.

Failure model (the ISSUE's degraded mode): an RPC answer of ``no_band`` /
``stale_band`` heals in-line — re-assign the slab (the coordinator always
holds the full signal) and retry once, which is also the entire worker
**rejoin** story.  A transport fault after the client's retries marks the
worker down and the coordinator builds that band **locally with the same
tolerance** — fingerprint-identical output, a 200 response, and only the
``cluster_degraded_builds`` counter knows.  Down workers are skipped for
``reprobe_s`` (no per-request timeout storms), then probed again by the
next build.
"""
from __future__ import annotations

import concurrent.futures as _fut
import threading
import time

import numpy as np

from repro import obs
from repro.core.coreset import SignalCoreset, signal_coreset
from repro.core.sharded import band_bounds, shared_tolerance
from repro.core.streaming import compose
from repro.service.admission import current_ticket
from repro.service.engine import CoresetEngine, SignalState

from .rpc import (WorkerClient, WorkerRPCError, WorkerTransportError,
                  band_hash, coreset_from_msg)

__all__ = ["ClusterEngine"]


class _Peer:
    """One worker endpoint + its health word."""

    __slots__ = ("url", "client", "up", "fails", "down_since", "lock")

    def __init__(self, url: str, client: WorkerClient):
        self.url = url
        self.client = client
        self.up = True          # optimistic: the first build probes for real
        self.fails = 0
        self.down_since = 0.0
        self.lock = threading.Lock()


class ClusterEngine(CoresetEngine):
    def __init__(self, peers: list[str], *, encoding: str = "binary",
                 rpc_timeout: float = 30.0, rpc_retries: int = 2,
                 rpc_backoff: float = 0.05, reprobe_s: float = 1.0, **kw):
        # one band per worker by default: band i lives on worker i, so the
        # layout IS the ownership map (callers may still override num_bands;
        # extra bands round-robin)
        kw.setdefault("num_bands", max(len(peers), 1))
        super().__init__(**kw)
        self._peers = [
            _Peer(url, WorkerClient(url, encoding=encoding,
                                    timeout=rpc_timeout,
                                    retries=rpc_retries,
                                    backoff=rpc_backoff))
            for url in peers]
        self.reprobe_s = float(reprobe_s)
        self.rpc_timeout = float(rpc_timeout)
        # scatter pool: sized so one build can fan to every peer at once
        # with headroom for a concurrent delta forward
        self._rpc = _fut.ThreadPoolExecutor(
            max_workers=max(2 * max(len(self._peers), 1), 4),
            thread_name_prefix="cluster-rpc")
        for p in self._peers:
            self.metrics.set_gauge("cluster_worker_up", 1.0, worker=p.url)

    # ---------------------------------------------------------------- health
    def _usable(self, peer: _Peer) -> bool:
        """Down workers rest for ``reprobe_s`` — during the cooldown their
        bands degrade to local builds without paying a connect timeout; the
        first build after it probes the worker again (rejoin)."""
        with peer.lock:
            return peer.up or \
                (time.monotonic() - peer.down_since) >= self.reprobe_s

    def _mark_down(self, peer: _Peer) -> None:
        with peer.lock:
            was_up = peer.up
            peer.up = False
            peer.fails += 1
            peer.down_since = time.monotonic()
        self.metrics.set_gauge("cluster_worker_up", 0.0, worker=peer.url)
        if was_up:
            self.metrics.inc("cluster_worker_down_total", worker=peer.url)

    def _mark_up(self, peer: _Peer) -> None:
        with peer.lock:
            was_up = peer.up
            peer.up = True
            peer.fails = 0
        self.metrics.set_gauge("cluster_worker_up", 1.0, worker=peer.url)
        if not was_up:
            self.metrics.inc("cluster_worker_rejoins")

    def probe_workers(self, timeout: float = 2.0) -> dict:
        """Active health sweep (/v1/healthz per peer) — the launch CLI calls
        this once at startup; builds keep health fresh passively after."""
        out = {}
        for peer in self._peers:
            try:
                out[peer.url] = peer.client.healthz(timeout=timeout)
                self._mark_up(peer)
            except Exception as exc:
                out[peer.url] = {"status": "down",
                                 "error": f"{type(exc).__name__}: {exc}"}
                self._mark_down(peer)
        return out

    # ---------------------------------------------------------------- layout
    def _layout(self, n: int) -> list[tuple[int, int]]:
        # the engine's own band heuristic over the canonical linspace split:
        # identical on the single-host comparison engine by construction
        return band_bounds(n, min(self.num_bands, max(1, n // 32)))

    def _owner(self, band_index: int) -> _Peer:
        return self._peers[band_index % len(self._peers)]

    # ---------------------------------------------------------------- ingest
    def register_signal(self, name: str, values: np.ndarray, *,
                        replace: bool = False,
                        tenant: str | None = None) -> dict:
        # admit BEFORE scattering: a refused registration must cost zero
        # worker RPCs.  Requests arriving over HTTP already hold a ticket
        # (api.py admitted them and made it current), so only direct engine
        # callers trigger a fresh decision here — one request, one charge.
        ctl = self.admission
        if ctl is not None and current_ticket() is None:
            with ctl.admit("register", tenant, signal=name):
                info = super().register_signal(name, values, replace=replace)
                self._scatter(name)
                return info
        info = super().register_signal(name, values, replace=replace)
        self._scatter(name)
        return info

    def _scatter(self, name: str) -> int:
        """Push every band slab to its owner (best-effort: a failed assign
        only marks the worker down — the build path heals or degrades)."""
        st = self.signal(name)
        with st.lock:
            if st.streamed:
                return 0      # streamed signals build via merge-reduce, local
            y = st.dense_locked()
        layout = self._layout(y.shape[0])
        if len(layout) <= 1 or not self._peers:
            return 0

        def _one(i: int, b0: int, b1: int) -> bool:
            peer = self._owner(i)
            if not self._usable(peer):
                return False
            try:
                peer.client.assign(name, b0, y[b0:b1])
                self._mark_up(peer)
                return True
            except WorkerTransportError:
                self._mark_down(peer)
            except WorkerRPCError:
                pass          # an answer; the build path will heal
            return False

        futs = [self._rpc.submit(_one, i, b0, b1)
                for i, (b0, b1) in enumerate(layout)]
        sent = sum(bool(f.result()) for f in futs)
        if sent:
            self.metrics.inc("cluster_bands_scattered", sent)
        return sent

    def ingest_delta(self, name: str, band, *, row0: int | None = None,
                     row0s: list | None = None,
                     rows: list | None = None) -> dict:
        out = super().ingest_delta(name, band, row0=row0, row0s=row0s,
                                   rows=rows)
        # forward only dense replaces: appends flip the signal streamed,
        # which routes builds through local merge-reduce — workers hold no
        # role there (their stale slabs die on the next dense build's heal)
        if out["streamed"] or not self._peers:
            return out
        st = self.signal(name)
        with st.lock:
            if st.streamed or st.version != out["version"]:
                return out    # racing writer; its own forward covers the rest
            y = st.dense_locked()
        if row0s is not None:
            splits = np.split(np.ascontiguousarray(band, np.float64),
                              np.cumsum([int(r) for r in rows])[:-1], axis=0)
            deltas = [(int(r0), p.shape[0]) for r0, p in zip(row0s, splits)]
        else:
            deltas = [(int(row0), int(out["rows"]))]
        self._forward_deltas(name, y, deltas)
        return out

    def _forward_deltas(self, name: str, y: np.ndarray,
                        deltas: list[tuple[int, int]]) -> None:
        """Send each owner only its intersection with the changed rows plus
        the expected post-patch slab hash (O(changed rows) on the wire; a
        re-assign ships the whole band).  Failures self-heal at build."""
        layout = self._layout(y.shape[0])
        if len(layout) <= 1:
            return
        jobs = []   # (band index, slab-absolute r0, r1)
        for i, (b0, b1) in enumerate(layout):
            touched: list[tuple[int, int]] = []
            for r0, nrows in deltas:
                lo, hi = max(r0, b0), min(r0 + nrows, b1)
                if lo < hi:
                    touched.append((lo, hi))
            if touched:
                # one merged window per band keeps it a single RPC
                lo = min(t[0] for t in touched)
                hi = max(t[1] for t in touched)
                jobs.append((i, lo, hi))

        def _one(i: int, lo: int, hi: int) -> bool:
            peer = self._owner(i)
            if not self._usable(peer):
                return False
            b0, b1 = layout[i]
            slab_hash = band_hash(y[b0:b1])
            try:
                try:
                    peer.client.delta(name, lo, y[lo:hi], slab_hash)
                except WorkerRPCError as exc:
                    if exc.code not in ("no_band", "stale_band"):
                        raise
                    # worker missed a prior write (or is freshly restarted):
                    # ship the whole current slab instead
                    peer.client.assign(name, b0, y[b0:b1])
                    self.metrics.inc("cluster_band_heals", code=exc.code)
                self._mark_up(peer)
                return True
            except WorkerTransportError:
                self._mark_down(peer)
            except WorkerRPCError:
                pass
            return False

        futs = [self._rpc.submit(_one, *job) for job in jobs]
        sent = sum(bool(f.result()) for f in futs)
        if sent:
            self.metrics.inc("cluster_deltas_forwarded", sent)

    # ----------------------------------------------------------------- build
    def _build_dense(self, st: SignalState, k: int, eps: float,
                     ) -> tuple[SignalCoreset, float, str]:
        with st.lock:
            y = st.dense_locked()
            version = st.version
        n = y.shape[0]
        layout = self._layout(n)
        if len(layout) <= 1 or not self._peers:
            return super()._build_dense(st, k, eps)
        # the one full-signal computation the coordinator keeps: the global
        # sigma estimate -> shared per-block cap (reusing the delta-patched
        # integral images when a delta write already materialized them)
        ps = st.stats_snapshot(version)
        tol = shared_tolerance(y, k, eps, _stats=ps)
        t0 = time.perf_counter()
        with obs.span("cluster.gather", signal=st.name, k=int(k),
                      bands=len(layout)) as g:
            futs = [self._rpc.submit(self._band_part, g, st.name, y,
                                     i, b0, b1, k, eps, tol)
                    for i, (b0, b1) in enumerate(layout)]
            results = [f.result() for f in futs]
            for _, peer_ctx in results:
                if g and peer_ctx is not None:
                    # fan-in visibility: the gather span links every worker
                    # root, so GET /v1/trace/{id} resolves the remote hops
                    g.add_link(peer_ctx)
        self.metrics.observe("cluster_gather", time.perf_counter() - t0,
                             exemplar=g.trace_id if g else None)
        self.metrics.inc("cluster_gathers")
        cs = compose([part for part, _ in results],
                     [b0 for b0, _ in layout], n_total=n)
        return cs, eps, version   # composition of disjoint bands is exact

    def _band_part(self, gather_span, name: str, y: np.ndarray, i: int,
                   b0: int, b1: int, k: int, eps: float, tol: float):
        """One band's coreset: worker RPC with heal-retry, or the local
        degraded build.  Returns (coreset, worker SpanContext | None)."""
        peer = self._owner(i)
        slab = y[b0:b1]
        if not self._usable(peer):
            return self._local_part(slab, k, eps, tol), None
        slab_hash = band_hash(slab)
        deadline = time.perf_counter() + self.rpc_timeout
        # re-enter the request's trace on this pool thread so the rpc span
        # parents under the gather and the client stamps its traceparent
        with obs.attach(gather_span), \
                obs.span("cluster.rpc", worker=peer.url, row0=int(b0),
                         rows=int(b1 - b0)) as sp:
            t0 = time.perf_counter()
            try:
                msg = None
                for attempt in (0, 1):
                    try:
                        msg = peer.client.build(name, b0, b1 - b0, slab_hash,
                                                k, eps, tol,
                                                deadline=deadline)
                        break
                    except WorkerRPCError as exc:
                        if attempt == 0 and exc.code in ("no_band",
                                                         "stale_band"):
                            # the heal path doubles as rejoin: a restarted
                            # worker 404s, gets its slab, serves the retry
                            peer.client.assign(name, b0, slab,
                                               deadline=deadline)
                            self.metrics.inc("cluster_band_heals",
                                             code=exc.code)
                            continue
                        raise
                self._mark_up(peer)
                # last_peer_span is safe here: one in-flight RPC per client
                # (band i -> worker i % P; same-worker bands run serially
                # only when bands > pool, still one result read per call)
                peer_ctx = peer.client.last_peer_span
                dt = time.perf_counter() - t0
                self.metrics.observe("cluster_rpc", dt, worker=peer.url,
                                     exemplar=sp.trace_id if sp else None)
                self.metrics.inc("cluster_rpc_total", worker=peer.url,
                                 outcome="ok")
                if msg.cache == "hit":
                    self.metrics.inc("cluster_band_cache_hits")
                if sp:
                    sp.set_attr("cache", msg.cache)
                    sp.set_attr("worker_id", msg.worker_id)
                return coreset_from_msg(msg), peer_ctx
            except WorkerTransportError as exc:
                if sp:
                    sp.set_attr("error", str(exc))
                self._mark_down(peer)
                self.metrics.inc("cluster_rpc_total", worker=peer.url,
                                 outcome="transport_error")
                return self._local_part(slab, k, eps, tol), None
            except WorkerRPCError as exc:
                # an unexpected *answer* (not no_band/stale_band): the
                # worker is alive but cannot serve this band — degrade
                # without declaring it down
                if sp:
                    sp.set_attr("error", str(exc))
                self.metrics.inc("cluster_rpc_total", worker=peer.url,
                                 outcome=f"http_{exc.http}")
                return (self._local_part(slab, k, eps, tol),
                        peer.client.last_peer_span)

    def _local_part(self, slab: np.ndarray, k: int, eps: float,
                    tol: float) -> SignalCoreset:
        """Degraded-mode band build: same bytes, same shared tolerance ->
        bitwise the coreset the worker would have returned.  Clients see a
        normal 200; only the counter records the downgrade."""
        self.metrics.inc("cluster_degraded_builds")
        return signal_coreset(slab, int(k), float(eps),
                              tolerance_override=float(tol))

    # ------------------------------------------------------------- lifecycle
    def stats(self) -> dict:
        out = super().stats()
        m = self.metrics
        out["cluster"] = {
            "role": "coordinator",
            "num_bands": self.num_bands,
            "peers": [{"url": p.url, "up": bool(p.up),
                       "fails": int(p.fails)} for p in self._peers],
            "gathers": m.get("cluster_gathers"),
            "bands_scattered": m.get("cluster_bands_scattered"),
            "deltas_forwarded": m.get("cluster_deltas_forwarded"),
            "degraded_builds": m.get("cluster_degraded_builds"),
            "band_cache_hits": m.get("cluster_band_cache_hits"),
            "worker_rejoins": m.get("cluster_worker_rejoins"),
            # coordinator-cache re-anchors (appends to streamed signals ride
            # the inherited engine fast path); the per-band analogue lives
            # worker-side as worker_band_cache_purged — a delta drops ONLY
            # the owning worker's content-addressed entries
            "cache_reanchored": m.get("cache_reanchored"),
            "reanchor_candidates": m.get("cache_reanchor_candidates"),
        }
        return out

    def close(self) -> None:
        self._rpc.shutdown(wait=False, cancel_futures=True)
        super().close()
