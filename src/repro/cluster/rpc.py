"""Cluster RPC vocabulary + worker client (coordinator -> ShardWorker).

The worker RPC rides the SAME v1 wire machinery as the public API: messages
are ``service.protocol`` dataclasses registered under their own kinds, so
they inherit the JSON / npz+zstd frame codecs, the ``Accept`` negotiation,
the decompression bomb ceiling, and the uniform error envelope for free.
Four messages cover the whole worker surface:

  ``band_assign``   the coordinator hands a worker its row-band slab of a
                    signal (full bytes — registration / re-scatter);
  ``band_delta``    only the changed rows of a slab cross the wire (the
                    ``ingest:delta`` fan-out) — the worker patches its slab
                    and delta-patches its band ``PrefixStats`` in O(rows);
  ``band_build``    "build YOUR band's coreset under this shared
                    tolerance" — the k/eps/tolerance_override triple is
                    coordinator-computed so every band build (remote or
                    thread-pool) caps blocks identically;
  ``band_coreset``  the tiny coreset back: a few KB of block arrays
                    instead of the band's MBs — the merge-reduce gather.

Consistency is content-addressed, not versioned: every band-touching
request carries ``band_hash`` — blake2b of the slab bytes the coordinator
*expects* the worker to hold (post-patch for deltas).  A worker whose slab
hashes differently answers 409 ``stale_band`` and drops the slab; the
coordinator heals by re-assigning the band (it always holds the full
signal) and retrying.  A restarted, empty worker 404s ``no_band`` into the
same heal path — rejoin needs no handshake beyond the next build.

:class:`WorkerClient` is the coordinator-side stub: binary frames by
default, retry with exponential backoff on transport faults only (API
errors are answers, not faults), a per-RPC deadline inherited from the
request's ``deadline_ms``, and W3C ``traceparent`` injection from the
*current span* so one trace spans the scatter/gather (S3: the worker
continues the coordinator's trace id).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
import urllib.error
import urllib.request

import numpy as np

from repro import obs
from repro.core.bicriteria import BicriteriaResult
from repro.core.coreset import SignalCoreset
from repro.service import protocol as P

__all__ = [
    "BandAssignRequest", "BandDeltaRequest", "BandBuildRequest", "BandAck",
    "BandCoresetResponse", "WorkerRPCError", "WorkerTransportError",
    "WorkerClient", "band_hash", "coreset_to_msg", "coreset_from_msg",
]


def band_hash(band: np.ndarray) -> str:
    """Content address of a band slab (the cluster's consistency token) —
    the same blake2b family the engine's version fold uses."""
    return hashlib.blake2b(np.ascontiguousarray(band, np.float64).tobytes(),
                           digest_size=12).hexdigest()


# ------------------------------------------------------------------ messages
@P._message("band_assign")
class BandAssignRequest(P._Wire):
    """Full band slab hand-off: worker becomes the owner of rows
    [row0, row0 + band.shape[0]) of ``signal``."""
    signal: P.SignalRef
    row0: int
    band: np.ndarray                       # (rows, m) the slab bytes
    band_hash: str                         # blake2b of the slab (integrity)
    _NESTED = {"signal": P.SignalRef}
    _COERCE = {"band": P._arr(np.float64, ndim=2)}


@P._message("band_delta")
class BandDeltaRequest(P._Wire):
    """Changed rows only.  ``row0`` is SIGNAL-absolute; the worker maps it
    into its slab and delta-patches slab + PrefixStats.  ``band_hash`` is
    the expected hash of the WHOLE slab after the patch — a mismatch means
    the worker's pre-state was stale (it missed an earlier write), and the
    worker must drop the slab rather than serve silently wrong coresets."""
    signal: P.SignalRef
    row0: int
    band: np.ndarray                       # (rows, m) replacement rows
    band_hash: str                         # post-patch slab hash
    _NESTED = {"signal": P.SignalRef}
    _COERCE = {"band": P._arr(np.float64, ndim=2)}


@P._message("band_build")
class BandBuildRequest(P._Wire):
    """Build the band coreset under the coordinator's SHARED tolerance.

    ``tolerance_override`` is the global ``eps^2 * sigma / k`` cap from
    ``core.sharded.shared_tolerance`` — computed once at the coordinator
    (it owns the full-signal integral images), so remote band builds are
    bitwise the thread-pool path's ``signal_coreset(y[b0:b1], k, eps,
    tolerance_override=tol)``."""
    signal: P.SignalRef
    row0: int
    rows: int
    band_hash: str                         # expected slab hash (consistency)
    k: int
    eps: float
    tolerance_override: float
    deadline_ms: float | None = None
    _NESTED = {"signal": P.SignalRef}


@P._message("band_ack")
class BandAck(P._Wire):
    """Assignment / delta acknowledgement."""
    signal: str
    row0: int
    rows: int
    m: int
    band_hash: str
    worker_id: str


@P._message("band_coreset")
class BandCoresetResponse(P._Wire):
    """A serialized band ``SignalCoreset`` — the only thing the gather
    moves.  Arrays keep their exact dtypes through both codecs (npz stores
    raw IEEE bytes; JSON floats round-trip via shortest-repr), so the
    composed fingerprint is bitwise stable across the wire."""
    n: int
    m: int
    k: int
    eps: float
    rects: np.ndarray                      # (B, 4) int64
    labels: np.ndarray                     # (B, 4) float64
    weights: np.ndarray                    # (B, 4) float64
    moments: np.ndarray                    # (B, 3) float64
    sigma: float
    tolerance: float
    max_slices: int
    build_seconds: float
    certified: bool
    bicriteria: dict                       # BicriteriaResult fields (scalars)
    cache: str = "built"                   # built | hit (worker-side cache)
    worker_id: str = ""
    _COERCE = {"rects": P._arr(np.int64, ndim=2),
               "labels": P._arr(np.float64, ndim=2),
               "weights": P._arr(np.float64, ndim=2),
               "moments": P._arr(np.float64, ndim=2)}


def coreset_to_msg(cs: SignalCoreset, *, cache: str = "built",
                   worker_id: str = "") -> BandCoresetResponse:
    return BandCoresetResponse(
        n=int(cs.n), m=int(cs.m), k=int(cs.k), eps=float(cs.eps),
        rects=np.ascontiguousarray(cs.rects, np.int64),
        labels=np.ascontiguousarray(cs.labels, np.float64),
        weights=np.ascontiguousarray(cs.weights, np.float64),
        moments=np.ascontiguousarray(cs.moments, np.float64),
        sigma=float(cs.sigma), tolerance=float(cs.tolerance),
        max_slices=int(cs.max_slices),
        build_seconds=float(cs.build_seconds), certified=bool(cs.certified),
        bicriteria=dataclasses.asdict(cs.bicriteria),
        cache=cache, worker_id=worker_id)


def coreset_from_msg(msg: BandCoresetResponse) -> SignalCoreset:
    bic = BicriteriaResult(**{
        f.name: msg.bicriteria[f.name]
        for f in dataclasses.fields(BicriteriaResult)
        if f.name in msg.bicriteria})
    return SignalCoreset(
        n=int(msg.n), m=int(msg.m), k=int(msg.k), eps=float(msg.eps),
        rects=np.ascontiguousarray(msg.rects, np.int64),
        labels=np.ascontiguousarray(msg.labels, np.float64),
        weights=np.ascontiguousarray(msg.weights, np.float64),
        moments=np.ascontiguousarray(msg.moments, np.float64),
        sigma=float(msg.sigma), tolerance=float(msg.tolerance),
        max_slices=int(msg.max_slices), bicriteria=bic,
        build_seconds=float(msg.build_seconds), certified=bool(msg.certified))


# -------------------------------------------------------------------- client
class WorkerRPCError(Exception):
    """Structured error from a worker's v1 envelope (an *answer* — never
    retried).  ``code`` drives the coordinator's healing: ``no_band`` /
    ``stale_band`` mean re-assign and retry the build."""

    def __init__(self, http: int, code: str, message: str,
                 trace_id: str | None = None):
        tail = f" [trace {trace_id}]" if trace_id else ""
        super().__init__(f"[{http} {code}] {message}{tail}")
        self.http = http
        self.code = code
        self.message = message
        self.trace_id = trace_id


class WorkerTransportError(Exception):
    """Worker unreachable after exhausting retries — the health tracker's
    down signal."""


class WorkerClient:
    """Stub for one ShardWorker.  Thread-safe (no mutable request state
    beyond the codec downgrade flag, which only ever goes binary->zlib)."""

    def __init__(self, base_url: str, *, encoding: str = "binary",
                 timeout: float = 30.0, retries: int = 2,
                 backoff: float = 0.05):
        self.base_url = base_url.rstrip("/")
        self.encoding = encoding
        self.timeout = float(timeout)
        self.retries = int(retries)
        self.backoff = float(backoff)
        # last worker root-span context seen on a response: the gather span
        # links it so fan-in shows up in /v1/trace/{id}
        self.last_peer_span: obs.SpanContext | None = None

    # ---------------------------------------------------------- raw request
    def _headers(self, content_type: str) -> dict:
        if self.encoding == "binary":
            codec = "zstd" if P.zstandard is not None else "zlib"
            accept = f"{P.CONTENT_TYPE_BINARY};codec={codec}"
        else:
            accept = P.CONTENT_TYPE_JSON
        headers = {"Accept": accept, "Content-Type": content_type}
        # propagate the CURRENT span, not a fresh trace: the worker hop is
        # part of the request's trace (S3 — one trace id across the RPC)
        sp = obs.current_span()
        if sp:
            headers["traceparent"] = obs.format_traceparent(sp.trace_id,
                                                            sp.span_id)
        return headers

    def _note_peer(self, headers) -> None:
        ctx = obs.parse_traceparent(
            headers.get("traceparent") if headers is not None else None)
        self.last_peer_span = (obs.SpanContext(*ctx) if ctx is not None
                               else None)

    def call(self, path: str, msg: P._Wire, expect: type, *,
             deadline: float | None = None):
        """POST ``msg``, return the decoded ``expect`` response.

        ``deadline`` is an absolute ``time.perf_counter()`` instant (the
        engine's representation): each attempt's socket timeout is clipped
        to the time remaining, and an expired deadline fails fast with
        :class:`WorkerTransportError` instead of opening a doomed socket.
        """
        attempt = 0
        while True:
            budget = self.timeout
            if deadline is not None:
                budget = min(budget, deadline - time.perf_counter())
                if budget <= 0:
                    raise WorkerTransportError(
                        f"deadline expired before {path}")
            ctype, body = msg.to_wire(self.encoding)
            req = urllib.request.Request(self.base_url + path, data=body,
                                         headers=self._headers(ctype),
                                         method="POST")
            try:
                with urllib.request.urlopen(req, timeout=budget) as resp:
                    self._note_peer(resp.headers)
                    raw = resp.read()
                    return P.decode(resp.headers.get("Content-Type", ""),
                                    raw, expect=expect)
            except urllib.error.HTTPError as exc:
                raw = exc.read()
                self._note_peer(exc.headers)
                tid = exc.headers.get("X-Coreset-Trace-Id") \
                    if exc.headers is not None else None
                try:
                    env = P.decode(exc.headers.get("Content-Type", ""),
                                   raw, expect=P.ErrorResponse)
                    raise WorkerRPCError(exc.code, env.error.code,
                                         env.error.message, tid) from None
                except P.ProtocolError:
                    raise WorkerRPCError(
                        exc.code, "unknown",
                        raw[:256].decode("utf-8", "replace"), tid) from None
            except (urllib.error.URLError, TimeoutError, ConnectionError,
                    OSError) as exc:
                last = WorkerTransportError(f"{type(exc).__name__}: {exc}")
            if attempt >= self.retries:
                raise last
            time.sleep(self.backoff * (2 ** attempt))
            attempt += 1

    # ------------------------------------------------------------ rpc verbs
    def assign(self, name: str, row0: int, band: np.ndarray, *,
               deadline: float | None = None) -> BandAck:
        msg = BandAssignRequest(signal=P.SignalRef(name=name), row0=int(row0),
                                band=np.ascontiguousarray(band, np.float64),
                                band_hash=band_hash(band))
        return self.call("/v1/worker/band:assign", msg, BandAck,
                         deadline=deadline)

    def delta(self, name: str, row0: int, band: np.ndarray,
              slab_hash: str, *, deadline: float | None = None) -> BandAck:
        msg = BandDeltaRequest(signal=P.SignalRef(name=name), row0=int(row0),
                               band=np.ascontiguousarray(band, np.float64),
                               band_hash=slab_hash)
        return self.call("/v1/worker/band:delta", msg, BandAck,
                         deadline=deadline)

    def build(self, name: str, row0: int, rows: int, slab_hash: str,
              k: int, eps: float, tolerance_override: float, *,
              deadline: float | None = None) -> BandCoresetResponse:
        ms = None if deadline is None else \
            max((deadline - time.perf_counter()) * 1e3, 0.0)
        msg = BandBuildRequest(signal=P.SignalRef(name=name), row0=int(row0),
                               rows=int(rows), band_hash=slab_hash,
                               k=int(k), eps=float(eps),
                               tolerance_override=float(tolerance_override),
                               deadline_ms=ms)
        return self.call("/v1/worker/band:build", msg, BandCoresetResponse,
                         deadline=deadline)

    def healthz(self, *, timeout: float | None = None) -> dict:
        import json
        req = urllib.request.Request(self.base_url + "/v1/healthz",
                                     headers=self._headers("") or {})
        with urllib.request.urlopen(
                req, timeout=timeout or self.timeout) as resp:
            self._note_peer(resp.headers)
            return json.loads(resp.read())
