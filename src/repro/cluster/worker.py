"""ShardWorker — owns one row-band slab of every registered signal.

The paper's construction is embarrassingly band-parallel: a band's coreset
is a pure function of (band bytes, k, eps, tolerance_override), and
coresets of disjoint bands compose exactly (streaming.py).  A worker is
therefore tiny state + one hot function:

  * per signal: the band slab (raw rows it owns), its blake2b content
    hash, and the band's three integral images (``PrefixStats``) —
    materialized once at assignment and **delta-patched** through the
    dispatched ``delta_sat`` op on every ``band:delta`` (O(changed rows),
    bitwise identical to a from-scratch SAT on the f64 oracle);
  * a small LRU of built band coresets keyed by (slab hash, k, eps,
    tolerance) — repeat gathers for a cached spec cost one dict hit.

Consistency is content-addressed (see rpc.py): every request names the
slab hash it expects.  A mismatch 409s ``stale_band`` AND drops the slab —
a worker that missed a write must force a re-assign rather than serve a
coreset of stale bytes; an unknown band 404s ``no_band`` into the same
coordinator heal path, which is also the whole rejoin story.

The HTTP server speaks the same wire conventions as ``service.api``:
protocol frames in both codecs, the uniform error envelope, W3C
``traceparent`` continuation (the coordinator's trace id spans the hop)
and ``X-Coreset-Trace-Id`` on every response **including errors** (S3).
In-process servers (tests) take a private ``Tracer`` — two roots of one
trace id must not share a ring buffer — while a worker subprocess uses the
global ``obs.TRACER`` like any other process.
"""
from __future__ import annotations

import collections
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro import obs
from repro.core.coreset import SignalCoreset, signal_coreset
from repro.core.stats import PrefixStats
from repro.service import protocol as P
from repro.service.api import ApiError
from repro.service.metrics import ServiceMetrics

from .rpc import (BandAck, BandAssignRequest, BandBuildRequest,
                  BandCoresetResponse, BandDeltaRequest, band_hash,
                  coreset_to_msg)

__all__ = ["ShardWorker", "make_worker_server"]

_MAX_BODY = 256 << 20


class _BandState:
    """One owned slab: bytes, content hash, delta-patched PrefixStats."""

    __slots__ = ("row0", "band", "hash", "stats", "lock")

    def __init__(self, row0: int, band: np.ndarray):
        self.row0 = int(row0)
        self.band = np.ascontiguousarray(band, np.float64)
        self.hash = band_hash(self.band)
        # the band's own integral images; every build reuses them (the
        # _stats seam of signal_coreset) and every delta patches them
        self.stats = PrefixStats.build(self.band)
        self.lock = threading.RLock()


class ShardWorker:
    MAX_CACHE = 32   # built band coresets are KB-scale; small LRU suffices

    def __init__(self, worker_id: str = "w0",
                 metrics: ServiceMetrics | None = None,
                 tracer: obs.Tracer | None = None):
        self.worker_id = worker_id
        self.metrics = metrics or ServiceMetrics()
        # spans must record into the SAME tracer the HTTP handler roots the
        # request trace in (make_worker_server aligns this) — in-process
        # test workers use a private tracer precisely so their spans never
        # land in the coordinator's ring buffer
        self.tracer = tracer or obs.TRACER
        self._bands: dict[str, _BandState] = {}
        self._lock = threading.Lock()
        # (signal, slab_hash, k, eps, tolerance) -> SignalCoreset
        self._cache: "collections.OrderedDict[tuple, SignalCoreset]" = \
            collections.OrderedDict()
        self._cache_lock = threading.Lock()

    # ----------------------------------------------------------------- state
    def _band(self, name: str) -> _BandState:
        with self._lock:
            st = self._bands.get(name)
        if st is None:
            raise ApiError(404, "no_band",
                           f"worker {self.worker_id} holds no band of "
                           f"signal {name!r}")
        return st

    def _drop(self, name: str) -> None:
        with self._lock:
            self._bands.pop(name, None)

    def _purge_stale(self, name: str, keep_hash: str) -> int:
        """Per-band cache invalidation: drop this signal's LRU entries
        built against any slab hash other than ``keep_hash``.  The cache
        key is content-addressed, so a delta to THIS worker's slab only
        ever strands this worker's entries — the coordinator's other band
        workers keep serving their (unchanged) band coresets from cache,
        the cluster analogue of the engine's row-span re-anchor rule."""
        with self._cache_lock:
            dead = [key for key in self._cache
                    if key[0] == name and key[1] != keep_hash]
            for key in dead:
                del self._cache[key]
        if dead:
            self.metrics.inc("worker_band_cache_purged", len(dead))
        return len(dead)

    def assign(self, msg: BandAssignRequest) -> BandAck:
        band = np.ascontiguousarray(msg.band, np.float64)
        if band.ndim != 2 or band.size == 0:
            raise ApiError(400, "bad_request",
                           "band must be a non-empty 2-D array")
        st = _BandState(msg.row0, band)
        if msg.band_hash and st.hash != msg.band_hash:
            raise ApiError(400, "bad_request",
                           f"assigned slab hashes {st.hash}, coordinator "
                           f"declared {msg.band_hash} (corrupt frame?)")
        with self._lock:
            self._bands[msg.signal.name] = st
        self._purge_stale(msg.signal.name, st.hash)
        self.metrics.inc("worker_bands_assigned")
        self.metrics.set_gauge("worker_bands_held", len(self._bands))
        return self._ack(msg.signal.name, st)

    def delta(self, msg: BandDeltaRequest) -> BandAck:
        st = self._band(msg.signal.name)
        rows = msg.band.shape[0]
        with st.lock:
            r0 = int(msg.row0) - st.row0      # signal-absolute -> slab-local
            if not (0 <= r0 and r0 + rows <= st.band.shape[0]):
                raise ApiError(
                    409, "stale_band",
                    f"delta rows [{msg.row0}, {msg.row0 + rows}) fall "
                    f"outside this worker's slab "
                    f"[{st.row0}, {st.row0 + st.band.shape[0]})")
            if msg.band.shape[1] != st.band.shape[1]:
                raise ApiError(400, "bad_request",
                               f"delta has {msg.band.shape[1]} columns, "
                               f"slab has {st.band.shape[1]}")
            # patch a FRESH slab (a concurrent build may still be reading
            # the old array outside the lock), then the integral images in
            # O(suffix) through the dispatched delta_sat op
            slab = np.array(st.band, np.float64, copy=True)
            slab[r0:r0 + rows] = msg.band
            new_hash = band_hash(slab)
            if new_hash != msg.band_hash:
                # pre-state was stale: this worker missed an earlier write.
                # Serving from it would be silently wrong — drop the slab
                # and force the coordinator's re-assign heal path.
                self._drop(msg.signal.name)
                self.metrics.inc("worker_stale_bands_dropped")
                raise ApiError(
                    409, "stale_band",
                    f"post-patch slab hashes {new_hash}, coordinator "
                    f"expects {msg.band_hash} — slab dropped, re-assign")
            st.band = slab
            st.stats = st.stats.patch_rows(r0, slab[r0:], copy=True)
            st.hash = new_hash
        self._purge_stale(msg.signal.name, new_hash)
        self.metrics.inc("worker_deltas_applied")
        return self._ack(msg.signal.name, st)

    def _ack(self, name: str, st: _BandState) -> BandAck:
        return BandAck(signal=name, row0=st.row0,
                       rows=int(st.band.shape[0]),
                       m=int(st.band.shape[1]), band_hash=st.hash,
                       worker_id=self.worker_id)

    # ----------------------------------------------------------------- build
    def build(self, msg: BandBuildRequest) -> BandCoresetResponse:
        st = self._band(msg.signal.name)
        with st.lock:
            if st.hash != msg.band_hash:
                self._drop(msg.signal.name)
                self.metrics.inc("worker_stale_bands_dropped")
                raise ApiError(
                    409, "stale_band",
                    f"slab hashes {st.hash}, coordinator expects "
                    f"{msg.band_hash} — slab dropped, re-assign")
            band, stats, slab_hash = st.band, st.stats, st.hash
        key = (msg.signal.name, slab_hash, int(msg.k), float(msg.eps),
               float(msg.tolerance_override))
        with self._cache_lock:
            cs = self._cache.get(key)
            if cs is not None:
                self._cache.move_to_end(key)
        if cs is not None:
            self.metrics.inc("worker_build_cache_hits")
            return coreset_to_msg(cs, cache="hit", worker_id=self.worker_id)
        # the hot function: bitwise the thread-pool path's per-band build
        # (same bytes, same k/eps, same shared tolerance; the delta-patched
        # stats are bitwise a from-scratch SAT, see core/stats.py)
        with self.tracer.span("worker.band_build", signal=msg.signal.name,
                              k=int(msg.k), rows=int(band.shape[0])), \
                self.metrics.timed("worker_band_build"):
            cs = signal_coreset(band, int(msg.k), float(msg.eps),
                                tolerance_override=float(
                                    msg.tolerance_override),
                                _stats=stats)
        with self._cache_lock:
            self._cache[key] = cs
            while len(self._cache) > self.MAX_CACHE:
                self._cache.popitem(last=False)
        self.metrics.inc("worker_band_builds")
        return coreset_to_msg(cs, cache="built", worker_id=self.worker_id)

    # ------------------------------------------------------------ telemetry
    def status(self) -> dict:
        with self._lock:
            bands = {name: {"row0": st.row0, "rows": int(st.band.shape[0]),
                            "m": int(st.band.shape[1]), "hash": st.hash}
                     for name, st in self._bands.items()}
        return {"status": "ok", "role": "worker",
                "worker_id": self.worker_id, "bands": bands,
                "uptime_s": self.metrics.uptime_s()}


# ----------------------------------------------------------------- transport
_WORKER_POST = {
    "/v1/worker/band:assign": (BandAssignRequest, ShardWorker.assign),
    "/v1/worker/band:delta": (BandDeltaRequest, ShardWorker.delta),
    "/v1/worker/band:build": (BandBuildRequest, ShardWorker.build),
}


class _WorkerHandler(BaseHTTPRequestHandler):
    worker: ShardWorker            # set by make_worker_server
    tracer: obs.Tracer             # global for subprocess, private in-process
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):  # noqa: D102 - metrics carry the signal
        pass

    def _reply(self, code: int, body: bytes, content_type: str,
               span) -> None:
        if code >= 400:
            self.close_connection = True
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        if span:
            # every response — error envelopes included — names the trace
            # it ran under; the coordinator links this context into its
            # gather span, so fan-in is visible from /v1/trace/{id}
            self.send_header("traceparent",
                             obs.format_traceparent(span.trace_id,
                                                    span.span_id))
            self.send_header("X-Coreset-Trace-Id", span.trace_id)
        self.end_headers()
        self.wfile.write(body)

    def _reply_msg(self, code: int, msg: P._Wire, encoding: str, span):
        codec = None
        if encoding == "binary":
            codec = P._Wire.accept_codec(self.headers.get("Accept", ""))
            if codec == "zstd" and P.zstandard is None:
                codec = "zlib"
        ctype, body = msg.to_wire(encoding, binary_codec=codec)
        self._reply(code, body, ctype, span)

    def _error(self, http: int, code: str, message: str, span) -> None:
        env = P.ErrorResponse(error=P.ErrorInfo(code=code, message=message))
        self._reply_msg(http, env, "json", span)

    def do_GET(self):  # noqa: N802
        path = self.path.partition("?")[0].rstrip("/")
        root = self.tracer.start_trace(
            "GET /v1/healthz",
            traceparent=self.headers.get("traceparent"))
        try:
            if path == "/v1/healthz":
                body = json.dumps(self.worker.status()).encode()
                self._reply(200, body, "application/json", root)
            elif path == "/v1/metrics":
                self._reply(200, self.worker.metrics.render().encode(),
                            "text/plain; version=0.0.4", root)
            else:
                self._error(404, "not_found", f"no route GET {path}", root)
        finally:
            if root:
                root.end()

    def do_POST(self):  # noqa: N802
        w = self.worker
        path = self.path.partition("?")[0].rstrip("/")
        route = _WORKER_POST.get(path)
        metric_route = f"POST {path}" if route else "POST <unmatched>"
        t0 = time.perf_counter()
        # continue the coordinator's trace: the scatter/gather is ONE trace
        root = self.tracer.start_trace(
            metric_route, traceparent=self.headers.get("traceparent"))
        status = 500
        try:
            with self.tracer.attach(root):
                if route is None:
                    status = 404
                    self._error(404, "not_found",
                                f"no route POST {path}", root)
                    return
                length = int(self.headers.get("Content-Length", 0))
                if length > _MAX_BODY:
                    raise ApiError(413, "payload_too_large",
                                   f"body of {length} bytes exceeds "
                                   f"{_MAX_BODY}")
                raw = self.rfile.read(length) if length else b""
                msg_cls, method = route
                msg = P.decode(self.headers.get("Content-Type", ""), raw,
                               expect=msg_cls)
                out_enc = ("binary" if P.CONTENT_TYPE_BINARY in
                           self.headers.get("Accept", "") else "json")
                resp = method(w, msg)
                status = 200
                self._reply_msg(200, resp, out_enc, root)
        except ApiError as exc:
            status = exc.http
            self._error(exc.http, exc.code, str(exc), root)
        except P.UnsupportedCodec as exc:
            status = 415
            self._error(415, "unsupported_media", str(exc), root)
        except (P.ProtocolError, ValueError, TypeError) as exc:
            status = 400
            self._error(400, "bad_request",
                        f"{type(exc).__name__}: {exc}", root)
        except Exception as exc:  # pragma: no cover - defensive 500
            status = 500
            self._error(500, "internal", f"{type(exc).__name__}: {exc}",
                        root)
        finally:
            if root:
                root.set_attr("http.status", status)
                root.end()
            w.metrics.inc(f"worker_http_{status}")
            w.metrics.observe(f"http {metric_route}",
                              time.perf_counter() - t0,
                              exemplar=root.trace_id if root else None)


def make_worker_server(worker: ShardWorker, host: str = "127.0.0.1",
                       port: int = 0, *,
                       tracer: obs.Tracer | None = None,
                       ) -> ThreadingHTTPServer:
    """Bind the worker's RPC server; port 0 = ephemeral.

    ``tracer``: pass a private :class:`obs.Tracer` when the worker runs
    IN-PROCESS with its coordinator (tests) — continuing a trace id that is
    active in the same ring buffer would collide with the coordinator's
    root.  Worker subprocesses keep the default global tracer.
    """
    if tracer is not None:
        worker.tracer = tracer    # worker spans join the handler's traces
    handler = type("ShardWorkerHandler", (_WorkerHandler,), {
        "worker": worker, "tracer": tracer or worker.tracer})
    srv = _WorkerServer((host, port), handler)
    return srv


class _WorkerServer(ThreadingHTTPServer):
    daemon_threads = True
    # the coordinator's gather fans a band RPC per signal band at once (and
    # retries fast on failure); socketserver's default backlog of 5 turns
    # accept-loop lag into kernel RSTs, so give the listen queue real depth
    request_queue_size = 128
