"""repro.cluster — the distributed serving plane.

Row-band sharding over processes: :class:`ShardWorker` owns a band of
every signal and serves band coresets over the v1 wire protocol;
:class:`ClusterEngine` is a drop-in ``CoresetEngine`` whose dense builds
scatter to workers and gather only the tiny coresets back, bitwise
fingerprint-equal to the single-host thread-pool path.  See DESIGN.md
"Distributed serving plane".
"""
from .coordinator import ClusterEngine
from .rpc import (BandAck, BandAssignRequest, BandBuildRequest,
                  BandCoresetResponse, BandDeltaRequest, WorkerClient,
                  WorkerRPCError, WorkerTransportError, band_hash,
                  coreset_from_msg, coreset_to_msg)
from .worker import ShardWorker, make_worker_server

__all__ = [
    "ClusterEngine", "ShardWorker", "make_worker_server", "WorkerClient",
    "WorkerRPCError", "WorkerTransportError", "band_hash",
    "coreset_to_msg", "coreset_from_msg",
    "BandAssignRequest", "BandDeltaRequest", "BandBuildRequest", "BandAck",
    "BandCoresetResponse",
]
