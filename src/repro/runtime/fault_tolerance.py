"""Fault tolerance: heartbeats, straggler detection, crash-only supervision.

At 1000+ nodes the failure model is: nodes die (no heartbeat), nodes limp
(straggler: heartbeats arrive but step progress lags the fleet), and
transient step failures.  Policy implemented here:

  * ``HeartbeatMonitor``: workers report (step, t); a worker is FAILED after
    ``deadline_s`` of silence, and a STRAGGLER when its step lags the fleet
    median by ``lag_factor`` x the median step duration.
  * ``supervise``: crash-only training driver — on any step exception the
    loop restores the last committed checkpoint and replays (the data
    pipeline is step-indexed, so replays are bit-identical); after
    ``max_restarts`` it re-raises.
  * Failure injection hooks for tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = ["HeartbeatMonitor", "WorkerState", "supervise"]


@dataclasses.dataclass
class WorkerState:
    step: int = -1
    last_seen: float = 0.0


class HeartbeatMonitor:
    def __init__(self, deadline_s: float = 60.0, lag_factor: float = 3.0):
        self.deadline_s = deadline_s
        self.lag_factor = lag_factor
        self.workers: dict[str, WorkerState] = {}
        self._step_times: list[float] = []
        self._last_step_t: float | None = None

    def report(self, worker: str, step: int, now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        st = self.workers.setdefault(worker, WorkerState())
        if st.step >= 0 and step > st.step and self._last_step_t is not None:
            self._step_times.append(now - self._last_step_t)
            self._step_times = self._step_times[-64:]
        st.step, st.last_seen = step, now
        self._last_step_t = now

    def median_step_s(self) -> float:
        if not self._step_times:
            return 0.0
        s = sorted(self._step_times)
        return s[len(s) // 2]

    def check(self, now: float | None = None) -> dict[str, list[str]]:
        now = time.monotonic() if now is None else now
        failed, stragglers = [], []
        steps = sorted(st.step for st in self.workers.values())
        med_step = steps[len(steps) // 2] if steps else 0
        med_t = self.median_step_s()
        for name, st in self.workers.items():
            if now - st.last_seen > self.deadline_s:
                failed.append(name)
            elif med_t > 0 and (med_step - st.step) * med_t > self.lag_factor * med_t \
                    and med_step - st.step >= self.lag_factor:
                stragglers.append(name)
        return {"failed": sorted(failed), "stragglers": sorted(stragglers)}


def supervise(run_step: Callable[[int, dict], dict], state: dict, *,
              steps: int, ckpt_mgr, save_every: int = 50,
              max_restarts: int = 3, on_restore=None,
              log: Callable[[str], None] = print) -> dict:
    """Crash-only loop: run_step(step, state) -> state; restores the last
    committed checkpoint on failure (state must be checkpoint-round-trip
    clean; the data pipeline must be step-indexed)."""
    start = state.get("step", 0)
    restarts = 0
    step = start
    while step < steps:
        try:
            state = run_step(step, state)
            state["step"] = step + 1
            if (step + 1) % save_every == 0 or step + 1 == steps:
                ckpt_mgr.save(step + 1, state)
            step += 1
        except Exception as e:  # noqa: BLE001 — crash-only: restore & replay
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt_mgr.wait()            # commit any in-flight save first
            last = ckpt_mgr.latest_step()
            log(f"[ft] step {step} failed ({e!r}); restart {restarts}/"
                f"{max_restarts} from checkpoint {last}")
            if last is None:
                raise RuntimeError(
                    "failure before the first committed checkpoint — "
                    "lower save_every or re-submit the job") from e
            state = ckpt_mgr.restore(last, state)
            if on_restore is not None:
                state = on_restore(state)
            step = int(state.get("step", last))
            state["step"] = step
    ckpt_mgr.wait()
    return state
