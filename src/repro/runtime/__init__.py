from .fault_tolerance import HeartbeatMonitor, WorkerState, supervise
from .elastic import plan_mesh, reshard_state

__all__ = ["HeartbeatMonitor", "WorkerState", "supervise", "plan_mesh",
           "reshard_state"]
