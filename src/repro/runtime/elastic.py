"""Elastic re-meshing: shrink/grow the mesh around failed hosts.

``plan_mesh``: given the healthy device count and a model-parallel size that
must be preserved (TP degree is baked into layouts/divisibility), pick the
largest (data, model) grid that fits — data parallelism absorbs the loss.
``reshard``: device_put a checkpointed state tree onto the new mesh's
shardings (restore and reshard are the same code path; see
checkpoint/checkpointer.py).
"""
from __future__ import annotations

import jax

from repro.launch.mesh import compat_make_mesh
from repro.sharding import named, opt_specs, param_specs

__all__ = ["plan_mesh", "reshard_state"]


def plan_mesh(n_healthy: int, model_size: int, axis_names=("data", "model")):
    """Largest (data, model_size) mesh with data * model_size <= n_healthy."""
    if n_healthy < model_size:
        raise RuntimeError(
            f"cannot keep TP={model_size} with only {n_healthy} devices")
    data = n_healthy // model_size
    devices = jax.devices()[: data * model_size]
    return compat_make_mesh((data, model_size), axis_names, devices)


def reshard_state(state: dict, params_shapes, new_mesh):
    """Re-place {params, opt} onto a new mesh after an elastic resize."""
    ps = named(new_mesh, param_specs(params_shapes, new_mesh))
    os_ = named(new_mesh, opt_specs(params_shapes, new_mesh))
    out = dict(state)
    out["params"] = jax.tree.map(jax.device_put, state["params"], ps)
    if "opt" in state:
        out["opt"] = jax.tree.map(jax.device_put, state["opt"], os_)
    return out
