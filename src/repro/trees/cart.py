"""Weighted CART regression trees (histogram algorithm).

The downstream solver the paper feeds its coresets to (sklearn's
DecisionTreeRegressor / LightGBM's LGBMRegressor — neither is installable in
this offline container, so the baselines are implemented here).  Design
follows LightGBM's histogram algorithm:

  * features are quantile-binned once (<= 255 bins, uint8 codes);
  * each node builds per-(feature, bin) histograms of (w, w*y, w*y^2) and
    scans prefix sums for the best variance-reduction split;
  * growth is *best-first* with a leaf budget (``max_leaves = k`` — the
    paper's k-tree notion), like LightGBM's leaf-wise growth.

Sample weights are first-class throughout (coreset points are weighted).
The histogram build is the training hot spot; it dispatches through
``repro.ops.hist_split`` — numpy bincount oracle, xla segment-sum, or the
one-hot-matmul Pallas kernel in ``repro.kernels.histsplit`` (GPU scatter-
atomics have no TPU analogue — see DESIGN.md §4).  ``hist_backend``
selects: "auto" (dispatcher rules / REPRO_OPS_BACKEND), "numpy", "xla",
"pallas", or the legacy alias "jax" (= "pallas", the kernel path).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

__all__ = ["DecisionTreeRegressor", "quantile_bins", "apply_bins"]

# legacy spelling -> registry backend ("jax" predates the ops registry and
# always meant the Pallas kernel's jit wrapper); "auto" defers to selection
_HIST_BACKENDS = {"auto": None, "jax": "pallas",
                  "numpy": "numpy", "xla": "xla", "pallas": "pallas"}


def quantile_bins(X: np.ndarray, max_bins: int = 255) -> list[np.ndarray]:
    """Per-feature bin upper edges from quantiles (deduplicated)."""
    edges = []
    for f in range(X.shape[1]):
        qs = np.quantile(X[:, f], np.linspace(0, 1, max_bins + 1)[1:-1])
        edges.append(np.unique(qs))
    return edges


def apply_bins(X: np.ndarray, edges: list[np.ndarray]) -> np.ndarray:
    """uint8 bin codes; bin b covers (edges[b-1], edges[b]]."""
    out = np.empty(X.shape, np.uint8)
    for f, e in enumerate(edges):
        out[:, f] = np.searchsorted(e, X[:, f], side="left").astype(np.uint8)
    return out


@dataclasses.dataclass
class _Node:
    feature: int = -1        # -1: leaf
    threshold: float = 0.0   # raw-value threshold (go left if x <= thr)
    bin_thr: int = 0
    left: int = -1
    right: int = -1
    value: float = 0.0


class DecisionTreeRegressor:
    """Best-first weighted CART with a leaf budget (the paper's k)."""

    def __init__(self, max_leaves: int = 31, max_depth: int = 64,
                 min_weight_leaf: float = 1e-9, min_gain: float = 0.0,
                 max_bins: int = 255, hist_backend: str = "auto",
                 feature_indices: np.ndarray | None = None):
        self.max_leaves = int(max_leaves)
        self.max_depth = int(max_depth)
        self.min_weight_leaf = float(min_weight_leaf)
        self.min_gain = float(min_gain)
        self.max_bins = int(max_bins)
        self.hist_backend = hist_backend
        self.feature_indices = feature_indices
        self.nodes: list[_Node] = []
        self.edges: list[np.ndarray] | None = None

    # -------------------------------------------------------------- fitting
    def fit(self, X: np.ndarray, y: np.ndarray, sample_weight: np.ndarray | None = None,
            bins: tuple[list[np.ndarray], np.ndarray] | None = None):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        w = np.ones(len(y)) if sample_weight is None else np.asarray(sample_weight, np.float64)
        if bins is not None:
            self.edges, codes = bins
        else:
            self.edges = quantile_bins(X, self.max_bins)
            codes = apply_bins(X, self.edges)
        if self.feature_indices is not None:
            codes = codes[:, self.feature_indices]
        n_bins = max(self.max_bins + 1, 2)
        wy, wy2 = w * y, w * y * y
        from repro import ops
        try:
            hist_backend = _HIST_BACKENDS[self.hist_backend]
        except KeyError:
            raise ValueError(
                f"unknown hist_backend {self.hist_backend!r}; "
                f"valid: {sorted(_HIST_BACKENDS)}") from None

        def hist_fn(codes, w_, wy_, wy2_, n_bins_):
            return np.asarray(ops.hist_split(codes, w_, wy_, wy2_, n_bins_,
                                             backend=hist_backend))

        self.nodes = [_Node()]
        # heap entries: (-gain, counter, node_id, row_idx, depth, split_info)
        heap: list = []
        counter = 0

        def leaf_stats(idx):
            return w[idx].sum(), wy[idx].sum(), wy2[idx].sum()

        def consider(node_id, idx, depth):
            nonlocal counter
            s0, s1, s2 = leaf_stats(idx)
            self.nodes[node_id].value = s1 / max(s0, 1e-300)
            if depth >= self.max_depth or s0 <= 2 * self.min_weight_leaf or len(idx) < 2:
                return
            H = hist_fn(codes[idx], w[idx], wy[idx], wy2[idx], n_bins)
            c0 = np.cumsum(H[:, :, 0], axis=1)
            c1 = np.cumsum(H[:, :, 1], axis=1)
            l0, l1 = c0[:, :-1], c1[:, :-1]
            r0, r1 = s0 - l0, s1 - l1
            ok = (l0 >= self.min_weight_leaf) & (r0 >= self.min_weight_leaf)
            with np.errstate(divide="ignore", invalid="ignore"):
                gain = (l1 * l1 / np.maximum(l0, 1e-300)
                        + r1 * r1 / np.maximum(r0, 1e-300)
                        - s1 * s1 / max(s0, 1e-300))
            gain = np.where(ok, gain, -np.inf)
            f, b = np.unravel_index(np.argmax(gain), gain.shape)
            if not np.isfinite(gain[f, b]) or gain[f, b] <= self.min_gain:
                return
            heapq.heappush(heap, (-float(gain[f, b]), counter, node_id, idx,
                                  depth, (int(f), int(b))))
            counter += 1

        all_idx = np.arange(len(y))
        consider(0, all_idx, 0)
        n_leaves = 1
        while heap and n_leaves < self.max_leaves:
            _, _, node_id, idx, depth, (f, b) = heapq.heappop(heap)
            go_left = codes[idx, f] <= b
            li, ri = idx[go_left], idx[~go_left]
            if len(li) == 0 or len(ri) == 0:
                continue
            node = self.nodes[node_id]
            node.feature = int(self.feature_indices[f]) if self.feature_indices is not None else f
            fe = self.edges[node.feature]
            node.threshold = float(fe[b]) if b < len(fe) else float("inf")
            node.bin_thr = b
            node.left, node.right = len(self.nodes), len(self.nodes) + 1
            self.nodes += [_Node(), _Node()]
            consider(node.left, li, depth + 1)
            consider(node.right, ri, depth + 1)
            n_leaves += 1
        return self

    # ----------------------------------------------------------- prediction
    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float64)
        out = np.empty(len(X))
        feat = np.array([nd.feature for nd in self.nodes])
        thr = np.array([nd.threshold for nd in self.nodes])
        left = np.array([nd.left for nd in self.nodes])
        right = np.array([nd.right for nd in self.nodes])
        val = np.array([nd.value for nd in self.nodes])
        cur = np.zeros(len(X), np.int64)
        active = feat[cur] >= 0
        while active.any():
            f = feat[cur[active]]
            goleft = X[active, f] <= thr[cur[active]]
            nxt = np.where(goleft, left[cur[active]], right[cur[active]])
            cur[active] = nxt
            active = feat[cur] >= 0
        out = val[cur]
        return out

    @property
    def n_leaves(self) -> int:
        return sum(1 for nd in self.nodes if nd.feature < 0)

    def leaf_rectangles(self, lo: np.ndarray, hi: np.ndarray):
        """Axis-aligned leaf cells over box [lo, hi) — for 2D signal-domain
        trees this yields the k-segmentation consumed by Algorithm 5."""
        rects, vals = [], []

        def rec(node_id, lo, hi):
            nd = self.nodes[node_id]
            if nd.feature < 0:
                rects.append(np.concatenate([lo, hi]))
                vals.append(nd.value)
                return
            mid_lo, mid_hi = lo.copy(), hi.copy()
            mid_hi[nd.feature] = min(hi[nd.feature], nd.threshold)
            rec(nd.left, lo, mid_hi)
            mid_lo[nd.feature] = min(hi[nd.feature], nd.threshold)
            rec(nd.right, mid_lo, hi)

        rec(0, np.asarray(lo, np.float64), np.asarray(hi, np.float64))
        return np.asarray(rects), np.asarray(vals)
