"""Histogram gradient-boosted trees (the LightGBM stand-in of §5).

Squared-loss GBDT: residual fitting with shrinkage, leaf-wise histogram
trees, first-class sample weights.
"""
from __future__ import annotations

import numpy as np

from .cart import DecisionTreeRegressor, apply_bins, quantile_bins

__all__ = ["GradientBoostingRegressor"]


class GradientBoostingRegressor:
    def __init__(self, n_estimators: int = 50, learning_rate: float = 0.1,
                 max_leaves: int = 31, max_depth: int = 64, max_bins: int = 255,
                 hist_backend: str = "auto"):
        self.n_estimators = int(n_estimators)
        self.learning_rate = float(learning_rate)
        self.max_leaves = int(max_leaves)
        self.max_depth = int(max_depth)
        self.max_bins = int(max_bins)
        self.hist_backend = hist_backend
        self.base_: float = 0.0
        self.trees: list[DecisionTreeRegressor] = []

    def fit(self, X: np.ndarray, y: np.ndarray, sample_weight: np.ndarray | None = None):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        w = np.ones(len(y)) if sample_weight is None else np.asarray(sample_weight, np.float64)
        edges = quantile_bins(X, self.max_bins)
        codes = apply_bins(X, edges)
        self.base_ = float(np.average(y, weights=w))
        pred = np.full(len(y), self.base_)
        self.trees = []
        for _ in range(self.n_estimators):
            resid = y - pred
            t = DecisionTreeRegressor(max_leaves=self.max_leaves,
                                      max_depth=self.max_depth,
                                      max_bins=self.max_bins,
                                      hist_backend=self.hist_backend)
            t.fit(X, resid, sample_weight=w, bins=(edges, codes))
            pred = pred + self.learning_rate * t.predict(X)
            self.trees.append(t)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = np.full(len(X), self.base_)
        for t in self.trees:
            out += self.learning_rate * t.predict(X)
        return out
