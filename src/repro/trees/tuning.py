"""AutoML / hyperparameter tuning on compressed data (paper §5, Fig 4).

The experiment protocol: hold out random 5x5 patches of the signal as
"missing values"; train a forest on the observed cells — either on the full
data, on the coreset, or on a uniform sample of equal size — for every
candidate k (max_leaves); pick the k with the lowest held-out SSE.  The
coreset is built ONCE and reused across the whole sweep (that is where the
x10 comes from).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.coreset import SignalCoreset, signal_coreset
from .forest import RandomForestRegressor

__all__ = ["signal_to_points", "uniform_sample", "TuneResult", "tune_k",
           "score_segmentations", "best_segmentation"]


def score_segmentations(cs: SignalCoreset, seg_rects_batch, seg_labels_batch,
                        *, backend: str | None = None) -> np.ndarray:
    """(T,) Algorithm-5 losses of T candidate k-trees against one coreset.

    The tuning-sweep inner loop as ONE dispatched ``fitting_loss_batched``
    evaluation (numpy oracle / jitted xla / batched Pallas kernel by the
    ``repro.ops`` selection rules) instead of T sequential scores.
    """
    from repro import ops
    sr = np.asarray(seg_rects_batch, np.float64)
    sl = np.asarray(seg_labels_batch, np.float64)
    return np.asarray(ops.fitting_loss_batched(cs, sr, sl, backend=backend),
                      np.float64)


def best_segmentation(cs: SignalCoreset, seg_rects_batch, seg_labels_batch,
                      *, backend: str | None = None) -> tuple[int, float]:
    """(argmin index, loss) over T candidates — coreset model selection."""
    losses = score_segmentations(cs, seg_rects_batch, seg_labels_batch,
                                 backend=backend)
    i = int(np.argmin(losses))
    return i, float(losses[i])


def signal_to_points(values: np.ndarray, mask: np.ndarray | None = None):
    """(i, j) -> y regression dataset from a signal; mask selects cells."""
    n, m = values.shape
    ii, jj = np.meshgrid(np.arange(n), np.arange(m), indexing="ij")
    sel = np.ones((n, m), bool) if mask is None else mask
    X = np.stack([ii[sel], jj[sel]], axis=1).astype(np.float64)
    return X, values[sel].astype(np.float64)


def uniform_sample(X: np.ndarray, y: np.ndarray, size: int, rng: np.random.Generator):
    """The RandomSample(D, tau) baseline: uniform rows, reweighted to total mass."""
    size = min(size, len(y))
    idx = rng.choice(len(y), size=size, replace=False)
    w = np.full(size, len(y) / size, np.float64)
    return X[idx], y[idx], w


@dataclasses.dataclass
class TuneResult:
    ks: list[int]
    losses: dict[str, list[float]]        # method -> per-k held-out SSE
    times: dict[str, float]               # method -> total seconds (incl. compression)
    best_k: dict[str, int]
    sizes: dict[str, int]                 # training-set sizes per method


def tune_k(values: np.ndarray, train_mask: np.ndarray, test_mask: np.ndarray,
           ks: list[int], *, eps: float = 0.2, coreset_k: int | None = None,
           target_frac: float | None = None,
           n_estimators: int = 10, methods: tuple[str, ...] = ("full", "coreset", "uniform"),
           rng: np.random.Generator | None = None,
           forest_factory: Callable | None = None,
           hist_backend: str = "auto") -> TuneResult:
    """Sweep max_leaves=k over the given training methods; §5 protocol.

    ``hist_backend`` selects the split-histogram op backend for the default
    forest factory ("auto" = dispatcher rules / REPRO_OPS_BACKEND).
    """
    rng = rng or np.random.default_rng(0)
    forest_factory = forest_factory or (lambda k: RandomForestRegressor(
        n_estimators=n_estimators, max_leaves=k, random_state=0,
        hist_backend=hist_backend))

    X_tr, y_tr = signal_to_points(values, train_mask)
    X_te, y_te = signal_to_points(values, test_mask)

    datasets: dict[str, tuple] = {}
    times: dict[str, float] = {}
    sizes: dict[str, int] = {}

    if "full" in methods:
        datasets["full"] = (X_tr, y_tr, None)
        times["full"] = 0.0
        sizes["full"] = len(y_tr)
    cs: SignalCoreset | None = None
    if "coreset" in methods:
        t0 = time.perf_counter()
        # mask-aware construction: only observed cells carry mass (§5 trains
        # on the available data; held-out patches contribute nothing)
        if target_frac is not None:
            from repro.core.coreset import signal_coreset_to_size
            cs = signal_coreset_to_size(values, coreset_k or 64, target_frac,
                                        mask=train_mask)
        else:
            cs = signal_coreset(values, coreset_k or max(ks), eps,
                                mask=train_mask)
        Xc, yc, wc = cs.as_points()
        times["coreset"] = time.perf_counter() - t0
        datasets["coreset"] = (Xc, yc, wc)
        sizes["coreset"] = len(yc)
    if "uniform" in methods:
        t0 = time.perf_counter()
        tau = sizes.get("coreset", max(64, len(y_tr) // 100))
        Xu, yu, wu = uniform_sample(X_tr, y_tr, tau, rng)
        times["uniform"] = time.perf_counter() - t0
        datasets["uniform"] = (Xu, yu, wu)
        sizes["uniform"] = len(yu)

    losses = {name: [] for name in datasets}
    for name, (X, y, w) in datasets.items():
        t0 = time.perf_counter()
        for k in ks:
            f = forest_factory(k)
            f.fit(X, y, sample_weight=w)
            pred = f.predict(X_te)
            losses[name].append(float(((pred - y_te) ** 2).sum()))
        times[name] += time.perf_counter() - t0

    best_k = {name: ks[int(np.argmin(ls))] for name, ls in losses.items()}
    return TuneResult(ks=list(ks), losses=losses, times=times, best_k=best_k,
                      sizes=sizes)
