"""Random forests and weighted bootstrap (the paper's §5 solver stand-in).

``RandomForestRegressor`` mirrors sklearn's: bootstrap resampling + feature
subsampling, average vote.  Weighted inputs (coreset points) are resampled
by multinomial draws proportional to the weights, which preserves the
weighted empirical distribution in expectation — each tree then trains on
integer multiplicity weights.
"""
from __future__ import annotations

import numpy as np

from .cart import DecisionTreeRegressor, apply_bins, quantile_bins

__all__ = ["RandomForestRegressor"]


class RandomForestRegressor:
    def __init__(self, n_estimators: int = 20, max_leaves: int = 31,
                 max_depth: int = 64, feature_fraction: float = 1.0,
                 bootstrap: bool = True, max_bins: int = 255,
                 random_state: int = 0, hist_backend: str = "auto"):
        self.n_estimators = int(n_estimators)
        self.max_leaves = int(max_leaves)
        self.max_depth = int(max_depth)
        self.feature_fraction = float(feature_fraction)
        self.bootstrap = bool(bootstrap)
        self.max_bins = int(max_bins)
        self.random_state = int(random_state)
        self.hist_backend = hist_backend
        self.trees: list[DecisionTreeRegressor] = []

    def fit(self, X: np.ndarray, y: np.ndarray, sample_weight: np.ndarray | None = None):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        P, F = X.shape
        w = np.ones(P) if sample_weight is None else np.asarray(sample_weight, np.float64)
        rng = np.random.default_rng(self.random_state)
        edges = quantile_bins(X, self.max_bins)
        codes = apply_bins(X, edges)
        self.trees = []
        n_feat = max(1, int(round(self.feature_fraction * F)))
        for _ in range(self.n_estimators):
            if self.bootstrap:
                p = w / w.sum()
                counts = rng.multinomial(P, p)
                tw = counts.astype(np.float64)
            else:
                tw = w
            feats = np.sort(rng.choice(F, size=n_feat, replace=False)) if n_feat < F else None
            t = DecisionTreeRegressor(max_leaves=self.max_leaves,
                                      max_depth=self.max_depth,
                                      max_bins=self.max_bins,
                                      hist_backend=self.hist_backend,
                                      feature_indices=feats)
            keep = tw > 0
            t.fit(X[keep], y[keep], sample_weight=tw[keep],
                  bins=(edges, codes[keep]))
            self.trees.append(t)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.mean([t.predict(X) for t in self.trees], axis=0)
