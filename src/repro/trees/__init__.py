# Downstream tree solvers: the paper applies existing libraries
# (sklearn RandomForestRegressor, LightGBM LGBMRegressor) as black boxes on
# the coreset; offline, those baselines are implemented here with
# first-class sample weights and LightGBM-style leaf-wise histogram growth.
from .cart import DecisionTreeRegressor, apply_bins, quantile_bins
from .forest import RandomForestRegressor
from .boosting import GradientBoostingRegressor
from .tuning import (TuneResult, best_segmentation, score_segmentations,
                     signal_to_points, tune_k, uniform_sample)

__all__ = [
    "DecisionTreeRegressor", "apply_bins", "quantile_bins",
    "RandomForestRegressor", "GradientBoostingRegressor",
    "TuneResult", "best_segmentation", "score_segmentations",
    "signal_to_points", "tune_k", "uniform_sample",
]
