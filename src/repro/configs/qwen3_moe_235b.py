"""qwen3-moe-235b-a22b — [moe] 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936, head_dim=128,
    n_experts=128, moe_top_k=8, d_ff_expert=1536)
