"""musicgen-medium — [audio] decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]  4 codebooks x vocab 2048, summed codebook embeddings
+ per-codebook heads; delay-pattern interleaving stubbed (frontend stub)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048,
    frontend="audio_codebooks", n_codebooks=4)
