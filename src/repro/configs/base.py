"""Architecture configuration schema for the assigned model pool."""
from __future__ import annotations

import dataclasses

__all__ = ["ArchConfig"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 1
    mamba_headdim: int = 64
    ssm_chunk: int = 128
    attn_q_chunk: int = 4096
    attn_k_chunk: int = 2048

    # --- hybrid (zamba2) -------------------------------------------------------
    attn_every: int = 0          # shared attention block every N layers

    # --- modality frontends (stubs per the brief) ------------------------------
    frontend: str = "none"       # none | vision_stub | audio_codebooks
    n_codebooks: int = 0         # musicgen EnCodec codebooks
    n_patches: int = 0           # pixtral precomputed patch embeddings

    # --- numerics / training ----------------------------------------------------
    dtype: str = "bfloat16"
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    remat: bool = True
    z_loss_coef: float = 1e-4

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return max(self.d_inner // self.mamba_headdim, 1)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def effective_vocab(self) -> int:
        return self.vocab

    def param_count(self) -> int:
        """Approximate N for MODEL_FLOPS = 6 N D accounting (dense count)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (self.n_codebooks or 1)
        per_layer = 0
        if self.family in ("ssm", "hybrid"):
            di, s = self.d_inner, self.ssm_state
            per_layer += d * 2 * di + di * self.ssm_conv + di * s * 2 + di * d
            if self.mamba_version == 1:
                dt_rank = max(d // 16, 1)
                per_layer += di * (dt_rank + 2 * s) + dt_rank * di
            else:
                G = 1
                per_layer += d * (2 * G * s + self.ssm_heads)
        if self.family == "hybrid" and self.attn_every:
            pass  # shared attn counted once below
        if self.family not in ("ssm",):
            if self.is_mla:
                qd = self.qk_nope_dim + self.qk_rope_dim
                per_attn = (d * (self.q_lora_rank or d) // (1 if self.q_lora_rank else 1))
                per_attn = (d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qd
                            if self.q_lora_rank else d * self.n_heads * qd)
                per_attn += d * (self.kv_lora_rank + self.qk_rope_dim)
                per_attn += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                per_attn += self.n_heads * self.v_head_dim * d
            else:
                per_attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
                    + self.n_heads * self.hd * d
            if self.family == "hybrid":
                shared_attn = per_attn  # one shared block
            else:
                per_layer += per_attn
        if self.is_moe:
            per_layer += (self.n_experts + self.n_shared_experts) * 3 * d * self.d_ff_expert
            per_layer += d * self.n_experts
        elif self.d_ff > 0:
            per_layer += 3 * d * self.d_ff
        total = emb + L * per_layer + d * V * (self.n_codebooks or 1)
        if self.family == "hybrid" and self.attn_every:
            total += shared_attn
        return int(total)

    def active_param_count(self) -> int:
        """N_active for MoE MODEL_FLOPS accounting."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        routed_all = self.n_layers * self.n_experts * 3 * d * self.d_ff_expert
        routed_active = self.n_layers * self.moe_top_k * 3 * d * self.d_ff_expert
        return int(full - routed_all + routed_active)
