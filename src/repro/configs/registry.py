"""Architecture registry (one module per assigned arch) + input shapes."""
from __future__ import annotations

from .base import ArchConfig
from . import (deepseek_v2_236b, falcon_mamba_7b, granite_20b, musicgen_medium,
               phi3_medium_14b, pixtral_12b, qwen2_0p5b, qwen3_moe_235b,
               yi_9b, zamba2_1p2b)

__all__ = ["ARCHS", "get_arch", "SHAPES", "get_shape", "runnable_cells"]


ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (pixtral_12b, zamba2_1p2b, qwen2_0p5b, yi_9b, phi3_medium_14b,
              granite_20b, deepseek_v2_236b, qwen3_moe_235b, falcon_mamba_7b,
              musicgen_medium)
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


# -------------------------------------------------------------------- shapes
SHAPES: dict[str, dict] = {
    # kind: train -> train_step; prefill -> serve prefill; decode -> serve_step
    "train_4k":    {"kind": "train",   "seq_len": 4096,    "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768,   "global_batch": 32},
    "decode_32k":  {"kind": "decode",  "seq_len": 32768,   "global_batch": 128},
    "long_500k":   {"kind": "decode",  "seq_len": 524288,  "global_batch": 1},
}

# long_500k needs sub-quadratic sequence mixing: run only for SSM/hybrid
# (full-attention archs are skipped per the brief; see DESIGN.md §5).
_LONG_OK = ("ssm", "hybrid")


def get_shape(name: str) -> dict:
    return dict(SHAPES[name], name=name)


def runnable_cells() -> list[tuple[str, str, bool]]:
    """All 40 (arch, shape) cells with a runnable flag (long_500k skips)."""
    cells = []
    for a, cfg in ARCHS.items():
        for s in SHAPES:
            ok = (s != "long_500k") or (cfg.family in _LONG_OK)
            cells.append((a, s, ok))
    return cells
