"""Input specs: ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation — the dry-run lowers against these.  Frontends are
stubs per the brief: pixtral gets precomputed patch embeddings, musicgen a
(B, L, n_codebooks) token grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ArchConfig
from .registry import get_shape

__all__ = ["input_specs", "reduced_config"]


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Pytree of ShapeDtypeStructs for the cell's entry point.

    train:   {"tokens", "targets"} full-sequence batches
    prefill: {"tokens"} full-sequence batch
    decode:  {"tokens"} single-token batch (cache is built separately)
    """
    sh = get_shape(shape_name)
    B, L = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32
    if sh["kind"] == "decode":
        tok_len = 1
    else:
        tok_len = L

    if cfg.frontend == "audio_codebooks":
        toks = jax.ShapeDtypeStruct((B, tok_len, cfg.n_codebooks), i32)
    elif cfg.frontend == "vision_stub" and sh["kind"] != "decode":
        # patch embeddings replace the first n_patches positions
        text_len = max(tok_len - cfg.n_patches, 1)
        return {
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, text_len), i32),
            **({"targets": jax.ShapeDtypeStruct((B, cfg.n_patches + text_len), i32)}
               if sh["kind"] == "train" else {}),
        }
    else:
        toks = jax.ShapeDtypeStruct((B, tok_len), i32)

    specs = {"tokens": toks}
    if sh["kind"] == "train":
        specs["targets"] = jax.ShapeDtypeStruct(toks.shape, i32)
    return specs


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """CPU-smoke-test-sized variant of the same family: tiny widths/layers,
    few experts, small vocab — same code paths."""
    import dataclasses
    small = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(max(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 1), 4),
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        head_dim=32 if cfg.head_dim else 0,
    )
    if cfg.is_moe:
        small.update(n_experts=4, moe_top_k=2, d_ff_expert=64,
                     n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.is_mla:
        # v_head_dim deliberately != qk_nope+qk_rope (catches mixed-head-dim
        # attention bugs, as in the full DeepSeek config: 128 vs 192)
        small.update(kv_lora_rank=32, q_lora_rank=48 if cfg.q_lora_rank else 0,
                     qk_rope_dim=16, qk_nope_dim=16, v_head_dim=48)
    if cfg.is_ssm:
        small.update(ssm_state=min(cfg.ssm_state, 16), ssm_chunk=16,
                     mamba_headdim=16)
    if cfg.attn_every:
        small.update(attn_every=2)
    if cfg.n_patches:
        small.update(n_patches=8)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
