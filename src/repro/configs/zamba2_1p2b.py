"""zamba2-1.2b — [hybrid] Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]  38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000
ssm_state=64; one *shared* GQA block applied every 6 layers."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, ssm_state=64,
    mamba_version=2, ssm_expand=2, mamba_headdim=64, attn_every=6)
