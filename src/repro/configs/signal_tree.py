"""signal-tree — the paper's own model family as a selectable config.

Not an LM: a (k, eps)-coreset + decision-tree/forest pipeline over n x m
signals (the paper's contribution).  `--arch signal-tree` selects it in the
examples; the config pins the §5 experimental setup.
"""
from __future__ import annotations

import dataclasses

__all__ = ["SignalTreeConfig", "CONFIG"]


@dataclasses.dataclass(frozen=True)
class SignalTreeConfig:
    name: str = "signal-tree"
    family: str = "coreset"
    # construction (paper §5: k=2000 fixed, eps controls the trade-off;
    # practical builds use target_frac via signal_coreset_to_size)
    k: int = 64
    eps: float = 0.3
    target_frac: float | None = 0.02
    fidelity: str = "practical"
    # downstream solver (sklearn/LightGBM stand-ins in repro.trees)
    solver: str = "forest"          # tree | forest | gbdt
    n_estimators: int = 20
    max_leaves: int = 256
    # §5 protocol
    test_fraction: float = 0.3
    patch: int = 5

    def build(self, values, mask=None):
        from repro.core import signal_coreset, signal_coreset_to_size
        if self.target_frac is not None:
            return signal_coreset_to_size(values, self.k, self.target_frac,
                                          mask=mask)
        return signal_coreset(values, self.k, self.eps, mask=mask,
                              fidelity=self.fidelity)

    def make_solver(self, max_leaves=None):
        from repro.trees import (DecisionTreeRegressor, GradientBoostingRegressor,
                                 RandomForestRegressor)
        k = max_leaves or self.max_leaves
        if self.solver == "tree":
            return DecisionTreeRegressor(max_leaves=k)
        if self.solver == "gbdt":
            return GradientBoostingRegressor(n_estimators=self.n_estimators,
                                             max_leaves=min(k, 64))
        return RandomForestRegressor(n_estimators=self.n_estimators,
                                     max_leaves=k)


CONFIG = SignalTreeConfig()
