"""deepseek-v2-236b — [moe] MLA (kv_lora=512) + 2 shared / 160 routed top-6.
[arXiv:2405.04434; hf]  Decode caches the compressed 512+64 latent (absorbed
matmuls) — the MLA serving design."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=1536, vocab=102400,
    n_experts=160, n_shared_experts=2, moe_top_k=6, d_ff_expert=1536,
    kv_lora_rank=512, q_lora_rank=1536, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128)
