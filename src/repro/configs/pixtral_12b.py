"""pixtral-12b — [vlm] pixtral-ViT + Mistral-NeMo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]  Vision frontend is a stub:
input_specs() supplies precomputed patch embeddings (see DESIGN.md §5)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, head_dim=128,
    frontend="vision_stub", n_patches=256)
