from .base import ArchConfig
from .registry import ARCHS, SHAPES, get_arch, get_shape, runnable_cells
from .shapes import input_specs, reduced_config

__all__ = ["ArchConfig", "ARCHS", "SHAPES", "get_arch", "get_shape",
           "runnable_cells", "input_specs", "reduced_config"]
