"""repro.ops — one canonical op surface, three backends per op.

The serving/tuning hot paths reduce to six primitives:

  ``sat_moments(y)``                     (3, n, m) integral images of
                                         (1, y, y²) — PrefixStats' build
  ``delta_sat(carry, tail)``             the integral-image rows that change
                                         when a row band is replaced or
                                         appended — the O(band) ingest patch
  ``fitting_loss(cs, rects, labels)``    Algorithm-5 loss of one tree
  ``fitting_loss_batched(cs, R, L)``     (T,) losses, one fused evaluation
  ``hist_split(codes, w, wy, wy2, B)``   CART split histograms
  ``streaming_compress(coresets)``       merge-reduce recompress of many
                                         buckets in one dispatch

Each dispatches through the backend registry (numpy oracle / jitted xla /
Pallas kernel) with capability+size auto-selection and the
``REPRO_OPS_BACKEND`` env override — see ``registry.py`` for the rules.
Core, trees, and the serving engine all route through this module instead
of importing kernel modules directly, so both the read path (losses,
histograms) and the write path (delta ingest, streaming compress) are
backend-swappable and benchmarkable through one surface.
"""
from __future__ import annotations

import numpy as np

from . import autotune  # noqa: F401  (tuning cache + precision promotion)
from . import backends as _backends  # noqa: F401  (registers implementations)
from .registry import (BACKENDS, ENV_VAR, OPS, BackendError,
                       available_backends, backend_override, dispatch,
                       register, resolve, select_backend, snapshot)

__all__ = [
    "OPS", "BACKENDS", "ENV_VAR", "BackendError", "autotune",
    "available_backends", "backend_override", "dispatch", "register",
    "resolve", "select_backend", "selected_backend", "snapshot",
    "sat_moments", "delta_sat", "fitting_loss", "fitting_loss_batched",
    "hist_split", "streaming_compress",
    "fitting_loss_size", "fitting_loss_batched_size",
]


def sat_moments(y, *, backend: str | None = None, **kw) -> np.ndarray:
    """(3, n, m) integral images of (1, y, y^2) for a 2-D signal."""
    y = np.asarray(y)
    if y.ndim != 2:
        raise ValueError(f"signal must be 2D, got shape {y.shape}")
    return dispatch("sat_moments", y, backend=backend, size=3 * y.size, **kw)


def delta_sat(carry, tail, *, backend: str | None = None, **kw) -> np.ndarray:
    """(3, b, m) patched integral-image rows for a replaced/appended band.

    ``carry`` (3, m) is the integral-image row just above the first changed
    row (zeros when patching from row 0); ``tail`` (b, m) holds the raw
    signal rows from the first changed row to the (new) end of the signal.
    The numpy oracle continues the canonical ``sat_moments`` recurrence with
    the exact same sequential float64 additions, so chained delta patches
    are bitwise equal to a from-scratch rebuild; like ``sat_moments`` it
    never size-promotes off the f64 oracle (the rows feed S2 - S1^2/S0).
    """
    carry = np.asarray(carry)
    tail = np.asarray(tail)
    if tail.ndim != 2 or tail.shape[0] < 1:
        raise ValueError(f"tail must be a non-empty 2D band, got {tail.shape}")
    if carry.shape != (3, tail.shape[1]):
        raise ValueError(f"carry must have shape (3, {tail.shape[1]}), "
                         f"got {carry.shape}")
    return dispatch("delta_sat", carry, tail, backend=backend,
                    size=3 * tail.size, **kw)


def streaming_compress(coresets, k: int | None = None,
                       eps: float | None = None, *,
                       backend: str | None = None, **kw) -> list:
    """Merge-reduce "reduce": recompress a list of composed coresets.

    One dispatch recompresses every bucket in ``coresets`` (the dirty
    buckets of a merge-reduce level); the accelerator backends integrate all
    per-bucket moment rasters in a single batched call.  ``k``/``eps``
    default to each coreset's own parameters.  Precision-critical like
    ``sat_moments``: the rebuilt prefix stats feed the variance identity, so
    the f64 numpy oracle is never size-promoted away.
    """
    coresets = list(coresets)
    if not coresets:
        return []
    size = 3 * sum(int(cs.n) * int(cs.m) for cs in coresets)
    return dispatch("streaming_compress", coresets, k, eps, backend=backend,
                    size=size, **kw)


def fitting_loss_size(cs, seg_rects) -> int:
    """Selection 'size' of a fitting_loss problem (blocks x leaves) — the
    one definition shared by the wrapper below and callers that need to
    know the backend a dispatch will use (``selected_backend``)."""
    k = np.asarray(seg_rects).reshape(-1, 4).shape[0]
    return cs.num_blocks * max(k, 1)


def fitting_loss_batched_size(cs, seg_rects) -> int:
    """Selection 'size' of a batched problem (trees x blocks x leaves)."""
    sr = np.asarray(seg_rects)
    return cs.num_blocks * sr.shape[0] * max(sr.shape[1], 1)


def fitting_loss(cs, seg_rects, seg_labels, *,
                 backend: str | None = None, **kw) -> float:
    """Scalar Algorithm-5 loss of one k-segmentation against ``cs``."""
    sr = np.asarray(seg_rects).reshape(-1, 4)
    sl = np.asarray(seg_labels, np.float64).ravel()
    if sr.shape[0] != sl.shape[0]:
        raise ValueError("rects/labels length mismatch")
    return dispatch("fitting_loss", cs, sr, sl, backend=backend,
                    size=fitting_loss_size(cs, sr), **kw)


def fitting_loss_batched(cs, seg_rects, seg_labels, *,
                         backend: str | None = None, **kw) -> np.ndarray:
    """(T,) Algorithm-5 losses: seg_rects (T, K, 4), seg_labels (T, K)."""
    sr = np.asarray(seg_rects)
    sl = np.asarray(seg_labels, np.float64)
    if sr.ndim != 3 or sr.shape[-1] != 4:
        raise ValueError("batch rects must have shape (T, K, 4)")
    if sl.shape != sr.shape[:2]:
        raise ValueError("batch labels must have shape (T, K)")
    return dispatch("fitting_loss_batched", cs, sr, sl, backend=backend,
                    size=fitting_loss_batched_size(cs, sr), **kw)


def hist_split(codes, w, wy, wy2, n_bins: int, *,
               backend: str | None = None, **kw) -> np.ndarray:
    """(F, n_bins, 3) per-(feature, bin) sums of (w, wy, wy2);
    codes (P, F) integer bin ids."""
    codes = np.asarray(codes)
    if codes.ndim != 2:
        raise ValueError(f"codes must be (P, F), got shape {codes.shape}")
    return dispatch("hist_split", codes, w, wy, wy2, int(n_bins),
                    backend=backend, size=codes.size, **kw)


def selected_backend(op: str, size: int | None = None,
                     backend: str | None = None) -> str:
    """The backend name a dispatch of ``op`` at ``size`` would use — for
    surfacing in responses, ``/v1/stats`` and bench output."""
    return backend or select_backend(op, size)
