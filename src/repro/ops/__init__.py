"""repro.ops — one canonical op surface, three backends per op.

The serving/tuning hot paths reduce to four primitives:

  ``sat_moments(y)``                     (3, n, m) integral images of
                                         (1, y, y²) — PrefixStats' build
  ``fitting_loss(cs, rects, labels)``    Algorithm-5 loss of one tree
  ``fitting_loss_batched(cs, R, L)``     (T,) losses, one fused evaluation
  ``hist_split(codes, w, wy, wy2, B)``   CART split histograms

Each dispatches through the backend registry (numpy oracle / jitted xla /
Pallas kernel) with capability+size auto-selection and the
``REPRO_OPS_BACKEND`` env override — see ``registry.py`` for the rules.
Core, trees, and the serving engine all route through this module instead
of importing kernel modules directly, so a future op (delta ingest,
streaming compress) plugs in here once and is immediately servable.
"""
from __future__ import annotations

import numpy as np

from . import backends as _backends  # noqa: F401  (registers implementations)
from .registry import (BACKENDS, ENV_VAR, OPS, BackendError,
                       available_backends, backend_override, dispatch,
                       register, resolve, select_backend, snapshot)

__all__ = [
    "OPS", "BACKENDS", "ENV_VAR", "BackendError",
    "available_backends", "backend_override", "dispatch", "register",
    "resolve", "select_backend", "selected_backend", "snapshot",
    "sat_moments", "fitting_loss", "fitting_loss_batched", "hist_split",
    "fitting_loss_size", "fitting_loss_batched_size",
]


def sat_moments(y, *, backend: str | None = None, **kw) -> np.ndarray:
    """(3, n, m) integral images of (1, y, y^2) for a 2-D signal."""
    y = np.asarray(y)
    if y.ndim != 2:
        raise ValueError(f"signal must be 2D, got shape {y.shape}")
    return dispatch("sat_moments", y, backend=backend, size=3 * y.size, **kw)


def fitting_loss_size(cs, seg_rects) -> int:
    """Selection 'size' of a fitting_loss problem (blocks x leaves) — the
    one definition shared by the wrapper below and callers that need to
    know the backend a dispatch will use (``selected_backend``)."""
    k = np.asarray(seg_rects).reshape(-1, 4).shape[0]
    return cs.num_blocks * max(k, 1)


def fitting_loss_batched_size(cs, seg_rects) -> int:
    """Selection 'size' of a batched problem (trees x blocks x leaves)."""
    sr = np.asarray(seg_rects)
    return cs.num_blocks * sr.shape[0] * max(sr.shape[1], 1)


def fitting_loss(cs, seg_rects, seg_labels, *,
                 backend: str | None = None, **kw) -> float:
    """Scalar Algorithm-5 loss of one k-segmentation against ``cs``."""
    sr = np.asarray(seg_rects).reshape(-1, 4)
    sl = np.asarray(seg_labels, np.float64).ravel()
    if sr.shape[0] != sl.shape[0]:
        raise ValueError("rects/labels length mismatch")
    return dispatch("fitting_loss", cs, sr, sl, backend=backend,
                    size=fitting_loss_size(cs, sr), **kw)


def fitting_loss_batched(cs, seg_rects, seg_labels, *,
                         backend: str | None = None, **kw) -> np.ndarray:
    """(T,) Algorithm-5 losses: seg_rects (T, K, 4), seg_labels (T, K)."""
    sr = np.asarray(seg_rects)
    sl = np.asarray(seg_labels, np.float64)
    if sr.ndim != 3 or sr.shape[-1] != 4:
        raise ValueError("batch rects must have shape (T, K, 4)")
    if sl.shape != sr.shape[:2]:
        raise ValueError("batch labels must have shape (T, K)")
    return dispatch("fitting_loss_batched", cs, sr, sl, backend=backend,
                    size=fitting_loss_batched_size(cs, sr), **kw)


def hist_split(codes, w, wy, wy2, n_bins: int, *,
               backend: str | None = None, **kw) -> np.ndarray:
    """(F, n_bins, 3) per-(feature, bin) sums of (w, wy, wy2);
    codes (P, F) integer bin ids."""
    codes = np.asarray(codes)
    if codes.ndim != 2:
        raise ValueError(f"codes must be (P, F), got shape {codes.shape}")
    return dispatch("hist_split", codes, w, wy, wy2, int(n_bins),
                    backend=backend, size=codes.size, **kw)


def selected_backend(op: str, size: int | None = None,
                     backend: str | None = None) -> str:
    """The backend name a dispatch of ``op`` at ``size`` would use — for
    surfacing in responses, ``/v1/stats`` and bench output."""
    return backend or select_backend(op, size)
