"""Registered backend implementations for the canonical op surface.

Each factory is resolved lazily on first dispatch (see registry.py), so the
numpy path never imports jax and importing ``repro.ops`` never imports the
kernel packages.  Conventions:

  * inputs are host values (SignalCoreset / numpy arrays / plain ints);
  * outputs are numpy arrays (or Python floats) — the dispatch surface is
    host-level; device-resident pipelines (the mesh-sharded scorer in
    ``core.sharded``) use the kernel/ref modules directly;
  * the ``pallas`` implementations accept ``interpret=`` so tests can pin
    interpret mode explicitly;
  * every implementation accepts ``config=`` — a tuning configuration dict
    (tile sizes, lowering variant, compensated-summation flag).  ``None``
    means "consult the autotune cache for this problem size" (a cold cache
    yields ``{}`` and the built-in defaults below); the tuner passes
    explicit configs while measuring.  The numpy oracle ignores it.

The dense jnp math exists exactly once, in ``repro.kernels.*.ref`` — the
xla backends jit those oracles; nothing here re-derives a formula.  The
``compensated`` configs run the two-float (TwoSum) twins of the same refs
and recombine the (hi, lo) pairs in f64 on the host: accelerator-resident
f32 arithmetic whose result matches the f64 oracle to ~1e-10 scaled
relative error — the path that lets the autotuner lift a precision pin
(see ``autotune.py``).
"""
from __future__ import annotations

import numpy as np

from . import autotune
from .registry import register

# ------------------------------------------------------------- sat_moments
# (3, n, m) inclusive integral images of (1, y, y^2) — PrefixStats' core.


@register("sat_moments", "numpy")
def _sat_moments_numpy():
    def sat_moments(y, config=None):
        # canonical order: columns-within-row first, then down the rows, so
        # row i of the result is exactly row i-1 + rowprefix(stk[i]) — the
        # recurrence the delta_sat patch op continues bitwise from a stored
        # carry row (np.cumsum is a sequential per-element reduction)
        y = np.asarray(y, np.float64)
        stk = np.stack([np.ones_like(y), y, y * y], axis=0)
        return np.cumsum(np.cumsum(stk, axis=2), axis=1)
    return sat_moments


@register("sat_moments", "xla")
def _sat_moments_xla():
    import jax
    import jax.numpy as jnp
    from repro.kernels.sat2d.ref import (sat_moments_comp_ref,
                                         sat_moments_ref, split_hi_lo)
    f = jax.jit(sat_moments_ref)
    f_comp = jax.jit(sat_moments_comp_ref)

    def sat_moments(y, config=None):
        cfg = config if config is not None else autotune.plan(
            "sat_moments", "xla", 3 * np.asarray(y).size)
        if cfg.get("compensated"):
            hi, lo = f_comp(*split_hi_lo(y))
            return np.asarray(hi, np.float64) + np.asarray(lo, np.float64)
        return np.asarray(f(jnp.asarray(y, jnp.float32)))
    return sat_moments


@register("sat_moments", "pallas")
def _sat_moments_pallas():
    import jax.numpy as jnp
    from repro.kernels.sat2d.ops import sat_moments as kernel_sat_moments

    def sat_moments(y, interpret=None, config=None):
        cfg = config if config is not None else autotune.plan(
            "sat_moments", "pallas", 3 * np.asarray(y).size)
        # donate: the device copy made here is never reused on the host
        return np.asarray(kernel_sat_moments(
            jnp.asarray(y, jnp.float32), tile=int(cfg.get("tile", 256)),
            interpret=interpret, donate=True))
    return sat_moments


# --------------------------------------------------------------- delta_sat
# patched integral-image rows for a replaced/appended row band: carry (3, m)
# is the integral row just above the patch, tail (b, m) the raw rows from
# the first changed row to the (new) end.  Output (3, b, m).


@register("delta_sat", "numpy")
def _delta_sat_numpy():
    def delta_sat(carry, tail, config=None):
        t = np.asarray(tail, np.float64)
        stk = np.stack([np.ones_like(t), t, t * t], axis=0)
        inner = np.cumsum(stk, axis=2)
        # prepend the carry row and let the sequential cumsum continue it:
        # row i is computed as row i-1 + inner[i], the *same* float ops a
        # from-scratch sat_moments build performs for these rows, so chained
        # delta patches stay bitwise equal to a full rebuild
        full = np.concatenate(
            [np.asarray(carry, np.float64)[:, None, :], inner], axis=1)
        return np.cumsum(full, axis=1)[:, 1:, :]
    return delta_sat


@register("delta_sat", "xla")
def _delta_sat_xla():
    import jax
    import jax.numpy as jnp
    from repro.kernels.sat2d.ref import (delta_sat_comp_ref, delta_sat_ref,
                                         split_hi_lo)
    f = jax.jit(delta_sat_ref)
    f_comp = jax.jit(delta_sat_comp_ref)

    def delta_sat(carry, tail, config=None):
        cfg = config if config is not None else autotune.plan(
            "delta_sat", "xla", 3 * np.asarray(tail).size)
        if cfg.get("compensated"):
            # the stored carry enters as its own (hi, lo) pair, so chained
            # patches keep full two-float precision across calls
            hi, lo = f_comp(*split_hi_lo(carry), *split_hi_lo(tail))
            return np.asarray(hi, np.float64) + np.asarray(lo, np.float64)
        return np.asarray(f(jnp.asarray(carry, jnp.float32),
                            jnp.asarray(tail, jnp.float32)))
    return delta_sat


@register("delta_sat", "pallas")
def _delta_sat_pallas():
    import jax.numpy as jnp
    from repro.kernels.sat2d.ops import delta_sat_moments

    def delta_sat(carry, tail, interpret=None, config=None):
        cfg = config if config is not None else autotune.plan(
            "delta_sat", "pallas", 3 * np.asarray(tail).size)
        return np.asarray(delta_sat_moments(
            jnp.asarray(carry, jnp.float32), jnp.asarray(tail, jnp.float32),
            tile=int(cfg.get("tile", 256)), interpret=interpret, donate=True))
    return delta_sat


# ------------------------------------------------------------ fitting_loss
# scalar Algorithm-5 loss of one segmentation against a SignalCoreset.


@register("fitting_loss", "numpy")
def _fitting_loss_numpy():
    from repro.core.fitting_loss import fitting_loss

    def fl(cs, seg_rects, seg_labels, config=None):
        return float(fitting_loss(cs, seg_rects, seg_labels))
    return fl


@register("fitting_loss", "xla")
def _fitting_loss_xla():
    import jax
    import jax.numpy as jnp
    from repro.kernels.fitting_loss.ref import fitting_loss_ref
    f = jax.jit(fitting_loss_ref)

    def fl(cs, seg_rects, seg_labels, config=None):
        return float(f(
            jnp.asarray(cs.rects, jnp.float32),
            jnp.asarray(cs.labels, jnp.float32),
            jnp.asarray(cs.weights, jnp.float32),
            jnp.asarray(seg_rects, jnp.float32),
            jnp.asarray(seg_labels, jnp.float32)))
    return fl


@register("fitting_loss", "pallas")
def _fitting_loss_pallas():
    from repro.kernels.fitting_loss.ops import coreset_loss

    def fl(cs, seg_rects, seg_labels, interpret=None, config=None):
        cfg = config if config is not None else autotune.plan(
            "fitting_loss", "pallas",
            cs.num_blocks * max(np.asarray(seg_rects).reshape(-1, 4).shape[0],
                                1))
        return float(coreset_loss(cs, seg_rects, seg_labels,
                                  tile_b=int(cfg.get("tile_b", 1024)),
                                  interpret=interpret))
    return fl


# ---------------------------------------------------- fitting_loss_batched
# (T,) losses for T candidate segmentations against one coreset.


@register("fitting_loss_batched", "numpy")
def _fitting_loss_batched_numpy():
    from repro.core.fitting_loss import fitting_loss

    def fb(cs, seg_rects, seg_labels, config=None):
        return np.array([fitting_loss(cs, r, l)
                         for r, l in zip(seg_rects, seg_labels)], np.float64)
    return fb


@register("fitting_loss_batched", "xla")
def _fitting_loss_batched_xla():
    import jax
    import jax.numpy as jnp
    from repro.kernels.fitting_loss.ref import fitting_loss_batched_ref
    f = jax.jit(fitting_loss_batched_ref)

    def fb(cs, seg_rects, seg_labels, config=None):
        return np.asarray(f(
            jnp.asarray(cs.rects, jnp.float32),
            jnp.asarray(cs.labels, jnp.float32),
            jnp.asarray(cs.weights, jnp.float32),
            jnp.asarray(seg_rects, jnp.float32),
            jnp.asarray(seg_labels, jnp.float32)), np.float64)
    return fb


@register("fitting_loss_batched", "pallas")
def _fitting_loss_batched_pallas():
    from repro.kernels.fitting_loss.ops import coreset_loss_batched

    def fb(cs, seg_rects, seg_labels, interpret=None, config=None):
        sr = np.asarray(seg_rects)
        cfg = config if config is not None else autotune.plan(
            "fitting_loss_batched", "pallas",
            cs.num_blocks * sr.shape[0] * max(sr.shape[1], 1))
        return np.asarray(coreset_loss_batched(
            cs, seg_rects, seg_labels,
            tile_b=int(cfg.get("tile_b", 512)),
            tile_t=int(cfg.get("tile_t", 8)),
            interpret=interpret), np.float64)
    return fb


# -------------------------------------------------------------- hist_split
# per-(feature, bin) sums of (w, wy, wy2) — the CART split-search hot spot.


@register("hist_split", "numpy")
def _hist_split_numpy():
    def hist(codes, w, wy, wy2, n_bins, config=None):
        codes = np.asarray(codes)
        out = np.empty((codes.shape[1], n_bins, 3), np.float64)
        for f in range(codes.shape[1]):
            c = codes[:, f]
            out[f, :, 0] = np.bincount(c, weights=w, minlength=n_bins)
            out[f, :, 1] = np.bincount(c, weights=wy, minlength=n_bins)
            out[f, :, 2] = np.bincount(c, weights=wy2, minlength=n_bins)
        return out
    return hist


@register("hist_split", "xla")
def _hist_split_xla():
    import functools

    import jax
    import jax.numpy as jnp
    from repro.kernels.sat2d.ref import split_hi_lo

    # compensated variant: P-axis chunk combined in f64.  Short chunks keep
    # the *within*-chunk f32 accumulation (which the hi/lo split does not
    # compensate — only the input cast error) to ~32 adds per bin, an order
    # of magnitude inside the 1e-6 certificate bound.
    _CHUNK = 8192

    # segment-sum per feature: O(P*F) work and memory, unlike the one-hot
    # einsum oracle in kernels/histsplit/ref.py whose (P, F, n_bins) one-hot
    # would blow up host memory at training sizes
    @functools.partial(jax.jit, static_argnames=("n_bins",))
    def _hist_vmap(codes, vals, n_bins):
        def one(c):
            return jax.ops.segment_sum(vals, c, num_segments=n_bins)
        return jax.vmap(one, in_axes=1)(codes)          # (F, n_bins, S)

    # one flat segment-sum over F*n_bins fused ids instead of a vmap of F
    # scatters — algorithmically the same sums, a different XLA lowering
    @functools.partial(jax.jit, static_argnames=("n_bins",))
    def _hist_flat(codes, vals, n_bins):
        P, F = codes.shape
        ids = (codes + jnp.arange(F, dtype=codes.dtype)[None, :] * n_bins)
        out = jax.ops.segment_sum(
            jnp.broadcast_to(vals[:, None, :], (P, F, vals.shape[1]))
            .reshape(P * F, vals.shape[1]),
            ids.reshape(P * F), num_segments=F * n_bins)
        return out.reshape(F, n_bins, vals.shape[1])

    # compensated: per-chunk f32 segment sums of the (hi, lo) channel pairs,
    # combined across chunks (and hi+lo) in f64 on the host
    @functools.partial(jax.jit, static_argnames=("n_bins",))
    def _hist_chunked(codes, vals, n_bins):
        def one_chunk(c, v):
            def one(cf):
                return jax.ops.segment_sum(v, cf, num_segments=n_bins)
            return jax.vmap(one, in_axes=1)(c)
        return jax.vmap(one_chunk)(codes, vals)         # (C, F, n_bins, 6)

    def hist(codes, w, wy, wy2, n_bins, config=None):
        codes = np.asarray(codes)
        cfg = config if config is not None else autotune.plan(
            "hist_split", "xla", codes.size)
        if cfg.get("compensated"):
            pairs = [split_hi_lo(a) for a in (w, wy, wy2)]
            vals = jnp.stack([p[0] for p in pairs]
                             + [p[1] for p in pairs], axis=1)   # (P, 6)
            P = codes.shape[0]
            pad = (-P) % _CHUNK
            cj = jnp.asarray(codes, jnp.int32)
            if pad:
                cj = jnp.pad(cj, ((0, pad), (0, 0)))    # bin 0, zero weights
                vals = jnp.pad(vals, ((0, pad), (0, 0)))
            C = cj.shape[0] // _CHUNK
            out = np.asarray(_hist_chunked(
                cj.reshape(C, _CHUNK, -1), vals.reshape(C, _CHUNK, 6),
                n_bins), np.float64)
            return out[..., :3].sum(axis=0) + out[..., 3:].sum(axis=0)
        f = _hist_flat if cfg.get("variant") == "flat" else _hist_vmap
        vals = jnp.stack([jnp.asarray(w, jnp.float32),
                          jnp.asarray(wy, jnp.float32),
                          jnp.asarray(wy2, jnp.float32)], axis=1)
        return np.asarray(f(jnp.asarray(codes, jnp.int32), vals, n_bins),
                          np.float64)
    return hist


@register("hist_split", "pallas")
def _hist_split_pallas():
    from repro.kernels.histsplit.ops import histograms

    def hist(codes, w, wy, wy2, n_bins, interpret=None, config=None):
        cfg = config if config is not None else autotune.plan(
            "hist_split", "pallas", np.asarray(codes).size)
        return np.asarray(histograms(
            codes, w, wy, wy2, n_bins,
            tile_p=int(cfg.get("tile_p", 2048)),
            variant=cfg.get("variant", "fused"),
            interpret=interpret), np.float64)
    return hist


# ------------------------------------------------------- streaming_compress
# the merge-reduce "reduce" step as one dispatch: recompress a LIST of
# composed coresets (the dirty buckets of a level) into coresets-of-
# coresets.  The backend-differentiated stage is the integral images of the
# per-bucket moment rasters; rasterization and the partition/Caratheodory
# finish are shared host code in core.streaming.


def _stack_rasters(preps, dtype=np.float32):
    """Pad the per-bucket (3, n, m) moment rasters to one (L, 3, nmax, mmax)
    stack so the accelerator backends integrate every bucket in one call."""
    nmax = max(p.rasters[0].shape[0] for p in preps)
    mmax = max(p.rasters[0].shape[1] for p in preps)
    stk = np.zeros((len(preps), 3, nmax, mmax), dtype)
    for i, p in enumerate(preps):
        n, m = p.rasters[0].shape
        for c in range(3):
            stk[i, c, :n, :m] = p.rasters[c]
    return stk


def _finish_from_sats(coresets, preps, sats, k, eps):
    from repro.core.stats import PrefixStats
    from repro.core.streaming import _recompress_finish
    out = []
    for cs, p, sat in zip(coresets, preps, sats):
        n, m = p.rasters[0].shape
        ps = PrefixStats.from_sat(np.asarray(sat[:, :n, :m], np.float64))
        out.append(_recompress_finish(cs, p, ps, k, eps))
    return out


def _compress_size(coresets) -> int:
    return 3 * sum(int(cs.n) * int(cs.m) for cs in coresets)


@register("streaming_compress", "numpy")
def _streaming_compress_numpy():
    def sc(coresets, k=None, eps=None, config=None):
        from repro.core.stats import PrefixStats
        from repro.core.streaming import _recompress_finish, _recompress_prep
        out = []
        for cs in coresets:
            p = _recompress_prep(cs)
            ps = PrefixStats.build_moments(*p.rasters)
            out.append(_recompress_finish(cs, p, ps, k, eps))
        return out
    return sc


@register("streaming_compress", "xla")
def _streaming_compress_xla():
    import jax
    import jax.numpy as jnp
    from repro.kernels.sat2d.ref import (sat_stack_comp_ref, sat_stack_ref,
                                         split_hi_lo)
    f = jax.jit(sat_stack_ref)
    f_comp = jax.jit(sat_stack_comp_ref)

    def sc(coresets, k=None, eps=None, config=None):
        from repro.core.streaming import _recompress_prep
        cfg = config if config is not None else autotune.plan(
            "streaming_compress", "xla", _compress_size(coresets))
        preps = [_recompress_prep(cs) for cs in coresets]
        if cfg.get("compensated"):
            hi, lo = f_comp(*split_hi_lo(_stack_rasters(preps, np.float64)))
            sats = (np.asarray(hi, np.float64) + np.asarray(lo, np.float64))
        else:
            sats = np.asarray(f(jnp.asarray(_stack_rasters(preps))))
        return _finish_from_sats(coresets, preps, sats, k, eps)
    return sc


@register("streaming_compress", "pallas")
def _streaming_compress_pallas():
    import jax.numpy as jnp
    from repro.kernels.sat2d.ops import sat_stack

    def sc(coresets, k=None, eps=None, interpret=None, config=None):
        from repro.core.streaming import _recompress_prep
        cfg = config if config is not None else autotune.plan(
            "streaming_compress", "pallas", _compress_size(coresets))
        preps = [_recompress_prep(cs) for cs in coresets]
        sats = np.asarray(sat_stack(jnp.asarray(_stack_rasters(preps)),
                                    tile=int(cfg.get("tile", 256)),
                                    interpret=interpret, donate=True))
        return _finish_from_sats(coresets, preps, sats, k, eps)
    return sc
