"""Kernel autotuning + compensated-precision promotion for ``repro.ops``.

The dispatch heuristics in ``registry.py`` are static: capability (TPU ->
pallas) and a per-op size crossover, with the precision-critical ops pinned
to the f64 numpy oracle at every size.  On real hardware neither rule is
sharp — the best Pallas tile shape depends on the device generation and the
problem size, the XLA ``hist_split`` has several algorithmically different
lowerings, and the f64 pin forfeits the accelerator entirely even when a
compensated-summation f32 path would be provably accurate enough.  This
module closes all three gaps:

  * a **search**: per (op, backend) configuration space — Pallas tile
    sizes / grid shapes, XLA variant choices, compensated-summation on/off
    — measured against the numpy oracle on representative problems;
  * a **persisted cache**: ``~/.cache/repro/autotune.json`` (override with
    ``REPRO_AUTOTUNE_CACHE``), versioned by a fingerprint of the kernel
    sources so stale entries never outlive the code they measured; corrupt
    or mismatched caches are ignored, never fatal;
  * a **dispatch consult**: ``registry.select_backend`` asks
    :func:`tuned_backend` before falling back to the static heuristics, and
    each accelerator backend asks :func:`plan` for its tuned configuration
    (tile sizes, variant, compensated flag) at call time.  A cold cache
    reproduces today's behaviour exactly.

Precision promotion: a tuning entry for a precision-pinned op
(``XLA_SIZE_THRESHOLD[op] is None``) may carry a *parity certificate* — the
measured scaled relative error of the compensated-f32 path against the f64
oracle.  Only entries whose certificate passes :data:`PARITY_RTOL` can lift
the pin, and ``REPRO_OPS_PRECISION=f64`` disables promotion outright (the
pin is both the cold-cache default and the escape hatch).

CLI::

    python -m repro.ops.autotune [--ops OP,OP] [--budget quick|full]
                                 [--cache PATH] [--json]
"""
from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import pathlib
import platform
import sys
import threading
import time

import numpy as np

__all__ = [
    "CACHE_ENV_VAR", "DISABLE_ENV_VAR", "PRECISION_ENV_VAR", "PARITY_RTOL",
    "TuneCache", "cache_path", "kernel_fingerprint", "device_kind",
    "precision_mode", "get_cache", "reset_cache", "plan", "tuned_backend",
    "tune_op", "tune_all", "counters_snapshot", "snapshot", "main",
]

CACHE_ENV_VAR = "REPRO_AUTOTUNE_CACHE"
DISABLE_ENV_VAR = "REPRO_AUTOTUNE"          # "0"/"off" disables consultation
PRECISION_ENV_VAR = "REPRO_OPS_PRECISION"   # f64 | compensated | fast
SCHEMA_VERSION = 1
PARITY_RTOL = 1e-6     # compensated-f32 certificate bound vs the f64 oracle

# ----------------------------------------------------------- search spaces
# Each op/backend maps to the list of configurations the tuner measures.
# Config keys are interpreted by the backend implementations (backends.py):
#   compensated  — two-float (TwoSum) summation, f64-combined on the host
#   tile / tile_p / tile_t / tile_b — Pallas block shapes
#   variant      — algorithmically distinct lowering of the same op
SEARCH_SPACE: dict[str, dict[str, list[dict]]] = {
    "sat_moments": {
        "xla": [{"compensated": False}, {"compensated": True}],
        "pallas": [{"tile": t} for t in (128, 256, 512)],
    },
    "delta_sat": {
        "xla": [{"compensated": False}, {"compensated": True}],
        "pallas": [{"tile": t} for t in (128, 256, 512)],
    },
    "hist_split": {
        "xla": [{"variant": "vmap", "compensated": False},
                {"variant": "flat", "compensated": False},
                {"variant": "chunked", "compensated": True}],
        "pallas": [{"variant": "fused", "tile_p": t}
                   for t in (512, 1024, 2048, 4096, 8192)]
                  + [{"variant": "partials", "compensated": True, "tile_p": t}
                     for t in (1024, 2048, 4096, 8192)]
                  + [{"variant": "legacy", "tile_p": 512}],
    },
    "fitting_loss": {
        "xla": [{}],
        "pallas": [{"tile_b": t} for t in (256, 512, 1024)],
    },
    "fitting_loss_batched": {
        "xla": [{}],
        "pallas": [{"tile_t": tt, "tile_b": tb}
                   for tt in (4, 8, 16) for tb in (256, 512)],
    },
    "streaming_compress": {
        "xla": [{"compensated": False}, {"compensated": True}],
        "pallas": [{"tile": t} for t in (128, 256)],
    },
}

# Canonical large-bucket problem shapes: shared by ``tune_all`` and the
# ``autotune`` section of bench_ops so the tuned entries land in exactly the
# buckets the bench (and the regression gate) reads back.
LARGE_SHAPES = {
    "sat_moments": {"n": 384, "m": 384},
    "delta_sat": {"band": 64, "m": 2048},
    "hist_split": {"P": 120_000, "F": 8, "B": 256},
    "fitting_loss_batched": {"n": 320, "m": 240, "k": 8, "T": 64},
}

_COUNTERS = {"cache_hit": 0, "cache_miss": 0, "tune_runs": 0,
             "promoted_f32": 0, "tuned_dispatch": 0, "cache_load_errors": 0}


def _count(name: str, by: int = 1) -> None:
    # deliberately lock-free: these sit on the dispatch hot path, and a
    # rare lost increment in telemetry beats a lock acquire per dispatch
    _COUNTERS[name] = _COUNTERS.get(name, 0) + by


def counters_snapshot() -> dict:
    return dict(_COUNTERS)


def _enabled() -> bool:
    return os.environ.get(DISABLE_ENV_VAR, "").strip().lower() not in (
        "0", "off", "false", "no")


def precision_mode() -> str:
    """``f64`` (never lift a pin), ``compensated`` (lift only with a parity
    certificate — the default), or ``fast`` (plain-f32 promotion allowed,
    the documented TPU trade-off)."""
    mode = os.environ.get(PRECISION_ENV_VAR, "").strip().lower()
    return mode if mode in ("f64", "compensated", "fast") else "compensated"


@functools.cache
def kernel_fingerprint() -> str:
    """Hash of the kernel/backend sources + the search space: a cache entry
    measured against different code is stale and must not be consulted."""
    here = pathlib.Path(__file__).resolve()
    kernels = here.parents[1] / "kernels"
    h = hashlib.sha256()
    for p in sorted((here.parent / "backends.py",
                     *kernels.glob("*/kernel.py"), *kernels.glob("*/ref.py"))):
        try:
            h.update(p.read_bytes())
        except OSError:
            pass
    h.update(repr(sorted(SEARCH_SPACE.items())).encode())
    h.update(str(SCHEMA_VERSION).encode())
    return h.hexdigest()[:12]


@functools.cache
def device_kind() -> str:
    """Coarse accelerator class ("cpu"/"tpu"/"gpu") — cache entries do not
    transfer across device kinds.  Forces XLA client init, like the
    registry's capability rule; cached for the same reason."""
    import jax
    return jax.default_backend()


def host_fingerprint() -> str:
    """Provenance string for bench rows: which machine produced a number."""
    return (f"{platform.system()}-{platform.machine()}"
            f"-py{platform.python_version()}-cpus{os.cpu_count()}")


from repro.obs.profile import shape_bucket  # noqa: E402  (lightweight, and
# already imported by registry.py — kept module-level so the per-dispatch
# consult does not pay a sys.modules lookup)


def cache_path() -> pathlib.Path:
    env = os.environ.get(CACHE_ENV_VAR, "").strip()
    if env:
        return pathlib.Path(env).expanduser()
    return pathlib.Path("~/.cache/repro/autotune.json").expanduser()


class TuneCache:
    """The persisted tuning table: (op, backend, device, bucket) -> entry.

    An entry records the winning config, its measured wall time, the numpy
    oracle's wall time on the same problem, and (for precision-pinned ops)
    the compensated path's measured scaled relative error — the parity
    certificate promotion is gated on.
    """

    def __init__(self, path: pathlib.Path | None = None):
        self.path = path or cache_path()
        self.entries: dict[str, dict] = {}
        self.loaded_from_disk = False

    @staticmethod
    def key(op: str, backend: str, device: str, bucket: str) -> str:
        return f"{op}|{backend}|{device}|{bucket}"

    def load(self) -> "TuneCache":
        """Tolerant load: corrupt JSON, wrong schema version, or a kernel-
        fingerprint mismatch all yield an empty cache (heuristics apply) —
        a bad cache file must never take down dispatch."""
        self.entries = {}
        self.loaded_from_disk = False
        try:
            doc = json.loads(self.path.read_text())
        except (OSError, ValueError):
            if self.path.exists():
                _count("cache_load_errors")
            return self
        if not isinstance(doc, dict) or doc.get("version") != SCHEMA_VERSION:
            _count("cache_load_errors")
            return self
        if doc.get("fingerprint") != kernel_fingerprint():
            # stale-by-construction: the kernels changed under the entries
            _count("cache_load_errors")
            return self
        entries = doc.get("entries")
        if isinstance(entries, dict):
            self.entries = {k: v for k, v in entries.items()
                            if isinstance(v, dict) and "config" in v}
            self.loaded_from_disk = True
        return self

    def save(self) -> pathlib.Path:
        """Atomic write (tmp + rename): a concurrent reader never sees a
        torn file, which load() would otherwise discard as corrupt."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        doc = {"version": SCHEMA_VERSION, "fingerprint": kernel_fingerprint(),
               "host": host_fingerprint(), "entries": self.entries}
        tmp = self.path.with_suffix(f".tmp.{os.getpid()}")
        tmp.write_text(json.dumps(doc, indent=1, default=float))
        tmp.replace(self.path)
        return self.path

    def put(self, op: str, backend: str, bucket: str, entry: dict) -> None:
        self.entries[self.key(op, backend, device_kind(), bucket)] = entry
        _DECISIONS.clear()     # new measurements invalidate memoized picks

    def get(self, op: str, backend: str, bucket: str) -> dict | None:
        return self.entries.get(self.key(op, backend, device_kind(), bucket))

    def for_op(self, op: str, bucket: str) -> dict[str, dict]:
        """backend -> entry for every backend tuned at this bucket."""
        out = {}
        for backend in ("xla", "pallas"):
            e = self.get(op, backend, bucket)
            if e is not None:
                out[backend] = e
        return out


_CACHE: TuneCache | None = None
_CACHE_KEY: str | None = None     # value of $REPRO_AUTOTUNE_CACHE at load
_CACHE_LOCK = threading.Lock()


_DECISIONS: dict[tuple, str | None] = {}   # (op, bucket, mode) -> backend
_MISSING = object()


@functools.cache
def _pinned_ops() -> frozenset:
    """Ops whose XLA_SIZE_THRESHOLD is None (precision-pinned) — snapshotted
    once; the threshold table is a module constant."""
    from . import registry
    return frozenset(op for op, thr in registry.XLA_SIZE_THRESHOLD.items()
                     if thr is None)


def get_cache() -> TuneCache:
    """The in-process cache, reloaded when the env var is repointed.  The
    staleness check is one environ lookup + string compare: this sits on
    the dispatch hot path (per CART node for ``hist_split``)."""
    global _CACHE, _CACHE_KEY
    key = os.environ.get(CACHE_ENV_VAR, "")
    if _CACHE is None or _CACHE_KEY != key:
        with _CACHE_LOCK:
            if _CACHE is None or _CACHE_KEY != key:
                _CACHE = TuneCache().load()
                _CACHE_KEY = key
                _DECISIONS.clear()
    return _CACHE


def reset_cache() -> None:
    """Drop the in-process cache so the next consult re-reads disk/env —
    tests repoint ``REPRO_AUTOTUNE_CACHE`` (or tune in-process) and call
    this."""
    global _CACHE
    with _CACHE_LOCK:
        _CACHE = None
        _DECISIONS.clear()


# ------------------------------------------------------- dispatch consults
def plan(op: str, backend: str, size: int | None) -> dict:
    """The tuned configuration an accelerator backend should run with at
    this problem size — ``{}`` on a cold miss (the backend's built-in
    defaults apply).  Called by backends.py on every accelerator dispatch;
    one dict lookup when warm."""
    if backend == "numpy" or not _enabled():
        return {}
    entry = get_cache().get(op, backend, shape_bucket(size))
    if entry is None:
        _count("cache_miss")
        return {}
    _count("cache_hit")
    return dict(entry.get("config") or {})


def tuned_backend(op: str, size: int | None) -> str | None:
    """The backend the tuning cache recommends for ``op`` at ``size``, or
    ``None`` when the static heuristics should decide (cold cache, no
    winning entry, or a precision pin with no passing certificate).

    On the hot path (warm cache) this is a memoized dict lookup — the full
    decision below runs once per (op, bucket, precision mode)."""
    if size is None or not _enabled():
        return None
    cache = get_cache()
    if not cache.entries:
        return None
    pinned = op in _pinned_ops()
    mode = precision_mode()
    if pinned and mode == "f64":
        return None          # the escape hatch: never lift the pin
    key = (op, shape_bucket(size), mode)
    best_name = _DECISIONS.get(key, _MISSING)
    if best_name is _MISSING:
        best_name = _DECISIONS[key] = _decide(cache, op, key[1], pinned, mode)
    if best_name is not None:
        _count("tuned_dispatch")
        if pinned:
            _count("promoted_f32")
    return best_name


def _decide(cache: TuneCache, op: str, bucket: str, pinned: bool,
            mode: str) -> str | None:
    best_name, best_us = None, None
    for backend, entry in cache.for_op(op, bucket).items():
        if backend == "pallas" and device_kind() != "tpu":
            # interpret-mode Pallas is a correctness path, never an auto
            # selection — a quick-budget timing fluke must not promote it
            continue
        us, numpy_us = entry.get("us"), entry.get("numpy_us")
        if not us or not numpy_us or us >= numpy_us:
            continue         # the oracle won at tune time: nothing to gain
        if pinned and mode == "compensated":
            cfg = entry.get("config") or {}
            rel = entry.get("rel_err")
            if not cfg.get("compensated") or rel is None or rel > PARITY_RTOL:
                continue     # no parity certificate: the pin holds
        if best_us is None or us < best_us:
            best_name, best_us = backend, us
    return best_name


def snapshot() -> dict:
    """Cache + counter state for ``/v1/stats`` and bench provenance."""
    cache = get_cache()
    return {"enabled": _enabled(), "cache_path": str(cache.path),
            "cache_loaded": cache.loaded_from_disk,
            "entries": len(cache.entries),
            "fingerprint": kernel_fingerprint(),
            "precision_mode": precision_mode(),
            "counters": counters_snapshot()}


# ------------------------------------------------------------------ tuning
def _scaled_rel_err(got, want) -> float:
    """max |a-b| scaled by the output's own magnitude (floor 1): the error
    measure the S2 - S1^2/S0 identity actually feels.  Elementwise relative
    error is meaningless here — integral images pass through near-zero
    entries whose denominators amplify benign f32 rounding."""
    got = np.asarray(got, np.float64).ravel()
    want = np.asarray(want, np.float64).ravel()
    scale = max(float(np.max(np.abs(want))) if want.size else 0.0, 1.0)
    return float(np.max(np.abs(got - want))) / scale if got.size else 0.0


def _time_call(fn, repeat: int) -> tuple[float, object]:
    fn()                                    # warmup / compile
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn()
    return (time.perf_counter() - t0) / repeat, out


def _problem(op: str, rng: np.random.Generator, fast: bool) -> tuple:
    """(call_factory, size) for a representative large-bucket problem.
    ``call_factory(backend, config)`` returns a zero-arg callable running
    the op end to end through the public wrapper (so the timing includes
    the same host<->device traffic dispatch pays)."""
    from repro import ops
    if op == "sat_moments":
        n = 256 if fast else LARGE_SHAPES[op]["n"]
        y = rng.normal(size=(n, n))
        return (lambda backend, cfg:
                lambda: ops.sat_moments(y, backend=backend, config=cfg)), \
            3 * y.size
    if op == "delta_sat":
        shp = LARGE_SHAPES[op]
        b, m = (32, 512) if fast else (shp["band"], shp["m"])
        y = rng.normal(size=(b + 1, m))
        carry = ops.sat_moments(y[:1], backend="numpy")[:, 0, :]
        tail = y[1:]
        return (lambda backend, cfg:
                lambda: ops.delta_sat(carry, tail, backend=backend,
                                      config=cfg)), 3 * tail.size
    if op == "hist_split":
        shp = LARGE_SHAPES[op]
        P, F, B = (40_000, 4, 64) if fast else (shp["P"], shp["F"], shp["B"])
        codes = rng.integers(0, B, size=(P, F)).astype(np.uint8)
        w = rng.uniform(0.5, 1.5, P)
        yv = rng.normal(size=P)
        wy, wy2 = w * yv, w * yv * yv
        return (lambda backend, cfg:
                lambda: ops.hist_split(codes, w, wy, wy2, B, backend=backend,
                                       config=cfg)), codes.size
    if op == "fitting_loss_batched":
        from repro.core import random_tree_segmentation, signal_coreset
        from repro.data import piecewise_signal
        shp = LARGE_SHAPES[op]
        n, m, k, T = ((96, 80, 6, 16) if fast else
                      (shp["n"], shp["m"], shp["k"], shp["T"]))
        y = piecewise_signal(n, m, k, noise=0.2, seed=3)
        cs = signal_coreset(y, k, 0.25)
        segs = [random_tree_segmentation(n, m, k, rng) for _ in range(T)]
        sr = np.stack([s.rects for s in segs]).astype(np.float64)
        sl = np.stack([s.labels for s in segs])
        return (lambda backend, cfg:
                lambda: ops.fitting_loss_batched(cs, sr, sl, backend=backend,
                                                 config=cfg)), \
            ops.fitting_loss_batched_size(cs, sr)
    raise ValueError(f"no tuning problem defined for op {op!r}")


TUNABLE_OPS = ("sat_moments", "delta_sat", "hist_split",
               "fitting_loss_batched")


def tune_op(op: str, *, budget: str = "quick", seed: int = 0,
            verbose: bool = False) -> dict[str, dict]:
    """Measure every configured (backend, config) for ``op`` on its
    representative problem and record the per-backend winner (with the
    numpy-oracle baseline and, for compensated configs, the parity
    certificate) into the cache.  Returns backend -> winning entry."""
    _count("tune_runs")
    rng = np.random.default_rng(seed)
    fast = budget == "quick"
    repeat = 2 if fast else 5
    call_of, size = _problem(op, rng, fast)
    bucket = shape_bucket(size)
    numpy_us, want = _time_call(call_of("numpy", {}), repeat)
    numpy_us *= 1e6
    cache = get_cache()
    winners: dict[str, dict] = {}
    for backend, configs in SEARCH_SPACE.get(op, {}).items():
        best = None
        for cfg in configs:
            try:
                us, got = _time_call(call_of(backend, cfg), repeat)
            except Exception as exc:  # noqa: BLE001 — a config that cannot
                # run on this device (VMEM overflow, unsupported lowering)
                # is a lost candidate, not a failed tune
                if verbose:
                    print(f"[autotune] {op}/{backend} {cfg}: "
                          f"{type(exc).__name__}: {exc}", file=sys.stderr)
                continue
            rel = _scaled_rel_err(_comparable(op, got), _comparable(op, want))
            entry = {"config": cfg, "us": us * 1e6, "numpy_us": numpy_us,
                     "rel_err": rel, "size": int(size), "bucket": bucket,
                     "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
                     "host": host_fingerprint()}
            if verbose:
                print(f"[autotune] {op}/{backend} {cfg}: "
                      f"{entry['us']:.0f}us (numpy {numpy_us:.0f}us) "
                      f"rel_err={rel:.2e}")
            if best is None or entry["us"] < best["us"]:
                best = entry
        if best is not None:
            cache.put(op, backend, bucket, best)
            winners[backend] = best
    return winners


def _comparable(op: str, out):
    """Project an op's output to the array the parity certificate compares
    (streaming_compress returns coreset objects; everything else arrays)."""
    if op == "streaming_compress":
        return np.concatenate([np.sort(np.asarray(c.moments), axis=None)
                               for c in out])
    return out


def tune_all(ops_list=None, *, budget: str = "quick", seed: int = 0,
             verbose: bool = False, save: bool = True) -> dict:
    """Tune every (or the named) tunable op and persist the cache."""
    results = {}
    for op in (ops_list or TUNABLE_OPS):
        if op not in TUNABLE_OPS:
            raise ValueError(f"op {op!r} is not tunable; "
                             f"tunable ops: {TUNABLE_OPS}")
        results[op] = tune_op(op, budget=budget, seed=seed, verbose=verbose)
    if save:
        get_cache().save()
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.ops.autotune",
        description="Populate the kernel tuning cache for this host.")
    ap.add_argument("--ops", default=None,
                    help=f"comma list of ops to tune (default: all of "
                         f"{','.join(TUNABLE_OPS)})")
    ap.add_argument("--budget", choices=("quick", "full"), default="quick")
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default: ${CACHE_ENV_VAR} or "
                         f"~/.cache/repro/autotune.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="print the tuned entries as JSON on stdout")
    args = ap.parse_args(argv)
    if args.cache:
        os.environ[CACHE_ENV_VAR] = args.cache
        reset_cache()
    ops_list = ([s.strip() for s in args.ops.split(",") if s.strip()]
                if args.ops else None)
    results = tune_all(ops_list, budget=args.budget, seed=args.seed,
                       verbose=not args.json)
    path = get_cache().save()
    summary = {"cache": str(path), "fingerprint": kernel_fingerprint(),
               "device": device_kind(),
               "entries": len(get_cache().entries),
               "tuned": {op: {b: {"config": e["config"],
                                  "us": e["us"], "numpy_us": e["numpy_us"],
                                  "rel_err": e["rel_err"]}
                              for b, e in per.items()}
                         for op, per in results.items()}}
    if args.json:
        print(json.dumps(summary, indent=1, default=float))
    else:
        print(f"[autotune] wrote {len(get_cache().entries)} entr"
              f"{'y' if len(get_cache().entries) == 1 else 'ies'} to {path} "
              f"(fingerprint {kernel_fingerprint()}, device {device_kind()})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
