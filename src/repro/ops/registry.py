"""Backend registry + dispatch for the canonical op surface.

Every hot-path primitive in this repo exists (or existed) several times:
a numpy oracle, a jitted dense-jnp variant, and a Pallas TPU kernel.  The
registry makes that structure explicit instead of ad hoc: each *op* name
maps to up to three registered *backends*,

    numpy   — float64 host oracle (ground truth; fastest for small inputs)
    xla     — jitted dense jnp (the dry-run / CPU-compiled path)
    pallas  — the TPU kernel (interpret-mode on CPU)

and callers go through :func:`dispatch`, never through a kernel module
directly.  Selection order:

  1. explicit ``backend=`` argument (callers that must pin a path);
  2. a :func:`backend_override` context (tests);
  3. the ``REPRO_OPS_BACKEND`` environment variable — either one backend
     name for every op (``REPRO_OPS_BACKEND=pallas``) or a comma list of
     ``op=backend`` pairs with an optional bare default
     (``REPRO_OPS_BACKEND=xla,hist_split=numpy``);
  4. the **autotune cache** (see ``autotune.py``): a persisted, measured
     winner for this (op, device, shape bucket) — only consulted when it
     beat the numpy oracle at tune time, and for precision-pinned ops only
     with a passing compensated-parity certificate;
  5. capability: on a TPU host, ``pallas`` (the kernels are written for it);
  6. size: below the per-op ``XLA_SIZE_THRESHOLD`` the numpy oracle wins
     (no dispatch/compile overhead), above it the jitted xla path.
     Precision-critical ops (``XLA_SIZE_THRESHOLD[op] is None``) never
     size-promote to the float32 accelerator backends, and interpret-mode
     Pallas is never auto-selected — on CPU it is a correctness path, not
     a fast one.

Implementations are registered as *factories* resolved on first use, so
importing ``repro.ops`` pulls in neither jax nor the kernel packages and
the registry stays import-cycle free (backends import ``repro.core`` /
``repro.kernels`` lazily).
"""
from __future__ import annotations

import contextlib
import functools
import os
import threading
import time
from typing import Callable

from repro.obs import profile as _profile
from repro.obs import trace as _trace

__all__ = [
    "OPS", "BACKENDS", "ENV_VAR", "BackendError", "register",
    "available_backends", "select_backend", "resolve", "dispatch",
    "backend_override", "snapshot",
]

OPS = ("sat_moments", "delta_sat", "fitting_loss", "fitting_loss_batched",
       "hist_split", "streaming_compress")
BACKENDS = ("numpy", "xla", "pallas")
ENV_VAR = "REPRO_OPS_BACKEND"

# auto-selection crossover (problem "size" is op-specific, computed by the
# public wrappers in __init__): below -> numpy oracle, above -> jitted xla.
# None = NEVER size-promote: sat_moments, delta_sat, hist_split and
# streaming_compress feed the variance identity S2 - S1^2/S0, which is
# catastrophically cancellation-sensitive — their float32 xla/pallas
# backends are only used when explicitly pinned (env/override) or on TPU,
# where f32 is the documented trade-off.  The two loss ops sum non-negative
# terms, so f32 promotion is safe.
XLA_SIZE_THRESHOLD = {
    "sat_moments": None,               # precision-critical (f64 oracle)
    "delta_sat": None,                 # patches the same integral images
    "fitting_loss": 1 << 16,           # blocks * leaves
    "fitting_loss_batched": 1 << 16,   # trees * blocks * leaves
    "hist_split": None,                # precision-critical (f64 oracle)
    "streaming_compress": None,        # rebuilds prefix stats (opt1 feed)
}


class BackendError(KeyError):
    """Unknown op/backend pair requested from the registry."""


_FACTORIES: dict[tuple[str, str], Callable[[], Callable]] = {}
_RESOLVED: dict[tuple[str, str], Callable] = {}
_RESOLVE_LOCK = threading.Lock()
_OVERRIDE: list[str] = []   # backend_override stack (innermost last)


def register(op: str, backend: str):
    """Decorator: register a lazy factory for (op, backend)."""
    if op not in OPS:
        raise BackendError(f"unknown op {op!r}; ops are {OPS}")
    if backend not in BACKENDS:
        raise BackendError(f"unknown backend {backend!r}; backends are {BACKENDS}")

    def deco(factory: Callable[[], Callable]) -> Callable[[], Callable]:
        _FACTORIES[(op, backend)] = factory
        return factory

    return deco


def available_backends(op: str) -> tuple[str, ...]:
    return tuple(b for b in BACKENDS if (op, b) in _FACTORIES)


def _env_choice(op: str) -> str | None:
    """Parse REPRO_OPS_BACKEND: bare default + op-specific overrides."""
    spec = os.environ.get(ENV_VAR, "").strip()
    if not spec:
        return None
    default = specific = None
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            o, b = (s.strip() for s in part.split("=", 1))
            if o not in OPS:
                # a typo'd op name must not silently pin nothing — the
                # operator asked for a precision/backend pin and would get
                # the auto-selection rules instead
                raise BackendError(
                    f"{ENV_VAR}={spec!r} names unknown op {o!r}; "
                    f"ops are {OPS}")
            if o == op:
                specific = b
        elif default is None:
            default = part
    choice = specific or default
    if choice is not None and choice not in BACKENDS:
        raise BackendError(
            f"{ENV_VAR}={spec!r} names unknown backend {choice!r}; "
            f"valid backends are {BACKENDS}")
    return choice


@functools.cache
def _platform_is_tpu() -> bool:
    # cached: the platform cannot change mid-process, and the first
    # jax.default_backend() call forces XLA client init — pure-numpy hot
    # paths (PrefixStats.build, per-node hist_split) must pay it only once
    import jax
    return jax.default_backend() == "tpu"


def select_backend(op: str, size: int | None = None) -> str:
    """The backend :func:`dispatch` would use for ``op`` at ``size``."""
    if op not in OPS:
        raise BackendError(f"unknown op {op!r}; ops are {OPS}")
    if _OVERRIDE:
        return _OVERRIDE[-1]
    env = _env_choice(op)
    if env is not None:
        return env
    from . import autotune
    tuned = autotune.tuned_backend(op, size)
    if tuned is not None:
        return tuned
    if _platform_is_tpu():
        return "pallas"
    thr = XLA_SIZE_THRESHOLD[op]
    if thr is not None and size is not None and size >= thr:
        return "xla"
    return "numpy"


def resolve(op: str, backend: str | None = None,
            size: int | None = None) -> tuple[str, Callable]:
    """(backend name, callable) after selection + lazy factory resolution."""
    name = backend or select_backend(op, size)
    key = (op, name)
    fn = _RESOLVED.get(key)
    if fn is None:
        with _RESOLVE_LOCK:
            fn = _RESOLVED.get(key)
            if fn is None:
                factory = _FACTORIES.get(key)
                if factory is None:
                    raise BackendError(
                        f"no {name!r} backend registered for op {op!r}; "
                        f"available: {available_backends(op)}")
                fn = _RESOLVED[key] = factory()
    return name, fn


def dispatch(op: str, *args, backend: str | None = None,
             size: int | None = None, **kw):
    name, fn = resolve(op, backend, size)
    # observability seam: every backend call crosses this line, so this is
    # where per-(op, backend, shape) wall time becomes a span + a profile
    # sample.  Outside a trace the span is the NOOP singleton and with no
    # hooks installed the profile branch is one falsy check — the pure-
    # library hot paths (per-node hist_split) pay two perf_counter reads.
    span = _trace.TRACER.child_span("ops.dispatch")
    t0 = time.perf_counter()
    try:
        return fn(*args, **kw)
    finally:
        dt = time.perf_counter() - t0
        if span:
            span.set_attr("op", op)
            span.set_attr("backend", name)
            span.set_attr("size", size)
            span.set_attr("shape_bucket", _profile.shape_bucket(size))
            span.end()
        if _profile._HOOKS:
            _profile.record(op, name, size, dt)


@contextlib.contextmanager
def backend_override(backend: str):
    """Force every dispatch inside the context onto one backend (tests)."""
    if backend not in BACKENDS:
        raise BackendError(f"unknown backend {backend!r}; backends are {BACKENDS}")
    _OVERRIDE.append(backend)
    try:
        yield
    finally:
        _OVERRIDE.pop()


def snapshot() -> dict:
    """Selection state per op — surfaced in ``/v1/stats`` and bench output.

    ``selected`` is the small-problem choice (size-unaware); large problems
    auto-promote to ``xla`` at ``xla_threshold`` unless pinned.
    """
    out = {}
    for op in OPS:
        out[op] = {
            "available": list(available_backends(op)),
            "selected": select_backend(op),
            "env_override": _env_choice(op),
            "xla_threshold": XLA_SIZE_THRESHOLD[op],
        }
    return out
