"""Sharded, asynchronous, atomic checkpointing with elastic restore.

Layout per step:  <dir>/step_<k>/host_<i>.npz.<codec>  +  <dir>/step_<k>/DONE
                  <dir>/latest   (text pointer, written after DONE)
where <codec> is zst (zstandard, when installed) or zlib (stdlib fallback);
the DONE metadata records which codec committed the step.

Design points for the 1000-node posture:
  * each host serializes only its addressable shard values (here: the whole
    array on the single-host container; the API takes the host count);
  * writes go to a temp name and are renamed — a reader never sees a torn
    file; the DONE marker commits the step atomically across files;
  * saving runs on a background thread (training continues; ``wait()``
    joins before the next save or at exit);
  * restore reshards on load: arrays are device_put against the *current*
    mesh's shardings, so reloading onto a different mesh (elastic resize)
    is the same code path;
  * ``max_to_keep`` garbage-collects old steps after commit.
"""
from __future__ import annotations

import io
import json
import pathlib
import shutil
import threading
import zlib

import jax
import numpy as np

try:
    import zstandard
except ModuleNotFoundError:  # bare containers: stdlib zlib fallback
    zstandard = None

__all__ = ["CheckpointManager"]

# codec name -> (file extension, compress, decompress); the writer records
# its codec in the DONE metadata and the reader dispatches on the extension,
# so checkpoints stay readable across environments with/without zstandard
# (zstd payloads still need the module to restore — the error says so).
_CODECS = {
    "zstd": (".npz.zst",
             lambda b: zstandard.ZstdCompressor(level=3).compress(b),
             lambda b: zstandard.ZstdDecompressor().decompress(b)),
    "zlib": (".npz.zlib",
             lambda b: zlib.compress(b, 3),
             zlib.decompress),
}
_DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
            for kp, _ in flat]
    return keys, [v for _, v in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3,
                 host_id: int = 0, num_hosts: int = 1, async_save: bool = True,
                 codec: str = _DEFAULT_CODEC):
        if codec not in _CODECS:
            raise ValueError(f"unknown codec {codec!r}; have {sorted(_CODECS)}")
        if codec == "zstd" and zstandard is None:
            raise ValueError("codec 'zstd' requires the zstandard module")
        self.codec = codec
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.max_to_keep = max_to_keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> None:
        step = int(step)   # np.int64 from a restored state must not poison
        self.wait()        # the f-string paths / DONE json
        keys, leaves, _ = _flatten(tree)
        arrays = [np.asarray(v) for v in leaves]   # host copy before async
        # npz silently degrades extension dtypes (bfloat16/fp8 have kind 'V')
        # to raw void — unrestorable.  Widen them to float32 for storage;
        # restore casts back to the template dtype, and float32 is exact for
        # every sub-32-bit float, so the roundtrip is lossless.
        arrays = [a.astype(np.float32) if a.dtype.kind == "V" else a
                  for a in arrays]

        def _write():
            step_dir = self.dir / f"step_{step:08d}"
            step_dir.mkdir(parents=True, exist_ok=True)
            buf = io.BytesIO()
            np.savez(buf, **{k: a for k, a in zip(keys, arrays)})
            ext, compress, _ = _CODECS[self.codec]
            payload = compress(buf.getvalue())
            tmp = step_dir / f"host_{self.host_id}{ext}.tmp"
            final = step_dir / f"host_{self.host_id}{ext}"
            tmp.write_bytes(payload)
            tmp.rename(final)
            # single-host container: host 0 commits
            if self.host_id == 0:
                (step_dir / "DONE").write_text(json.dumps(
                    {"step": step, "num_hosts": self.num_hosts,
                     "codec": self.codec}))
                (self.dir / "latest.tmp").write_text(str(step))
                (self.dir / "latest.tmp").rename(self.dir / "latest")
                self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.max_to_keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "DONE").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        p = self.dir / "latest"
        if p.exists():
            s = int(p.read_text())
            if (self.dir / f"step_{s:08d}" / "DONE").exists():
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """Load into the template tree structure; device_put against
        ``shardings`` (a matching tree) if given — the elastic-remesh path."""
        step_dir = self.dir / f"step_{step:08d}"
        for name, (ext, _, decompress) in _CODECS.items():
            shard = step_dir / f"host_{self.host_id}{ext}"
            if shard.exists():
                if name == "zstd" and zstandard is None:
                    raise RuntimeError(f"{shard} is zstd-compressed but the "
                                       "zstandard module is not installed")
                break
        else:
            raise FileNotFoundError(f"no host_{self.host_id} shard in {step_dir}")
        raw = decompress(shard.read_bytes())
        npz = np.load(io.BytesIO(raw))
        keys, leaves, treedef = _flatten(template)
        out = []
        for k, tmpl in zip(keys, leaves):
            a = npz[k]
            if hasattr(tmpl, "dtype"):
                a = a.astype(tmpl.dtype)
            out.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree
