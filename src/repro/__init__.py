"""repro: Coresets for Decision Trees of Signals (NeurIPS 2021) as a
production multi-pod JAX framework.  See DESIGN.md for the system map."""

__version__ = "1.0.0"
