# First-class Python SDK for the coreset service's v1 API.  Typed requests/
# responses (repro.service.protocol dataclasses — no raw dicts), binary/JSON
# encoding negotiation, and bounded retries over stdlib urllib.
from .client import (AdmissionRejectedError, CoresetAPIError, CoresetClient,
                     TransportError)

__all__ = ["CoresetClient", "CoresetAPIError", "TransportError",
           "AdmissionRejectedError"]
