"""CoresetClient — typed v1 SDK over stdlib urllib.

Every method takes/returns ``repro.service.protocol`` messages (or numpy
arrays that are coerced into them) — callers never hand-roll dicts, and the
wire encoding is invisible to them:

  * ``encoding="binary"`` (default): requests ship as compressed npz frames
    and responses are requested in the same format via ``Accept`` — large
    signal registration skips ``tolist``/JSON entirely;
  * ``encoding="json"``: readable bodies, same dataclasses;
  * a server that rejects the binary media type (HTTP 415 — e.g. an older
    deployment) downgrades the client to JSON for the rest of its life.

Transient failures (connection errors, timeouts, HTTP 5xx) retry with
exponential backoff up to ``retries`` times — a ``Retry-After`` header on
a retryable 5xx (503 overload pushback) stretches the next sleep to at
least that many seconds; structured API errors (status < 500 with the v1
envelope) raise ``CoresetAPIError(http, code, message)`` immediately and
never retry.

Large ``compress`` responses stream: with ``stream=True`` (the default on
binary encoding) the client advertises ``;v=2`` in ``Accept`` and decodes
the server's chunked segment stream incrementally — same typed result,
same retry semantics (a stream that dies mid-transfer surfaces as a
retryable transport fault, a corrupt one as ``ProtocolError``).  v1-only
servers ignore the parameter and the buffered path is used unchanged;
``client.last_stream_chunks`` tells which happened (0 = buffered).

Every request carries a client-minted W3C ``traceparent`` header, so the
server-side trace of a call IS the client's trace id: after any call,
``client.last_trace_id`` names the trace ``client.trace(...)`` retrieves,
and a ``CoresetAPIError`` carries the failing request's ``trace_id`` —
the server-side story of an error is one GET away.

    from repro.client import CoresetClient
    c = CoresetClient("http://127.0.0.1:8787")
    c.register_signal("img", values=y)
    r = c.query_loss("img", rects, labels, eps=0.3)
    print(r.loss, r.eps_eff, r.served_from)
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.request

import numpy as np

from repro import obs
from repro.service import protocol as P

__all__ = ["CoresetClient", "CoresetAPIError", "TransportError",
           "AdmissionRejectedError"]


class CoresetAPIError(Exception):
    """Structured error from the service's uniform v1 envelope.
    ``trace_id`` (when the server returned one) names the server-side trace
    of the failing request — ``client.trace(err.trace_id)`` fetches it."""

    def __init__(self, http: int, code: str, message: str,
                 trace_id: str | None = None):
        tail = f" [trace {trace_id}]" if trace_id else ""
        super().__init__(f"[{http} {code}] {message}{tail}")
        self.http = http
        self.code = code
        self.message = message
        self.trace_id = trace_id


class AdmissionRejectedError(CoresetAPIError):
    """503 ``overloaded``: the server refused the request ON ARRIVAL
    (admission control) and every retry met the same pushback.
    ``retry_after`` is the server's final backoff hint in seconds;
    ``reason`` is the admission verdict (``deadline_unmeetable``,
    ``tenant_rate``, ``tenant_inflight``); ``tenant`` is who it was
    charged to."""

    def __init__(self, http: int, code: str, message: str,
                 trace_id: str | None = None, *,
                 retry_after: float | None = None,
                 tenant: str | None = None, reason: str | None = None):
        super().__init__(http, code, message, trace_id)
        self.retry_after = retry_after
        self.tenant = tenant
        self.reason = reason


class TransportError(Exception):
    """Connection-level failure after exhausting retries."""


class CoresetClient:
    def __init__(self, base_url: str, *, encoding: str = "binary",
                 timeout: float = 120.0, retries: int = 2,
                 backoff: float = 0.1, backoff_cap: float = 30.0,
                 deadline_ms: float | None = None,
                 stream: bool = True, tenant: str | None = None):
        if encoding not in ("binary", "json"):
            raise ValueError(f"encoding must be 'binary' or 'json', "
                             f"got {encoding!r}")
        self.base_url = base_url.rstrip("/")
        self.encoding = encoding
        # offer the v2 chunked stream on compress (binary encoding only);
        # servers without v2 serve the buffered v1 response unchanged
        self.stream = bool(stream)
        self.timeout = float(timeout)
        self.retries = int(retries)
        self.backoff = float(backoff)
        # ceiling on any single retry sleep, INCLUDING a server-sent
        # Retry-After: an admission-controlled server computes its hint
        # from the configured rate, and a tiny rate yields an honest but
        # enormous hint — a client must never block unboundedly on it
        self.backoff_cap = float(backoff_cap)
        # default server-side budget attached to every query/build request;
        # per-call deadline_ms overrides it.  Past the budget the server
        # fails the request 504 deadline_exceeded (never retried here — the
        # deadline passing is the definitive answer, and the batch the
        # request was queued in is unaffected)
        self.deadline_ms = float(deadline_ms) if deadline_ms is not None \
            else None
        # QoS identity: sent as X-Coreset-Tenant on every request so an
        # admission-controlled server charges this client's traffic to its
        # fair-share bucket (None = the server's default tenant)
        self.tenant = tenant
        # request-frame codec: None = best this host encodes; negotiated
        # down to "zlib" if the server 415s a zstd frame
        self._codec: str | None = None
        # trace propagation: every request carries a minted traceparent,
        # and these name the LAST request's trace (the server echoes the
        # trace id back in X-Coreset-Trace-Id, so both sides agree)
        self.last_traceparent: str | None = None
        self.last_trace_id: str | None = None
        # last compress: v2 segments decoded (0 = buffered v1 response);
        # last retryable 5xx: the server's Retry-After seconds, if any
        self.last_stream_chunks: int = 0
        self.last_retry_after: float | None = None

    def _deadline(self, deadline_ms: float | None) -> float | None:
        ms = deadline_ms if deadline_ms is not None else self.deadline_ms
        return float(ms) if ms is not None else None

    # ------------------------------------------------------------ transport
    def _request(self, method: str, path: str, body: bytes | None,
                 content_type: str | None, stream: bool = False):
        if self.encoding == "binary":
            # advertise the strongest codec THIS host can decode; the
            # server encodes its response accordingly (zlib unless zstd is
            # explicitly offered), so a 200 is always decodable here
            codec = "zstd" if P.zstandard is not None else "zlib"
            accept = f"{P.CONTENT_TYPE_BINARY};codec={codec}"
            if stream:
                # v2 offer: a stream-capable server answers with chunked
                # segments; everyone else ignores the parameter (v1)
                accept += ";v=2"
        else:
            accept = P.CONTENT_TYPE_JSON
        headers = {"Accept": accept}
        if self.tenant is not None:
            headers["X-Coreset-Tenant"] = self.tenant
        if content_type is not None:
            headers["Content-Type"] = content_type
        # W3C trace propagation: the server continues THIS trace id, so the
        # server-side trace of the call is retrievable under an id the
        # client chose (one fresh id per attempt — retries are new traces)
        trace_id = obs.mint_trace_id()
        tp = obs.format_traceparent(trace_id, obs.mint_span_id())
        headers["traceparent"] = tp
        self.last_traceparent = tp
        self.last_trace_id = trace_id
        req = urllib.request.Request(self.base_url + path, data=body,
                                     headers=headers, method=method)
        with urllib.request.urlopen(req, timeout=self.timeout) as resp:
            self._note_trace(resp.headers)
            rtype = resp.headers.get("Content-Type", "")
            if rtype.split(";")[0].strip().lower() == P.CONTENT_TYPE_STREAM:
                # v2 negotiated: decode segments as they arrive off the
                # socket (urllib de-chunks the transfer encoding) — peak
                # client memory is O(chunk) + the assembled arrays, never
                # a second whole-body buffer
                msg, chunks = P.read_compress_stream(resp.read)
                self.last_stream_chunks = chunks
                return resp.status, rtype, msg
            return resp.status, rtype, resp.read()

    def _note_trace(self, headers) -> str | None:
        """Record the server's trace id for the last request (it normally
        equals the minted one; a proxy or non-tracing server may differ)."""
        tid = headers.get("X-Coreset-Trace-Id") if headers is not None else None
        if tid:
            self.last_trace_id = tid
        return tid

    def _raise_api_error(self, http: int, ctype: str, raw: bytes,
                         trace_id: str | None = None):
        trace_id = trace_id or self.last_trace_id
        try:
            env = P.decode(ctype, raw, expect=P.ErrorResponse)
            raise CoresetAPIError(http, env.error.code, env.error.message,
                                  trace_id)
        except P.ProtocolError:
            raise CoresetAPIError(http, "unknown",
                                  raw[:512].decode("utf-8", "replace"),
                                  trace_id) from None

    def _admission_error(self, ctype: str, raw: bytes,
                         trace_id: str | None,
                         retry_after: float | None,
                         ) -> AdmissionRejectedError | None:
        """Typed rejection from a 503 body carrying the ``overloaded``
        envelope; None for any other 503 (proxy, mid-restart, no body)."""
        try:
            env = P.decode(ctype, raw, expect=P.ErrorResponse)
        except (P.ProtocolError, ValueError):
            return None
        if env.error.code != "overloaded":
            return None
        return AdmissionRejectedError(
            503, env.error.code, env.error.message,
            trace_id or self.last_trace_id,
            retry_after=(env.error.retry_after if env.error.retry_after
                         is not None else retry_after),
            tenant=env.error.tenant, reason=env.error.reason)

    @staticmethod
    def _retry_after_s(headers) -> float | None:
        """Seconds form of a Retry-After header (the HTTP-date form is not
        worth a date parser on this path); absent/garbage -> None."""
        val = headers.get("Retry-After") if headers is not None else None
        if val is None:
            return None
        try:
            return max(0.0, float(val))
        except ValueError:
            return None

    def _call(self, path: str, msg: P._Wire, expect: type,
              retryable: bool = True, stream: bool = False):
        retries = self.retries if retryable else 0
        attempt = 0
        downgraded = False
        while True:
            ctype, body = msg.to_wire(self.encoding,
                                      binary_codec=self._codec)
            retry_after = None
            try:
                status, rtype, raw = self._request("POST", path, body, ctype,
                                                   stream=stream)
            except urllib.error.HTTPError as exc:
                raw = exc.read()
                err_tid = self._note_trace(exc.headers)
                if exc.code == 415 and self.encoding == "binary":
                    # format mismatches are not transient failures, so the
                    # renegotiation retries spend no budget slots: first
                    # drop the frame codec to stdlib zlib, then give up on
                    # binary entirely and speak JSON
                    if self._codec != "zlib":
                        self._codec = "zlib"
                        continue
                    if not downgraded:
                        self.encoding = "json"
                        downgraded = True
                        continue
                if exc.code >= 500 and exc.code != 504:
                    last = TransportError(f"HTTP {exc.code} from {path}: "
                                          f"{raw[:256]!r}")
                    # an overloaded server's 503 may carry Retry-After —
                    # honor it below instead of hammering the fixed
                    # exponential schedule into the same congestion
                    retry_after = self._retry_after_s(exc.headers)
                    self.last_retry_after = retry_after
                    if exc.code == 503:
                        # admission pushback still retries (the server said
                        # when), but once the budget is spent the caller
                        # gets the typed rejection, not a bare transport
                        # error: reason/tenant/retry_after survive
                        rej = self._admission_error(
                            exc.headers.get("Content-Type", ""), raw,
                            err_tid, retry_after)
                        if rej is not None:
                            last = rej
                else:
                    # < 500 (structured API error) and 504 deadline_exceeded
                    # raise immediately: a missed deadline is the answer,
                    # not a transient fault to retry against a fresh budget
                    self._raise_api_error(
                        exc.code, exc.headers.get("Content-Type", ""), raw,
                        trace_id=err_tid)
            except P.StreamTruncated as exc:
                # the v2 stream died mid-transfer: indistinguishable from a
                # dropped connection, so it retries like one (other
                # ProtocolErrors — corrupt frames — raise through: resending
                # the request would fetch the same corruption)
                last = TransportError(f"stream truncated from {path}: {exc}")
            except (urllib.error.URLError, TimeoutError, ConnectionError,
                    OSError) as exc:
                last = TransportError(f"{type(exc).__name__}: {exc}")
            else:
                if status >= 400:  # non-raising urlopen implementations
                    self._raise_api_error(status, rtype, raw)
                if isinstance(raw, P._Wire):
                    # _request already decoded a v2 stream incrementally
                    if not isinstance(raw, expect):
                        raise P.ProtocolError(
                            f"expected {expect.__name__}, streamed "
                            f"{type(raw).__name__}")
                    return raw
                self.last_stream_chunks = 0
                return P.decode(rtype, raw, expect=expect)
            if attempt >= retries:
                raise last
            delay = self.backoff * (2 ** attempt)
            if retry_after is not None:
                delay = max(delay, retry_after)
            time.sleep(min(delay, self.backoff_cap))
            attempt += 1

    @staticmethod
    def _spec(k: int | None, eps: float | None,
              k_default: int | None = None) -> P.CoresetSpec | None:
        if k is None and eps is None:
            return None
        kk = k if k is not None else k_default
        if kk is None:
            raise ValueError("eps given without k and no default k available")
        return P.CoresetSpec(k=int(kk), eps=float(eps if eps is not None else 0.2))

    # ------------------------------------------------------------- registry
    def register_signal(self, name: str, values=None, *, synthetic=None,
                        replace: bool = False) -> P.SignalInfo:
        msg = P.RegisterRequest(
            signal=P.SignalRef(name=name),
            values=(np.ascontiguousarray(values, np.float64)
                    if values is not None else None),
            synthetic=synthetic, replace=replace)
        # replace=True is idempotent; replace=False is not — retrying it
        # after a lost response would 409 a registration that succeeded
        return self._call("/v1/signals", msg, P.SignalInfo,
                          retryable=replace)

    def ingest(self, name: str, band=None, *, synthetic=None) -> P.SignalInfo:
        msg = P.IngestRequest(
            signal=P.SignalRef(name=name),
            band=(np.ascontiguousarray(band, np.float64)
                  if band is not None else None),
            synthetic=synthetic)
        # append-only state mutation with no dedup token: a retry after a
        # lost response would ingest the band twice and silently corrupt
        # the signal, so transport failures surface to the caller instead
        return self._call("/v1/ingest", msg, P.SignalInfo, retryable=False)

    def ingest_delta(self, name: str, band, *, row0: int | None = None,
                     ) -> P.IngestDeltaResponse:
        """Delta write: ship ONLY the changed rows.  ``row0`` pins the
        absolute row offset of the replaced band (on streamed signals it
        must start an ingested band); None appends at the current end.  The
        server patches its integral images and merge-reduce state
        incrementally instead of re-ingesting the whole signal."""
        msg = P.IngestDeltaRequest(
            signal=P.SignalRef(name=name),
            band=np.ascontiguousarray(band, np.float64),
            row0=int(row0) if row0 is not None else None)
        # replacement is idempotent (same row0 + bytes -> same version), so
        # it may retry; an append retry would double-ingest like ingest()
        return self._call("/v1/ingest:delta", msg, P.IngestDeltaResponse,
                          retryable=row0 is not None)

    def ingest_delta_burst(self, name: str, deltas,
                           ) -> P.IngestDeltaResponse:
        """MANY delta writes in one request: ``deltas`` is a sequence of
        ``(row0, band)`` pairs (row0=None appends).  The bands are
        concatenated on the wire and the server fans their per-band leaf
        rebuilds out through one batched scheduler submission instead of N
        sequential builds — the cheap way to apply a burst of band
        replacements."""
        deltas = [(None if r0 is None else int(r0),
                   np.ascontiguousarray(b, np.float64)) for r0, b in deltas]
        if not deltas:
            raise ValueError("burst needs at least one (row0, band) delta")
        msg = P.IngestDeltaRequest(
            signal=P.SignalRef(name=name),
            band=np.concatenate([b for _, b in deltas], axis=0),
            row0s=[r0 for r0, _ in deltas],
            rows=[int(b.shape[0]) for _, b in deltas])
        # retryable only when every delta is an idempotent replacement
        return self._call("/v1/ingest:delta", msg, P.IngestDeltaResponse,
                          retryable=all(r0 is not None for r0, _ in deltas))

    # -------------------------------------------------------------- queries
    def build(self, name: str, k: int, eps: float = 0.2, *,
              deadline_ms: float | None = None) -> P.BuildResponse:
        msg = P.BuildRequest(signal=P.SignalRef(name=name),
                             spec=P.CoresetSpec(k=k, eps=eps),
                             deadline_ms=self._deadline(deadline_ms))
        return self._call("/v1/build", msg, P.BuildResponse)

    def query_loss(self, name: str, rects, labels, *, k: int | None = None,
                   eps: float | None = None,
                   deadline_ms: float | None = None,
                   coalesce: bool = True) -> P.LossResponse:
        """One tree's loss.  Concurrent same-signal queries (from any
        connection) fuse server-side into one batched dispatch — the
        response's ``fused_batch_size`` says how many rode along;
        ``coalesce=False`` opts this request out."""
        rects = np.asarray(rects, np.int64).reshape(-1, 4)
        msg = P.LossQuery(
            signal=P.SignalRef(name=name), rects=rects,
            labels=np.asarray(labels, np.float64).ravel(),
            spec=self._spec(k, eps, k_default=max(rects.shape[0], 1)),
            deadline_ms=self._deadline(deadline_ms), coalesce=coalesce)
        return self._call("/v1/query/loss", msg, P.LossResponse)

    def query_loss_batch(self, name: str, rects, labels, *,
                         k: int | None = None, eps: float | None = None,
                         deadline_ms: float | None = None,
                         coalesce: bool = True) -> P.BatchLossResponse:
        """Score T same-signal segmentations in ONE fused request:
        ``rects`` (T, K, 4), ``labels`` (T, K).  ``coalesce=False`` skips
        the server's cross-request fusion and dispatches the batch alone."""
        rects = np.asarray(rects, np.int64)
        labels = np.asarray(labels, np.float64)
        if rects.ndim != 3:
            raise ValueError("batch rects must have shape (T, K, 4)")
        msg = P.BatchLossQuery(
            signal=P.SignalRef(name=name), rects=rects, labels=labels,
            spec=self._spec(k, eps, k_default=max(rects.shape[1], 1)),
            deadline_ms=self._deadline(deadline_ms), coalesce=coalesce)
        return self._call("/v1/query/loss:batch", msg, P.BatchLossResponse)

    def fit(self, name: str, k: int, eps: float = 0.2, *,
            n_estimators: int = 10, max_leaves: int | None = None,
            predict=None, seed: int = 0,
            deadline_ms: float | None = None) -> P.FitResponse:
        msg = P.FitRequest(
            signal=P.SignalRef(name=name), spec=P.CoresetSpec(k=k, eps=eps),
            n_estimators=n_estimators, max_leaves=max_leaves,
            predict=(np.asarray(predict, np.float64).reshape(-1, 2)
                     if predict is not None else None),
            seed=seed, deadline_ms=self._deadline(deadline_ms))
        return self._call("/v1/query/fit", msg, P.FitResponse)

    def compress(self, name: str, k: int, eps: float = 0.2, *,
                 target_frac: float | None = None, style: str = "mean",
                 max_points: int = 4096,
                 deadline_ms: float | None = None) -> P.CompressResponse:
        msg = P.CompressRequest(
            signal=P.SignalRef(name=name), spec=P.CoresetSpec(k=k, eps=eps),
            target_frac=target_frac, style=style, max_points=max_points,
            deadline_ms=self._deadline(deadline_ms))
        return self._call("/v1/query/compress", msg, P.CompressResponse,
                          stream=self.stream and self.encoding == "binary")

    # ------------------------------------------------------------ telemetry
    def _get_json(self, path: str) -> dict:
        try:
            status, _, raw = self._request("GET", path, None, None)
        except urllib.error.HTTPError as exc:
            self._raise_api_error(exc.code, exc.headers.get("Content-Type", ""),
                                  exc.read())
        if status >= 400:
            self._raise_api_error(status, "application/json", raw)
        return json.loads(raw)

    def healthz(self) -> dict:
        return self._get_json("/v1/healthz")

    def stats(self) -> dict:
        return self._get_json("/v1/stats")

    def metrics_text(self) -> str:
        _, _, raw = self._request("GET", "/v1/metrics", None, None)
        return raw.decode()

    def traces_recent(self, limit: int = 50) -> list[dict]:
        """Newest-first summaries of the server's completed traces."""
        return self._get_json(f"/v1/traces:recent?limit={int(limit)}")["traces"]

    def trace(self, trace_id: str | None = None, *,
              format: str | None = None) -> dict:
        """Fetch one server-side trace (default: the LAST request's —
        ``last_trace_id``).  ``format="chrome"`` returns Chrome trace-event
        JSON that Perfetto / chrome://tracing load directly."""
        tid = trace_id or self.last_trace_id
        if not tid:
            raise ValueError("no trace_id given and no request made yet")
        suffix = "?format=chrome" if format == "chrome" else ""
        return self._get_json(f"/v1/trace/{tid}{suffix}")
