"""k-segmentations and k-trees of signals: models, samplers, solvers, oracles.

A k-segmentation (Definition 1) is represented extensionally as K half-open
rectangles tiling [n] x [m] plus a label per rectangle.  k-trees (recursive
guillotine partitions — the decision-tree special case) are generated/solved
here:

  * ``random_tree_segmentation`` — uniform-ish random recursive splits
    (query sampler for guarantee tests);
  * ``greedy_tree`` — top-down best-split CART on the *signal domain* using
    O(1) SAT gain queries (the "train on full data" baseline of §5);
  * ``optimal_tree_dp`` — exact minimum-loss k-tree by exhaustive
    rectangle-split DP (tiny grids only; the test oracle);
  * ``segment_1d_dp`` — exact 1D k-segmentation DP (O(n^2 k)).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .stats import PrefixStats

__all__ = [
    "Segmentation", "random_tree_segmentation", "greedy_tree",
    "optimal_tree_dp", "segment_1d_dp", "optimal_labels",
]


@dataclasses.dataclass(frozen=True)
class Segmentation:
    rects: np.ndarray    # (K, 4) int64 half-open (r0, r1, c0, c1)
    labels: np.ndarray   # (K,) float64

    @property
    def k(self) -> int:
        return int(self.rects.shape[0])

    def assignment_raster(self, n: int, m: int) -> np.ndarray:
        out = np.full((n, m), np.nan)
        for (r0, r1, c0, c1), lam in zip(self.rects, self.labels):
            out[r0:r1, c0:c1] = lam
        return out


def optimal_labels(ps: PrefixStats, rects: np.ndarray) -> np.ndarray:
    """Per-rectangle mean labels (the loss-minimizing assignment)."""
    rects = np.asarray(rects, np.int64).reshape(-1, 4)
    s0, s1, _ = ps.sums(rects[:, 0], rects[:, 1], rects[:, 2], rects[:, 3])
    return np.where(s0 > 0, s1 / np.maximum(s0, 1e-300), 0.0)


# ------------------------------------------------------------------ samplers
def random_tree_segmentation(n: int, m: int, k: int, rng: np.random.Generator,
                             labels: str | np.ndarray = "random") -> Segmentation:
    """Random k-leaf guillotine tree over [n] x [m]."""
    rects = [(0, n, 0, m)]
    while len(rects) < k:
        # pick a splittable rect, biased by area
        areas = np.array([(r1 - r0) * (c1 - c0) for r0, r1, c0, c1 in rects], float)
        splittable = np.array([(r1 - r0 > 1) or (c1 - c0 > 1) for r0, r1, c0, c1 in rects])
        if not splittable.any():
            break
        p = areas * splittable
        i = rng.choice(len(rects), p=p / p.sum())
        r0, r1, c0, c1 = rects.pop(i)
        axes = [a for a, ok in ((0, r1 - r0 > 1), (1, c1 - c0 > 1)) if ok]
        ax = axes[rng.integers(len(axes))]
        if ax == 0:
            s = int(rng.integers(r0 + 1, r1))
            rects += [(r0, s, c0, c1), (s, r1, c0, c1)]
        else:
            s = int(rng.integers(c0 + 1, c1))
            rects += [(r0, r1, c0, s), (r0, r1, s, c1)]
    rect_arr = np.asarray(rects, np.int64)
    if isinstance(labels, str) and labels == "random":
        lab = rng.normal(size=len(rects))
    else:
        lab = np.asarray(labels, np.float64)
    return Segmentation(rect_arr, lab)


# ------------------------------------------------------------ greedy solver
def greedy_tree(ps: PrefixStats, k: int, min_cells: int = 1,
                rect: tuple[int, int, int, int] | None = None) -> Segmentation:
    """Top-down best-first k-tree: repeatedly split the leaf with the largest
    SSE reduction over all axis/positions (O(1) gain per candidate via SAT).
    Mean labels.  This is the full-data CART baseline on the signal domain.
    """
    import heapq
    n, m = ps.shape
    root = rect or (0, n, 0, m)

    def best_split(r0, r1, c0, c1):
        base = float(ps.opt1(r0, r1, c0, c1))
        best = (0.0, None)
        if r1 - r0 >= 2 * min_cells:
            ss = np.arange(r0 + min_cells, r1 - min_cells + 1)
            g = base - ps.opt1(r0, ss, c0, c1) - ps.opt1(ss, r1, c0, c1)
            j = int(np.argmax(g))
            if g[j] > best[0]:
                best = (float(g[j]), (0, int(ss[j])))
        if c1 - c0 >= 2 * min_cells:
            ss = np.arange(c0 + min_cells, c1 - min_cells + 1)
            g = base - ps.opt1(r0, r1, c0, ss) - ps.opt1(r0, r1, ss, c1)
            j = int(np.argmax(g))
            if g[j] > best[0]:
                best = (float(g[j]), (1, int(ss[j])))
        return best

    heap = []
    counter = 0

    def push(rc):
        nonlocal counter
        gain, split = best_split(*rc)
        if split is not None:
            heapq.heappush(heap, (-gain, counter, rc, split))
            counter += 1

    leaves = [root]
    push(root)
    while len(leaves) < k and heap:
        neg_gain, _, rc, (ax, s) = heapq.heappop(heap)
        if -neg_gain <= 0:
            break
        if rc not in leaves:
            continue
        leaves.remove(rc)
        r0, r1, c0, c1 = rc
        kids = ([(r0, s, c0, c1), (s, r1, c0, c1)] if ax == 0
                else [(r0, r1, c0, s), (r0, r1, s, c1)])
        leaves += kids
        for kid in kids:
            push(kid)
    rects = np.asarray(leaves, np.int64)
    return Segmentation(rects, optimal_labels(ps, rects))


# ------------------------------------------------------------------- oracles
def optimal_tree_dp(values: np.ndarray, k: int):
    """Exact optimal k-tree loss (and one optimal tree) by DP over
    (rectangle, leaves) — O(n^2 m^2 (n+m) k^2); tiny grids only."""
    y = np.asarray(values, np.float64)
    n, m = y.shape
    ps = PrefixStats.build(y)

    @functools.lru_cache(maxsize=None)
    def solve(r0, r1, c0, c1, kk):
        if kk == 1:
            return float(ps.opt1(r0, r1, c0, c1)), None
        best = solve(r0, r1, c0, c1, 1)
        for s in range(r0 + 1, r1):
            for k1 in range(1, kk):
                a, _ = solve(r0, s, c0, c1, k1)
                b, _ = solve(s, r1, c0, c1, kk - k1)
                if a + b < best[0]:
                    best = (a + b, (0, s, k1))
        for s in range(c0 + 1, c1):
            for k1 in range(1, kk):
                a, _ = solve(r0, r1, c0, s, k1)
                b, _ = solve(r0, r1, s, c1, kk - k1)
                if a + b < best[0]:
                    best = (a + b, (1, s, k1))
        return best

    loss, _ = solve(0, n, 0, m, k)

    def extract(r0, r1, c0, c1, kk):
        _, mv = solve(r0, r1, c0, c1, kk)
        if mv is None:
            return [(r0, r1, c0, c1)]
        ax, s, k1 = mv
        if ax == 0:
            return extract(r0, s, c0, c1, k1) + extract(s, r1, c0, c1, kk - k1)
        return extract(r0, r1, c0, s, k1) + extract(r0, r1, s, c1, kk - k1)

    rects = np.asarray(extract(0, n, 0, m, k), np.int64)
    return loss, Segmentation(rects, optimal_labels(ps, rects))


def segment_1d_dp(values: np.ndarray, k: int):
    """Exact optimal k-segmentation of a 1D signal: O(n^2 k) DP.
    Returns (loss, boundaries) with boundaries of length k+1."""
    y = np.asarray(values, np.float64).ravel()
    n = y.size
    p0 = np.arange(n + 1, dtype=np.float64)
    p1 = np.concatenate([[0.0], np.cumsum(y)])
    p2 = np.concatenate([[0.0], np.cumsum(y * y)])

    def cost(i, j):  # [i, j)
        s0 = p0[j] - p0[i]
        s1 = p1[j] - p1[i]
        s2 = p2[j] - p2[i]
        return max(s2 - s1 * s1 / max(s0, 1e-300), 0.0)

    INF = float("inf")
    dp = np.full((k + 1, n + 1), INF)
    arg = np.zeros((k + 1, n + 1), np.int64)
    dp[0, 0] = 0.0
    for kk in range(1, k + 1):
        for j in range(kk, n + 1):
            best, bi = INF, kk - 1
            for i in range(kk - 1, j):
                v = dp[kk - 1, i] + cost(i, j)
                if v < best:
                    best, bi = v, i
            dp[kk, j], arg[kk, j] = best, bi
    bounds = [n]
    j = n
    for kk in range(k, 0, -1):
        j = int(arg[kk, j])
        bounds.append(j)
    return float(dp[k, n]), np.asarray(bounds[::-1], np.int64)
